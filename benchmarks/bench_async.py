"""Sync vs async rounds-to-gap under simulated stragglers.

For each straggler severity the bulk-synchronous engine pays
``max(delays)`` ticks per round (every round barriers on the slowest
worker), while the bounded-staleness engine keeps the fast workers
committing. The headline metric is *ticks to reach a target duality gap*
on the shared simulated clock.

    PYTHONPATH=src python -m benchmarks.bench_async
    PYTHONPATH=src python -m benchmarks.bench_async --devices 4 --tau 1 2 4
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def run(n_dev: int, taus, straggler: int, seed: int = 0):
    import jax

    from repro.core import DMTRLConfig, MeshAxes
    from repro.core.async_dmtrl import fit_async
    from repro.core.distributed import fit_distributed
    from repro.core import convergence as cv
    from repro.data.synthetic import synthetic

    sp = synthetic(1, m=n_dev, d=32, n_train_avg=80, n_test_avg=20, seed=2)
    delays = (1,) * (n_dev - 1) + (straggler,)
    base = dict(
        loss="hinge", lam=1e-4, outer_iters=2, rounds=8, local_iters=64,
        solver="block_gram", block_size=32, seed=seed,
    )
    mesh = jax.make_mesh((n_dev,), ("data",))
    ax = MeshAxes(data="data")

    _, _, _, h_sync = fit_distributed(DMTRLConfig(**base), sp.train, mesh, ax)
    sync_ticks = cv.sync_effective_ticks(h_sync, delays)
    target = 1.5 * float(h_sync["gap"][-1])
    rows = [
        {
            "engine": "sync",
            "tau": 0,
            "straggler": straggler,
            "final_gap": float(h_sync["gap"][-1]),
            "gap_target": target,
            "ticks_total": float(sync_ticks[-1]),
            "ticks_to_target": cv.ticks_to_gap(sync_ticks, h_sync["gap"], target),
            "max_staleness": 0,
        }
    ]
    for tau in taus:
        cfg = DMTRLConfig(**base, tau=tau, async_delays=delays)
        _, _, _, h = fit_async(cfg, sp.train, mesh, ax)
        ticks, gaps = cv.effective_gap_curve(h)
        s = cv.staleness_summary(h)
        rows.append(
            {
                "engine": "async",
                "tau": tau,
                "straggler": straggler,
                "final_gap": float(gaps[-1]),
                "gap_target": target,
                "ticks_total": float(ticks[-1]),
                "ticks_to_target": cv.ticks_to_gap(ticks, gaps, target),
                "max_staleness": s["max_staleness"],
            }
        )
    return rows, target


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--tau", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--straggler", type=int, nargs="+", default=[2, 4])
    args = ap.parse_args()

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}"
    )
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    all_rows = []
    print("engine,tau,straggler,final_gap,ticks_total,ticks_to_target,max_staleness")
    for s in args.straggler:
        rows, _ = run(args.devices, args.tau, s)
        for r in rows:
            print(
                f"{r['engine']},{r['tau']},{r['straggler']},{r['final_gap']:.5f},"
                f"{r['ticks_total']:.0f},{r['ticks_to_target']:.0f},"
                f"{r['max_staleness']}",
                flush=True,
            )
        all_rows.extend(rows)
    os.makedirs("results", exist_ok=True)
    with open("results/bench_async.json", "w") as f:
        json.dump(all_rows, f, indent=1)


if __name__ == "__main__":
    main()
