"""Fleet serving benchmark: N replicas behind the task-affinity router.

Three virtual-clock fleet simulations, all recorded to BENCH_fleet.json
(every scenario asserts its own invariants before the file is written,
and ``check()`` re-validates the full record — the CI smoke gate):

* ``kind: shed_vs_baseline`` — Zipf-skewed per-task traffic at ~1.3x the
  FLEET's service capacity, served three ways: the no-router
  single-scheduler baseline (one host drowning in backlog), the fleet
  with shedding disabled (N hosts, still overloaded), and the fleet with
  deadline-aware router shedding.  Half the traffic carries a hard
  deadline (misses expire = SLO violations), half is best-effort (the
  baseline queues it unboundedly — that is where its p99 explodes).  The
  shedding router must beat the baseline on completed-request p99 AND
  total SLO violations (asserted): rejecting at the door beats admitting
  a guaranteed violation.

* ``kind: rolling_swap`` — model publishes roll across the fleet one
  replica per router step while sequential per-client sessions keep
  submitting.  Every completion is checked against the version floor its
  client had already observed at submit time: the row records ZERO
  monotonic-read regressions (asserted) across every publish.

* ``kind: crash_restart`` — a replica's engine starts raising mid-run;
  the router fails it over (backlog re-pinned onto survivors, stamps
  intact), later restores it (model caught up to the fleet version
  first).  Every admitted request must end ``done`` or ``expired`` —
  nothing lost, all non-expired requests complete (asserted).

    PYTHONPATH=src python -m benchmarks.bench_fleet
    PYTHONPATH=src python -m benchmarks.bench_fleet --tiny
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _zipf_tasks(rng, n, tasks, a=1.2):
    """Zipf-skewed task draw: p_k proportional to 1/(k+1)^a."""
    import numpy as np

    p = 1.0 / np.arange(1, tasks + 1) ** a
    return rng.choice(tasks, size=n, p=p / p.sum())


def _make_requests(rng, n, tasks, d, zipf_a):
    from repro.serve import ScoreRequest

    tids = _zipf_tasks(rng, n, tasks, zipf_a)
    return [
        ScoreRequest(task=int(t), x=rng.randn(d).astype("float32"))
        for t in tids
    ]


class CrashableEngine:
    """Adapter wrapper whose ``run_tile`` raises while ``crashed`` is set
    — the router's failover path sees exactly what a dead host looks like
    (the scheduler re-queues the packed tile, the router drains and
    re-pins it).  Everything else delegates to the wrapped engine."""

    def __init__(self, inner):
        self.inner = inner
        self.crashed = False

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def run_tile(self, reqs, snapshot):
        if self.crashed:
            raise RuntimeError("replica host down")
        self.inner.run_tile(reqs, snapshot)


def _build_fleet(W, n_replicas, batch, clock, *, slo_s, tile_cost_s,
                 crashable=False, version=1):
    from repro.serve import FleetRouter, MTLScoringEngine
    from repro.serve.scheduler import ContinuousBatchingScheduler

    engines = []
    for _ in range(n_replicas):
        eng = MTLScoringEngine(W, batch=batch, version=version)
        engines.append(CrashableEngine(eng) if crashable else eng)
    replicas = [
        ContinuousBatchingScheduler(eng, slo_s=slo_s, clock=clock)
        for eng in engines
    ]
    router = FleetRouter(replicas, slo_s=slo_s, tile_cost_s=tile_cost_s)
    return router, engines


def run_shed_vs_baseline(
    *,
    requests: int = 4000,
    n_replicas: int = 3,
    batch: int = 8,
    tasks: int = 16,
    d: int = 32,
    tile_ms: float = 4.0,
    overload: float = 1.3,
    slo_ms: float = 20.0,
    deadline_ms: float = 30.0,
    zipf_a: float = 1.2,
    seed: int = 0,
):
    """Zipf-skewed overload: single scheduler vs fleet (shed off / on).

    One replica serves ``batch / tile_s`` requests per virtual second;
    arrivals run at ``overload`` x the FLEET capacity, so even N replicas
    cannot keep up — the only question is where the excess goes: into an
    unbounded queue (baseline, fleet-noshed) or back to the client as an
    explicit shed (fleet-shed).
    """
    import numpy as np

    from repro.serve import MTLScoringEngine, VirtualClock
    from repro.serve.scheduler import ContinuousBatchingScheduler

    tile_s = tile_ms / 1e3
    slo_s = slo_ms / 1e3
    rate = overload * n_replicas * batch / tile_s

    def traffic():
        rng = np.random.RandomState(seed)
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=requests))
        reqs = _make_requests(rng, requests, tasks, d, zipf_a)
        with_deadline = rng.rand(requests) < 0.5
        return arrivals, reqs, with_deadline

    rng_w = np.random.RandomState(seed)
    W = rng_w.randn(tasks, d).astype(np.float32)

    def drive(submit, step, pending, clock, arrivals, reqs, with_deadline):
        """Round-driven sim: deliver due arrivals, one parallel fleet step,
        advance one tile time; idle-skip to the next arrival."""
        i = 0
        while i < len(reqs) or pending():
            while i < len(reqs) and arrivals[i] <= clock():
                submit(reqs[i], deadline_ms / 1e3 if with_deadline[i] else None)
                i += 1
            if not pending():
                if i < len(reqs):
                    clock.advance_to(max(clock(), arrivals[i]))
                continue
            step()
            clock.advance(tile_s)

    results = {}

    # --- no-router single-scheduler baseline ------------------------------
    clock = VirtualClock()
    eng = MTLScoringEngine(W, batch=batch, version=1)
    sched = ContinuousBatchingScheduler(eng, slo_s=slo_s, clock=clock)
    arrivals, reqs, wd = traffic()
    drive(
        lambda r, dl: sched.submit(r, deadline_s=dl),
        sched.step, lambda: sched.pending, clock, arrivals, reqs, wd,
    )
    results["baseline"] = {"metrics": sched.metrics.summary(), "shed": 0}

    # --- fleet, shedding off / on -----------------------------------------
    for label, tile_cost in (("fleet_noshed", None), ("fleet_shed", tile_s)):
        clock = VirtualClock()
        router, _ = _build_fleet(
            W, n_replicas, batch, clock, slo_s=slo_s, tile_cost_s=tile_cost
        )
        arrivals, reqs, wd = traffic()
        drive(
            lambda r, dl: router.submit(r, deadline_s=dl),
            router.step, lambda: router.pending, clock, arrivals, reqs, wd,
        )
        results[label] = {
            "metrics": router.metrics().summary(),
            "shed": router.counters["shed"],
            "spills": router.counters["spills"],
        }

    base = results["baseline"]["metrics"]
    shed = results["fleet_shed"]["metrics"]
    assert results["fleet_shed"]["shed"] > 0, "overload never tripped the router"
    assert shed["latency"]["p99_s"] < base["latency"]["p99_s"], (
        f"router shedding did not beat the single-scheduler baseline p99: "
        f"{shed['latency']['p99_s']:.4f}s vs {base['latency']['p99_s']:.4f}s"
    )
    assert shed["slo_violations"] < base["slo_violations"], (
        f"router shedding did not cut SLO violations: "
        f"{shed['slo_violations']} vs {base['slo_violations']}"
    )
    return {
        "kind": "shed_vs_baseline",
        "requests": requests,
        "n_replicas": n_replicas,
        "batch": batch,
        "tasks": tasks,
        "d": d,
        "tile_ms": tile_ms,
        "rate_rps": rate,
        "overload": overload,
        "slo_ms": slo_ms,
        "deadline_ms": deadline_ms,
        "zipf_a": zipf_a,
        "seed": seed,
        "results": results,
        "p99_speedup": base["latency"]["p99_s"] / shed["latency"]["p99_s"],
    }


def run_rolling_swap(
    *,
    requests: int = 1200,
    n_replicas: int = 3,
    batch: int = 8,
    tasks: int = 16,
    d: int = 32,
    tile_ms: float = 4.0,
    clients: int = 24,
    publish_every: int = 7,
    seed: int = 1,
):
    """Rolling hot-swap under load with sequential per-client sessions.

    ``clients`` sessions each keep ONE outstanding request (submit after
    observing the previous completion — the regime the monotonic-read
    guarantee covers).  A publish lands every ``publish_every`` rounds and
    rolls across the fleet one replica per step; every completion is
    checked against the floor its client had observed at submit time.
    """
    import numpy as np

    from repro.serve import VirtualClock

    tile_s = tile_ms / 1e3
    rng = np.random.RandomState(seed)
    W = rng.randn(tasks, d).astype(np.float32)
    clock = VirtualClock()
    router, _ = _build_fleet(
        W, n_replicas, batch, clock, slo_s=None, tile_cost_s=None
    )
    reqs = _make_requests(rng, requests, tasks, d, 1.2)
    tokens = [router.session() for _ in range(clients)]
    owner = {}  # id(req) -> client index
    floor = {}  # id(req) -> client's min_version at submit
    idle = list(range(clients))
    i = completed = regressions = 0
    publishes = 0
    rounds = 0
    while completed + (requests - i) > 0 and (i < requests or router.pending):
        while idle and i < requests:
            c = idle.pop()
            tok = tokens[c]
            r = reqs[i]
            owner[id(r)] = c
            floor[id(r)] = tok.min_version
            out = router.submit(r, client=tok)
            assert out.admitted, out
            i += 1
        rounds += 1
        if rounds % publish_every == 0:
            W = W + rng.randn(tasks, d).astype(np.float32) * 0.01
            router.publish_weights(W)
            publishes += 1
        for r in router.step():
            completed += 1
            if r.snapshot_version < floor[id(r)]:
                regressions += 1
            idle.append(owner[id(r)])
        clock.advance(tile_s)
        if i >= requests and not router.pending and not router.in_flight:
            break
    assert regressions == 0, f"{regressions} monotonic-read regressions"
    assert completed == requests, f"completed {completed}/{requests}"
    assert publishes > 0 and router.counters["rolled_installs"] >= publishes
    return {
        "kind": "rolling_swap",
        "requests": requests,
        "n_replicas": n_replicas,
        "batch": batch,
        "clients": clients,
        "publish_every": publish_every,
        "publishes": publishes,
        "rolled_installs": router.counters["rolled_installs"],
        "final_version": router.version,
        "completed": completed,
        "version_regressions": regressions,
        "seed": seed,
        "metrics": router.metrics().summary(),
    }


def run_crash_restart(
    *,
    requests: int = 1500,
    n_replicas: int = 3,
    batch: int = 8,
    tasks: int = 16,
    d: int = 32,
    tile_ms: float = 4.0,
    deadline_ms: float = 80.0,
    crash_frac: float = 0.3,
    restore_frac: float = 0.6,
    seed: int = 2,
):
    """Replica crash + restart under load: no request is ever lost.

    Replica 1's engine starts raising once ``crash_frac`` of the traffic
    has arrived; the router fails it over (its backlog — including the
    re-queued in-flight tile — re-pins onto the survivors) and restores it
    at ``restore_frac`` (model caught up first).  Half the traffic carries
    deadlines; everything admitted must end ``done`` or ``expired``.
    """
    import numpy as np

    from repro.serve import VirtualClock

    tile_s = tile_ms / 1e3
    rate = 0.9 * n_replicas * batch / tile_s
    rng = np.random.RandomState(seed)
    W = rng.randn(tasks, d).astype(np.float32)
    clock = VirtualClock()
    router, engines = _build_fleet(
        W, n_replicas, batch, clock, slo_s=None, tile_cost_s=None,
        crashable=True,
    )
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=requests))
    reqs = _make_requests(rng, requests, tasks, d, 1.2)
    with_deadline = rng.rand(requests) < 0.5
    admitted = []
    i = 0
    crashed = restored = False
    while i < requests or router.pending:
        while i < requests and arrivals[i] <= clock():
            out = router.submit(
                reqs[i],
                deadline_s=deadline_ms / 1e3 if with_deadline[i] else None,
            )
            if out.admitted:
                admitted.append(out.request)
            i += 1
            if not crashed and i >= int(crash_frac * requests):
                engines[1].crashed = True  # next step() raises -> failover
                crashed = True
            if crashed and not restored and i >= int(restore_frac * requests):
                engines[1].crashed = False
                router.restore_replica(1)
                restored = True
        if not router.pending:
            if i < requests:
                clock.advance_to(max(clock(), arrivals[i]))
            continue
        router.step()
        clock.advance(tile_s)
    router.run_until_idle()

    lost = [r for r in admitted if r.status not in ("done", "expired")]
    expired = sum(1 for r in admitted if r.status == "expired")
    done = sum(1 for r in admitted if r.status == "done")
    assert crashed and restored
    assert router.counters["failovers"] == 1, router.counters
    assert router.replica(1).up and router.replica(1).restarts == 1
    assert not lost, f"{len(lost)} requests lost in failover"
    assert done + expired == len(admitted)
    return {
        "kind": "crash_restart",
        "requests": requests,
        "n_replicas": n_replicas,
        "batch": batch,
        "crash_frac": crash_frac,
        "restore_frac": restore_frac,
        "deadline_ms": deadline_ms,
        "admitted": len(admitted),
        "completed": done,
        "expired": expired,
        "lost": len(lost),
        "requeued": router.counters["requeued"],
        "failovers": router.counters["failovers"],
        "restarts": router.counters["restarts"],
        "seed": seed,
        "metrics": router.metrics().summary(),
    }


def check(rows) -> None:
    """Schema + invariant check of a BENCH_fleet.json record (also the CI
    smoke gate: bench_fleet --tiny runs this before writing)."""
    kinds = {r["kind"] for r in rows}
    missing = {"shed_vs_baseline", "rolling_swap", "crash_restart"} - kinds
    assert not missing, f"missing scenario rows: {sorted(missing)}"
    for r in rows:
        if r["kind"] == "shed_vs_baseline":
            for arm in ("baseline", "fleet_noshed", "fleet_shed"):
                m = r["results"][arm]["metrics"]
                assert m["completed"] > 0, f"{arm} completed nothing"
                assert "p99_s" in m["latency"]
            base = r["results"]["baseline"]["metrics"]
            shed = r["results"]["fleet_shed"]["metrics"]
            assert r["results"]["fleet_shed"]["shed"] > 0
            assert shed["latency"]["p99_s"] < base["latency"]["p99_s"]
            assert shed["slo_violations"] < base["slo_violations"]
        elif r["kind"] == "rolling_swap":
            assert r["version_regressions"] == 0
            assert r["completed"] == r["requests"]
            assert r["publishes"] > 0
        elif r["kind"] == "crash_restart":
            assert r["lost"] == 0
            assert r["completed"] + r["expired"] == r["admitted"]
            assert r["restarts"] == 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="small fast run (CI smoke): same scenarios, "
                         "fewer requests")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tasks", type=int, default=16)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--tile-ms", type=float, default=4.0)
    ap.add_argument("--overload", type=float, default=1.3)
    ap.add_argument("--zipf-a", type=float, default=1.2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--out",
        default=os.path.join(os.path.dirname(__file__), "..", "BENCH_fleet.json"),
    )
    args = ap.parse_args(argv)

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    n = args.requests or (400 if args.tiny else 4000)
    shed = run_shed_vs_baseline(
        requests=n, n_replicas=args.replicas, batch=args.batch,
        tasks=args.tasks, d=args.d, tile_ms=args.tile_ms,
        overload=args.overload, zipf_a=args.zipf_a, seed=args.seed,
    )
    base = shed["results"]["baseline"]["metrics"]
    best = shed["results"]["fleet_shed"]["metrics"]
    print(
        f"shed_vs_baseline: p99 {base['latency']['p99_s'] * 1e3:.1f}ms "
        f"(1 host) -> {best['latency']['p99_s'] * 1e3:.1f}ms "
        f"({args.replicas} hosts + shed), {shed['p99_speedup']:.1f}x; "
        f"violations {base['slo_violations']} -> {best['slo_violations']}; "
        f"shed {shed['results']['fleet_shed']['shed']}",
        flush=True,
    )
    roll = run_rolling_swap(
        requests=n // 3 if args.tiny else 1200, n_replicas=args.replicas,
        batch=args.batch, tasks=args.tasks, d=args.d,
        tile_ms=args.tile_ms, seed=args.seed + 1,
    )
    print(
        f"rolling_swap: {roll['publishes']} publishes rolled over "
        f"{args.replicas} replicas ({roll['rolled_installs']} installs, "
        f"final v{roll['final_version']}); {roll['completed']} requests, "
        f"{roll['version_regressions']} version regressions",
        flush=True,
    )
    crash = run_crash_restart(
        requests=n // 2 if args.tiny else 1500, n_replicas=args.replicas,
        batch=args.batch, tasks=args.tasks, d=args.d,
        tile_ms=args.tile_ms, seed=args.seed + 2,
    )
    print(
        f"crash_restart: {crash['requeued']} requests re-pinned on "
        f"failover; {crash['completed']} done + {crash['expired']} expired "
        f"= {crash['admitted']} admitted, {crash['lost']} lost",
        flush=True,
    )

    rows = [shed, roll, crash]
    check(rows)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
