"""Kernel micro-benchmarks: wall time of the jnp reference vs the Pallas
kernels in interpret mode. NOTE: interpret mode runs the kernel body via the
Python interpreter on CPU — numbers are for trajectory-recording and
correctness cross-checking, NOT TPU performance (see docs/DESIGN.md
§Roofline for the structural analysis).

The SDCA bench sweeps every registered solver backend
(repro.core.solver_backends) on one shared local-round problem and writes
the per-backend timings — including each backend's pallas_call launch count
per round — to BENCH_kernels.json at the repo root, so the perf trajectory
of the solver layer is recorded across PRs:

    PYTHONPATH=src python -m benchmarks.bench_kernels
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")


def _time(fn, *args, iters=3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def bench_flash() -> Dict:
    from repro.kernels.flash.ref import attention_ref

    key = jax.random.PRNGKey(0)
    B, H, S, HD = 2, 4, 512, 64
    q, k, v = (jax.random.normal(kk, (B, H, S, HD)) for kk in jax.random.split(key, 3))
    ref = jax.jit(lambda a, b, c: attention_ref(a, b, c, True, 0))
    us_ref = _time(ref, q, k, v)
    return {"name": "flash_ref_jit", "us_per_call": us_ref,
            "derived": f"B{B}H{H}S{S}D{HD}"}


def sdca_backend_rows(n=1024, d=256, H=256, block=64) -> List[Dict]:
    """One shared local-round problem, timed through EVERY registered solver
    backend. Returns one row per backend with its per-round pallas_call
    launch count (the fused-round acceptance metric: 1 vs H/B)."""
    from repro.core.losses import get_loss
    from repro.core.solver_backends import available_backends

    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (n, d))
    y = jnp.sign(jax.random.normal(ks[1], (n,)))
    alpha = jnp.zeros((n,))
    w = jnp.zeros((d,))
    n_i = jnp.int32(n)
    sigma_ii = jnp.float32(0.2)
    loss = get_loss("hinge")

    rows = []
    for name, be in available_backends().items():
        Hb = be.round_local_iters(H, block)
        solve = be.make(loss, 2.0, 1e-4, Hb, block=block)
        fn = jax.jit(
            lambda solve=solve: solve(x, y, alpha, w, n_i, sigma_ii, ks[2])
        )
        rows.append({
            "name": f"sdca_{name}",
            "backend": name,
            "us_per_call": _time(lambda fn=fn: fn()),
            "pallas_calls_per_round": be.pallas_calls_per_round(H, block),
            "derived": f"n{n}d{d}H{Hb}B{block}",
        })
    return rows


def write_bench_json(rows: List[Dict], path: str = BENCH_JSON) -> None:
    payload = {
        "bench": "sdca_solver_backends",
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "note": "interpret-mode wall times (CPU), not TPU performance",
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


def bench_sdca() -> Dict:
    """Registry sweep; emits BENCH_kernels.json and returns a headline row."""
    rows = sdca_backend_rows()
    write_bench_json(rows)
    by = {r["backend"]: r for r in rows}
    hl = by["pallas_round"]
    return {
        "name": "sdca_backends",
        "us_per_call": hl["us_per_call"],
        "derived": (
            f"{hl['derived']} pallas_calls/round: round=1 "
            f"block={by['pallas_block']['pallas_calls_per_round']} "
            f"(all backends -> BENCH_kernels.json)"
        ),
        "backends": rows,
    }


def bench_ssd() -> Dict:
    from repro.models.ssm import ssd_chunked

    key = jax.random.PRNGKey(2)
    B, L, Hh, P, N = 2, 512, 8, 32, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, L, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, Hh))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (Hh,)))
    Bm = jax.random.normal(ks[3], (B, L, Hh, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, L, Hh, N)) * 0.3
    fn = jax.jit(lambda: ssd_chunked(x, dt, A, Bm, Cm, 64))
    us = _time(lambda: fn())
    return {"name": "ssd_chunked_jit", "us_per_call": us,
            "derived": f"B{B}L{L}H{Hh}P{P}N{N}"}


ALL = {"flash": bench_flash, "sdca": bench_sdca, "ssd": bench_ssd}


if __name__ == "__main__":
    row = bench_sdca()
    print("name,us_per_call,derived")
    for r in row["backends"]:
        print(f"{r['name']},{r['us_per_call']:.0f},"
              f"calls={r['pallas_calls_per_round']} {r['derived']}")
    print(f"# wrote {os.path.normpath(BENCH_JSON)}")
