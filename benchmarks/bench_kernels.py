"""Kernel micro-benchmarks: wall time of the jnp reference vs the Pallas
kernel in interpret mode. NOTE: interpret mode runs the kernel body via the
Python interpreter on CPU — numbers are for CSV completeness and correctness
cross-checking, NOT TPU performance (see EXPERIMENTS.md §Roofline for the
structural analysis)."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def bench_flash() -> Dict:
    from repro.kernels.flash.ref import attention_ref

    key = jax.random.PRNGKey(0)
    B, H, S, HD = 2, 4, 512, 64
    q, k, v = (jax.random.normal(kk, (B, H, S, HD)) for kk in jax.random.split(key, 3))
    ref = jax.jit(lambda a, b, c: attention_ref(a, b, c, True, 0))
    us_ref = _time(ref, q, k, v)
    return {"name": "flash_ref_jit", "us_per_call": us_ref,
            "derived": f"B{B}H{H}S{S}D{HD}"}


def bench_sdca() -> Dict:
    from repro.core.losses import get_loss
    from repro.core.sdca import local_sdca_block, sample_coords

    key = jax.random.PRNGKey(1)
    n, d, H = 2048, 512, 512
    x = jax.random.normal(key, (n, d))
    y = jnp.sign(jax.random.normal(key, (n,)))
    alpha = jnp.zeros((n,))
    w = jnp.zeros((d,))
    coords = sample_coords(key, H, jnp.int32(n), n)
    loss = get_loss("hinge")
    fn = jax.jit(
        lambda: local_sdca_block(
            x, y, alpha, w, jnp.int32(n), jnp.float32(0.2), coords, 2.0, 1e-4, loss,
            block=64,
        )
    )
    us = _time(lambda: fn())
    return {"name": "sdca_block_jit", "us_per_call": us,
            "derived": f"n{n}d{d}H{H}B64"}


def bench_ssd() -> Dict:
    from repro.models.ssm import ssd_chunked

    key = jax.random.PRNGKey(2)
    B, L, Hh, P, N = 2, 512, 8, 32, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, L, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, Hh))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (Hh,)))
    Bm = jax.random.normal(ks[3], (B, L, Hh, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, L, Hh, N)) * 0.3
    fn = jax.jit(lambda: ssd_chunked(x, dt, A, Bm, Cm, 64))
    us = _time(lambda: fn())
    return {"name": "ssd_chunked_jit", "us_per_call": us,
            "derived": f"B{B}L{L}H{Hh}P{P}N{N}"}


ALL = {"flash": bench_flash, "sdca": bench_sdca, "ssd": bench_ssd}
