"""Observability-layer bench: disabled-span overhead + traced run breakdown.

Two measurements, one result dict:

1. **Disabled-instrumentation overhead** — the tentpole's "nearly free
   when off" claim, measured where it can actually be bounded: a
   controlled hot loop (one 200k-element f32 reduction per iteration,
   ~40 microseconds of single-threaded work — the scale of one transport
   commit, and far more repeatable than a BLAS matmul, whose thread-pool
   jitter swamps a sub-2% signal) run bare vs. wrapped in a disabled
   ``obs.span``.  Min-of-repeats denoises scheduler jitter; ``check()``
   enforces the ≤ 2% acceptance bound.
   The per-call cost of a disabled ``span()`` (a global flag check + a
   shared no-op context manager) is also reported in nanoseconds.

2. **Per-phase wall-clock breakdown of a reference async run** — the
   threaded transport with tracing ON: where does the wall-clock of a
   straggler fit go (gate wait vs. solve vs. commit vs. Omega-step)?
   The exported Chrome trace is validated structurally (every worker
   has nested gate/snapshot/commit spans inside its round spans, per-
   thread intervals form a proper nesting) and the driver-phase spans
   (setup / w_step / omega_step / result) must tile the ``fit_async``
   root span — ``check()`` asserts their sum lands within
   [``BREAKDOWN_SUM_LO``, ``BREAKDOWN_SUM_HI``] of the root duration.

Results land in BENCH_obs.json at the repo root.

    PYTHONPATH=src python -m benchmarks.bench_obs
    PYTHONPATH=src python -m benchmarks.bench_obs --tiny
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# thresholds of the measured claims (check() + the CI bench-smoke step)
OVERHEAD_PCT_BOUND = 2.0  # disabled-span overhead vs the bare loop
NULL_SPAN_NS_BOUND = 2000.0  # absolute per-call cost of a disabled span()
BREAKDOWN_SUM_LO = 0.80  # driver phase spans must tile the root span:
BREAKDOWN_SUM_HI = 1.05  # sum(setup+w_step+omega_step+result) / fit_async
NEST_EPS_US = 0.5  # float rounding slack for the interval-nesting check


def run_overhead(tiny: bool = False) -> dict:
    """Bare hot loop vs. the same loop under a disabled span()."""
    import numpy as np

    from repro import obs

    obs.disable()
    rng = np.random.default_rng(0)
    v = rng.standard_normal(200_000).astype(np.float32)
    iters = 100 if tiny else 150
    repeats = 12 if tiny else 24

    def loop_bare():
        t0 = time.perf_counter()
        for _ in range(iters):
            float(np.sum(v))
        return time.perf_counter() - t0

    def loop_spanned():
        t0 = time.perf_counter()
        for _ in range(iters):
            with obs.span("bench_work", worker=0):
                float(np.sum(v))
        return time.perf_counter() - t0

    loop_bare(), loop_spanned()  # warm caches before timing
    # interleave the two loops so background-load drift hits both equally;
    # min-of-many short loops is the robust estimator (a long loop cannot
    # dodge a noise burst, many short ones can)
    bares, instrs = [], []
    for _ in range(repeats):
        bares.append(loop_bare())
        instrs.append(loop_spanned())
    base = min(bares)
    instr = min(instrs)
    overhead_pct = 100.0 * (instr - base) / base

    # absolute per-call cost of the disabled path, no workload
    n = 100_000
    t0 = time.perf_counter()
    for _ in range(n):
        with obs.span("noop"):
            pass
    null_ns = (time.perf_counter() - t0) / n * 1e9
    return {
        "iters": iters,
        "repeats": repeats,
        "bare_s": base,
        "instrumented_s": instr,
        "overhead_pct": overhead_pct,
        "null_span_ns": null_ns,
    }


def run_traced(n_workers: int = 4, straggler: int = 4, tiny: bool = False,
               trace_path: str = None) -> dict:
    """Reference async run (threaded transport, one straggler) with
    tracing ON: export the Chrome trace, return the phase breakdown."""
    from repro import obs
    from repro.core import AsyncOptions, DMTRLConfig, MeshAxes
    from repro.core.async_dmtrl import fit_async
    from repro.data.synthetic import synthetic

    sp = synthetic(
        1, m=n_workers, d=16 if tiny else 32,
        n_train_avg=40 if tiny else 80, n_test_avg=10, seed=2,
    )
    cfg = AsyncOptions(
        tau=2,
        async_delays=(1,) * (n_workers - 1) + (straggler,),
        transport="threaded",
        n_workers=n_workers,
    ).merge_into(
        DMTRLConfig(
            loss="hinge", lam=1e-4,
            outer_iters=2, rounds=3 if tiny else 6,
            local_iters=32 if tiny else 64,
            solver="block_gram", block_size=32, seed=0,
            track_every=10**6,
        )
    )
    tracer = obs.enable(clear=True)
    try:
        fit_async(cfg, sp.train, None, MeshAxes(), options=None)
    finally:
        obs.disable()
    if trace_path is None:
        trace_path = os.path.join(_repo_root(), "results", "trace_obs.json")
        os.makedirs(os.path.dirname(trace_path), exist_ok=True)
    n_events = tracer.export_chrome(trace_path)
    breakdown = tracer.phase_breakdown()
    return {
        "workers": n_workers,
        "straggler": straggler,
        "trace_path": os.path.abspath(trace_path),
        "n_events": n_events,
        "dropped": tracer.dropped,
        "breakdown": breakdown,
    }


def _check_trace_file(path: str, n_workers: int) -> None:
    """Structural validity of the exported Chrome trace."""
    with open(path) as f:
        doc = json.load(f)
    events = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert events, "trace has no span events"
    for e in events:
        assert {"name", "ts", "dur", "pid", "tid"} <= set(e), e
        assert e["dur"] >= 0, e
    # per-thread intervals must form a proper nesting (what the context-
    # manager protocol guarantees when emission is uncorrupted): any two
    # spans on one thread are either disjoint or one contains the other
    by_tid: dict = {}
    for e in events:
        by_tid.setdefault(e["tid"], []).append(e)
    for tid, evs in by_tid.items():
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack = []  # end timestamps of open ancestors
        for e in evs:
            t0, t1 = e["ts"], e["ts"] + e["dur"]
            while stack and stack[-1] <= t0 + NEST_EPS_US:
                stack.pop()
            if stack:
                assert t1 <= stack[-1] + NEST_EPS_US, (
                    f"tid {tid}: span {e['name']!r} overlaps its "
                    f"predecessor without nesting ({t1} > {stack[-1]})"
                )
            stack.append(t1)
    # every worker emitted nested gate/snapshot/commit spans, and the
    # driver emitted the omega-step
    names = {e["name"] for e in events}
    assert {"fit_async", "w_step", "omega_step", "round"} <= names, names
    for phase in ("gate", "snapshot", "commit"):
        workers = {
            e.get("args", {}).get("worker")
            for e in events
            if e["name"] == phase
        }
        missing = set(range(n_workers)) - workers
        assert not missing, f"no {phase!r} span for workers {sorted(missing)}"


def check(result: dict) -> None:
    """Claim assertions (CI bench-smoke step)."""
    ov = result["overhead"]
    assert ov["overhead_pct"] <= OVERHEAD_PCT_BOUND, (
        f"disabled-tracing overhead {ov['overhead_pct']:.3f}% exceeds "
        f"{OVERHEAD_PCT_BOUND}%"
    )
    assert ov["null_span_ns"] <= NULL_SPAN_NS_BOUND, ov["null_span_ns"]
    tr = result["trace"]
    assert tr["dropped"] == 0, f"ring buffer dropped {tr['dropped']} spans"
    _check_trace_file(tr["trace_path"], tr["workers"])
    # driver-phase spans tile the root: their total must account for the
    # fit_async duration (small gaps = un-spanned driver glue only)
    bd = tr["breakdown"]
    root = bd["fit_async"]["total_s"]
    phases = sum(
        bd[k]["total_s"]
        for k in ("setup", "w_step", "omega_step", "result")
        if k in bd
    )
    ratio = phases / root
    assert BREAKDOWN_SUM_LO <= ratio <= BREAKDOWN_SUM_HI, (
        f"driver phase spans sum to {ratio:.3f} of the fit_async root "
        f"(expected [{BREAKDOWN_SUM_LO}, {BREAKDOWN_SUM_HI}])"
    )


def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--straggler", type=int, default=4)
    ap.add_argument(
        "--tiny", action="store_true",
        help="small fixture + short schedule (CI bench-smoke)",
    )
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace-out", default=None,
                    help="where to write the Chrome trace JSON")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    ov = run_overhead(tiny=args.tiny)
    print("metric,value")
    print(f"disabled_overhead_pct,{ov['overhead_pct']:.4f}")
    print(f"null_span_ns,{ov['null_span_ns']:.0f}", flush=True)

    tr = run_traced(args.workers, args.straggler, tiny=args.tiny,
                    trace_path=args.trace_out)
    print("phase,count,total_s,mean_s")
    for name, row in sorted(
        tr["breakdown"].items(), key=lambda kv: -kv[1]["total_s"]
    ):
        print(
            f"{name},{row['count']},{row['total_s']:.4f},"
            f"{row['mean_s']:.6f}",
            flush=True,
        )

    result = {"overhead": ov, "trace": tr}
    check(result)
    print("check() passed")
    out = args.out or os.path.join(_repo_root(), "BENCH_obs.json")
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {os.path.abspath(out)}")


if __name__ == "__main__":
    main()
