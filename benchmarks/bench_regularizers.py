"""Omega-regularizer family sweep through the estimator facade.

For each registered family member (core/omega_regularizers.py) this fits
the same Synthetic-1 problem through ``DMTRLEstimator`` and records the
final duality gap, test accuracy, rho trajectory, and the learned-coupling
mass — the family-level counterpart of ``bench_kernels.py``'s backend
sweep. Results land in ``BENCH_regularizers.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.bench_regularizers
    PYTHONPATH=src python -m benchmarks.bench_regularizers --tiny
"""
from __future__ import annotations

import argparse
import json
import os
import time


def run(tiny: bool, seed: int = 0):
    import numpy as np

    from repro.core import DMTRLEstimator, available_regularizers
    from repro.data.synthetic import synthetic

    if tiny:
        m, d, n_tr = 6, 24, 60
        fit_kw = dict(outer_iters=2, rounds=4, local_iters=64)
    else:
        m, d, n_tr = 16, 64, 200
        fit_kw = dict(outer_iters=3, rounds=8, local_iters=256)
    sp = synthetic(1, m=m, d=d, n_train_avg=n_tr, n_test_avg=80, seed=seed)

    # graph_laplacian needs a task graph: use the ground-truth parent groups
    # (3 groups of sign-flipped children) as a block adjacency
    A = (np.asarray(sp.corr_true) > 0.5).astype(np.float64)
    np.fill_diagonal(A, 0.0)
    member_params = {"graph_laplacian": {"adjacency": A}}

    rows = []
    for name in sorted(available_regularizers()):
        est = DMTRLEstimator(
            engine="reference", loss="hinge", lam=1e-4, block_size=32,
            seed=seed, regularizer=name,
            regularizer_params=member_params.get(name), **fit_kw,
        )
        t0 = time.perf_counter()
        est.fit(sp.train)
        wall = time.perf_counter() - t0
        s = np.asarray(est.sigma_)
        rows.append(
            dict(
                regularizer=name,
                gap_first=float(est.history["gap"][0]),
                gap_last=float(est.history["gap"][-1]),
                test_accuracy=float(est.score(sp.test)),
                rho_per_outer=[round(float(r), 4) for r in est.rho_per_outer_],
                offdiag_mass=float(np.abs(s - np.diag(np.diag(s))).sum()),
                sigma_min_eig=float(np.linalg.eigvalsh(s).min()),
                wall_s=round(wall, 3),
            )
        )
        print(
            f"{name:18s} gap {rows[-1]['gap_first']:.3f} -> "
            f"{rows[-1]['gap_last']:.4f}  acc {rows[-1]['test_accuracy']:.3f}  "
            f"offdiag {rows[-1]['offdiag_mass']:.3f}"
        )
    return dict(m=m, d=d, n_train_avg=n_tr, seed=seed, tiny=tiny, rows=rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run(args.tiny)
    out = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_regularizers.json",
    )
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
