"""Roofline table generator: aggregates results/dryrun/*.json into the
docs/DESIGN.md §Roofline tables."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_rows(out_dir: str = "results/dryrun") -> List[Dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def fmt_seconds(x) -> str:
    if x is None:
        return "-"
    x = float(x)
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table(rows: List[Dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL_FLOPS/HLO | HBM bytes/dev | coll bytes/dev | status |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | - | "
                f"skipped ({r['reason'][:40]}...) |"
            )
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | - | "
                f"ERROR |"
            )
            continue
        lines.append(
            "| {arch} | {shape} | {c} | {m} | {k} | **{dom}** | {ratio:.3f} | "
            "{hbm:.1f} GB | {coll:.2f} GB | ok |".format(
                arch=r["arch"],
                shape=r["shape"],
                c=fmt_seconds(r["compute_s"]),
                m=fmt_seconds(r["memory_s"]),
                k=fmt_seconds(r["collective_s"]),
                dom=r["dominant"],
                ratio=r["useful_flops_ratio"],
                hbm=r["bytes_per_device"] / 1e9,
                coll=r["collective_bytes_per_device"] / 1e9,
            )
        )
    return "\n".join(lines)


def summary(rows: List[Dict]) -> Dict:
    n_ok = sum(1 for r in rows if r["status"] == "ok")
    n_skip = sum(1 for r in rows if r["status"] == "skipped")
    n_err = sum(1 for r in rows if r["status"] not in ("ok", "skipped"))
    return {"ok": n_ok, "skipped": n_skip, "errors": n_err, "total": len(rows)}


def main(out_dir: str = "results/dryrun"):
    rows = load_rows(out_dir)
    print("dry-run grid:", summary(rows))
    for mesh in ("single", "multi"):
        print(f"\n== mesh: {mesh} ==")
        print(roofline_table(rows, mesh))


if __name__ == "__main__":
    main()
