"""Serving load benchmark: queued arrivals -> continuous-batching scheduler.

Generates a Poisson arrival stream of mixed-task scoring requests and
drives it through the ``ContinuousBatchingScheduler`` on a VIRTUAL clock
whose per-tile service time is the MEASURED wall-clock of the real jitted
scoring tile (so latency numbers reflect actual compute), with every
``--straggler-every``-th tile slowed by ``--straggler-mult`` to model a
straggler batch. Halfway through the stream the model is hot-swapped to a
new ``(W, version)`` snapshot, exercising the no-drain switch under load.

Per policy (EDF and FIFO) the bench records p50/p95/p99 latency,
throughput, queue depth, tile fill, per-task counters and SLO-violation
counts (``ServingMetrics.summary()``) to BENCH_serving.json.

    PYTHONPATH=src python -m benchmarks.bench_serving
    PYTHONPATH=src python benchmarks/bench_serving.py --requests 2000 --rate 500
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


class MeasuredEngine:
    """Adapter wrapper: advances the virtual clock by each tile's measured
    wall-clock service time (x straggler multiplier on straggler tiles).
    Everything but ``run_tile`` delegates to the wrapped engine."""

    def __init__(self, inner, clock, straggler_every: int, straggler_mult: float):
        self.inner, self.clock = inner, clock
        self.straggler_every = straggler_every
        self.straggler_mult = straggler_mult
        self.tiles = 0

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def run_tile(self, reqs, snapshot):
        t0 = time.perf_counter()
        self.inner.run_tile(reqs, snapshot)
        dt = time.perf_counter() - t0
        self.tiles += 1
        if self.straggler_every and self.tiles % self.straggler_every == 0:
            dt *= self.straggler_mult
        self.clock.advance(dt)


def run_load(
    *,
    requests: int = 2000,
    batch: int = 32,
    tasks: int = 16,
    d: int = 64,
    rate: float = 1000.0,
    slo_ms: float = 20.0,
    deadline_ms: float = 200.0,
    straggler_every: int = 10,
    straggler_mult: float = 8.0,
    policy: str = "edf",
    seed: int = 0,
):
    import numpy as np

    from repro.serve import (
        ContinuousBatchingScheduler,
        ModelSnapshot,
        MTLScoringEngine,
        ScoreRequest,
        VirtualClock,
    )

    rng = np.random.RandomState(seed)
    W1 = rng.randn(tasks, d).astype(np.float32)
    W2 = rng.randn(tasks, d).astype(np.float32)
    clock = VirtualClock()
    inner = MTLScoringEngine(W1, batch=batch, version=1)
    inner.score_batch(np.zeros((batch, d), np.float32), 0)  # compile warmup
    engine = MeasuredEngine(inner, clock, straggler_every, straggler_mult)
    sched = ContinuousBatchingScheduler(
        engine, slo_s=slo_ms / 1e3, policy=policy, clock=clock
    )

    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=requests))
    reqs = [
        ScoreRequest(
            task=int(rng.randint(tasks)), x=rng.randn(d).astype(np.float32)
        )
        for _ in range(requests)
    ]
    # half the traffic carries a hard deadline, half is best-effort
    with_deadline = rng.rand(requests) < 0.5

    i = 0
    swapped = False
    served_versions: dict = {}
    while i < requests or sched.pending:
        while i < requests and arrivals[i] <= clock():
            sched.submit(
                reqs[i],
                deadline_s=deadline_ms / 1e3 if with_deadline[i] else None,
            )
            i += 1
            if not swapped and i >= requests // 2:
                sched.publish(ModelSnapshot(version=2, W=W2))
                swapped = True
        if not sched.pending:
            if i < requests:
                clock.advance_to(arrivals[i])
            continue
        for r in sched.step():
            served_versions[r.snapshot_version] = (
                served_versions.get(r.snapshot_version, 0) + 1
            )

    return {
        "requests": requests,
        "batch": batch,
        "tasks": tasks,
        "d": d,
        "rate_rps": rate,
        "policy": policy,
        "slo_ms": slo_ms,
        "deadline_ms": deadline_ms,
        "straggler_every": straggler_every,
        "straggler_mult": straggler_mult,
        "seed": seed,
        "served_per_version": {str(k): v for k, v in sorted(served_versions.items())},
        "metrics": sched.metrics.summary(),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--tasks", type=int, default=16)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--rate", type=float, default=1000.0,
                    help="mean arrival rate (requests per virtual second)")
    ap.add_argument("--slo-ms", type=float, default=20.0)
    ap.add_argument("--deadline-ms", type=float, default=200.0)
    ap.add_argument("--straggler-every", type=int, default=10,
                    help="every k-th tile is a straggler (0 disables)")
    ap.add_argument("--straggler-mult", type=float, default=8.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policies", nargs="+", default=["edf", "fifo"])
    ap.add_argument(
        "--out",
        default=os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json"),
    )
    args = ap.parse_args(argv)

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    rows = []
    print("policy,completed,expired,p50_ms,p95_ms,p99_ms,throughput_rps,"
          "slo_violations,queue_max,tile_fill")
    for policy in args.policies:
        row = run_load(
            requests=args.requests, batch=args.batch, tasks=args.tasks,
            d=args.d, rate=args.rate, slo_ms=args.slo_ms,
            deadline_ms=args.deadline_ms,
            straggler_every=args.straggler_every,
            straggler_mult=args.straggler_mult,
            policy=policy, seed=args.seed,
        )
        rows.append(row)
        s = row["metrics"]
        lat = s["latency"]
        print(
            f"{policy},{s['completed']},{s['expired']},"
            f"{lat['p50_s'] * 1e3:.2f},{lat['p95_s'] * 1e3:.2f},"
            f"{lat['p99_s'] * 1e3:.2f},{s['throughput_rps']:.1f},"
            f"{s['slo_violations']},{s['queue_depth_max']},"
            f"{s['tile_fill']:.3f}",
            flush=True,
        )
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
