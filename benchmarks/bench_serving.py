"""Serving load benchmark: queued arrivals -> continuous-batching scheduler.

Three experiments, all recorded to BENCH_serving.json:

* ``kind: load`` (one row per policy) — a Poisson arrival stream of
  mixed-task scoring requests through the ``ContinuousBatchingScheduler``
  on a VIRTUAL clock whose per-tile service time is the MEASURED
  wall-clock of the real jitted scoring tile, with every
  ``--straggler-every``-th tile slowed by ``--straggler-mult``. Halfway
  through the model is hot-swapped, exercising the no-drain switch.

* ``kind: lm_interleave`` — the head-of-line-blocking experiment: a few
  LONG generations mixed with many SHORT ones through a real (reduced)
  LM, once behind a whole-generation-tile facade (the pre-slot-table
  engine shape, where a tile completes when its longest generation does)
  and once through per-slot decode-step batching. The row records short-
  request p50/p99 vs the longest generation for both modes; per-slot
  batching must cut short-request p99 decisively (asserted).

* ``kind: warm_vs_cold`` — first-request wall time on cold engines
  (executables compiled lazily on the first request) vs engines warmed
  with the AOT ``warmup()`` pass, for the LM decode bucket AND the MTL
  scorer tile. The bench ASSERTS the warm-start worst case carries no
  retrace spike before writing the file.

    PYTHONPATH=src python -m benchmarks.bench_serving
    PYTHONPATH=src python benchmarks/bench_serving.py --requests 2000 --rate 500
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


class MeasuredEngine:
    """Adapter wrapper: advances the virtual clock by each tile's measured
    wall-clock service time (x straggler multiplier on straggler tiles).
    Everything but ``run_tile`` delegates to the wrapped engine."""

    def __init__(self, inner, clock, straggler_every: int, straggler_mult: float):
        self.inner, self.clock = inner, clock
        self.straggler_every = straggler_every
        self.straggler_mult = straggler_mult
        self.tiles = 0

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def run_tile(self, reqs, snapshot):
        t0 = time.perf_counter()
        self.inner.run_tile(reqs, snapshot)
        dt = time.perf_counter() - t0
        self.tiles += 1
        if self.straggler_every and self.tiles % self.straggler_every == 0:
            dt *= self.straggler_mult
        self.clock.advance(dt)


def run_load(
    *,
    requests: int = 2000,
    batch: int = 32,
    tasks: int = 16,
    d: int = 64,
    rate: float = 1000.0,
    slo_ms: float = 20.0,
    deadline_ms: float = 200.0,
    straggler_every: int = 10,
    straggler_mult: float = 8.0,
    policy: str = "edf",
    seed: int = 0,
):
    import numpy as np

    from repro.serve import (
        ContinuousBatchingScheduler,
        ModelSnapshot,
        MTLScoringEngine,
        ScoreRequest,
        VirtualClock,
    )

    rng = np.random.RandomState(seed)
    W1 = rng.randn(tasks, d).astype(np.float32)
    W2 = rng.randn(tasks, d).astype(np.float32)
    clock = VirtualClock()
    inner = MTLScoringEngine(W1, batch=batch, version=1)
    inner.score_batch(np.zeros((batch, d), np.float32), 0)  # compile warmup
    engine = MeasuredEngine(inner, clock, straggler_every, straggler_mult)
    sched = ContinuousBatchingScheduler(
        engine, slo_s=slo_ms / 1e3, policy=policy, clock=clock
    )

    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=requests))
    reqs = [
        ScoreRequest(
            task=int(rng.randint(tasks)), x=rng.randn(d).astype(np.float32)
        )
        for _ in range(requests)
    ]
    # half the traffic carries a hard deadline, half is best-effort
    with_deadline = rng.rand(requests) < 0.5

    i = 0
    swapped = False
    served_versions: dict = {}
    while i < requests or sched.pending:
        while i < requests and arrivals[i] <= clock():
            sched.submit(
                reqs[i],
                deadline_s=deadline_ms / 1e3 if with_deadline[i] else None,
            )
            i += 1
            if not swapped and i >= requests // 2:
                sched.publish(ModelSnapshot(version=2, W=W2))
                swapped = True
        if not sched.pending:
            if i < requests:
                clock.advance_to(arrivals[i])
            continue
        for r in sched.step():
            served_versions[r.snapshot_version] = (
                served_versions.get(r.snapshot_version, 0) + 1
            )

    return {
        "kind": "load",
        "requests": requests,
        "batch": batch,
        "tasks": tasks,
        "d": d,
        "rate_rps": rate,
        "policy": policy,
        "slo_ms": slo_ms,
        "deadline_ms": deadline_ms,
        "straggler_every": straggler_every,
        "straggler_mult": straggler_mult,
        "seed": seed,
        "served_per_version": {str(k): v for k, v in sorted(served_versions.items())},
        "metrics": sched.metrics.summary(),
    }


class _BlockingFacade:
    """The pre-slot-table adapter surface: ONLY whole-generation tiles.
    Hides the streaming API so the scheduler packs full generations — a
    tile's short requests then wait for its longest one (the head-of-line
    defect this bench quantifies)."""

    def __init__(self, inner):
        self.inner = inner

    @property
    def batch(self):
        return self.inner.batch

    def admit(self, r):
        self.inner.admit(r)

    def model_snapshot(self):
        return self.inner.model_snapshot()

    def run_tile(self, reqs, snapshot):
        self.inner.run_tile(reqs, snapshot)


class MeasuredStreamingEngine:
    """Streaming analogue of ``MeasuredEngine``: advances the virtual
    clock by the measured wall time of each inject (prefill + first
    token) and each decode step."""

    def __init__(self, inner, clock):
        self.inner, self.clock = inner, clock

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def inject(self, reqs, snapshot):
        t0 = time.perf_counter()
        self.inner.inject(reqs, snapshot)
        self.clock.advance(time.perf_counter() - t0)

    def decode_tick(self):
        t0 = time.perf_counter()
        out = self.inner.decode_tick()
        self.clock.advance(time.perf_counter() - t0)
        return out


def _pctl(sorted_vals, q):
    return sorted_vals[min(len(sorted_vals) - 1, int(q * len(sorted_vals)))]


def run_lm_interleave(
    *,
    arch: str = "qwen1_5-4b",
    batch: int = 4,
    longs: int = 2,
    long_tokens: int = 32,
    shorts: int = 12,
    short_tokens: int = 2,
    seed: int = 0,
):
    """Short generations interleaved with long ones, whole-generation
    tiles vs per-slot decode-step batching (same model, same requests,
    virtual time = measured compute)."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve import (
        ContinuousBatchingScheduler,
        Request,
        ServeConfig,
        ServingEngine,
        VirtualClock,
    )

    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.RandomState(seed)
    prompts = [
        rng.randint(2, cfg.vocab_size, size=rng.randint(2, 7)).astype(np.int32)
        for _ in range(longs + shorts)
    ]

    modes = {}
    for mode in ("blocking", "streaming"):
        clock = VirtualClock()
        eng = ServingEngine(
            cfg, params, ServeConfig(batch=batch, max_len=128, bucket_min=8)
        )
        eng.warmup([8])  # both modes equally warm: measure decode, not compile
        if mode == "blocking":
            engine = MeasuredEngine(_BlockingFacade(eng), clock, 0, 1.0)
        else:
            engine = MeasuredStreamingEngine(eng, clock)
        sched = ContinuousBatchingScheduler(engine, policy="fifo", clock=clock)
        # longs first: they grab slots, shorts must ride alongside
        reqs = [
            Request(prompt=p.copy(), max_new_tokens=long_tokens)
            for p in prompts[:longs]
        ] + [
            Request(prompt=p.copy(), max_new_tokens=short_tokens)
            for p in prompts[longs:]
        ]
        sched.submit_many(reqs)
        sched.run_until_idle()
        assert all(r.status == "done" for r in reqs)
        short_lat = sorted(
            r.latency_s for r in reqs if r.max_new_tokens == short_tokens
        )
        modes[mode] = {
            "short_p50_s": _pctl(short_lat, 0.50),
            "short_p99_s": _pctl(short_lat, 0.99),
            "long_max_s": max(
                r.latency_s for r in reqs if r.max_new_tokens == long_tokens
            ),
            "decode_steps": sched.metrics.decode_steps,
            "slot_occupancy": sched.metrics.slot_occupancy(),
            "ttft_p99_s": sched.metrics.ttft.percentile(99.0),
        }

    blocked, streamed = modes["blocking"], modes["streaming"]
    # the head-of-line fix, quantified: under whole-generation tiles a
    # short request's p99 tracks the longest in-flight generation; under
    # per-slot batching it tracks its own length
    assert streamed["short_p99_s"] < 0.5 * blocked["short_p99_s"], (
        f"per-slot batching did not cut short-request p99: "
        f"{streamed['short_p99_s']:.4f}s vs {blocked['short_p99_s']:.4f}s"
    )
    return {
        "kind": "lm_interleave",
        "arch": arch,
        "batch": batch,
        "longs": longs,
        "long_tokens": long_tokens,
        "shorts": shorts,
        "short_tokens": short_tokens,
        "seed": seed,
        "blocking": blocked,
        "streaming": streamed,
        "short_p99_speedup": blocked["short_p99_s"] / streamed["short_p99_s"],
    }


def run_warm_vs_cold(*, arch: str = "qwen1_5-4b", repeats: int = 4, seed: int = 0):
    """First-request wall time: cold engines (lazy compile on request 1)
    vs AOT-warmed engines, for the LM decode bucket and the MTL scorer
    tile. Asserts the warm worst case beats the cold first request."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve import MTLScoringEngine, Request, ServeConfig, ServingEngine

    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(seed))

    def lm_times(warm):
        eng = ServingEngine(
            cfg, params, ServeConfig(batch=2, max_len=64, bucket_min=8)
        )
        if warm:
            eng.warmup([8])
        times = []
        for k in range(repeats):
            r = Request(
                prompt=np.asarray([3 + k, 5, 7], np.int32), max_new_tokens=4
            )
            t0 = time.perf_counter()
            eng.run([r])
            times.append(time.perf_counter() - t0)
        return times

    def mtl_times(warm):
        rng = np.random.RandomState(seed)
        W = rng.randn(8, 32).astype(np.float32)
        eng = MTLScoringEngine(W, batch=16)
        if warm:
            eng.warmup()
        times = []
        for _ in range(repeats):
            X = rng.randn(16, 32).astype(np.float32)
            t0 = time.perf_counter()
            eng.score_batch(X, np.zeros(16, np.int32))
            times.append(time.perf_counter() - t0)
        return times

    lm_cold, lm_warm = lm_times(False), lm_times(True)
    mtl_cold, mtl_warm = mtl_times(False), mtl_times(True)
    # warm-start p99 must carry NO retrace spike: the SLOWEST warm request
    # (first included) stays below the cold first request, which pays the
    # trace+compile
    assert max(lm_warm) < lm_cold[0], (
        f"LM warm worst case {max(lm_warm):.4f}s >= cold first "
        f"{lm_cold[0]:.4f}s: warmup did not remove the retrace spike"
    )
    assert max(mtl_warm) < mtl_cold[0], (
        f"MTL warm worst case {max(mtl_warm):.4f}s >= cold first "
        f"{mtl_cold[0]:.4f}s: warmup did not remove the retrace spike"
    )
    return {
        "kind": "warm_vs_cold",
        "arch": arch,
        "repeats": repeats,
        "seed": seed,
        "lm": {
            "cold_first_s": lm_cold[0],
            "warm_first_s": lm_warm[0],
            "warm_max_s": max(lm_warm),
            "steady_s": min(lm_cold + lm_warm),
            "first_request_speedup": lm_cold[0] / lm_warm[0],
        },
        "mtl": {
            "cold_first_s": mtl_cold[0],
            "warm_first_s": mtl_warm[0],
            "warm_max_s": max(mtl_warm),
            "steady_s": min(mtl_cold + mtl_warm),
            "first_request_speedup": mtl_cold[0] / mtl_warm[0],
        },
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--tasks", type=int, default=16)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--rate", type=float, default=1000.0,
                    help="mean arrival rate (requests per virtual second)")
    ap.add_argument("--slo-ms", type=float, default=20.0)
    ap.add_argument("--deadline-ms", type=float, default=200.0)
    ap.add_argument("--straggler-every", type=int, default=10,
                    help="every k-th tile is a straggler (0 disables)")
    ap.add_argument("--straggler-mult", type=float, default=8.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policies", nargs="+", default=["edf", "fifo"])
    ap.add_argument("--skip-lm", action="store_true",
                    help="skip the LM interleaving + warm-vs-cold rows "
                         "(MTL load rows only)")
    ap.add_argument("--lm-batch", type=int, default=4)
    ap.add_argument("--lm-long-tokens", type=int, default=32)
    ap.add_argument("--lm-shorts", type=int, default=12)
    ap.add_argument(
        "--out",
        default=os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json"),
    )
    args = ap.parse_args(argv)

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    rows = []
    print("policy,completed,expired,p50_ms,p95_ms,p99_ms,throughput_rps,"
          "slo_violations,queue_max,tile_fill")
    for policy in args.policies:
        row = run_load(
            requests=args.requests, batch=args.batch, tasks=args.tasks,
            d=args.d, rate=args.rate, slo_ms=args.slo_ms,
            deadline_ms=args.deadline_ms,
            straggler_every=args.straggler_every,
            straggler_mult=args.straggler_mult,
            policy=policy, seed=args.seed,
        )
        rows.append(row)
        s = row["metrics"]
        lat = s["latency"]
        print(
            f"{policy},{s['completed']},{s['expired']},"
            f"{lat['p50_s'] * 1e3:.2f},{lat['p95_s'] * 1e3:.2f},"
            f"{lat['p99_s'] * 1e3:.2f},{s['throughput_rps']:.1f},"
            f"{s['slo_violations']},{s['queue_depth_max']},"
            f"{s['tile_fill']:.3f}",
            flush=True,
        )
    if not args.skip_lm:
        inter = run_lm_interleave(
            batch=args.lm_batch, long_tokens=args.lm_long_tokens,
            shorts=args.lm_shorts, seed=args.seed,
        )
        rows.append(inter)
        print(
            "lm_interleave: short p99 "
            f"{inter['blocking']['short_p99_s'] * 1e3:.1f}ms (whole-gen tiles)"
            f" -> {inter['streaming']['short_p99_s'] * 1e3:.1f}ms (per-slot),"
            f" {inter['short_p99_speedup']:.1f}x; long max "
            f"{inter['streaming']['long_max_s'] * 1e3:.1f}ms",
            flush=True,
        )
        wc = run_warm_vs_cold(seed=args.seed)
        rows.append(wc)
        print(
            "warm_vs_cold: LM first request "
            f"{wc['lm']['cold_first_s'] * 1e3:.1f}ms cold -> "
            f"{wc['lm']['warm_first_s'] * 1e3:.1f}ms warm; MTL "
            f"{wc['mtl']['cold_first_s'] * 1e3:.1f}ms -> "
            f"{wc['mtl']['warm_first_s'] * 1e3:.1f}ms",
            flush=True,
        )
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
