"""Structured-Sigma scaling sweep: m x {dense, low_rank_diag, graphical_lasso}.

Measures, per (m, member) cell, the four costs the structured-Sigma PR
claims to shrink (no training loop — the Omega-step, wire and serve-gather
costs are benched directly on a synthetic W so m = 32768 stays tractable):

  * ``omega_step_wall_s``     one Omega-step (jitted dense eigh vs jitted
                              rank-r subspace iteration vs host-side
                              blockwise soft-thresholding)
  * ``peak_sigma_bytes``      resident Sigma representation
                              (``SigmaView.nbytes()`` vs 4 m^2)
  * ``commit_payload_bytes``  one worker's snapshot + commit wire bytes
                              under the host parameter-server protocol
                              (``transport.payload_nbytes``)
  * ``serve_gather_s``        one 32-row serve-tile Sigma-row gather
                              (``MTLScoringEngine.sigma_rows_for``)

Cells that would materialize a dense (m, m) beyond the materialization
limit are skipped with an explicit reason and analytic byte counts — a
skip is recorded, never silent. Results land in ``BENCH_sigma.json``.

    PYTHONPATH=src python -m benchmarks.bench_sigma
    PYTHONPATH=src python -m benchmarks.bench_sigma --tiny
"""
from __future__ import annotations

import argparse
import json
import os
import time

# the same dense-materialization ceiling core/sigma_view.py enforces
DENSE_LIMIT = 4096
# graphical_lasso's Omega-step is host-side O(m^2): cap the sweep there too
GL_LIMIT = 4096
WORKERS = 8
D = 32
N_MAX = 16
RANK = 32
TILE = 32


def _best_of(fn, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _payload_bytes(m, m_loc, sigma_entry_floats):
    """Snapshot + commit wire bytes for one worker round (float32)."""
    snapshot = m_loc * D + sigma_entry_floats + m_loc * N_MAX
    commit = m_loc * N_MAX + m_loc * D  # dalpha_rows + db_rows
    return 4 * (snapshot + commit)


def run(tiny: bool, seed: int = 0):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.omega import omega_step, omega_step_lowrank
    from repro.core.omega_regularizers import get_regularizer
    from repro.core.sigma_view import LowRankDiagSigma
    from repro.core.transport import Snapshot, payload_nbytes
    from repro.serve.mtl import MTLScoringEngine

    ms = [16, 64] if tiny else [64, 512, 4096, 32768]
    members = ["dense", "low_rank_diag", "graphical_lasso"]
    rng = np.random.RandomState(seed)

    dense_step = jax.jit(omega_step)
    rows = []
    for m in ms:
        W = jnp.asarray(rng.randn(m, D).astype(np.float32) / np.sqrt(D))
        m_loc = max(m // WORKERS, 1)
        W_rows = np.zeros((m_loc, D), np.float32)
        alpha_rows = np.zeros((m_loc, N_MAX), np.float32)
        tasks = rng.randint(0, m, size=TILE)
        for member in members:
            row = dict(
                m=m, member=member, omega_step_wall_s=None,
                peak_sigma_bytes=None, commit_payload_bytes=None,
                serve_gather_s=None, skipped=None,
            )
            if member == "dense":
                row["peak_sigma_bytes"] = 4 * m * m
                row["commit_payload_bytes"] = payload_nbytes(
                    Snapshot(
                        W_rows=W_rows,
                        sigma_rows=np.zeros((m_loc, m), np.float32),
                        alpha_rows=alpha_rows, version=0,
                    )
                ) + 4 * (m_loc * N_MAX + m_loc * D)
                if m > DENSE_LIMIT:
                    row["skipped"] = (
                        f"dense eigh/gather skipped at m={m} > {DENSE_LIMIT} "
                        "(4 m^2 bytes recorded analytically)"
                    )
                else:
                    sig, _ = dense_step(W, 1e-6)
                    jax.block_until_ready(sig)
                    row["omega_step_wall_s"] = _best_of(
                        lambda: jax.block_until_ready(dense_step(W, 1e-6)[0])
                    )
                    eng = MTLScoringEngine(
                        np.asarray(W), batch=TILE, sigma=np.asarray(sig)
                    )
                    eng.sigma_rows_for(tasks)
                    row["serve_gather_s"] = _best_of(
                        lambda: eng.sigma_rows_for(tasks)
                    )
            elif member == "low_rank_diag":
                r = min(RANK, m)
                lr_step = jax.jit(
                    omega_step_lowrank, static_argnums=(1, 2)
                )
                U, s, d = lr_step(W, r, 8, 1e-6)
                jax.block_until_ready(d)
                row["omega_step_wall_s"] = _best_of(
                    lambda: jax.block_until_ready(lr_step(W, r, 8, 1e-6)[2])
                )
                view = LowRankDiagSigma(U=U, core=jnp.diag(s), d=d)
                row["peak_sigma_bytes"] = view.nbytes()
                row["commit_payload_bytes"] = payload_nbytes(
                    Snapshot(
                        W_rows=W_rows, sigma_rows=None,
                        alpha_rows=alpha_rows, version=0,
                        sigma_diag=np.zeros((m_loc,), np.float32),
                    )
                ) + 4 * (m_loc * N_MAX + m_loc * D)
                eng = MTLScoringEngine(np.asarray(W), batch=TILE, sigma=view)
                eng.sigma_rows_for(tasks)
                row["serve_gather_s"] = _best_of(
                    lambda: eng.sigma_rows_for(tasks)
                )
            else:  # graphical_lasso
                if m > GL_LIMIT:
                    row["skipped"] = (
                        f"graphical_lasso host step skipped at m={m} > "
                        f"{GL_LIMIT} (O(m^2) host Gram)"
                    )
                else:
                    reg = get_regularizer("graphical_lasso", penalty=0.5)
                    view, _ = reg.step(W, 1e-6)
                    row["omega_step_wall_s"] = _best_of(
                        lambda: reg.step(W, 1e-6), reps=1 if m >= 4096 else 3
                    )
                    row["peak_sigma_bytes"] = view.nbytes()
                    row["commit_payload_bytes"] = payload_nbytes(
                        Snapshot(
                            W_rows=W_rows, sigma_rows=None,
                            alpha_rows=alpha_rows, version=0,
                            sigma_diag=np.zeros((m_loc,), np.float32),
                        )
                    ) + 4 * (m_loc * N_MAX + m_loc * D)
                    eng = MTLScoringEngine(
                        np.asarray(W), batch=TILE, sigma=view
                    )
                    eng.sigma_rows_for(tasks)
                    row["serve_gather_s"] = _best_of(
                        lambda: eng.sigma_rows_for(tasks)
                    )
            rows.append(row)
            wall = row["omega_step_wall_s"]
            print(
                f"m={m:6d} {member:16s} "
                f"omega {wall * 1e3:9.2f} ms  " if wall is not None
                else f"m={m:6d} {member:16s} omega      --     ",
                end="",
            )
            print(
                f"sigma {row['peak_sigma_bytes'] or 0:>12d} B  "
                f"payload {row['commit_payload_bytes'] or 0:>10d} B"
                + (f"  [{row['skipped']}]" if row["skipped"] else "")
            )
    return dict(
        tiny=tiny, seed=seed, d=D, workers=WORKERS, rank=RANK,
        n_max=N_MAX, tile=TILE, ms=ms, rows=rows,
    )


def check(res: dict) -> None:
    """Schema + claim assertions (shared by the CI bench-smoke step)."""
    keys = {
        "m", "member", "omega_step_wall_s", "peak_sigma_bytes",
        "commit_payload_bytes", "serve_gather_s", "skipped",
    }
    assert res["rows"], "empty sweep"
    for row in res["rows"]:
        assert keys <= set(row), f"missing keys in {row}"
    by = {(r["m"], r["member"]): r for r in res["rows"]}
    for m in res["ms"]:
        dense = by[(m, "dense")]
        lr = by[(m, "low_rank_diag")]
        # the diag-not-rows wire win holds at every m; the factor-storage
        # win only once m clears the rank (at m ~ r dense is smaller)
        assert lr["commit_payload_bytes"] < dense["commit_payload_bytes"], m
        if m >= 512:
            assert lr["peak_sigma_bytes"] < dense["peak_sigma_bytes"], m
        if m >= 4096:  # the PR's 10x acceptance bar at scale
            assert lr["peak_sigma_bytes"] * 10 <= dense["peak_sigma_bytes"]
            assert (
                lr["commit_payload_bytes"] * 10 <= dense["commit_payload_bytes"]
            )
        if (
            m >= 512
            and dense["omega_step_wall_s"] is not None
            and lr["omega_step_wall_s"] is not None
        ):
            assert lr["omega_step_wall_s"] <= dense["omega_step_wall_s"], m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run(args.tiny)
    check(res)
    out = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_sigma.json",
    )
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
