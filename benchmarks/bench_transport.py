"""Transport x tau sweep on the straggler workload.

For every ``core.transport`` member and staleness bound the bench runs the
same heterogeneous-worker fit (one straggler, ``--straggler``x slower) and
records the protocol-level health metrics the transports account through
the shared CommitReceipt path:

  * commits/sec  — server commit-event throughput (wall clock; for the
    ``simulated`` member this is simulation throughput, for the host
    members real parameter-server throughput),
  * mean/max staleness — commits between a contribution's snapshot and its
    apply (``convergence.staleness_summary``),
  * gate refusals — SSP admission-refusal episodes (cumulative counter in
    ``history["gate_refusals"]``).

Results land in BENCH_transport.json at the repo root.

    PYTHONPATH=src python -m benchmarks.bench_transport
    PYTHONPATH=src python -m benchmarks.bench_transport --workers 4 --tau 0 1 2
    PYTHONPATH=src python -m benchmarks.bench_transport --no-multiprocess
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def run_one(transport: str, tau, n_workers: int, straggler: int, seed: int = 0):
    import jax

    from repro.core import AsyncOptions, DMTRLConfig, MeshAxes
    from repro.core import convergence as cv
    from repro.core.async_dmtrl import fit_async
    from repro.data.synthetic import synthetic

    sp = synthetic(1, m=n_workers, d=32, n_train_avg=80, n_test_avg=20, seed=2)
    delays = (1,) * (n_workers - 1) + (straggler,)
    cfg = DMTRLConfig(
        loss="hinge", lam=1e-4, outer_iters=2, rounds=8, local_iters=64,
        solver="block_gram", block_size=32, seed=seed,
        track_every=10**6,  # one objective sample at the end of each W-step
    )
    opts = AsyncOptions(
        tau=tau,
        async_delays=delays,
        transport=transport,
        n_workers=None if transport == "simulated" else n_workers,
    )
    mesh = (
        jax.make_mesh((n_workers,), ("data",))
        if transport == "simulated"
        else None
    )
    t0 = time.perf_counter()
    _, _, _, hist = fit_async(cfg, sp.train, mesh, MeshAxes(data="data"), options=opts)
    wall = time.perf_counter() - t0
    s = cv.staleness_summary(hist)
    commits = int(len(hist["tau_trace"]))
    return {
        "transport": transport,
        "tau": tau,
        "workers": n_workers,
        "straggler": straggler,
        "commit_events": commits,
        "contributions": s["n_commits"],
        "wall_s": wall,
        "commits_per_sec": commits / wall,
        "mean_staleness": s["mean_staleness"],
        "max_staleness": s["max_staleness"],
        "max_lag": s["max_lag"],
        "gate_refusals": int(hist["gate_refusals"][-1]) if commits else 0,
        "tau_final": int(hist["tau_trace"][-1]) if commits else 0,
        "final_gap": float(hist["gap"][-1]) if len(hist["gap"]) else None,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--tau", nargs="+", default=[0, 1, 4, "auto"])
    ap.add_argument("--straggler", type=int, default=4)
    ap.add_argument(
        "--no-multiprocess", action="store_true",
        help="skip the multiprocess member (process spawns pay a jax "
        "import each)",
    )
    args = ap.parse_args()
    taus = [t if t == "auto" else int(t) for t in args.tau]

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.workers}"
    )
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    transports = ["simulated", "threaded"]
    if not args.no_multiprocess:
        transports.append("multiprocess")

    rows = []
    print(
        "transport,tau,commit_events,commits_per_sec,mean_staleness,"
        "gate_refusals,final_gap"
    )
    for transport in transports:
        for tau in taus:
            r = run_one(transport, tau, args.workers, args.straggler)
            rows.append(r)
            print(
                f"{r['transport']},{r['tau']},{r['commit_events']},"
                f"{r['commits_per_sec']:.2f},{r['mean_staleness']:.3f},"
                f"{r['gate_refusals']},{r['final_gap']:.5f}",
                flush=True,
            )
    out = os.path.join(os.path.dirname(__file__), "..", "BENCH_transport.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {os.path.abspath(out)}")


if __name__ == "__main__":
    main()
