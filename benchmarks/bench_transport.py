"""Transport x tau sweep + gossip/codec wire grid on the straggler workload.

Two grids, one row list:

1. For every ``core.transport`` member and staleness bound the bench runs
   the same heterogeneous-worker fit (one straggler, ``--straggler``x
   slower) and records the protocol-level health metrics the transports
   account through the shared CommitReceipt path: commits/sec, mean/max
   staleness, gate refusals.
2. The wire grid (``core/wire.py`` x ``core/gossip.py``): threaded and
   gossip (complete + ring) under every codec (``none``/``bf16``/``int8``),
   recording the bytes actually shipped (``wire_stats``), the payload
   reduction vs the raw f32 wire, the measured final-objective convergence
   gap against that transport's own exact (codec="none") run, and the
   topology's spectral gap. ``check()`` asserts the PR's claims: payload
   strictly decreases none > bf16 > int8, int8 beats 4x on the server
   wire (alpha elision — see DESIGN.md §13), the convergence gap stays
   bounded, and gossip-complete matches threaded within 1e-5.

Results land in BENCH_transport.json at the repo root.

    PYTHONPATH=src python -m benchmarks.bench_transport
    PYTHONPATH=src python -m benchmarks.bench_transport --tiny
    PYTHONPATH=src python -m benchmarks.bench_transport --workers 4 --tau 0 1 2
    PYTHONPATH=src python -m benchmarks.bench_transport --no-multiprocess
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _problem(n_workers: int, tiny: bool):
    from repro.data.synthetic import synthetic

    if tiny:
        return synthetic(1, m=n_workers, d=16, n_train_avg=40, n_test_avg=10,
                         seed=2)
    return synthetic(1, m=n_workers, d=32, n_train_avg=80, n_test_avg=20,
                     seed=2)


def _config(tiny: bool, seed: int = 0):
    from repro.core import DMTRLConfig

    return DMTRLConfig(
        loss="hinge", lam=1e-4,
        outer_iters=2, rounds=3 if tiny else 8,
        local_iters=32 if tiny else 64,
        solver="block_gram", block_size=32, seed=seed,
        track_every=10**6,  # one objective sample at the end of each W-step
    )


def run_one(transport: str, tau, n_workers: int, straggler: int,
            tiny: bool = False, seed: int = 0):
    import jax

    from repro.core import AsyncOptions, MeshAxes
    from repro.core import convergence as cv
    from repro.core.async_dmtrl import fit_async

    sp = _problem(n_workers, tiny)
    delays = (1,) * (n_workers - 1) + (straggler,)
    cfg = _config(tiny, seed)
    opts = AsyncOptions(
        tau=tau,
        async_delays=delays,
        transport=transport,
        n_workers=None if transport == "simulated" else n_workers,
    )
    mesh = (
        jax.make_mesh((n_workers,), ("data",))
        if transport == "simulated"
        else None
    )
    t0 = time.perf_counter()
    _, _, _, hist = fit_async(cfg, sp.train, mesh, MeshAxes(data="data"), options=opts)
    wall = time.perf_counter() - t0
    s = cv.staleness_summary(hist)
    commits = int(len(hist["tau_trace"]))
    return {
        "transport": transport,
        "tau": tau,
        "workers": n_workers,
        "straggler": straggler,
        "commit_events": commits,
        "contributions": s["n_commits"],
        "wall_s": wall,
        "commits_per_sec": commits / wall,
        "mean_staleness": s["mean_staleness"],
        "max_staleness": s["max_staleness"],
        "max_lag": s["max_lag"],
        "gate_refusals": int(hist["gate_refusals"][-1]) if commits else 0,
        "tau_final": int(hist["tau_trace"][-1]) if commits else 0,
        "final_gap": float(hist["gap"][-1]) if len(hist["gap"]) else None,
    }


def run_codec_one(transport: str, topology, codec: str, n_workers: int,
                  tiny: bool = False, seed: int = 0):
    """One wire-grid cell: drive the transport manually so ``wire_stats``
    (bytes shipped / raw) is readable before close()."""
    import jax
    import numpy as np

    from repro.core import AsyncOptions, MeshAxes
    from repro.core import omega_regularizers as omega_reg
    from repro.core.dmtrl import _rho_value
    from repro.core.transport import get_transport

    sp = _problem(n_workers, tiny)
    cfg = AsyncOptions(
        tau=0, transport=transport, n_workers=n_workers,
        topology=topology, codec=codec,
    ).merge_into(_config(tiny, seed))
    reg = omega_reg.resolve_regularizer(cfg, None, m=sp.train.m)
    t = get_transport(transport).factory()
    t.setup(cfg, sp.train, mesh=None, axes=MeshAxes(), reg=reg,
            init=None, track=True)
    t0 = time.perf_counter()
    try:
        key = jax.random.PRNGKey(cfg.seed)
        rho_sigma = t.rho_sigma()
        for p in range(cfg.outer_iters):
            rho = _rho_value(cfg, rho_sigma, n_blocks_scale=1.0, reg=reg)
            key, ok = jax.random.split(key)
            t.run_w_step(p, rho, ok)
            if reg.learns:
                sig_t, om_t = reg.step(t.w_true(), cfg.omega_jitter)
                sig, om = t.pad_sigma(sig_t, om_t)
                t.install_sigma(sig, om, defer=False)
                rho_sigma = sig
        W, _, _, hist = t.result()
        s = dict(t.wire_stats)
    finally:
        t.close()
    wall = time.perf_counter() - t0
    shipped = s["snapshot_bytes"] + s["commit_bytes"] + s["mix_bytes"]
    raw = (
        s["raw_snapshot_bytes"] + s["raw_commit_bytes"] + s["raw_mix_bytes"]
    )
    return {
        "transport": transport,
        "topology": (topology if isinstance(topology, str) else "explicit"),
        "codec": codec,
        "tau": 0,
        "workers": n_workers,
        "wall_s": wall,
        "payload_nbytes": int(shipped),
        "raw_payload_nbytes": int(raw),
        "payload_reduction": (raw / shipped) if shipped else None,
        "snapshot_bytes": int(s["snapshot_bytes"]),
        "commit_bytes": int(s["commit_bytes"]),
        "mix_bytes": int(s["mix_bytes"]),
        "spectral_gap": s.get("spectral_gap"),
        "final_objective": float(np.asarray(hist["primal"])[-1]),
        "final_gap": float(hist["gap"][-1]) if len(hist["gap"]) else None,
        "W_norm": float(np.linalg.norm(np.asarray(W))),
    }


# thresholds of the measured claims (check() + the CI bench-smoke step)
CODEC_GAP_BOUND = {"none": 1e-5, "bf16": 5e-3, "int8": 2e-2}
INT8_SERVER_REDUCTION = 4.0  # alpha elision pushes the server wire past 4x
INT8_GOSSIP_REDUCTION = 3.0  # mix wire ships full replicas (no alpha leg)
PARITY_OBJECTIVE_TOL = 1e-5  # gossip complete == threaded acceptance bar


def check(rows) -> None:
    """Claim assertions over the wire grid (CI bench-smoke step)."""
    grid = [r for r in rows if "codec" in r]
    assert grid, "no codec rows in the sweep"
    by = {(r["transport"], r["topology"], r["codec"]): r for r in grid}
    members = sorted({(r["transport"], r["topology"]) for r in grid})
    for tr, topo in members:
        none = by[(tr, topo, "none")]
        bf16 = by[(tr, topo, "bf16")]
        int8 = by[(tr, topo, "int8")]
        # payload strictly decreases under the lossy codecs
        assert (
            none["payload_nbytes"]
            > bf16["payload_nbytes"]
            > int8["payload_nbytes"]
        ), (tr, topo)
        assert none["payload_reduction"] == 1.0, (tr, topo)
        # measured reduction floors: the server wire (alpha elision)
        # clears 4x under int8; the gossip mix wire ships full replicas
        # so its aggregate floor is lower (DESIGN.md §13)
        floor = (
            INT8_GOSSIP_REDUCTION if tr == "gossip"
            else INT8_SERVER_REDUCTION
        )
        assert int8["payload_reduction"] >= floor, (
            tr, topo, int8["payload_reduction"],
        )
        # bounded convergence gap vs the member's own exact run
        ref = abs(none["final_objective"])
        for r in (bf16, int8):
            gap = abs(r["final_objective"] - none["final_objective"])
            assert gap <= CODEC_GAP_BOUND[r["codec"]] * max(1.0, ref), (
                tr, topo, r["codec"], gap,
            )
    # gossip on a complete graph matches the threaded server (exact wire)
    if ("threaded", "complete") in members and (
        "gossip", "complete",
    ) in members:
        obj_t = by[("threaded", "complete", "none")]["final_objective"]
        obj_g = by[("gossip", "complete", "none")]["final_objective"]
        assert abs(obj_g - obj_t) <= PARITY_OBJECTIVE_TOL * max(
            1.0, abs(obj_t)
        ), (obj_g, obj_t)
        assert by[("gossip", "complete", "none")]["spectral_gap"] >= 0.999


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--tau", nargs="+", default=[0, 1, 4, "auto"])
    ap.add_argument("--straggler", type=int, default=4)
    ap.add_argument(
        "--tiny", action="store_true",
        help="small fixture + short schedule (CI bench-smoke)",
    )
    ap.add_argument("--out", default=None)
    ap.add_argument(
        "--no-multiprocess", action="store_true",
        help="skip the multiprocess member (process spawns pay a jax "
        "import each)",
    )
    args = ap.parse_args()
    taus = [t if t == "auto" else int(t) for t in args.tau]
    if args.tiny:
        taus = [0, "auto"]

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.workers}"
    )
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    transports = ["simulated", "threaded", "gossip"]
    if not (args.no_multiprocess or args.tiny):
        transports.append("multiprocess")

    rows = []
    print(
        "transport,tau,commit_events,commits_per_sec,mean_staleness,"
        "gate_refusals,final_gap"
    )
    for transport in transports:
        for tau in taus:
            r = run_one(transport, tau, args.workers, args.straggler,
                        tiny=args.tiny)
            rows.append(r)
            print(
                f"{r['transport']},{r['tau']},{r['commit_events']},"
                f"{r['commits_per_sec']:.2f},{r['mean_staleness']:.3f},"
                f"{r['gate_refusals']},{r['final_gap']:.5f}",
                flush=True,
            )

    print(
        "transport,topology,codec,payload_nbytes,payload_reduction,"
        "spectral_gap,final_objective"
    )
    for transport, topology in (
        ("threaded", "complete"),
        ("gossip", "complete"),
        ("gossip", "ring"),
    ):
        for codec in ("none", "bf16", "int8"):
            r = run_codec_one(transport, topology, codec, args.workers,
                              tiny=args.tiny)
            rows.append(r)
            sg = r["spectral_gap"]
            print(
                f"{r['transport']},{r['topology']},{r['codec']},"
                f"{r['payload_nbytes']},{r['payload_reduction']:.2f},"
                f"{'-' if sg is None else f'{sg:.3f}'},"
                f"{r['final_objective']:.6f}",
                flush=True,
            )
    check(rows)
    print("check() passed")
    out = args.out or os.path.join(
        os.path.dirname(__file__), "..", "BENCH_transport.json"
    )
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {os.path.abspath(out)}")


if __name__ == "__main__":
    main()
