"""Paper-experiment benchmarks — one function per table/figure.

Each returns a dict of derived metrics (also dumped to results/benchmarks.json
by run.py) and validates the paper's qualitative claims:

  fig2  task-relationship recovery on Synthetic-1
  fig3  primal-dual convergence vs task correlation (rho): Syn-1 vs Syn-2
  fig4  local computation (H) vs communication rounds (T) trade-off; DMTRL
        converges to the centralized solution
  table2 School regression: DMTRL == centralized MTRL, beats STL
  table3 MNIST-like (data-rich: parity) and MDS-like (imbalanced: win)
  theory smooth-loss linear rate / Lipschitz 1/T primal-dual convergence
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DMTRLConfig
from repro.core.dmtrl import fit
from repro.core import dual as dm
from repro.core import omega as om
from repro.core.baselines import fit_centralized_mtrl, fit_stl
from repro.core.dmtrl import w_step
from repro.data import synthetic as ds


def _timer():
    t0 = time.time()
    return lambda: time.time() - t0


# ---------------------------------------------------------------------------
def fig2_recovery(seed: int = 0) -> Dict:
    """Learned task correlations vs ground truth (paper Fig. 2)."""
    sp = ds.synthetic(1, m=16, d=100, n_train_avg=400, n_test_avg=150, seed=seed)
    cfg = DMTRLConfig(
        loss="hinge", lam=1e-4, outer_iters=5, rounds=10, local_iters=512,
        solver="block_gram", block_size=64, seed=seed,
    )
    el = _timer()
    res = fit(cfg, sp.train)
    t = el()
    learned = np.asarray(om.correlation_from_sigma(res.sigma))
    truth = sp.corr_true
    iu = np.triu_indices(16, k=1)
    align = float(np.corrcoef(learned[iu], truth[iu])[0, 1])
    sign_acc = float(
        np.mean(np.sign(learned[iu][np.abs(truth[iu]) > 0.5])
                == np.sign(truth[iu][np.abs(truth[iu]) > 0.5]))
    )
    return {
        "name": "fig2_recovery",
        "seconds": t,
        "corr_alignment": align,
        "strong_pair_sign_accuracy": sign_acc,
        "final_gap": float(res.history["gap"][-1]),
        "claim": "learned Sigma matches ground-truth task relations",
        "pass": align > 0.8 and sign_acc > 0.9,
    }


# ---------------------------------------------------------------------------
def fig3_rho_convergence(seed: int = 0) -> Dict:
    """Higher task correlation (Syn-2) => larger rho => slower convergence."""
    rows = {}
    for variant in (1, 2):
        sp = ds.synthetic(variant, m=16, d=100, n_train_avg=400, n_test_avg=50,
                          seed=seed)
        data = sp.train
        cfg = DMTRLConfig(loss="hinge", lam=1e-4, rounds=30, local_iters=256,
                          seed=seed)
        # measure with the ORACLE Sigma (from true weights) so rho reflects
        # the task-correlation structure, exactly as the paper's Fig. 3
        W_true = jnp.asarray(sp.W_true)
        sigma, _ = om.omega_step(W_true)
        rho = float(om.rho_lemma10(sigma))
        alpha = jnp.zeros((data.m, data.n_max))
        W = jnp.zeros((data.m, data.d))
        key = jax.random.PRNGKey(seed)
        alpha, W, hist = w_step(cfg, data, alpha, W, sigma, rho, key)
        gaps = hist["gap"] / max(hist["gap"][0], 1e-12)
        # rounds to reach 5% of the initial gap
        idx = np.argmax(gaps <= 0.05)
        rounds_to_5pct = int(hist["round"][idx]) if gaps.min() <= 0.05 else -1
        rows[f"syn{variant}"] = {
            "rho": rho,
            "rounds_to_5pct_gap": rounds_to_5pct,
            "final_rel_gap": float(gaps[-1]),
        }
    ok = (
        rows["syn2"]["rho"] > rows["syn1"]["rho"]
        and rows["syn2"]["final_rel_gap"] >= rows["syn1"]["final_rel_gap"]
    )
    return {
        "name": "fig3_rho_convergence",
        **{f"{k}_{kk}": vv for k, v in rows.items() for kk, vv in v.items()},
        "claim": "larger rho (more task correlation) converges slower",
        "pass": bool(ok),
    }


# ---------------------------------------------------------------------------
def fig4_tradeoff(seed: int = 0) -> Dict:
    """H (local SDCA iters) vs communication rounds to a target gap, plus
    agreement with the centralized optimum (paper Fig. 4)."""
    sp = ds.synthetic(1, m=16, d=100, n_train_avg=300, n_test_avg=150, seed=seed)
    data = sp.train
    sigma, _ = om.init_sigma(data.m)
    rho = 1.0
    target = 0.05
    rows = {}
    for H in (64, 256, 1024):
        cfg = DMTRLConfig(loss="hinge", lam=1e-4, rounds=40, local_iters=H,
                          seed=seed)
        alpha = jnp.zeros((data.m, data.n_max))
        W = jnp.zeros((data.m, data.d))
        alpha, W, hist = w_step(
            cfg, data, alpha, W, sigma, rho, jax.random.PRNGKey(seed)
        )
        gaps = hist["gap"] / max(hist["gap"][0], 1e-12)
        idx = np.argmax(gaps <= target)
        rows[H] = int(hist["round"][idx]) if gaps.min() <= target else 999
    # centralized agreement (with Omega fixed at init: STL-regularized MTL)
    cfg_full = DMTRLConfig(loss="hinge", lam=1e-4, outer_iters=3, rounds=15,
                           local_iters=1024, seed=seed)
    res = fit(cfg_full, data)
    err_d = float(dm.error_rate(sp.test, jnp.asarray(res.W)))
    cfg_c = dataclasses.replace(cfg_full, loss="smoothed_hinge")
    W_c, _, _ = fit_centralized_mtrl(cfg_c, data, inner_steps=600)
    err_c = float(dm.error_rate(sp.test, jnp.asarray(W_c)))
    monotone = rows[64] >= rows[256] >= rows[1024]
    return {
        "name": "fig4_tradeoff",
        "rounds_to_5pct_H64": rows[64],
        "rounds_to_5pct_H256": rows[256],
        "rounds_to_5pct_H1024": rows[1024],
        "test_err_dmtrl": err_d,
        "test_err_centralized": err_c,
        "claim": "larger H => fewer communication rounds; DMTRL ~= centralized",
        "pass": bool(monotone and abs(err_d - err_c) < 0.05),
    }


# ---------------------------------------------------------------------------
def table2_school(seed: int = 0) -> Dict:
    sp = ds.school_like(seed=seed)
    cfg = DMTRLConfig(loss="squared", lam=1e-3, outer_iters=4, rounds=10,
                      local_iters=128, seed=seed)
    el = _timer()
    res = fit(cfg, sp.train)
    t = el()
    stl = fit_stl(cfg, sp.train)
    W_c, _, _ = fit_centralized_mtrl(cfg, sp.train, inner_steps=500)
    out = {}
    for nm, W in (("dmtrl", res.W), ("stl", stl.W), ("centralized", W_c)):
        out[f"rmse_{nm}"] = float(dm.rmse(sp.test, jnp.asarray(W)))
        out[f"explvar_{nm}"] = float(dm.explained_variance(sp.test, jnp.asarray(W)))
    ok = (
        out["rmse_dmtrl"] <= out["rmse_stl"] + 1e-3
        and abs(out["rmse_dmtrl"] - out["rmse_centralized"])
        <= 0.05 * out["rmse_centralized"]
    )
    return {
        "name": "table2_school",
        "seconds": t,
        **out,
        "claim": "DMTRL == centralized MTRL, better than STL (School)",
        "pass": bool(ok),
    }


# ---------------------------------------------------------------------------
def table3_classification(seed: int = 0, scale: float = 0.25) -> Dict:
    out = {}
    # MNIST-like: data-rich, expect parity
    mn = ds.mnist_like(seed=seed, scale=scale)
    cfg = DMTRLConfig(loss="hinge", lam=1e-5, outer_iters=3, rounds=8,
                      local_iters=512, seed=seed)
    res = fit(cfg, mn.train)
    stl = fit_stl(cfg, mn.train)
    out["mnist_err_dmtrl"] = float(dm.error_rate(mn.test, jnp.asarray(res.W)))
    out["mnist_err_stl"] = float(dm.error_rate(mn.test, jnp.asarray(stl.W)))
    # MDS-like: imbalanced tasks, expect a clear win
    md = ds.mds_like(seed=seed, scale=0.12)
    cfg2 = DMTRLConfig(loss="hinge", lam=1e-4, outer_iters=4, rounds=8,
                       local_iters=256, seed=seed)
    res2 = fit(cfg2, md.train)
    stl2 = fit_stl(cfg2, md.train)
    out["mds_err_dmtrl"] = float(dm.error_rate(md.test, jnp.asarray(res2.W)))
    out["mds_err_stl"] = float(dm.error_rate(md.test, jnp.asarray(stl2.W)))
    ok = (
        out["mnist_err_dmtrl"] <= out["mnist_err_stl"] + 0.01
        and out["mds_err_dmtrl"] < out["mds_err_stl"] - 0.01
    )
    return {
        "name": "table3_classification",
        **out,
        "claim": "parity on data-rich MNIST; DMTRL >> STL on imbalanced MDS",
        "pass": bool(ok),
    }


# ---------------------------------------------------------------------------
def convergence_theory(seed: int = 0) -> Dict:
    """Thm 8 (smooth: linear dual convergence) vs Thm 9 (Lipschitz: 1/T)."""
    sp = ds.synthetic(1, m=8, d=60, n_train_avg=200, n_test_avg=50, seed=seed)
    data = sp.train
    sigma, _ = om.init_sigma(data.m)
    out = {}
    for loss_name in ("squared", "hinge"):
        cfg = DMTRLConfig(loss=loss_name, lam=1e-3, rounds=40, local_iters=256,
                          seed=seed)
        alpha = jnp.zeros((data.m, data.n_max))
        W = jnp.zeros((data.m, data.d))
        alpha, W, hist = w_step(
            cfg, data, alpha, W, sigma, 1.0, jax.random.PRNGKey(seed)
        )
        dual = hist["dual"]
        d_star = dual[-1] + (hist["gap"][-1])  # upper bound via P >= D*
        subopt = np.maximum(d_star - dual, 1e-12)
        # fit log-linear rate on the first 20 rounds
        k = 20
        slope = np.polyfit(hist["round"][:k], np.log(subopt[:k]), 1)[0]
        out[f"{loss_name}_log_subopt_slope"] = float(slope)
        out[f"{loss_name}_final_gap"] = float(hist["gap"][-1])
    # smooth loss should contract strictly faster per round
    ok = out["squared_log_subopt_slope"] < out["hinge_log_subopt_slope"] < 0
    return {
        "name": "convergence_theory",
        **out,
        "claim": "smooth loss: linear rate; Lipschitz: slower sublinear decay",
        "pass": bool(ok),
    }


ALL = {
    "fig2": fig2_recovery,
    "fig3": fig3_rho_convergence,
    "fig4": fig4_tradeoff,
    "table2": table2_school,
    "table3": table3_classification,
    "theory": convergence_theory,
}
