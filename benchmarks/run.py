"""Benchmark driver. One benchmark per paper table/figure plus kernel
micro-benches and the roofline aggregation.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig2 table2

Prints ``name,us_per_call,derived`` CSV rows and writes the full metric
dicts to results/benchmarks.json.
"""
from __future__ import annotations

import json
import os
import sys
import time


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks import paper
    from benchmarks import bench_kernels
    from benchmarks import bench_roofline

    selected = sys.argv[1:] or (
        list(paper.ALL) + list(bench_kernels.ALL) + ["roofline"]
    )
    results = []
    print("name,us_per_call,derived")
    for name in selected:
        if name in paper.ALL:
            t0 = time.time()
            row = paper.ALL[name]()
            us = (time.time() - t0) * 1e6
            derived = row.get("claim", "") + f" -> pass={row.get('pass')}"
            print(f"{row['name']},{us:.0f},{derived}", flush=True)
            results.append(row)
        elif name in bench_kernels.ALL:
            row = bench_kernels.ALL[name]()
            print(f"{row['name']},{row['us_per_call']:.0f},{row['derived']}",
                  flush=True)
            results.append(row)
        elif name == "roofline":
            rows = bench_roofline.load_rows()
            s = bench_roofline.summary(rows)
            print(f"roofline_grid,0,{s}", flush=True)
            results.append({"name": "roofline_grid", **s})
        else:
            print(f"{name},0,UNKNOWN BENCH", file=sys.stderr)
    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(results, f, indent=1, default=str)
    npass = sum(1 for r in results if r.get("pass") is True)
    nfail = sum(1 for r in results if r.get("pass") is False)
    print(f"# paper-claim benches: {npass} pass, {nfail} fail", file=sys.stderr)


if __name__ == "__main__":
    main()
