"""Benchmark driver. One benchmark per paper table/figure plus kernel
micro-benches, the roofline aggregation, and the standalone sweep modules.

    PYTHONPATH=src python -m benchmarks.run            # paper + kernels
    PYTHONPATH=src python -m benchmarks.run fig2 table2
    PYTHONPATH=src python -m benchmarks.run sigma      # a standalone sweep

Standalone sweeps (``bench_async`` / ``bench_regularizers`` /
``bench_serving`` / ``bench_sigma`` / ``bench_transport``) are discovered
from the directory — a new ``bench_*.py`` with a ``main()`` shows up here
with no driver edit — and selectable by short name (``sigma``) or module
name (``bench_sigma``); ``--tiny`` is forwarded where supported.

Prints ``name,us_per_call,derived`` CSV rows, writes the full metric dicts
to results/benchmarks.json, and ends with the BENCH_*.json index: which
root-level result files exist, which sweep refreshes each, and which
sweeps have not been run yet (scanned live, so it can never go stale).
"""
from __future__ import annotations

import glob
import importlib
import json
import os
import sys
import time

# modules of the ALL-registry / aggregation kind the driver runs inline;
# everything else matching bench_*.py is a standalone sweep with a main()
_INLINE = {"bench_kernels", "bench_roofline"}
# sweeps that accept --tiny (forwarded when the driver invokes them)
_TINY_OK = {
    "bench_fleet",
    "bench_obs",
    "bench_regularizers",
    "bench_sigma",
    "bench_transport",
}


def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def standalone_sweeps() -> dict:
    """{short_name: module_name} for every bench_*.py with its own main()."""
    out = {}
    for path in sorted(glob.glob(os.path.join(os.path.dirname(__file__), "bench_*.py"))):
        mod = os.path.splitext(os.path.basename(path))[0]
        if mod not in _INLINE:
            out[mod.removeprefix("bench_")] = mod
    return out


def bench_json_index() -> list:
    """The live BENCH_*.json index: (file, exists, producing sweep) rows."""
    sweeps = standalone_sweeps()
    rows = []
    seen = set()
    for short, mod in sorted(sweeps.items()):
        fname = f"BENCH_{short}.json"
        src_path = os.path.join(os.path.dirname(__file__), f"{mod}.py")
        with open(src_path) as f:
            src = f.read()
        if fname not in src:
            continue  # sweep writes elsewhere (e.g. results/), not a root file
        path = os.path.join(_repo_root(), fname)
        rows.append((fname, os.path.exists(path), f"python -m benchmarks.{mod}"))
        seen.add(fname)
    # kernels writes its BENCH file from the inline registry sweep
    kfile = "BENCH_kernels.json"
    rows.append(
        (
            kfile,
            os.path.exists(os.path.join(_repo_root(), kfile)),
            "python -m benchmarks.run kernels_*",
        )
    )
    # orphans: result files no current sweep produces (renamed/removed)
    for path in sorted(glob.glob(os.path.join(_repo_root(), "BENCH_*.json"))):
        fname = os.path.basename(path)
        if fname not in seen and fname != kfile:
            rows.append((fname, True, "STALE — no sweep produces this file"))
    return rows


def _print_bench_index() -> None:
    print("# BENCH_*.json index (repo root):", file=sys.stderr)
    for fname, exists, producer in bench_json_index():
        state = "present" if exists else "MISSING (not yet run)"
        print(f"#   {fname:28s} {state:22s} <- {producer}", file=sys.stderr)


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks import paper
    from benchmarks import bench_kernels
    from benchmarks import bench_roofline

    argv = [a for a in sys.argv[1:] if a != "--tiny"]
    tiny = "--tiny" in sys.argv[1:]
    sweeps = standalone_sweeps()
    selected = argv or (list(paper.ALL) + list(bench_kernels.ALL) + ["roofline"])
    results = []
    print("name,us_per_call,derived")
    for name in selected:
        if name in paper.ALL:
            t0 = time.time()
            row = paper.ALL[name]()
            us = (time.time() - t0) * 1e6
            derived = row.get("claim", "") + f" -> pass={row.get('pass')}"
            print(f"{row['name']},{us:.0f},{derived}", flush=True)
            results.append(row)
        elif name in bench_kernels.ALL:
            row = bench_kernels.ALL[name]()
            print(f"{row['name']},{row['us_per_call']:.0f},{row['derived']}",
                  flush=True)
            results.append(row)
        elif name == "roofline":
            rows = bench_roofline.load_rows()
            s = bench_roofline.summary(rows)
            print(f"roofline_grid,0,{s}", flush=True)
            results.append({"name": "roofline_grid", **s})
        elif name in sweeps or name in sweeps.values():
            mod_name = sweeps.get(name, name)
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            sweep_argv = ["--tiny"] if (tiny and mod_name in _TINY_OK) else []
            t0 = time.time()
            old_argv, sys.argv = sys.argv, [mod_name] + sweep_argv
            try:
                mod.main()
            finally:
                sys.argv = old_argv
            us = (time.time() - t0) * 1e6
            print(f"{mod_name},{us:.0f},standalone sweep", flush=True)
            results.append({"name": mod_name, "us_per_call": us})
        else:
            print(f"{name},0,UNKNOWN BENCH", file=sys.stderr)
    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(results, f, indent=1, default=str)
    npass = sum(1 for r in results if r.get("pass") is True)
    nfail = sum(1 for r in results if r.get("pass") is False)
    print(f"# paper-claim benches: {npass} pass, {nfail} fail", file=sys.stderr)
    _print_bench_index()


if __name__ == "__main__":
    main()
