"""Bounded-staleness DMTRL with a straggler worker, via the estimator.

8 simulated workers (host devices), one of them 4x slower. The synchronous
engine barriers every round on the straggler; the async engine (tau > 0)
lets the fast workers keep committing against bounded-stale snapshots, so
the duality gap falls much earlier on the simulated wall clock.

Install the package once (``pip install -e .``) or export
``PYTHONPATH=src``, then:

    python examples/async_workers.py
    python examples/async_workers.py --trace out.json   # span tracing on
    python examples/async_workers.py --tiny             # CI smoke schedule

With ``--trace`` the threaded and gossip runs execute under the ``obs``
span tracer and the whole run is exported as Chrome-trace JSON — open
``chrome://tracing`` (or https://ui.perfetto.dev) and load the file to
see every worker thread's gate/snapshot/solve/commit timeline nested
under its rounds, plus the driver's W-step/Omega-step alternation.
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

from repro import obs
from repro.core import AsyncOptions, DMTRLEstimator, MeshAxes
from repro.core import convergence as cv
from repro.data.synthetic import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--trace", metavar="OUT.json", default=None,
        help="enable span tracing and write a Chrome-trace JSON here",
    )
    ap.add_argument(
        "--tiny", action="store_true",
        help="short schedule (CI examples-smoke)",
    )
    args = ap.parse_args()

    n_dev = len(jax.devices())
    print(f"devices: {n_dev} (each = one worker group)")
    sp = synthetic(1, m=8, d=48, n_train_avg=120, n_test_avg=40, seed=0)
    delays = (1,) * (n_dev - 1) + (4,)  # last worker is a 4x straggler

    base = dict(
        loss="hinge", lam=1e-4, outer_iters=2,
        rounds=3 if args.tiny else 8,
        local_iters=32 if args.tiny else 128, seed=0,
    )
    mesh = jax.make_mesh((n_dev,), ("data",))
    ax = MeshAxes(data="data")

    print("synchronous (every round barriers on the straggler)...")
    sync = DMTRLEstimator(
        engine="distributed", mesh=mesh, axes=ax, **base
    ).fit(sp.train)
    sync_ticks = cv.sync_effective_ticks(sync.history, delays)

    print("async, tau=2, deterministic straggler schedule...")
    anc = DMTRLEstimator(
        engine="async", mesh=mesh, axes=ax,
        async_options=AsyncOptions(tau=2, async_delays=delays), **base
    ).fit(sp.train)
    a_ticks, a_gaps = cv.effective_gap_curve(anc.history)

    target = 2.0 * sync.history["gap"][-1]
    t_sync = cv.ticks_to_gap(sync_ticks, sync.history["gap"], target)
    t_async = cv.ticks_to_gap(a_ticks, a_gaps, target)
    print(f"  final gap      sync {sync.history['gap'][-1]:.4f}  async {a_gaps[-1]:.4f}")
    print(f"  ticks to gap<={target:.4f}:  sync {t_sync:.0f}  async {t_async:.0f}")
    s = cv.staleness_summary(anc.history)
    print(
        f"  staleness: max {s['max_staleness']:.0f} commits, "
        f"mean {s['mean_staleness']:.2f}, max lag {s['max_lag']:.0f} rounds"
    )

    # from here on the transports are REAL (worker threads): turn the span
    # tracer on so the runs land in the Chrome trace when --trace is given
    if args.trace:
        obs.enable(clear=True)

    # same protocol, different substrate: a REAL in-host parameter server
    # (worker threads, lock-protected versioned state, nondeterministic
    # arrival order). No mesh needed — the transport owns the workers.
    print("async, tau=2, threaded transport (real parameter server)...")
    thr = DMTRLEstimator(
        engine="async",
        async_options=AsyncOptions(
            tau=2, async_delays=delays, transport="threaded", n_workers=n_dev
        ),
        **base,
    ).fit(sp.train)
    st = cv.staleness_summary(thr.history)
    print(
        f"  final gap {thr.history['gap'][-1]:.4f}, "
        f"staleness mean {st['mean_staleness']:.2f} "
        f"(max lag {st['max_lag']:.0f} <= tau), "
        f"gate refusals {thr.history['gate_refusals'][-1]:.0f}"
    )

    # serverless: no parameter server at all. Each node keeps a W replica,
    # commits locally, and averages with graph neighbors (Metropolis
    # weights) at every round boundary; the int8 wire codec quantizes the
    # exchanged replicas with error feedback (core/wire.py). Sparse graphs
    # pay a consensus tax set by the mixing matrix's spectral gap — on a
    # ring of 8 it is 0.195 (slow), on a 2x4 torus 0.500 — so the torus
    # run below doubles the rounds to buy enough exchanges and lands
    # within reach of the parameter-server gap above.
    print("async, tau=2, gossip transport (torus topology, int8 wire)...")
    from repro.core.gossip import build_adjacency, mixing_matrix, spectral_gap

    for topo in ("ring", "torus", "complete"):
        g = spectral_gap(mixing_matrix(build_adjacency(topo, n_dev)))
        print(f"    spectral gap {topo:9s} {g:.3f}")
    gap = spectral_gap(mixing_matrix(build_adjacency("torus", n_dev)))
    gsp = DMTRLEstimator(
        engine="async",
        async_options=AsyncOptions(
            tau=2, async_delays=delays, transport="gossip",
            n_workers=n_dev, topology="torus", codec="int8",
        ),
        **dict(base, rounds=2 * base["rounds"]),
    ).fit(sp.train)
    sg = cv.staleness_summary(gsp.history)
    print(
        f"  final gap {gsp.history['gap'][-1]:.4f}, "
        f"spectral gap {gap:.3f} (consensus contraction/exchange), "
        f"{sg['n_exchanges']} edge exchanges, "
        f"edge staleness mean {sg['mean_edge_staleness']:.2f} "
        f"max {sg['max_edge_staleness']:.0f}"
    )

    if args.trace:
        n = obs.export_chrome(args.trace)
        obs.disable()
        breakdown = obs.phase_breakdown()
        top = sorted(
            breakdown.items(), key=lambda kv: -kv[1]["total_s"]
        )[:6]
        print(f"trace: {n} spans -> {os.path.abspath(args.trace)}")
        print("  top phases by inclusive wall-clock:")
        for name, row in top:
            print(
                f"    {name:16s} {row['count']:5d} x "
                f"{row['mean_s'] * 1e3:8.2f} ms = {row['total_s']:.3f} s"
            )
        print("  open chrome://tracing (or ui.perfetto.dev) and load the file")


if __name__ == "__main__":
    main()
