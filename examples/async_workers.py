"""Bounded-staleness DMTRL with a straggler worker.

8 simulated workers (host devices), one of them 4x slower. The synchronous
engine barriers every round on the straggler; the async engine (tau > 0)
lets the fast workers keep committing against bounded-stale snapshots, so
the duality gap falls much earlier on the simulated wall clock.

    PYTHONPATH=src python examples/async_workers.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, "src")

import jax

from repro.core import DMTRLConfig, MeshAxes, fit_async, fit_distributed
from repro.core import convergence as cv
from repro.data.synthetic import synthetic


def main():
    n_dev = len(jax.devices())
    print(f"devices: {n_dev} (each = one worker group)")
    sp = synthetic(1, m=8, d=48, n_train_avg=120, n_test_avg=40, seed=0)
    delays = (1,) * (n_dev - 1) + (4,)  # worker 7 is a 4x straggler

    base = dict(
        loss="hinge", lam=1e-4, outer_iters=2, rounds=8, local_iters=128, seed=0
    )
    mesh = jax.make_mesh((n_dev,), ("data",))
    ax = MeshAxes(data="data")

    print("synchronous (every round barriers on the straggler)...")
    _, _, _, h_sync = fit_distributed(DMTRLConfig(**base), sp.train, mesh, ax)
    sync_ticks = cv.sync_effective_ticks(h_sync, delays)

    print("async, tau=2, deterministic straggler schedule...")
    cfg = DMTRLConfig(**base, tau=2, async_delays=delays)
    _, _, _, h_async = fit_async(cfg, sp.train, mesh, ax)
    a_ticks, a_gaps = cv.effective_gap_curve(h_async)

    target = 2.0 * h_sync["gap"][-1]
    t_sync = cv.ticks_to_gap(sync_ticks, h_sync["gap"], target)
    t_async = cv.ticks_to_gap(a_ticks, a_gaps, target)
    print(f"  final gap      sync {h_sync['gap'][-1]:.4f}  async {a_gaps[-1]:.4f}")
    print(f"  ticks to gap<={target:.4f}:  sync {t_sync:.0f}  async {t_async:.0f}")
    s = cv.staleness_summary(h_async)
    print(
        f"  staleness: max {s['max_staleness']:.0f} commits, "
        f"mean {s['mean_staleness']:.2f}, max lag {s['max_lag']:.0f} rounds"
    )


if __name__ == "__main__":
    main()
