"""Geo-distributed DMTRL simulation: 8 'workers' (host devices), one task's
data pinned per worker; only delta_b vectors and task weights cross workers.

Install the package once (``pip install -e .``) or export
``PYTHONPATH=src``, then:

    python examples/distributed_workers.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.core import DMTRLEstimator, MeshAxes
from repro.data.synthetic import synthetic


def main():
    n_dev = len(jax.devices())
    print(f"devices: {n_dev} (each = one of the paper's workers)")
    sp = synthetic(1, m=8, d=64, n_train_avg=200, n_test_avg=60, seed=0)

    base = dict(
        loss="hinge", lam=1e-4, outer_iters=3, rounds=8, local_iters=256, seed=0
    )
    mesh = jax.make_mesh((min(8, n_dev),), ("data",))
    print("fitting DMTRL with tasks sharded over the 'data' axis...")
    dist = DMTRLEstimator(
        engine="distributed", mesh=mesh, axes=MeshAxes(data="data"), **base
    ).fit(sp.train)
    h = dist.history
    print(f"  gap: {h['gap'][0]:.3f} -> {h['gap'][-1]:.4f}")

    ref = DMTRLEstimator(engine="reference", **base).fit(sp.train)
    werr = float(np.max(np.abs(dist.W_ - ref.W_)))
    print(f"  max |W_distributed - W_reference| = {werr:.2e} (bit-equal rounds)")
    print("  per-round communication = m*d floats (delta_b gather + W scatter),")
    print("  the raw task data never left its worker.")


if __name__ == "__main__":
    main()
