"""Quickstart: DMTRL on the paper's Synthetic-1 dataset via the estimator.

Install the package once (``pip install -e .``) or export
``PYTHONPATH=src``, then:

    python examples/quickstart.py [--tiny]

Learns 16 related binary tasks jointly through the engine-agnostic
``DMTRLEstimator`` facade, recovers the task-correlation structure, and
compares against single-task learning (the identity_stl regularizer).
"""
import argparse

import numpy as np

from repro.core import DMTRLEstimator, correlation_from_sigma
from repro.data.synthetic import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--tiny", action="store_true",
        help="CI-sized shapes (seconds instead of minutes)",
    )
    args = ap.parse_args()
    if args.tiny:
        m, d, n_tr, n_te = 6, 24, 60, 30
        fit_kw = dict(outer_iters=2, rounds=4, local_iters=64)
    else:
        m, d, n_tr, n_te = 16, 100, 300, 150
        fit_kw = dict(outer_iters=4, rounds=10, local_iters=512)

    print(f"generating Synthetic-1 ({m} tasks, 3 parent groups, +- children)...")
    sp = synthetic(1, m=m, d=d, n_train_avg=n_tr, n_test_avg=n_te, seed=0)

    est = DMTRLEstimator(
        engine="reference",  # | "distributed" | "async" (core.engines)
        loss="hinge",
        lam=1e-4,
        solver="block_gram",  # local-SDCA backend (core.solver_backends)
        block_size=64,
        seed=0,
        regularizer="trace_constraint",  # the paper's Omega family member
        **fit_kw,
    )
    print("fitting DMTRL (Algorithm 1) via the estimator facade...")
    est.fit(sp.train)
    gaps = est.history["gap"]
    print(f"  duality gap: {gaps[0]:.3f} -> {gaps[-1]:.4f}")
    print(f"  rho per outer iteration: {[round(r, 2) for r in est.rho_per_outer_]}")

    # single-task baseline == the identity_stl member of the same family
    stl = DMTRLEstimator(
        config=est.config, regularizer="identity_stl"
    ).fit(sp.train)
    print(
        f"  test accuracy: DMTRL {est.score(sp.test):.3f}"
        f"  vs  STL {stl.score(sp.test):.3f}"
    )

    learned = np.asarray(correlation_from_sigma(est.sigma_))
    iu = np.triu_indices(m, k=1)
    align = np.corrcoef(learned[iu], sp.corr_true[iu])[0, 1]
    print(f"  task-correlation recovery alignment: {align:.3f}")
    print("\nlearned correlation matrix (rounded):")
    with np.printoptions(precision=1, suppress=True, linewidth=200):
        print(learned)


if __name__ == "__main__":
    main()
