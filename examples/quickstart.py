"""Quickstart: DMTRL on the paper's Synthetic-1 dataset.

    PYTHONPATH=src python examples/quickstart.py

Learns 16 related binary tasks jointly with the distributed primal-dual
algorithm, recovers the task-correlation structure, and compares against
single-task learning.
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import DMTRLConfig, fit, correlation_from_sigma
from repro.core import dual as dm
from repro.core.baselines import fit_stl
from repro.data.synthetic import synthetic


def main():
    print("generating Synthetic-1 (16 tasks, 3 parent groups, +- children)...")
    sp = synthetic(1, m=16, d=100, n_train_avg=300, n_test_avg=150, seed=0)

    cfg = DMTRLConfig(
        loss="hinge",
        lam=1e-4,
        outer_iters=4,  # P: alternations of (W-step, Omega-step)
        rounds=10,  # T: communication rounds per W-step
        local_iters=512,  # H: local SDCA iterations per round
        solver="block_gram",  # local-SDCA backend (core.solver_backends):
        #   "naive" | "block_gram" | "pallas_block" | "pallas_round"
        block_size=64,
        seed=0,
    )
    print("fitting DMTRL (Algorithm 1)...")
    res = fit(cfg, sp.train)
    print(f"  duality gap: {res.history['gap'][0]:.3f} -> {res.history['gap'][-1]:.4f}")
    print(f"  rho per outer iteration: {[round(r,2) for r in res.rho_per_outer]}")

    stl = fit_stl(cfg, sp.train)
    err_mtl = float(dm.error_rate(sp.test, jnp.asarray(res.W)))
    err_stl = float(dm.error_rate(sp.test, jnp.asarray(stl.W)))
    print(f"  test error: DMTRL {err_mtl:.3f}  vs  STL {err_stl:.3f}")

    learned = np.asarray(correlation_from_sigma(res.sigma))
    iu = np.triu_indices(16, k=1)
    align = np.corrcoef(learned[iu], sp.corr_true[iu])[0, 1]
    print(f"  task-correlation recovery alignment: {align:.3f}")
    print("\nlearned correlation matrix (rounded):")
    with np.printoptions(precision=1, suppress=True, linewidth=200):
        print(learned)


if __name__ == "__main__":
    main()
