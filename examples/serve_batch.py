"""Batched serving demo: prefill + decode with KV / SSM-state caches.

    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-780m
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params
from repro.serve import Request, ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=list(ARCH_IDS))
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"loading {cfg.name} (reduced) ...")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, ServeConfig(batch=4, max_len=128))

    rng = np.random.RandomState(0)
    reqs = [
        Request(prompt=rng.randint(2, cfg.vocab_size, size=n).astype(np.int32),
                max_new_tokens=args.max_new)
        for n in (5, 9, 3)
    ]
    print(f"serving {len(reqs)} requests (batched prefill + decode loop)...")
    done = engine.run(reqs)
    for i, r in enumerate(done[:3]):
        print(f"  req{i}: prompt[{r.prompt.shape[0]} toks] -> {r.output}")


if __name__ == "__main__":
    main()
