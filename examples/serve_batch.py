"""Batched serving demos.

Install the package once (``pip install -e .``) or export
``PYTHONPATH=src``, then:

    python examples/serve_batch.py --arch mamba2-780m     # LM decode demo
    python examples/serve_batch.py --mtl [--tiny]         # MTL scoring demo

The LM path exercises prefill + decode with KV / SSM-state caches; the
``--mtl`` path fits a small DMTRL estimator and serves per-task scoring
requests through the batched MTL scoring engine (serve/mtl.py).
"""
import argparse

import numpy as np


def lm_demo(arch: str, max_new: int):
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve import Request, ServeConfig, ServingEngine

    cfg = get_config(arch).reduced()
    print(f"loading {cfg.name} (reduced) ...")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, ServeConfig(batch=4, max_len=128))

    rng = np.random.RandomState(0)
    reqs = [
        Request(prompt=rng.randint(2, cfg.vocab_size, size=n).astype(np.int32),
                max_new_tokens=max_new)
        for n in (5, 9, 3)
    ]
    print(f"serving {len(reqs)} requests (batched prefill + decode loop)...")
    done = engine.run(reqs)
    for i, r in enumerate(done[:3]):
        print(f"  req{i}: prompt[{r.prompt.shape[0]} toks] -> {r.output}")


def mtl_demo(tiny: bool):
    from repro.core import DMTRLEstimator
    from repro.data.synthetic import synthetic
    from repro.serve import ScoreRequest

    m, d = (6, 24) if tiny else (16, 100)
    n_tr = 60 if tiny else 200
    print(f"fitting DMTRL on Synthetic-1 ({m} tasks) for the scoring demo...")
    sp = synthetic(1, m=m, d=d, n_train_avg=n_tr, n_test_avg=40, seed=0)
    est = DMTRLEstimator(
        loss="hinge", lam=1e-4, outer_iters=2, rounds=4, local_iters=64,
        block_size=32, seed=0,
    ).fit(sp.train)
    print(f"  test accuracy: {est.score(sp.test):.3f}")

    engine = est.scoring_engine(batch=4)
    rng = np.random.RandomState(1)
    reqs = []
    for _ in range(7):  # odd count: exercises the padded final batch
        t = int(rng.randint(m))
        j = int(rng.randint(int(sp.test.n[t])))
        reqs.append(ScoreRequest(task=t, x=np.asarray(sp.test.x[t, j])))
    print(f"serving {len(reqs)} scoring requests (batch=4, fixed-shape step)...")
    done = engine.run(reqs)
    for i, r in enumerate(done):
        print(f"  req{i}: task={r.task}  score={r.score:+.3f}  label={r.label:+.0f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mtl", action="store_true",
                    help="run the MTL scoring demo instead of the LM demo")
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized shapes for the MTL demo")
    args = ap.parse_args()
    if args.mtl:
        mtl_demo(args.tiny)
    else:
        from repro.configs import ARCH_IDS

        if args.arch not in ARCH_IDS:
            raise SystemExit(f"unknown arch {args.arch!r}; have {sorted(ARCH_IDS)}")
        lm_demo(args.arch, args.max_new)


if __name__ == "__main__":
    main()
