"""Fleet serving demo: N replicas, task-affinity routing, rolling hot-swap.

Install the package once (``pip install -e .``) or export
``PYTHONPATH=src``, then:

    python examples/serve_fleet.py [--tiny]

Fits a small DMTRL estimator, stands up a replica fleet behind the
task-affinity router (``est.serving_fleet``), and pushes a bursty stream
of per-task scoring requests through it:

  * requests are pinned to replicas by consistent hashing on task id
    (hot per-task state stays put; backlogged homes spill to the least
    loaded replica),
  * mid-stream the estimator keeps training (``partial_fit``) — the new
    ``(W, Sigma)`` rolls across the fleet ONE replica per router step,
    while every client session holds a monotonic-read token: no client
    ever observes the model version go backwards, even mid-roll,
  * then one replica "crashes" (its queue fails over to the survivors,
    stamps intact) and is restored (model caught up first),
  * the final summary is the fleet-level metrics rollup
    (``ServingMetrics.merge`` across replicas) plus the router's own
    shed/spill/failover counters.
"""
import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="CI-sized shapes")
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--slo-ms", type=float, default=50.0)
    args = ap.parse_args()

    from repro.core import DMTRLEstimator
    from repro.data.synthetic import synthetic
    from repro.serve import ScoreRequest

    m, d = (6, 24) if args.tiny else (16, 100)
    n_req = args.requests or (60 if args.tiny else 600)
    sp = synthetic(1, m=m, d=d, n_train_avg=60 if args.tiny else 200,
                   n_test_avg=40, seed=0)
    print(f"fitting DMTRL ({m} tasks) for the fleet demo...")
    est = DMTRLEstimator(
        loss="hinge", lam=1e-4, outer_iters=2, rounds=4, local_iters=64,
        block_size=32, seed=0,
    ).fit(sp.train)
    print(f"  test accuracy: {est.score(sp.test):.3f}")

    router = est.serving_fleet(
        n_replicas=args.replicas, batch=8, slo_s=args.slo_ms / 1e3
    )
    router.warmup()  # one compile, shared by every homogeneous replica
    print(f"fleet up: {router.n_replicas} replicas, batch=8, "
          f"slo={args.slo_ms:.0f}ms, model v{router.version}")
    homes = {}
    for t in range(m):
        homes.setdefault(router.home_of(t), []).append(t)
    print("  task affinity: " + "  ".join(
        f"replica {rid} <- tasks {ts}" for rid, ts in sorted(homes.items())
    ))

    rng = np.random.RandomState(1)
    token = router.session()  # ONE client session: monotonic reads

    def make_request():
        t = int(rng.randint(m))
        j = int(rng.randint(int(sp.test.n[t])))
        return ScoreRequest(task=t, x=np.asarray(sp.test.x[t, j]))

    served = {}
    floors_ok = True
    submitted = 0
    swapped = crashed = restored = False
    while submitted < n_req or router.pending:
        for _ in range(int(rng.randint(1, 13))):
            if submitted < n_req:
                out = router.submit(make_request(), client=token)
                assert out.admitted, out
                submitted += 1
        floor = token.min_version
        for r in router.step():
            served[r.snapshot_version] = served.get(r.snapshot_version, 0) + 1
            floors_ok &= r.snapshot_version >= floor
        if not swapped and submitted >= n_req // 3:
            print("  mid-stream partial_fit -> rolling hot-swap...")
            est.partial_fit(sp.train)  # rolls one replica per router step
            swapped = True
            print(f"  fleet target v{router.version} "
                  f"({router.roll_pending} replicas still rolling)")
        if swapped and not crashed and submitted >= n_req // 2:
            moved = router.fail_replica(1, "demo crash")
            crashed = True
            print(f"  replica 1 down: {moved} queued requests re-pinned "
                  f"onto {router.n_up} survivors")
        if crashed and not restored and submitted >= (2 * n_req) // 3:
            router.restore_replica(1)
            restored = True
            print(f"  replica 1 restored at v{router.replica(1).scheduler.version}")

    assert floors_ok, "a client observed the model version regress"
    s = router.metrics().summary()
    lat = s["latency"]
    c = router.counters
    print(f"served {s['completed']} requests on versions "
          f"{{{', '.join(f'v{v}: {n}' for v, n in sorted(served.items()))}}} "
          f"-- no version ever regressed for the client")
    print("  fleet p50/p95/p99 latency: "
          f"{lat['p50_s'] * 1e3:.2f} / {lat['p95_s'] * 1e3:.2f} / "
          f"{lat['p99_s'] * 1e3:.2f} ms   throughput: "
          f"{s['throughput_rps']:.0f} req/s")
    print(f"  router: {c['spills']} spills, {c['shed']} shed, "
          f"{c['failovers']} failover ({c['requeued']} re-pinned), "
          f"{c['restarts']} restart, {c['rolled_installs']} rolled installs")
    print("  per replica: " + "  ".join(
        f"[{p['id']}] v{p['version']} done={p['completed']}"
        for p in router.summary()["per_replica"]
    ))


if __name__ == "__main__":
    main()
