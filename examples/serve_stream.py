"""Continuous-batching MTL serving demo: request stream + live hot-swap.

Install the package once (``pip install -e .``) or export
``PYTHONPATH=src``, then:

    python examples/serve_stream.py [--tiny]

Fits a small DMTRL estimator, stands up the continuous-batching scheduler
(``est.serving_scheduler``), and serves a bursty stream of per-task
scoring requests with a latency SLO. Halfway through the stream the
estimator keeps training (``partial_fit``) — the new ``(W, Sigma)``
snapshot hot-swaps into the scheduler between tiles, without draining the
queue, and the demo shows requests served on each model version plus the
final p50/p95/p99 / throughput / SLO metrics.
"""
import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="CI-sized shapes")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--slo-ms", type=float, default=50.0)
    args = ap.parse_args()

    from repro.core import DMTRLEstimator
    from repro.data.synthetic import synthetic
    from repro.serve import ScoreRequest

    m, d = (6, 24) if args.tiny else (16, 100)
    n_req = args.requests or (48 if args.tiny else 400)
    sp = synthetic(1, m=m, d=d, n_train_avg=60 if args.tiny else 200,
                   n_test_avg=40, seed=0)
    print(f"fitting DMTRL ({m} tasks) for the serving demo...")
    est = DMTRLEstimator(
        loss="hinge", lam=1e-4, outer_iters=2, rounds=4, local_iters=64,
        block_size=32, seed=0,
    ).fit(sp.train)
    print(f"  test accuracy: {est.score(sp.test):.3f}")

    sched = est.serving_scheduler(batch=8, slo_s=args.slo_ms / 1e3)
    print(f"scheduler up: batch=8, policy=edf, slo={args.slo_ms:.0f}ms, "
          f"model v{sched.version}")

    rng = np.random.RandomState(1)

    def make_request():
        t = int(rng.randint(m))
        j = int(rng.randint(int(sp.test.n[t])))
        return ScoreRequest(task=t, x=np.asarray(sp.test.x[t, j]))

    served = {}
    swapped = False
    submitted = 0
    while submitted < n_req or sched.pending:
        # bursty arrivals: 1..12 requests land between tiles
        for _ in range(int(rng.randint(1, 13))):
            if submitted < n_req:
                sched.submit(make_request(), deadline_s=1.0)
                submitted += 1
        for r in sched.step():
            served[r.snapshot_version] = served.get(r.snapshot_version, 0) + 1
        if not swapped and submitted >= n_req // 2:
            print("  mid-stream partial_fit -> hot-swap...")
            est.partial_fit(sp.train)  # pushes the new snapshot, no drain
            swapped = True
            print(f"  now serving model v{sched.version}")

    s = sched.metrics.summary()
    lat = s["latency"]
    print(f"served {s['completed']} requests on versions "
          f"{{{', '.join(f'v{v}: {n}' for v, n in sorted(served.items()))}}}")
    print("  p50/p95/p99 latency: "
          f"{lat['p50_s'] * 1e3:.2f} / {lat['p95_s'] * 1e3:.2f} / "
          f"{lat['p99_s'] * 1e3:.2f} ms")
    print(f"  throughput: {s['throughput_rps']:.0f} req/s   "
          f"tile fill: {s['tile_fill']:.2f}   "
          f"queue depth max: {s['queue_depth_max']}")
    print(f"  SLO violations: {s['slo_violations']} "
          f"(expired: {s['expired']})   hot-swaps: {s['swaps']}")


if __name__ == "__main__":
    main()
