"""Continuous-batching MTL serving demo: request stream + live hot-swap.

Install the package once (``pip install -e .``) or export
``PYTHONPATH=src``, then:

    python examples/serve_stream.py [--tiny]
    python examples/serve_stream.py --tiny --interleave

Default mode fits a small DMTRL estimator, stands up the
continuous-batching scheduler (``est.serving_scheduler``), and serves a
bursty stream of per-task scoring requests with a latency SLO. Halfway
through the stream the estimator keeps training (``partial_fit``) — the
new ``(W, Sigma)`` snapshot hot-swaps into the scheduler between tiles,
without draining the queue, and the demo shows requests served on each
model version plus the final p50/p95/p99 / throughput / SLO metrics.

``--interleave`` runs the LM decode-step continuous-batching demo
instead: an AOT-warmed slot-table engine serves short generations
INTERLEAVED with long ones — shorts are injected into the running batch
at decode-step boundaries and finish while the longs keep decoding, so
time-to-first-token and short-request latency stay decoupled from the
longest in-flight generation (per-step slot occupancy shows the batch
staying busy as slots recycle).
"""
import argparse

import numpy as np


def run_interleave(args):
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve import (
        ContinuousBatchingScheduler,
        Request,
        ServeConfig,
        ServingEngine,
        VirtualClock,
    )

    batch, longs, shorts = (3, 1, 6) if args.tiny else (4, 2, 12)
    long_toks, short_toks = (12, 2) if args.tiny else (48, 4)
    cfg = get_config("qwen1_5-4b").reduced()
    print(f"initialising reduced {cfg.name} for the decode demo...")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(
        cfg, params, ServeConfig(batch=batch, max_len=128, bucket_min=8)
    )
    buckets = eng.warmup([8, 16])
    print(f"  AOT warmup done: prefill buckets {buckets}, decode + insert")

    clock = VirtualClock()
    sched = ContinuousBatchingScheduler(eng, policy="fifo", clock=clock)
    rng = np.random.RandomState(1)

    def req(n_new):
        prompt = rng.randint(2, cfg.vocab_size, size=rng.randint(2, 8))
        return Request(prompt=prompt.astype(np.int32), max_new_tokens=n_new)

    reqs = [req(long_toks) for _ in range(longs)]
    reqs += [req(short_toks) for _ in range(shorts)]
    sched.submit_many(reqs)
    while sched.pending or sched.in_flight:
        clock.advance(1e-3)  # 1 virtual ms per decode step
        done = sched.step()
        for r in done:
            kind = "long " if r.max_new_tokens == long_toks else "short"
            print(f"  [{clock():6.3f}s] {kind} done: {len(r.output)} tokens, "
                  f"ttft {r.ttft_s * 1e3:.0f}ms, latency {r.latency_s * 1e3:.0f}ms")
    s = sched.metrics.summary()
    short_lat = sorted(
        r.latency_s for r in reqs if r.max_new_tokens == short_toks
    )
    long_max = max(r.latency_s for r in reqs if r.max_new_tokens == long_toks)
    print(f"served {s['completed']} requests in {s['decode_steps']} decode steps, "
          f"slot occupancy {s['slot_occupancy']:.2f}")
    print(f"  ttft p50/p99: {s['ttft']['p50_s'] * 1e3:.0f} / "
          f"{s['ttft']['p99_s'] * 1e3:.0f} ms")
    print(f"  short-request max latency {short_lat[-1] * 1e3:.0f}ms vs longest "
          f"generation {long_max * 1e3:.0f}ms — shorts do not wait for longs")
    assert short_lat[-1] < long_max, "head-of-line blocking resurfaced"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="CI-sized shapes")
    ap.add_argument("--interleave", action="store_true",
                    help="LM decode-step continuous-batching demo")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--slo-ms", type=float, default=50.0)
    args = ap.parse_args()

    if args.interleave:
        run_interleave(args)
        return

    from repro.core import DMTRLEstimator
    from repro.data.synthetic import synthetic
    from repro.serve import ScoreRequest

    m, d = (6, 24) if args.tiny else (16, 100)
    n_req = args.requests or (48 if args.tiny else 400)
    sp = synthetic(1, m=m, d=d, n_train_avg=60 if args.tiny else 200,
                   n_test_avg=40, seed=0)
    print(f"fitting DMTRL ({m} tasks) for the serving demo...")
    est = DMTRLEstimator(
        loss="hinge", lam=1e-4, outer_iters=2, rounds=4, local_iters=64,
        block_size=32, seed=0,
    ).fit(sp.train)
    print(f"  test accuracy: {est.score(sp.test):.3f}")

    sched = est.serving_scheduler(batch=8, slo_s=args.slo_ms / 1e3)
    print(f"scheduler up: batch=8, policy=edf, slo={args.slo_ms:.0f}ms, "
          f"model v{sched.version}")

    rng = np.random.RandomState(1)

    def make_request():
        t = int(rng.randint(m))
        j = int(rng.randint(int(sp.test.n[t])))
        return ScoreRequest(task=t, x=np.asarray(sp.test.x[t, j]))

    served = {}
    swapped = False
    submitted = 0
    while submitted < n_req or sched.pending:
        # bursty arrivals: 1..12 requests land between tiles
        for _ in range(int(rng.randint(1, 13))):
            if submitted < n_req:
                sched.submit(make_request(), deadline_s=1.0)
                submitted += 1
        for r in sched.step():
            served[r.snapshot_version] = served.get(r.snapshot_version, 0) + 1
        if not swapped and submitted >= n_req // 2:
            print("  mid-stream partial_fit -> hot-swap...")
            est.partial_fit(sp.train)  # pushes the new snapshot, no drain
            swapped = True
            print(f"  now serving model v{sched.version}")

    s = sched.metrics.summary()
    lat = s["latency"]
    print(f"served {s['completed']} requests on versions "
          f"{{{', '.join(f'v{v}: {n}' for v, n in sorted(served.items()))}}}")
    print("  p50/p95/p99 latency: "
          f"{lat['p50_s'] * 1e3:.2f} / {lat['p95_s'] * 1e3:.2f} / "
          f"{lat['p99_s'] * 1e3:.2f} ms")
    print(f"  throughput: {s['throughput_rps']:.0f} req/s   "
          f"tile fill: {s['tile_fill']:.2f}   "
          f"queue depth max: {s['queue_depth_max']}")
    print(f"  SLO violations: {s['slo_violations']} "
          f"(expired: {s['expired']})   hot-swaps: {s['swaps']}")


if __name__ == "__main__":
    main()
