"""End-to-end driver: pretrain a small LM backbone, then fit DMTRL
multi-task heads on its features — the full backbone <-> paper-technique
bridge.

Install the package once (``pip install -e .``) or export
``PYTHONPATH=src``, then:

    python examples/train_lm_mtl.py --steps 200 --arch gemma3-1b

(reduced config on CPU; on a pod the same script scales via --no-reduced +
repro.launch.train's sharded path.)
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import DMTRLConfig
from repro.core import dual as dm
from repro.data.tokens import SyntheticTokenPipeline, TokenPipelineConfig
from repro.train import AdamW, TrainLogger, train
from repro.train.mtl_head import build_mtl_data_from_backbone, fit_mtl_heads


def make_task_datasets(cfg, m_tasks=6, n_per_task=48, seq=32, seed=0):
    """Per-'tenant' token classification tasks: each task prefers a distinct
    token-id band; labels = whether the sequence leans into that band."""
    rng = np.random.RandomState(seed)
    tokens, labels = [], []
    V = cfg.vocab_size
    for t in range(m_tasks):
        lo = (t * V) // m_tasks
        hi = ((t + 1) * V) // m_tasks
        toks = np.zeros((n_per_task, seq), np.int32)
        y = np.zeros((n_per_task,), np.float32)
        for i in range(n_per_task):
            pos = rng.rand() < 0.5
            if pos:
                toks[i] = rng.randint(lo, hi, size=seq)
            else:
                toks[i] = rng.randint(0, V, size=seq)
            y[i] = 1.0 if pos else -1.0
        tokens.append(toks), labels.append(y)
    return tokens, labels


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"1) pretraining backbone {cfg.name} for {args.steps} steps...")
    pipe = SyntheticTokenPipeline(
        TokenPipelineConfig(cfg.vocab_size, args.seq, args.batch, seed=0)
    )
    opt = AdamW(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    params, _, hist = train(
        cfg, opt, iter(pipe), steps=args.steps, logger=TrainLogger(every=25)
    )
    print(f"   loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    print("2) building per-task phi features from the backbone...")
    toks, labs = make_task_datasets(cfg)
    result = fit_mtl_heads(
        cfg,
        params,
        toks,
        labs,
        DMTRLConfig(loss="hinge", lam=1e-3, outer_iters=3, rounds=8,
                    local_iters=128, seed=0),
    )
    print(f"   phi dim = {result.features_dim}")

    print("3) evaluating the DMTRL heads on held-out task data...")
    toks_te, labs_te = make_task_datasets(cfg, seed=1)
    te = build_mtl_data_from_backbone(cfg, params, toks_te, labs_te)
    err = float(dm.error_rate(te, jnp.asarray(result.dmtrl.W)))
    print(f"   multi-task head test error: {err:.3f} (chance = 0.5)")
    print("   learned task covariance diag:",
          np.round(np.diag(np.asarray(result.dmtrl.sigma)), 3))


if __name__ == "__main__":
    main()
