"""jax version-compat shims.

The repo targets the newest jax API surface but must run on whatever jax
the container bakes in. Centralize every "this symbol moved between jax
releases" lookup here so call sites stay clean:

  * ``shard_map``: promoted from ``jax.experimental.shard_map.shard_map``
    to ``jax.shard_map`` around jax 0.4.35/0.5; the experimental module was
    later removed. Resolve whichever exists at import time.
  * ``shard_map_unchecked``: shard_map with replication checking disabled —
    required when the body contains a ``pallas_call`` (jax<=0.4 has no
    replication rule for it). The flag itself was renamed ``check_rep`` ->
    ``check_vma`` in newer jax, so the fallback chain lives here.

(``jax.make_mesh`` needs no shim: pyproject floors jax at 0.4.36, where it
already exists — verified on the 0.4.37 this container ships.)
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "shard_map_unchecked"]


def _resolve_shard_map():
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    try:  # jax <= 0.4.x: experimental home
        from jax.experimental.shard_map import shard_map as sm  # type: ignore
        return sm
    except ImportError as e:  # pragma: no cover - no known jax hits this
        raise ImportError(
            "neither jax.shard_map nor jax.experimental.shard_map.shard_map "
            f"is available on jax {jax.__version__}"
        ) from e


shard_map = _resolve_shard_map()


def shard_map_unchecked(f, mesh, in_specs, out_specs):
    """shard_map with the replication/varying-axes check disabled."""
    try:
        return shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )
    except TypeError:  # newer jax: the kwarg became check_vma
        return shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
