"""Architecture configs (one module per assigned architecture)."""
from .base import ARCH_IDS, ModelConfig, all_configs, get_config

__all__ = ["ARCH_IDS", "ModelConfig", "all_configs", "get_config"]
