"""Model/architecture configuration system.

One ``src/repro/configs/<arch>.py`` per assigned architecture defines a
``config()`` returning a ``ModelConfig`` with the exact published shape, and
the registry here exposes them by id for ``--arch``. ``reduced()`` produces
the CPU-smoke variant (<=2 layers, d_model<=512, <=4 experts) of the same
family, as required by the spec.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Tuple


def _round_up(x: int, k: int) -> int:
    return ((x + k - 1) // k) * k


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 => d_model // n_heads
    act: str = "swiglu"  # swiglu | squared_relu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    # attention pattern: local_ratio locals per 1 global; window for locals
    window: int = 0
    local_ratio: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_conv: int = 4
    ssm_groups: int = 1
    # hybrid (zamba2-style): shared attn block applied every k SSM layers
    hybrid_attn_every: int = 0
    # encoder-decoder (whisper-style)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500
    # modality frontend stub: none | audio_stub | vq_stub
    frontend: str = "none"
    # numerics / compilation
    dtype: str = "bfloat16"
    remat: bool = True
    attn_impl: str = "reference"  # reference | pallas
    # provenance
    source: str = ""

    # ---- derived ---------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.n_heads, 1))

    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_head_dim(self) -> int:
        return self.d_inner // max(self.ssm_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic 1-token decode memory: SSM/hybrid (O(1) state) and
        sliding-window archs (bounded local caches)."""
        return self.arch_type in ("ssm", "hybrid") or (
            self.window > 0 and self.local_ratio > 0
        )

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind: 'attn' | 'ssm' | 'moe' | 'local' | 'global'."""
        if self.arch_type == "ssm":
            return ("ssm",) * self.n_layers
        if self.arch_type == "hybrid":
            # handled structurally (periods of SSM + shared attn); report ssm
            return ("ssm",) * self.n_layers
        if self.arch_type == "moe":
            return ("moe",) * self.n_layers
        if self.local_ratio > 0:
            pat = ["local"] * self.local_ratio + ["global"]
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        return ("global",) * self.n_layers

    def param_count(self) -> int:
        """Total parameters N (for MODEL_FLOPS = 6*N*D roofline row)."""
        d, hd = self.d_model, self.head_dim
        emb = self.vocab_padded * d
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (
            self.n_heads * hd
        ) * d
        if self.arch_type == "moe":
            ff1 = self.n_experts * (3 * d * self.d_ff)
            ff1 += d * self.n_experts  # router
            ff1 += self.n_shared_experts * (3 * d * self.d_ff)
        elif self.act == "swiglu":
            ff1 = 3 * d * self.d_ff
        else:
            ff1 = 2 * d * self.d_ff
        ssm = 0
        if self.arch_type in ("ssm", "hybrid"):
            di, n, g, h = self.d_inner, self.ssm_state, self.ssm_groups, self.ssm_heads
            in_p = d * (2 * di + 2 * g * n + h)
            ssm = in_p + di * d + (di + 2 * g * n) * self.ssm_conv + 3 * h
        if self.arch_type == "ssm":
            per_layer = ssm
        elif self.arch_type == "hybrid":
            per_layer = ssm  # + shared attn counted once below
        else:
            per_layer = attn + ff1
        total = emb + self.n_layers * per_layer + d * self.vocab_padded
        if self.arch_type == "hybrid":
            total += attn + 3 * d * self.d_ff  # single shared block
        if self.is_encoder_decoder:
            # encoder layers + decoder cross-attn
            total += self.n_enc_layers * (attn + ff1) + self.n_layers * attn
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.arch_type != "moe":
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * self.n_experts * 3 * d * self.d_ff
        active_ff = self.n_layers * (self.top_k + self.n_shared_experts) * 3 * d * self.d_ff
        return int(dense + active_ff)

    def reduced(self) -> "ModelConfig":
        """CPU-smoke variant of the same family (spec: <=2 layers,
        d_model<=512, <=4 experts)."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        repl = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_head=min(self.head_dim, 64),
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 1024),
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            # lossless capacity at smoke scale: C >= T even if every token
            # routes to one expert => no drops => prefill/decode bit-consistent
            capacity_factor=float(min(self.n_experts, 4))
            / max(1, min(self.top_k, 2)),
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_heads=min(self.ssm_heads, 4) if self.ssm_heads else 0,
            ssm_chunk=16 if self.ssm_state else 0,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_frames=min(self.enc_frames, 64),
            window=min(self.window, 32) if self.window else 0,
            dtype="float32",
            remat=False,
        )
        if self.arch_type == "hybrid":
            repl["n_layers"] = 4  # 2 periods of (2 ssm + shared attn)
            repl["ssm_heads"] = 4
        if self.arch_type in ("ssm", "hybrid"):
            # keep d_inner divisible by heads
            repl["d_model"] = 128
            repl["d_ff"] = min(self.d_ff, 256)
        return dataclasses.replace(self, **repl)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
ARCH_IDS = (
    "nemotron-4-15b",
    "qwen1_5-32b",
    "zamba2-2_7b",
    "gemma3-1b",
    "mamba2-780m",
    "qwen3-moe-30b-a3b",
    "chameleon-34b",
    "kimi-k2-1t-a32b",
    "qwen1_5-4b",
    "whisper-tiny",
)

_ALIASES = {
    "qwen1.5-32b": "qwen1_5-32b",
    "qwen1.5-4b": "qwen1_5-4b",
    "zamba2-2.7b": "zamba2-2_7b",
}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
