"""Chameleon-34B [arXiv:2405.09818] — early-fusion VLM: text + VQ image
tokens share one vocab (65536); decoder-only with qk-norm. The VQ-VAE image
tokenizer is a stub (spec carve-out): image patches arrive as token ids."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b",
        arch_type="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=22016,
        vocab_size=65536,
        act="swiglu",
        qk_norm=True,  # Chameleon's QK-norm stability fix
        frontend="vq_stub",
        rope_theta=10_000.0,
        source="arXiv:2405.09818",
    )
