"""Gemma3-1B [hf:google/gemma-3-1b-pt] — dense, 5 local (sliding-window 512)
per 1 global layer, 128k-class context, GQA 4H/1KV, head_dim 256."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        arch_type="dense",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        d_head=256,
        d_ff=6912,
        vocab_size=262144,
        act="gelu",
        qk_norm=True,
        window=512,
        local_ratio=5,  # 5 local : 1 global
        rope_theta=1_000_000.0,
        source="hf:google/gemma-3-1b-pt",
    )
