"""Kimi K2 (1T total / 32B active) [arXiv:2501.kimi2, paper-table shapes] —
trillion-parameter MoE: 384 experts top-8, per-expert FFN 2048, 61 layers,
GQA 64H/8KV per the assignment table."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        arch_type="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=2048,  # per-expert intermediate size
        vocab_size=163840,
        act="swiglu",
        n_experts=384,
        top_k=8,
        n_shared_experts=1,
        rope_theta=50_000.0,
        source="arXiv:2501.kimi2",
    )
