"""Mamba2-780M [arXiv:2405.21060] — pure SSM (SSD / state-space duality),
attention-free, 48 layers, d_model 1536, state 128."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        arch_type="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=0,  # attention-free
        n_kv_heads=0,
        d_head=0,
        d_ff=0,  # no MLP; the Mamba2 block is the whole layer
        vocab_size=50280,
        ssm_state=128,
        ssm_heads=48,  # d_inner = 3072, P = 64
        ssm_expand=2,
        ssm_chunk=64,
        ssm_conv=4,
        source="arXiv:2405.21060",
    )
