"""Nemotron-4 15B [arXiv:2402.16819] — dense, GQA (48H/8KV), squared-ReLU MLP."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b",
        arch_type="dense",
        n_layers=32,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_head=128,
        d_ff=24576,
        vocab_size=256000,
        act="squared_relu",  # Nemotron-4 uses squared ReLU, ungated
        qkv_bias=False,
        rope_theta=10_000.0,
        source="arXiv:2402.16819",
    )
