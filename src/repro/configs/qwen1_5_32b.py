"""Qwen1.5-32B [hf:Qwen/Qwen1.5-0.5B card family] — dense, QKV bias, SwiGLU."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        arch_type="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=40,  # per assignment: GQA kv=40 (i.e. MHA)
        d_head=128,
        d_ff=27392,
        vocab_size=152064,
        act="swiglu",
        qkv_bias=True,  # Qwen1.5 attention uses QKV bias
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen1.5-0.5B",
    )
