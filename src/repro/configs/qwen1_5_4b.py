"""Qwen1.5-4B [hf:Qwen/Qwen1.5-0.5B card family] — dense, QKV bias, SwiGLU."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        arch_type="dense",
        n_layers=40,
        d_model=2560,
        n_heads=20,
        n_kv_heads=20,
        d_head=128,
        d_ff=6912,
        vocab_size=151936,
        act="swiglu",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen1.5-0.5B",
    )
