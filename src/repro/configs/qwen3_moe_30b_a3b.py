"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — MoE, 128 experts top-8,
per-expert FFN 768, GQA 32H/4KV, qk-norm."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        arch_type="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_head=128,
        d_ff=768,  # per-expert intermediate size
        vocab_size=151936,
        act="swiglu",
        qk_norm=True,
        n_experts=128,
        top_k=8,
        n_shared_experts=0,
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen3-30B-A3B",
    )
