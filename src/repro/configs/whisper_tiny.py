"""Whisper-tiny [arXiv:2212.04356] — encoder-decoder audio transformer.
The mel-spectrogram + conv feature extractor is a stub (spec carve-out):
``input_specs()`` supplies precomputed 1500-frame embeddings."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        arch_type="audio",
        n_layers=4,  # decoder layers
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_head=64,
        d_ff=1536,
        vocab_size=51865,
        act="gelu",
        is_encoder_decoder=True,
        n_enc_layers=4,
        enc_frames=1500,
        frontend="audio_stub",
        rope_theta=0.0,  # learned absolute positions, no RoPE
        source="arXiv:2212.04356",
    )
