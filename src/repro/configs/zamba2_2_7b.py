"""Zamba2-2.7B [arXiv:2411.15242] — hybrid: Mamba2 backbone + shared
attention blocks applied periodically (weights shared across applications)."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        arch_type="hybrid",
        n_layers=54,  # 54 Mamba2 layers; shared attn block every 6
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_head=80,
        d_ff=10240,
        vocab_size=32000,
        act="gelu",
        ssm_state=64,
        ssm_heads=80,  # d_inner = 2*2560 = 5120, head dim 64
        ssm_expand=2,
        ssm_chunk=64,
        hybrid_attn_every=6,
        rope_theta=10_000.0,
        source="arXiv:2411.15242",
    )
