"""DMTRL core: the paper's contribution as composable JAX modules.

The supported training surface is the engine-agnostic facade:

    from repro.core import DMTRLEstimator
    est = DMTRLEstimator(engine="distributed", mesh=mesh, loss="hinge")
    est.fit(train).score(test)

``fit`` / ``fit_distributed`` / ``fit_async`` remain importable as thin
deprecated wrappers over the same engine implementations.
"""
import functools as _functools
import warnings as _warnings

from .dmtrl import (
    DMTRLConfig,
    DMTRLResult,
    WarmStart,
    w_step,
    make_w_step_round,
)
from .dmtrl import fit as _fit_impl
from .distributed import (
    DistributedOptions,
    MeshAxes,
    make_distributed_round,
    make_local_solve,
    server_reduce,
)
from .distributed import fit_distributed as _fit_distributed_impl
from .async_dmtrl import AsyncOptions, make_async_tick
from .async_dmtrl import fit_async as _fit_async_impl
from .transport import (
    CommitReceipt,
    Snapshot,
    Transport,
    TransportSpec,
    available_transports,
    get_transport,
    register_transport,
)
from .gossip import (
    GossipTransport,
    build_adjacency,
    mixing_matrix,
    spectral_gap,
)
from .wire import (
    Codec,
    Encoded,
    ErrorFeedback,
    TransportProtocolError,
    available_codecs,
    get_codec,
)
from .engines import (
    Engine,
    EngineResult,
    available_engines,
    get_engine,
    register_engine,
)
from .estimator import DMTRLEstimator, NotFittedError
from .losses import Loss, get_loss, registered_losses
from .mtl_data import MTLData, from_task_list, normalize_rows
from .omega import (
    correlation_from_sigma,
    init_sigma,
    omega_step,
    omega_step_lowrank,
    rho_lemma10,
    rho_spectral,
)
from .sigma_view import (
    DenseSigma,
    LowRankDiagSigma,
    SigmaView,
    SparseSigma,
    as_view,
    maybe_dense,
    view_from_factors,
)
from .omega_regularizers import (
    OmegaRegularizer,
    available_regularizers,
    get_regularizer,
    register_regularizer,
)
from .solver_backends import (
    SolverBackend,
    available_backends,
    get_backend,
    register_backend,
)
from . import (
    baselines,
    convergence,
    dual,
    engines,
    estimator,
    feature_maps,
    omega_regularizers,
    sdca,
    sigma_view,
    solver_backends,
)
from . import transport  # noqa: F401 (registry module, part of the API)


def _deprecated(fn, replacement: str):
    @_functools.wraps(fn)
    def wrapper(*args, **kwargs):
        _warnings.warn(
            f"repro.core.{fn.__name__} is deprecated; use {replacement} "
            "(see docs/DESIGN.md §8 for the migration table)",
            DeprecationWarning,
            stacklevel=2,
        )
        return fn(*args, **kwargs)

    wrapper.__doc__ = (
        f"Deprecated: use {replacement}.\n\n{fn.__doc__ or ''}"
    )
    return wrapper


fit = _deprecated(_fit_impl, 'DMTRLEstimator(engine="reference").fit')
fit_distributed = _deprecated(
    _fit_distributed_impl, 'DMTRLEstimator(engine="distributed", mesh=...).fit'
)
fit_async = _deprecated(
    _fit_async_impl,
    'DMTRLEstimator(engine="async", mesh=..., '
    "async_options=AsyncOptions(...)).fit",
)

__all__ = [
    "DMTRLConfig",
    "DMTRLResult",
    "DMTRLEstimator",
    "NotFittedError",
    "WarmStart",
    "fit",
    "w_step",
    "make_w_step_round",
    "MeshAxes",
    "DistributedOptions",
    "AsyncOptions",
    "fit_distributed",
    "make_distributed_round",
    "make_local_solve",
    "server_reduce",
    "fit_async",
    "make_async_tick",
    "Transport",
    "TransportSpec",
    "CommitReceipt",
    "Snapshot",
    "available_transports",
    "get_transport",
    "register_transport",
    "GossipTransport",
    "build_adjacency",
    "mixing_matrix",
    "spectral_gap",
    "Codec",
    "Encoded",
    "ErrorFeedback",
    "TransportProtocolError",
    "available_codecs",
    "get_codec",
    "Engine",
    "EngineResult",
    "available_engines",
    "get_engine",
    "register_engine",
    "OmegaRegularizer",
    "available_regularizers",
    "get_regularizer",
    "register_regularizer",
    "Loss",
    "get_loss",
    "registered_losses",
    "MTLData",
    "from_task_list",
    "normalize_rows",
    "correlation_from_sigma",
    "init_sigma",
    "omega_step",
    "omega_step_lowrank",
    "rho_lemma10",
    "rho_spectral",
    "SigmaView",
    "DenseSigma",
    "LowRankDiagSigma",
    "SparseSigma",
    "as_view",
    "maybe_dense",
    "view_from_factors",
    "SolverBackend",
    "available_backends",
    "get_backend",
    "register_backend",
    "baselines",
    "convergence",
    "dual",
    "engines",
    "estimator",
    "feature_maps",
    "omega_regularizers",
    "sdca",
    "sigma_view",
    "solver_backends",
    "transport",
    "gossip",
    "wire",
]
