"""DMTRL core: the paper's contribution as composable JAX modules."""
from .dmtrl import DMTRLConfig, DMTRLResult, fit, w_step, make_w_step_round
from .distributed import (
    MeshAxes,
    fit_distributed,
    make_distributed_round,
    make_local_solve,
    server_reduce,
)
from .async_dmtrl import fit_async, make_async_tick
from .losses import Loss, get_loss, registered_losses
from .mtl_data import MTLData, from_task_list, normalize_rows
from .omega import (
    correlation_from_sigma,
    init_sigma,
    omega_step,
    rho_lemma10,
    rho_spectral,
)
from .solver_backends import (
    SolverBackend,
    available_backends,
    get_backend,
    register_backend,
)
from . import baselines, convergence, dual, feature_maps, sdca, solver_backends

__all__ = [
    "DMTRLConfig",
    "DMTRLResult",
    "fit",
    "w_step",
    "make_w_step_round",
    "MeshAxes",
    "fit_distributed",
    "make_distributed_round",
    "make_local_solve",
    "server_reduce",
    "fit_async",
    "make_async_tick",
    "Loss",
    "get_loss",
    "registered_losses",
    "MTLData",
    "from_task_list",
    "normalize_rows",
    "correlation_from_sigma",
    "init_sigma",
    "omega_step",
    "rho_lemma10",
    "rho_spectral",
    "SolverBackend",
    "available_backends",
    "get_backend",
    "register_backend",
    "baselines",
    "convergence",
    "dual",
    "feature_maps",
    "sdca",
    "solver_backends",
]
