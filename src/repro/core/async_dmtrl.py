"""Asynchronous bounded-staleness DMTRL engine.

Architecture (sync vs async rounds)
-----------------------------------
The paper's Algorithm 1 is bulk-synchronous: every communication round
barriers on ``all_gather(delta_b)`` before the server reduce, so one
straggler worker stalls all m tasks. Baytas et al. (arXiv:1609.09563) and
Wang et al. (arXiv:1802.03830) show the same primal-dual MTL structure
tolerates *bounded staleness* in the worker->server updates. This module
implements that regime on top of the factored round pieces in
``distributed.py``:

  * ``make_local_solve`` — the worker half (snapshot read + local SDCA),
    parameterized by the ``W_read``/``sigma_read`` snapshot it solves
    against; shared verbatim with the synchronous path.
  * ``server_reduce``   — the server half (all_gather + Sigma-coupled
    reduce), fed a *masked* delta_b so only arrived contributions apply.

Asynchrony is simulated on a deterministic per-worker clock so runs are
bit-reproducible: worker g (one ``data``-axis group) takes
``cfg.async_delays[g]`` simulated ticks per local solve. The host event
loop is stale-synchronous-parallel (SSP):

  * A worker may START its round r only if ``r <= min_completed + tau``
    (``tau = cfg.tau``); at ``tau=0`` this degenerates to the bulk-
    synchronous barrier.
  * On start it snapshots ``(W, Sigma)`` rows for its tasks; the solve it
    commits later is computed against exactly that snapshot.
  * On FINISH the server applies its delta_b immediately (together with
    any other worker finishing the same tick) as one masked reduce — no
    barrier on the other workers.

Staleness semantics
-------------------
A contribution's *staleness* is the number of server commit events between
its snapshot and its application; its *lag* is how many rounds ahead of the
slowest worker it ran. Both are recorded per commit in the returned history
(``w_worker / w_round / w_staleness / w_lag / w_tick``) and summarized by
``convergence.staleness_summary`` / ``convergence.effective_gap_curve``.
At ``tau=0`` lag is always 0; staleness is also 0 when delays are
homogeneous, but with stragglers a fast worker's commit can land between a
slow worker's snapshot and its apply, so per-commit staleness up to G-1 is
expected even at ``tau=0`` (round starts are still barriered).

``cfg.tau = "auto"`` turns the static bound into a small online controller
(ROADMAP "adaptive staleness"): starting bulk-synchronous, every G commits
``_adapt_tau`` widens the gate when it actually refused a start event and
narrows it when ``convergence.staleness_summary`` over the window shows the
slack went unused (max lag strictly under the bound), clamped to
``[0, cfg.tau_max]``. The bound in effect at every commit is recorded in
``history["tau_trace"]``.

Simulation cost: every commit event executes one full SPMD round (all G
shards solve, inactive results masked out). Caching per-worker solves at
their start events would not reduce this — under shard_map every shard
runs the program on every call and start events are about as frequent as
commits — so the simulated clock, not host wall-clock, is the quantity
this engine is built to measure.

The Omega-step overlaps with in-flight W-rounds instead of barriering:
with ``cfg.omega_delay = k > 0`` the Sigma/Omega computed at a W-step
boundary is *installed* only after k server commits of the next W-step;
rounds started inside that window read the stale Sigma through their
snapshot. rho is still computed from the new Sigma at the boundary (it is
a scalar safety bound, not part of the worker snapshot). At
``omega_delay=0`` installation happens at the boundary, exactly like the
synchronous path.

Parity anchor: at ``tau=0`` with homogeneous delays this engine calls the
same jitted computation as ``fit_distributed`` with an all-ones mask and a
fresh snapshot every tick, and therefore reproduces its ``(alpha, W)``
iterates bit-exactly (tested on 1- and 8-device meshes). That parity is
the correctness anchor for the whole sync/async refactor.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import convergence as conv_mod
from . import dual as dual_mod
from . import omega_regularizers as omega_reg
from .distributed import (
    MeshAxes,
    _axis_size,
    init_state,
    install_initial_state,
    make_local_solve,
    pad_sigma_blocks,
    round_in_specs,
    round_out_specs,
    round_shard_map,
    server_reduce,
    shard_mtl_data,
)
from .dmtrl import DMTRLConfig, WarmStart, _rho_value, validate_async_fields
from .losses import get_loss
from .mtl_data import MTLData

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AsyncOptions:
    """Staleness knobs of the async engine, split out of the legacy
    kitchen-sink config (the new home of ``DMTRLConfig.tau`` & friends).

    Validation is eager: ``AsyncOptions(tau="fast")`` raises at
    construction with a clear message, not mid-fit.
    """

    tau: Union[int, str] = 0  # SSP staleness bound; "auto" adapts online
    tau_max: int = 8  # clamp for the tau="auto" controller
    async_delays: Optional[Tuple[int, ...]] = None  # simulated per-worker
    #               solve ticks; None == homogeneous workers
    omega_delay: int = 0  # server commits the Sigma install may lag behind

    def __post_init__(self):
        validate_async_fields(
            self.tau, self.tau_max, self.async_delays, self.omega_delay
        )

    def merge_into(self, cfg: DMTRLConfig) -> DMTRLConfig:
        return dataclasses.replace(
            cfg,
            tau=self.tau,
            tau_max=self.tau_max,
            async_delays=self.async_delays,
            omega_delay=self.omega_delay,
        )


def make_async_tick(
    cfg: DMTRLConfig,
    mesh: Mesh,
    axes: MeshAxes,
    m: int,
    n_max: int,
    d: int,
    rho: float,
):
    """Build the jitted one-tick function of the async engine.

    tick(x, y, mask, n, alpha, W, sigma, W_snap, sigma_snap, keys, active)
        -> (alpha, W)

    ``W_snap``/``sigma_snap`` hold each worker group's bounded-staleness
    snapshot rows; ``keys`` is one PRNG key per worker (for the round that
    worker is currently solving); ``active`` masks which workers' results
    commit this tick. Workers solve against their snapshot; the server
    reduce uses the live sigma and only the active contributions.
    """
    local_solve = make_local_solve(cfg, mesh, axes, m, n_max, d, rho)
    in_specs = round_in_specs(axes) + (
        P(axes.data, axes.model),  # W_snap
        P(axes.data, None),  # sigma_snap rows
        P(axes.data, None),  # keys (workers, 2)
        P(axes.data),  # active (workers,)
    )
    out_specs = round_out_specs(axes)

    def tick_body(
        x, y, mask, n, alpha, W, sigma_rows, W_snap, sigma_snap, keys, active
    ):
        key = keys[0]
        a = active[0]
        dalpha, db = local_solve(x, y, n, alpha, W_snap, sigma_snap, key)
        dW = server_reduce(cfg, axes, sigma_rows, db * a)
        return alpha + cfg.eta * (dalpha * a), W + dW

    shmapped = round_shard_map(cfg, axes, tick_body, mesh, in_specs, out_specs)
    return jax.jit(shmapped)


@jax.jit
def _refresh_rows(dst, src, rowmask):
    """Refresh snapshot rows of (re)starting workers: rowmask is (m,) bool."""
    return jnp.where(rowmask[:, None], src, dst)


def _adapt_tau(
    tau: int, gate_blocks: int, window_summary: dict, tau_max: int
) -> int:
    """One step of the tau="auto" controller.

    Widen when the SSP gate actually blocked a worker during the window
    (``gate_blocks`` refusal episodes: a worker entering the blocked state
    counts once, not once per tick it stays blocked); narrow when nothing was
    blocked AND the observed per-commit lag (``staleness_summary``'s
    ``max_lag`` over the window) stayed strictly under the current bound,
    i.e. the slack went unused. Clamped to [0, tau_max].
    """
    if gate_blocks > 0:
        return min(tau + 1, tau_max)
    if window_summary["max_lag"] < tau:
        return max(tau - 1, 0)
    return tau


def _worker_delays(cfg: DMTRLConfig, n_workers: int) -> tuple:
    delays = (
        (1,) * n_workers if cfg.async_delays is None else cfg.async_delays
    )
    delays = tuple(int(v) for v in delays)
    if len(delays) != n_workers:
        raise ValueError(
            f"async_delays has {len(delays)} entries for {n_workers} workers"
        )
    if min(delays) < 1:
        raise ValueError(f"async_delays must be >= 1, got {delays}")
    return delays


def fit_async(
    cfg: DMTRLConfig,
    raw: MTLData,
    mesh: Mesh,
    axes: Optional[MeshAxes] = None,
    track: bool = True,
    *,
    options: Optional[AsyncOptions] = None,
    init: Optional[WarmStart] = None,
    regularizer=None,
):
    """Algorithm 1 under the bounded-staleness execution model.

    Same signature/returns as ``fit_distributed``: (W, sigma, state, hist).
    The history additionally carries per-commit staleness events and the
    simulated-clock tick of every objective sample.

    ``options`` (AsyncOptions) overrides the legacy staleness fields of the
    config; ``init`` warm-starts from raw-shaped (alpha, sigma, omega);
    ``regularizer`` overrides the Omega family member.
    """
    if axes is None:
        axes = MeshAxes()
    if options is not None:
        cfg = options.merge_into(cfg)
    # cfg may predate the eager __post_init__ validation (e.g. built via
    # dataclasses.replace on old pickles); keep the fit-time check too.
    validate_async_fields(cfg.tau, cfg.tau_max, cfg.async_delays, cfg.omega_delay)
    tau_auto = cfg.tau == "auto"
    reg = omega_reg.resolve_regularizer(cfg, regularizer)
    loss = get_loss(cfg.loss)
    data, m, d = shard_mtl_data(raw, mesh, axes)
    state = init_state(data, mesh, axes, m, d)
    key = jax.random.PRNGKey(cfg.seed)

    G = _axis_size(mesh, axes.data)
    m_loc = m // G
    delays = _worker_delays(cfg, G)
    n_pods = _axis_size(mesh, axes.pod)
    R = cfg.rounds
    sr = NamedSharding(mesh, P(axes.data, None))

    hist = {
        "round": [],  # server commit index (time-ordered, matches gap)
        "tick": [],  # simulated-clock time of each commit
        "dual": [],
        "primal": [],
        "gap": [],
        "min_round": [],  # slowest worker's completed rounds at each commit
        "w_worker": [],  # one entry per applied contribution:
        "w_round": [],  # which worker / its round index
        "w_staleness": [],  # commits between its snapshot and its apply
        "w_lag": [],  # rounds ahead of the slowest worker at start
        "w_tick": [],
        "tau_trace": [],  # SSP bound in effect at each commit (constant
        #                   unless cfg.tau == "auto")
    }

    @jax.jit
    def objectives(alpha, sigma):
        dd = dual_mod.dual_objective(data, alpha, sigma, cfg.lam, loss)
        pp = dual_mod.primal_objective_from_alpha(data, alpha, sigma, cfg.lam, loss)
        return dd, pp

    @jax.jit
    def w_from_alpha(alpha, sigma):
        return dual_mod.weights_from_alpha(data, alpha, sigma, cfg.lam)

    def install_sigma(sig, om):
        st = dataclasses.replace(
            state,
            sigma=jax.device_put(sig, sr),
            omega=jax.device_put(om, sr),
        )
        return dataclasses.replace(st, W=w_from_alpha(st.alpha, st.sigma))

    def row_mask(workers):
        mask = np.zeros((m,), bool)
        for g in workers:
            mask[g * m_loc : (g + 1) * m_loc] = True
        return jnp.asarray(mask)

    state = install_initial_state(
        state, raw, data, m, cfg, mesh, axes, reg, init, w_from_alpha
    )

    # snapshots start in sync with the live state
    W_snap = state.W
    sigma_snap = state.sigma
    commits_total = 0
    clock = 0  # global simulated time, accumulated across W-steps
    pending_install = None  # (sigma, omega) awaiting overlap installation

    # tau="auto": start bulk-synchronous and adapt once per G-commit window
    tau = 0 if tau_auto else cfg.tau
    adapt_window = G
    gate_blocks = 0  # refusal EPISODES this window: a worker entering the
    #                  gate-blocked state counts once until it unblocks (or
    #                  the window rolls over), not once per simulation tick
    refused: set = set()  # workers currently blocked by the gate
    win_start = 0  # index into the w_* event lists where the window began

    for p in range(cfg.outer_iters):
        rho = _rho_value(cfg, state.sigma if pending_install is None
                         else pending_install[0],
                         n_blocks_scale=float(n_pods), reg=reg)
        tick_fn = make_async_tick(cfg, mesh, axes, m, data.n_max, d, rho)
        # same key schedule as fit_distributed => bit-equal coordinate draws
        key, outer_key = jax.random.split(key)
        round_keys = jax.random.split(outer_key, R)  # (R, 2)

        completed = [0] * G
        cur_round = [0] * G
        busy = [False] * G
        finish_at = [0] * G
        snap_commit = [0] * G
        snap_lag = [0] * G
        tick = 0
        commits_outer = 0

        while min(completed) < R:
            # --- overlapped Omega-step installation --------------------
            if pending_install is not None and commits_outer >= cfg.omega_delay:
                state = install_sigma(*pending_install)
                pending_install = None
            # --- starts: idle workers gated by the SSP staleness bound --
            floor = min(completed)
            newly = [
                g
                for g in range(G)
                if not busy[g] and completed[g] < R and completed[g] <= floor + tau
            ]
            blocked = {
                g
                for g in range(G)
                if not busy[g] and completed[g] < R and completed[g] > floor + tau
            }
            gate_blocks += len(blocked - refused)
            refused = blocked
            if newly:
                rm = row_mask(newly)
                W_snap = _refresh_rows(W_snap, state.W, rm)
                sigma_snap = _refresh_rows(sigma_snap, state.sigma, rm)
                for g in newly:
                    busy[g] = True
                    cur_round[g] = completed[g]
                    finish_at[g] = tick + delays[g]
                    snap_commit[g] = commits_total
                    snap_lag[g] = completed[g] - floor
            # --- advance the clock to the next finish event ------------
            tick = min(finish_at[g] for g in range(G) if busy[g])
            active = [g for g in range(G) if busy[g] and finish_at[g] == tick]
            keys_arr = round_keys[
                np.clip(np.asarray(cur_round, np.int32), 0, R - 1)
            ]  # (G, 2)
            active_arr = jnp.zeros((G,), data.x.dtype).at[
                jnp.asarray(active, jnp.int32)
            ].set(1.0)
            alpha, W = tick_fn(
                data.x,
                data.y,
                data.mask,
                data.n,
                state.alpha,
                state.W,
                state.sigma,
                W_snap,
                sigma_snap,
                keys_arr,
                active_arr,
            )
            state = dataclasses.replace(state, alpha=alpha, W=W)
            commits_total += 1
            commits_outer += 1
            for g in active:
                busy[g] = False
                hist["w_worker"].append(g)
                hist["w_round"].append(p * R + cur_round[g])
                hist["w_staleness"].append(commits_total - 1 - snap_commit[g])
                hist["w_lag"].append(snap_lag[g])
                hist["w_tick"].append(clock + tick)
                completed[g] += 1
            hist["tau_trace"].append(tau)
            if tau_auto and commits_total % adapt_window == 0:
                win = {
                    k: np.asarray(hist[k][win_start:])
                    for k in ("w_staleness", "w_lag", "w_worker")
                }
                tau = _adapt_tau(
                    tau, gate_blocks, conv_mod.staleness_summary(win), cfg.tau_max
                )
                gate_blocks = 0
                refused = set()  # a still-blocked worker re-counts next window
                win_start = len(hist["w_worker"])
            done = min(completed) >= R
            if track and (commits_total % cfg.track_every == 0 or done):
                dd, pp = objectives(state.alpha, state.sigma)
                hist["round"].append(commits_total)
                hist["tick"].append(clock + tick)
                hist["dual"].append(float(dd))
                hist["primal"].append(float(pp))
                hist["gap"].append(float(pp - dd))
                hist["min_round"].append(p * R + min(completed))

        clock += tick
        # --- W-step boundary: Omega-step (possibly overlapped) ---------
        if pending_install is not None:
            # the W-step produced fewer commits than omega_delay; a pending
            # Sigma must never be dropped — it lands at the barrier instead
            state = install_sigma(*pending_install)
            pending_install = None
        if reg.learns:
            sigma_t, omega_t = reg.step(
                state.W[: raw.m], cfg.omega_jitter
            )
            sig, om = pad_sigma_blocks(
                sigma_t, omega_t, m, raw.m, cfg.omega_jitter
            )
            if cfg.omega_delay == 0 or p == cfg.outer_iters - 1:
                state = install_sigma(sig, om)
            else:
                pending_install = (sig, om)

    hist_np = {k: np.asarray(v) for k, v in hist.items()}
    W = np.asarray(state.W)[: raw.m, : raw.d]
    sigma = np.asarray(state.sigma)[: raw.m, : raw.m]
    return W, sigma, state, hist_np
