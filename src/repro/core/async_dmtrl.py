"""Asynchronous bounded-staleness DMTRL engine — a thin protocol driver.

Architecture (post transport refactor)
--------------------------------------
The paper's Algorithm 1 is bulk-synchronous: every communication round
barriers on ``all_gather(delta_b)`` before the server reduce, so one
straggler worker stalls all m tasks. Baytas et al. (arXiv:1609.09563) and
Wang et al. (arXiv:1802.03830) show the same primal-dual MTL structure
tolerates *bounded staleness* in the worker->server updates. The portable
object is the PROTOCOL — snapshot -> local solve -> SSP-gated commit —
not the execution substrate, so this module is now only the outer
alternation:

    for p in outer_iters:
        rho  <- regularizer rho bound on the (possibly pending) Sigma
        transport.run_w_step(p, rho, outer_key)      # R protocol rounds
        Sigma, Omega <- regularizer.step(W)          # Omega-step
        transport.install_sigma(...)                 # maybe overlapped

over a pluggable ``core.transport`` member (``AsyncOptions.transport``):

  simulated     deterministic per-worker clock simulation, fused masked
                SPMD commits — bit-reproducible; the default and the
                bit-parity anchor (tau=0 == ``fit_distributed`` exactly).
  threaded      real in-host parameter server (G worker threads, lock-
                protected versioned state, nondeterministic arrivals).
  multiprocess  socket/pickle parameter server with per-worker processes.

Staleness semantics (all transports)
------------------------------------
A contribution's *staleness* is the number of server commit events between
its snapshot and its application; its *lag* is how many rounds ahead of the
slowest worker it ran. The SSP gate admits a worker to round r only while
``r <= min_completed + tau`` (``tau=0`` degenerates to the bulk-synchronous
barrier). Every applied contribution flows through one accounting path —
``transport.CommitReceipt -> record_receipt -> history`` — summarized by
``convergence.staleness_summary`` / ``convergence.effective_gap_curve``
(``w_worker / w_round / w_staleness / w_lag / w_tick`` + ``tau_trace`` /
``gate_refusals`` in the returned history).

``tau="auto"`` turns the static bound into a small online controller
(``transport._adapt_tau``): widen on gate-refusal episodes, narrow when the
observed lag never used the slack — and, when ``staleness_budget`` is set,
narrow whenever the windowed mean commit staleness exceeds the budget even
if the gate never refused (cost-aware mode). The bound in effect at every
commit is recorded in ``history["tau_trace"]``.

The Omega-step overlaps with in-flight W-rounds instead of barriering:
with ``omega_delay = k > 0`` the Sigma/Omega computed at a W-step boundary
is *installed* only after k server commits of the next W-step; rounds
started inside that window read the stale Sigma through their snapshot.
rho is still computed from the new Sigma at the boundary. A pending Sigma
is never dropped — it lands at the next barrier at the latest.

Parity anchors: at ``tau=0`` the ``simulated`` transport reproduces
``fit_distributed``'s ``(alpha, W)`` iterates bit-exactly (tested on 1- and
8-device meshes) and its integer event bookkeeping is pinned by golden
histories (``tests/golden/``); ``threaded``/``multiprocess`` match the
``reference`` engine at ``tau=0`` to numerical tolerance (commit order
within a barriered round is nondeterministic, so float association
differs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh

from . import omega_regularizers as omega_reg
from .distributed import MeshAxes
from .dmtrl import DMTRLConfig, WarmStart, _rho_value, validate_async_fields
from .mtl_data import MTLData
from .transport import (  # re-exported for backward compatibility
    _adapt_tau,
    _worker_delays,
    get_transport,
    make_async_tick,
)
from ..obs.metrics import publish_wire_stats
from ..obs.trace import span

Array = jax.Array

__all__ = [
    "AsyncOptions",
    "fit_async",
    "make_async_tick",
    "_adapt_tau",
    "_worker_delays",
]


@dataclasses.dataclass(frozen=True)
class AsyncOptions:
    """Staleness knobs of the async engine, split out of the legacy
    kitchen-sink config (the new home of ``DMTRLConfig.tau`` & friends).

    Validation is eager: ``AsyncOptions(tau="fast")`` raises at
    construction with a clear message, not mid-fit.

    Transport selection (``core.transport`` registry): ``transport`` names
    the execution substrate of the snapshot/commit protocol; ``n_workers``
    sets the worker count for the host transports (``threaded`` /
    ``multiprocess``), which otherwise fall back to the mesh data-axis
    size (``simulated`` always derives workers from the mesh).
    """

    tau: Union[int, str] = 0  # SSP staleness bound; "auto" adapts online
    tau_max: int = 8  # clamp for the tau="auto" controller
    async_delays: Optional[Tuple[int, ...]] = None  # simulated per-worker
    #               solve ticks; None == homogeneous workers (host
    #               transports turn them into sleep pacing)
    omega_delay: int = 0  # server commits the Sigma install may lag behind
    transport: str = "simulated"  # core.transport member name
    n_workers: Optional[int] = None  # host-transport worker count
    staleness_budget: Optional[float] = None  # tau="auto" cost target:
    #               narrow when windowed mean commit staleness exceeds it
    topology: Union[str, tuple] = "complete"  # gossip neighbor graph
    #               ("ring" | "torus" | "complete" | explicit adjacency)
    codec: str = "none"  # wire codec for the (delta_w, Sigma) messages
    #               ("none" | "bf16" | "int8"; core.wire registry)

    def __post_init__(self):
        validate_async_fields(
            self.tau,
            self.tau_max,
            self.async_delays,
            self.omega_delay,
            transport=self.transport,
            n_workers=self.n_workers,
            staleness_budget=self.staleness_budget,
            topology=self.topology,
            codec=self.codec,
        )

    def merge_into(self, cfg: DMTRLConfig) -> DMTRLConfig:
        return dataclasses.replace(
            cfg,
            tau=self.tau,
            tau_max=self.tau_max,
            async_delays=self.async_delays,
            omega_delay=self.omega_delay,
            transport=self.transport,
            n_workers=self.n_workers,
            staleness_budget=self.staleness_budget,
            topology=self.topology,
            codec=self.codec,
        )


def fit_async(
    cfg: DMTRLConfig,
    raw: MTLData,
    mesh: Optional[Mesh] = None,
    axes: Optional[MeshAxes] = None,
    track: bool = True,
    *,
    options: Optional[AsyncOptions] = None,
    init: Optional[WarmStart] = None,
    regularizer=None,
):
    """Algorithm 1 under the bounded-staleness execution model.

    Same signature/returns as ``fit_distributed``: (W, sigma, state, hist).
    The history additionally carries per-commit staleness events and the
    transport clock of every objective sample.

    ``options`` (AsyncOptions) overrides the legacy staleness fields of the
    config — including ``transport=`` which picks the execution substrate;
    ``init`` warm-starts from raw-shaped (alpha, sigma, omega);
    ``regularizer`` overrides the Omega family member. ``mesh`` is required
    by the ``simulated`` transport and optional for the host transports
    (they only read its data-axis size when ``n_workers`` is unset).
    """
    if axes is None:
        axes = MeshAxes()
    if options is not None:
        cfg = options.merge_into(cfg)
    # cfg may predate the eager __post_init__ validation (e.g. built via
    # dataclasses.replace on old pickles); keep the fit-time check too.
    validate_async_fields(
        cfg.tau,
        cfg.tau_max,
        cfg.async_delays,
        cfg.omega_delay,
        transport=cfg.transport,
        n_workers=cfg.n_workers,
        staleness_budget=cfg.staleness_budget,
        topology=getattr(cfg, "topology", "complete"),
        codec=getattr(cfg, "codec", "none"),
    )
    reg = omega_reg.resolve_regularizer(cfg, regularizer, m=raw.m)
    # root span + sequential driver-phase spans: "setup" / per-outer
    # "w_step" / "omega_step" / "result" tile "fit_async" almost exactly,
    # which is what bench_obs's breakdown-sums-to-total check leans on
    with span("fit_async", cat="driver", transport=cfg.transport):
        with span("setup", cat="driver", transport=cfg.transport):
            spec = get_transport(cfg.transport)
            transport = spec.factory()
            transport.setup(
                cfg, raw, mesh=mesh, axes=axes, reg=reg, init=init, track=track
            )
        key = jax.random.PRNGKey(cfg.seed)
        # rho always sees the NEWEST Sigma, installed or pending (a pending
        # install is a worker-visibility delay, not a safety-bound delay)
        rho_sigma = transport.rho_sigma()
        try:
            for p in range(cfg.outer_iters):
                rho = _rho_value(
                    cfg, rho_sigma, n_blocks_scale=float(transport.n_pods), reg=reg
                )
                key, outer_key = jax.random.split(key)
                with span("w_step", cat="driver", outer=p):
                    transport.run_w_step(p, rho, outer_key)
                if reg.learns:
                    with span("omega_step", cat="driver", outer=p):
                        sigma_t, omega_t = reg.step(
                            transport.w_true(), cfg.omega_jitter
                        )
                        sig, om = transport.pad_sigma(sigma_t, omega_t)
                        # overlapped Omega-step: defer the install into the
                        # next W-step except at the end (the last Sigma must
                        # land now)
                        defer = cfg.omega_delay > 0 and p < cfg.outer_iters - 1
                        transport.install_sigma(sig, om, defer=defer)
                        rho_sigma = sig
            with span("result", cat="driver", transport=cfg.transport):
                out = transport.result()
                ws = getattr(transport, "wire_stats", None)
                if ws is not None:
                    publish_wire_stats(ws, transport=cfg.transport)
            return out
        finally:
            transport.close()
