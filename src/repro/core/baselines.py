"""Baselines from the paper's experiments section.

 * STL              -- each task an independent regularized ERM. Realized as
                       DMTRL with Sigma fixed at I/m and no Omega-step
                       (regularizer (lambda m/2)||w_i||^2, exactly the
                       paper's Omega = m I init held fixed).
 * Centralized MTRL -- Zhang & Yeung (2010) alternating optimization run on
                       one machine: full-batch accelerated gradient descent
                       on the primal W-step (+ closed-form Omega-step). The
                       paper's "gold standard".
 * SSDCA            -- single-machine SDCA over ALL dual coordinates with
                       exact (not block-approximated) global updates. The
                       paper's scalable single-machine solution.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import dual as dual_mod
from . import omega as omega_mod
from .dmtrl import DMTRLConfig, DMTRLResult, fit as dmtrl_fit
from .losses import get_loss
from .mtl_data import MTLData

Array = jax.Array


# ---------------------------------------------------------------------------
# STL
# ---------------------------------------------------------------------------
def fit_stl(cfg: DMTRLConfig, data: MTLData) -> DMTRLResult:
    stl_cfg = dataclasses.replace(cfg, learn_omega=False)
    return dmtrl_fit(stl_cfg, data)


# ---------------------------------------------------------------------------
# Centralized MTRL (primal FISTA W-step + closed-form Omega-step)
# ---------------------------------------------------------------------------
def _primal_grad(data: MTLData, W: Array, omega: Array, lam: float, loss):
    z = jnp.einsum("mnd,md->mn", data.x, W)
    g = loss.subgradient(z, data.y) * data.mask / data.n[:, None].astype(z.dtype)
    grad_emp = jnp.einsum("mn,mnd->md", g, data.x)
    grad_reg = lam * (omega @ W)
    return grad_emp + grad_reg


def fit_centralized_mtrl(
    cfg: DMTRLConfig,
    data: MTLData,
    inner_steps: int = 300,
    lr: float = 0.0,
) -> Tuple[Array, Array, Dict[str, np.ndarray]]:
    """Alternating primal optimization; smooth losses (use smoothed_hinge in
    place of hinge for the central baseline, as subgradient FISTA has no
    guarantee). Returns (W, sigma, history)."""
    loss = get_loss(cfg.loss)
    m, d = data.m, data.d
    W = jnp.zeros((m, d), data.x.dtype)
    sigma, omega = omega_mod.init_sigma(m, data.x.dtype)

    # Lipschitz estimate for the gradient: L <= max_i (q_max) + lam*||Omega||;
    # q_max = max row-norm^2 (features), conservative and cheap.
    qmax = float(jnp.max(jnp.sum(data.x**2, axis=-1)))

    hist = {"outer": [], "primal": []}
    for p in range(cfg.outer_iters):
        om_norm = float(jnp.linalg.norm(omega, 2))
        L = qmax + cfg.lam * om_norm
        step = lr if lr > 0 else 1.0 / max(L, 1e-12)

        @jax.jit
        def fista(W):
            def body(carry, _):
                Wk, Vk, tk = carry
                g = _primal_grad(data, Vk, omega, cfg.lam, loss)
                Wn = Vk - step * g
                tn = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * tk**2))
                Vn = Wn + ((tk - 1.0) / tn) * (Wn - Wk)
                return (Wn, Vn, tn), None

            (Wn, _, _), _ = jax.lax.scan(
                body, (W, W, jnp.float32(1.0)), None, length=inner_steps
            )
            return Wn

        W = fista(W)
        hist["outer"].append(p)
        hist["primal"].append(
            float(dual_mod.primal_objective(data, W, omega, cfg.lam, loss))
        )
        if cfg.learn_omega:
            sigma, omega = omega_mod.omega_step(W, cfg.omega_jitter)
    return W, sigma, {k: np.asarray(v) for k, v in hist.items()}


# ---------------------------------------------------------------------------
# Single-machine SDCA (exact global coordinate updates over all tasks)
# ---------------------------------------------------------------------------
def fit_ssdca(
    cfg: DMTRLConfig,
    data: MTLData,
    passes: int | None = None,
    track_every_pass: bool = True,
) -> Tuple[Array, Array, Dict[str, np.ndarray]]:
    """SDCA over all n = sum n_i coordinates with exact updates.

    For a sampled coordinate (i, j):
        c = w_i(alpha)^T x_j^i          (exact current margin)
        a = sigma_ii ||x_j||^2 / (lam n_i)
    and the same per-loss closed-form delta as Local SDCA. B (d, m) is
    maintained incrementally; w_i = (1/lam) B sigma[:, i].

    One "pass" = n_max coordinate updates per task (m * n_max total),
    comparable compute to one DMTRL round with H = n_max. Omega-steps happen
    every cfg.rounds passes to mirror Algorithm 1's schedule.
    """
    loss = get_loss(cfg.loss)
    m, n_max, d = data.m, data.n_max, data.d
    passes = passes if passes is not None else cfg.outer_iters * cfg.rounds
    alpha = jnp.zeros((m, n_max), data.x.dtype)
    B = jnp.zeros((d, m), data.x.dtype)
    sigma, omega = omega_mod.init_sigma(m, data.x.dtype)
    key = jax.random.PRNGKey(cfg.seed + 17)

    steps_per_pass = m * n_max

    def make_pass(sigma):
        @jax.jit
        def one_pass(alpha, B, key):
            ki, kj = jax.random.split(key)
            tis = jax.random.randint(ki, (steps_per_pass,), 0, m)
            us = jax.random.uniform(kj, (steps_per_pass,))

            def body(h, carry):
                alpha, B = carry
                i = tis[h]
                ni = data.n[i]
                j = jnp.minimum((us[h] * ni.astype(us.dtype)).astype(jnp.int32), ni - 1)
                xj = data.x[i, j]
                nif = ni.astype(xj.dtype)
                sii = sigma[i, i]
                w_i = (B @ sigma[:, i]) / cfg.lam
                c = jnp.dot(xj, w_i)
                a = sii * jnp.dot(xj, xj) / (cfg.lam * nif)
                atilde = alpha[i, j]
                delta = loss.sdca_delta(atilde, c, a, data.y[i, j])
                alpha = alpha.at[i, j].add(delta)
                B = B.at[:, i].add(delta * xj / nif)
                return alpha, B

            return jax.lax.fori_loop(0, steps_per_pass, body, (alpha, B))

        return one_pass

    hist = {"pass": [], "dual": [], "primal": [], "gap": []}
    one_pass = make_pass(sigma)
    for t in range(passes):
        key, sub = jax.random.split(key)
        alpha, B = one_pass(alpha, B, sub)
        if track_every_pass:
            dd = dual_mod.dual_objective(data, alpha, sigma, cfg.lam, loss)
            pp = dual_mod.primal_objective_from_alpha(data, alpha, sigma, cfg.lam, loss)
            hist["pass"].append(t + 1)
            hist["dual"].append(float(dd))
            hist["primal"].append(float(pp))
            hist["gap"].append(float(pp - dd))
        if cfg.learn_omega and (t + 1) % cfg.rounds == 0:
            W = (B @ sigma).T / cfg.lam
            sigma, omega = omega_mod.omega_step(W, cfg.omega_jitter)
            one_pass = make_pass(sigma)

    W = (B @ sigma).T / cfg.lam
    return W, sigma, {k: np.asarray(v) for k, v in hist.items()}
