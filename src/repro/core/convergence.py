"""Convergence-theory quantities (paper Section 6).

These are *measurable* implementations of the theorem quantities so the
benchmarks can check the theory against observed behaviour:

 * Theta (Assumption 1): observed local-subproblem approximation quality.
 * H bounds: Thm 4 (smooth) and Thm 5 (Lipschitz) lower bounds on local
   SDCA iterations for a target Theta.
 * T bounds: Thm 8 (smooth, linear rate) / Thm 9 (Lipschitz, O(1/T)).
 * rho_min estimation by power iteration on the generalized Rayleigh
   quotient of Eq. (5) (exact up to iteration tolerance, vs the Lemma 10
   closed-form upper bound).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import dual as dual_mod
from .losses import get_loss
from .mtl_data import MTLData

Array = jax.Array


def q_max(data: MTLData) -> float:
    """max_j ||phi(x_j)||^2 over real (unmasked) samples."""
    sq = jnp.sum(data.x**2, axis=-1) * data.mask
    return float(jnp.max(sq))


def h_bound_smooth(
    theta: float, rho: float, sigma_ii: float, qmax: float, mu: float, lam: float, n_i: int
) -> float:
    """Theorem 4: H >= log(1/Theta) (rho sigma_ii q_max + mu lam n_i)/(mu lam)."""
    return math.log(1.0 / theta) * (rho * sigma_ii * qmax + mu * lam * n_i) / (mu * lam)


def t_bound_smooth(
    eps_d: float,
    eta: float,
    theta: float,
    lam: float,
    mu: float,
    rho: float,
    n_star: int,
    pi_star: float,
    m: int,
) -> float:
    """Theorem 8 dual-suboptimality bound on communication rounds."""
    k = (lam * mu + rho * n_star * pi_star) / (lam * mu)
    return k / (eta * (1.0 - theta)) * math.log(m / eps_d)


def t_bound_lipschitz(
    eps_g: float, eta: float, theta: float, lam: float, rho: float, L: float, pi_sum: float, m: int
) -> float:
    """Theorem 9 (leading term): T >= T0 + max(ceil(1/(eta(1-Theta))),
    4 L^2 pi rho / (lam eps_G eta (1-Theta)))."""
    lead = 4.0 * L**2 * pi_sum * rho / (lam * eps_g * eta * (1.0 - theta))
    t0 = max(
        0.0,
        math.ceil(1.0 / (eta * (1.0 - theta)) * math.log(max(2.0 * lam * m / max(4.0 * L**2 * pi_sum * rho, 1e-30), 1.0))),
    )
    T0 = t0 + max(0.0, 2.0 / (eta * (1.0 - theta)) * (8.0 * L**2 * pi_sum * rho / (lam * eps_g) - 1.0))
    return T0 + max(math.ceil(1.0 / (eta * (1.0 - theta))), lead)


def pi_i(data: MTLData, sigma_ii: Array) -> Array:
    """pi_i = max_alpha (alpha^T K_[ii] alpha)/||alpha||^2
            = (sigma_ii/n_i^2) ||X_i||_2^2 (spectral norm squared of rows).

    Lemma 7 bounds it by sigma_ii / n_i for normalized features; we compute
    the exact value per task via SVD of each task's (masked) data block.
    """
    def per_task(x, msk, n, sii):
        xm = x * msk[:, None]
        s = jnp.linalg.norm(xm, 2)  # largest singular value
        nf = jnp.maximum(n.astype(x.dtype), 1.0)
        return sii * (s**2) / nf**2

    return jax.vmap(per_task)(data.x, data.mask, data.n, sigma_ii)


def rho_min_power_iteration(
    data: MTLData, sigma: Array, eta: float = 1.0, iters: int = 50, seed: int = 0
) -> float:
    """Estimate rho_min of Eq. (5) by power iteration on the generalized
    eigenproblem  K alpha = nu * Kblock alpha  restricted to range(Kblock).

    We work in b-space: with b_i = (1/n_i) X_i^T alpha_[i],
        alpha^T K alpha        = sum_{ii'} sigma_ii' b_i . b_i'
        sum_i alpha^T Kblk alpha = sum_i sigma_ii ||b_i||^2.
    The sup over alpha equals the sup over b in the product of task column
    spaces; we run projected power iteration in b-space (projection onto
    each task's column space via its data matrix).
    """
    key = jax.random.PRNGKey(seed)
    m, d = data.m, data.d
    dd = jnp.sqrt(jnp.maximum(jnp.diag(sigma), 1e-30))

    # orthonormal bases of each task's column space (masked rows)
    def basis(x, msk):
        xm = x * msk[:, None]
        qq, rr = jnp.linalg.qr(xm.T, mode="reduced")  # (d, n_max)
        keep = (jnp.abs(jnp.diag(rr)) > 1e-7).astype(x.dtype)
        return qq * keep[None, :]

    Q = jax.vmap(basis)(data.x, data.mask)  # (m, d, n_max)

    def project(b):  # (m, d) -> (m, d), task-wise projection onto col spaces
        return jnp.einsum("mdk,mk->md", Q, jnp.einsum("mdk,md->mk", Q, b))

    b = jax.random.normal(key, (m, d))
    b = project(b)

    # generalized power iteration: maximize (b^T S b)/(b^T D b) with
    # S = sigma (x) I on task blocks, D = diag(sigma_ii) (x) I.
    val = 0.0
    for _ in range(iters):
        # whitened operator: A = D^{-1/2} S D^{-1/2}, then project
        num = jnp.einsum("ij,jd->id", sigma, b)
        b_new = project(num / (dd**2)[:, None])
        nrm = jnp.sqrt(jnp.sum((b_new * dd[:, None]) ** 2))
        b = b_new / jnp.maximum(nrm, 1e-30)
        num_v = jnp.einsum("id,ij,jd->", b, sigma, b)
        den_v = jnp.sum((b * dd[:, None]) ** 2)
        val = num_v / jnp.maximum(den_v, 1e-30)
    return float(eta * val)


def staleness_summary(history: Dict[str, np.ndarray]) -> Dict[str, object]:
    """Summarize per-commit staleness events (``w_*`` keys).

    This is the single sink of the ``transport.CommitReceipt`` accounting
    path: every transport member (simulated/threaded/multiprocess) and the
    synchronous engine's degenerate tau=0 commits record through
    ``transport.record_receipt`` into the same history keys.

    Gossip histories additionally carry per-EDGE staleness events
    (``e_src/e_dst/e_stal/e_tick``: at each neighbor exchange, how many
    completed rounds the two endpoints disagreed by); when present the
    summary gains ``n_exchanges`` / ``max_edge_staleness`` /
    ``mean_edge_staleness`` / ``per_edge_mean`` keyed by ``(src, dst)``.

    Staleness of a contribution = server commits between its snapshot and
    its application; lag = rounds it ran ahead of the slowest worker. Under
    tau=0 with homogeneous delays both are 0 for every commit (the bulk-
    synchronous anchor). With heterogeneous delays, tau=0 still barriers
    round *starts* but a fast worker's commit can land between a slow
    worker's snapshot and its apply, so staleness up to G-1 is expected
    even at tau=0; lag stays 0.
    """
    stal = np.asarray(history.get("w_staleness", []), np.float64)
    lag = np.asarray(history.get("w_lag", []), np.float64)
    workers = np.asarray(history.get("w_worker", []), np.int64)
    if stal.size == 0:
        return {"n_commits": 0, "max_staleness": 0.0, "mean_staleness": 0.0,
                "p95_staleness": 0.0, "max_lag": 0.0, "per_worker_mean": {}}
    per_worker = {
        int(g): float(stal[workers == g].mean()) for g in np.unique(workers)
    }
    out = {
        "n_commits": int(stal.size),
        "max_staleness": float(stal.max()),
        "mean_staleness": float(stal.mean()),
        "p95_staleness": float(np.percentile(stal, 95)),
        "max_lag": float(lag.max()),
        "per_worker_mean": per_worker,
    }
    e_stal = np.asarray(history.get("e_stal", []), np.float64)
    if e_stal.size:
        e_src = np.asarray(history["e_src"], np.int64)
        e_dst = np.asarray(history["e_dst"], np.int64)
        edges = np.stack([e_src, e_dst], axis=1)
        per_edge = {
            (int(s), int(d)): float(
                e_stal[(e_src == s) & (e_dst == d)].mean()
            )
            for s, d in np.unique(edges, axis=0)
        }
        out.update(
            n_exchanges=int(e_stal.size),
            max_edge_staleness=float(e_stal.max()),
            mean_edge_staleness=float(e_stal.mean()),
            per_edge_mean=per_edge,
        )
    return out


def effective_gap_curve(
    history: Dict[str, np.ndarray]
) -> Tuple[np.ndarray, np.ndarray]:
    """Duality gap against the *transport clock*, not rounds.

    The x-axis is the tick of each objective sample: simulated ticks for
    the simulated transport, wall seconds for the host transports, and the
    round index for synchronous histories (``fit_distributed`` emits
    ``tick == round`` since PR 4; histories predating that fall back to
    round numbering here). A synchronous round under a straggler schedule
    really costs ``max(delays)`` ticks — use ``sync_effective_ticks`` to
    put sync and simulated-async runs on the same axis. The returned
    gaps are NOT monotone (best-so-far is not applied; the raw gap is
    returned so oscillations from stale commits stay visible) — use
    ``ticks_to_gap``'s first-crossing scan rather than binary search.
    """
    gaps = np.asarray(history["gap"], np.float64)
    if "tick" in history and len(history["tick"]):
        ticks = np.asarray(history["tick"], np.float64)
    else:
        ticks = np.arange(1, gaps.size + 1, dtype=np.float64)
    return ticks, gaps


def sync_effective_ticks(
    history: Dict[str, np.ndarray], delays
) -> np.ndarray:
    """Map a synchronous history's rounds onto the simulated clock: a BSP
    round barriers on the slowest worker, so it costs max(delays) ticks."""
    rounds = np.asarray(history["round"], np.float64)
    return rounds * float(max(delays))


def ticks_to_gap(
    ticks: np.ndarray, gaps: np.ndarray, target: float
) -> float:
    """First simulated tick at which the gap falls to ``target`` (inf if
    never) — the straggler bench's headline sync-vs-async comparison."""
    hit = np.nonzero(np.asarray(gaps) <= target)[0]
    return float(np.asarray(ticks)[hit[0]]) if hit.size else float("inf")


def measure_theta(
    data: MTLData,
    i: int,
    alpha: Array,
    W: Array,
    sigma: Array,
    rho: float,
    lam: float,
    loss_name: str,
    dalpha_i: Array,
    ref_steps: int = 20000,
    seed: int = 1234,
) -> Dict[str, float]:
    """Empirically measure Theta of Assumption 1 for one task:
    run a very long SDCA to approximate the local optimum D*, then
      Theta_hat = (D* - D(dalpha)) / (D* - D(0)).
    """
    from .sdca import local_sdca_naive, sample_coords

    loss = get_loss(loss_name)
    key = jax.random.PRNGKey(seed)
    coords = sample_coords(key, ref_steps, data.n[i], data.n_max)
    dstar, _ = local_sdca_naive(
        data.x[i],
        data.y[i],
        alpha[i],
        W[i],
        data.n[i],
        sigma[i, i],
        coords,
        rho,
        lam,
        loss,
    )
    obj = lambda da: dual_mod.local_subproblem_objective(
        data, i, da, alpha, W[i], sigma[i, i], rho, lam, loss, data.m
    )
    d_star = float(obj(dstar))
    d_cur = float(obj(dalpha_i))
    d_zero = float(obj(jnp.zeros_like(dalpha_i)))
    denom = d_star - d_zero
    theta = (d_star - d_cur) / denom if abs(denom) > 1e-12 else 0.0
    return {"theta": theta, "d_star": d_star, "d_cur": d_cur, "d_zero": d_zero}
