"""Distributed DMTRL — the paper's parameter-server W-step on a JAX mesh.

Mapping (docs/DESIGN.md §2):
  * ``data`` mesh axis  = the paper's workers; tasks are sharded over it.
  * ``model`` mesh axis = feature-dimension sharding (wide phi); the
    block-Gram solver psums its three d-contractions over this axis.
  * ``pod`` mesh axis   = intra-task sample partitioning (the paper's
    "further distribute data of one task over several local workers").
    Each pod owns a contiguous slice of every task's samples and the
    corresponding dual coordinates; delta_b is psum'ed over pods.

One communication round lowers to exactly:
    all_gather(delta_b, 'data')            -- the worker->server "send"
    local  dW = Sigma_rows @ dB / lambda   -- the server reduce, sharded
  (+ psum over 'pod' when present, + the block-Gram psums over 'model')
which is the paper's m*d-floats-per-round communication pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map, shard_map_unchecked
from . import dual as dual_mod
from . import omega as omega_mod
from . import omega_regularizers as omega_reg
from .dmtrl import DMTRLConfig, WarmStart, _rho_value
from .losses import get_loss
from .mtl_data import MTLData
from .sigma_view import LowRankDiagSigma, SigmaView
from .solver_backends import get_backend

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    data: str = "data"  # tasks
    model: Optional[str] = None  # feature dim
    pod: Optional[str] = None  # intra-task samples


@dataclasses.dataclass(frozen=True)
class DistributedOptions:
    """Mesh-engine knobs, split out of the legacy kitchen-sink config.

    The estimator facade passes these alongside the core ``DMTRLConfig``;
    the deprecated ``fit_distributed`` keeps reading the equivalent legacy
    config fields when no options object is given.
    """

    axes: MeshAxes = MeshAxes()
    dist_block_hoisted: bool = False  # hoisted block-Gram distributed round
    gram_bf16: bool = False  # bf16 MXU inputs in the distributed gram build

    def merge_into(self, cfg: DMTRLConfig) -> DMTRLConfig:
        return dataclasses.replace(
            cfg,
            dist_block_hoisted=self.dist_block_hoisted,
            gram_bf16=self.gram_bf16,
        )


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    return mesh.shape[name] if name is not None else 1


def pad_to_multiple(x: int, k: int) -> int:
    return ((x + k - 1) // k) * k


def shard_mtl_data(
    data: MTLData, mesh: Mesh, axes: MeshAxes
) -> Tuple[MTLData, int, int]:
    """Pad task count / feature dim / sample dim and device_put with shardings.

    Returns (sharded data, m_padded, d_padded).
    """
    dsz = _axis_size(mesh, axes.data)
    msz = _axis_size(mesh, axes.model)
    psz = _axis_size(mesh, axes.pod)

    m_pad = pad_to_multiple(data.m, dsz)
    d_pad = pad_to_multiple(data.d, msz)
    n_pad = pad_to_multiple(data.n_max, psz)

    d = data.pad_tasks(m_pad)
    x = jnp.zeros((m_pad, n_pad, d_pad), d.x.dtype)
    x = x.at[:, : d.n_max, : d.d].set(d.x)
    y = jnp.zeros((m_pad, n_pad), d.y.dtype).at[:, : d.n_max].set(d.y)
    mask = jnp.zeros((m_pad, n_pad), d.mask.dtype).at[:, : d.n_max].set(d.mask)

    sx = NamedSharding(mesh, P(axes.data, axes.pod, axes.model))
    sv = NamedSharding(mesh, P(axes.data, axes.pod))
    sn = NamedSharding(mesh, P(axes.data))
    out = MTLData(
        jax.device_put(x, sx),
        jax.device_put(y, sv),
        jax.device_put(mask, sv),
        jax.device_put(d.n, sn),
    )
    return out, m_pad, d_pad


def round_in_specs(axes: MeshAxes):
    """in_specs shared by the sync round and the async tick (first 7 args):
    (x, y, mask, n, alpha, W-like, sigma_rows)."""
    return (
        P(axes.data, axes.pod, axes.model),  # x
        P(axes.data, axes.pod),  # y
        P(axes.data, axes.pod),  # mask
        P(axes.data),  # n  (global per-task counts)
        P(axes.data, axes.pod),  # alpha
        P(axes.data, axes.model),  # W (or a stale snapshot of it)
        P(axes.data, None),  # sigma rows
    )


def round_out_specs(axes: MeshAxes):
    return (P(axes.data, axes.pod), P(axes.data, axes.model))


def make_local_solve(
    cfg: DMTRLConfig,
    mesh: Mesh,
    axes: MeshAxes,
    m: int,
    n_max: int,
    d: int,
    rho: float,
    sigma_input: str = "rows",
):
    """The worker half of one communication round, as a shard_map body.

    Returns ``local_solve(x, y, n, alpha, W_read, sigma_rows, key) ->
    (dalpha, db)`` where ``W_read`` is the (possibly stale) weight snapshot
    the worker solves against and ``db`` is this shard's delta_b rows
    (pod-psum'ed, eta/n-normalized) ready for the server reduce. The sync
    path passes the live ``W``; the async engine passes each worker group's
    bounded-staleness snapshot — the math is identical by construction.

    ``sigma_input`` names what the sigma argument carries: ``"rows"`` the
    dense (m_loc, m) owned Sigma rows (the historical layout — sigma_ii is
    extracted by global task id), ``"diag"`` just the local (m_loc,)
    diagonal (the structured-Sigma layout: workers never see full rows).
    """
    if sigma_input not in ("rows", "diag"):
        raise ValueError(f"sigma_input must be 'rows' or 'diag', got {sigma_input!r}")
    loss = get_loss(cfg.loss)
    dsz = _axis_size(mesh, axes.data)
    psz = _axis_size(mesh, axes.pod)
    m_loc = m // dsz
    n_loc = n_max // psz
    backend = get_backend(cfg.solver)
    H = backend.round_local_iters(cfg.local_iters or n_loc, cfg.block_size)
    # with a sharded feature dim the full-Gram form is used regardless of the
    # configured backend: ONE batched (q, G) build + psum over 'model' for
    # ALL local tasks (2 collectives per round vs 3 per block), then a
    # collective-free vmapped scalar recursion — identical iterates to
    # naive/block (tested). Per-task backends can't psum their own
    # d-contractions from inside a Pallas kernel (docs/DESIGN.md §5).
    use_gram = axes.model is not None
    solver = None if use_gram else backend.make(
        loss, rho, cfg.lam, H, block=cfg.block_size, axis_name=None
    )

    def local_solve(x, y, n, alpha, W_read, sigma_rows, key):
        di = jax.lax.axis_index(axes.data)
        pi = jax.lax.axis_index(axes.pod) if axes.pod else 0
        # global task ids of this shard + per-(task, pod, round) RNG
        tids = di * m_loc + jnp.arange(m_loc, dtype=jnp.int32)
        keys = jax.vmap(lambda t: jax.random.fold_in(jax.random.fold_in(key, t), pi))(
            tids
        )
        if sigma_input == "diag":
            sigma_ii = sigma_rows  # already the local (m_loc,) diagonal
        else:
            sigma_ii = jnp.take_along_axis(sigma_rows, tids[:, None], axis=1)[:, 0]
        # local valid sample count in this pod's contiguous slice
        n_local = jnp.clip(n - pi * n_loc, 0, n_loc).astype(jnp.int32)
        if use_gram:
            from .sdca import sample_coords, sdca_block_solve, sdca_gram_solve

            coords = jax.vmap(
                lambda nn, kk: sample_coords(kk, H, nn, x.shape[1])
            )(n_local, keys)  # (m_loc, H)
            if cfg.dist_block_hoisted:
                # docs/DESIGN.md §7: hoisted BLOCK-Gram — collective bytes per
                # round are 3*H*B per task (vs H^2 for the full Gram);
                # identical iterates to the block/naive modes.
                nf = jnp.maximum(n, 1).astype(x.dtype)
                kap = rho * sigma_ii / (cfg.lam * nf)
                Bsz = cfg.block_size
                nb = H // Bsz
                cb_all = coords.reshape(x.shape[0], nb, Bsz)

                def blk(carry, bi):
                    dalpha, r = carry
                    cb = cb_all[:, bi]  # (m_loc, B)
                    Xb = jnp.take_along_axis(x, cb[:, :, None], axis=1)
                    Xg = Xb.astype(
                        jnp.bfloat16 if cfg.gram_bf16 else Xb.dtype
                    )
                    q = jax.lax.psum(
                        jnp.einsum("mbd,md->mb", Xb, W_read), axes.model
                    )
                    xr = jax.lax.psum(
                        jnp.einsum("mbd,md->mb", Xb, r), axes.model
                    )
                    G = jax.lax.psum(
                        jnp.einsum(
                            "mbd,mkd->mbk",
                            Xg,
                            Xg,
                            preferred_element_type=jnp.float32,
                        ),
                        axes.model,
                    )
                    dalpha, deltas = jax.vmap(
                        lambda Gm, qm, xrm, dam, am, ym, cm, km: sdca_block_solve(
                            Gm, qm, xrm, dam, am, ym, cm, km, loss
                        )
                    )(G, q, xr, dalpha, alpha, y, cb, kap)
                    r = r + jnp.einsum("mbd,mb->md", Xb, deltas)
                    return (dalpha, r), None

                dalpha0 = jnp.zeros_like(alpha)
                r0 = jnp.zeros_like(W_read) + x[:, 0] * 0
                (dalpha, r), _ = jax.lax.scan(
                    blk, (dalpha0, r0), jnp.arange(nb)
                )
            else:
                Xs = jnp.take_along_axis(
                    x, coords[:, :, None], axis=1
                )  # (m_loc, H, d_loc)
                # docs/DESIGN.md §7: stream the sampled rows in bf16 for the MXU
                # contractions (fp32 accumulation); halves the dominant X-read
                # traffic. Validated against the fp32 path in tests.
                gemm_dtype = jnp.bfloat16 if cfg.gram_bf16 else Xs.dtype
                Xg = Xs.astype(gemm_dtype)
                q = jax.lax.psum(
                    jnp.einsum(
                        "mhd,md->mh",
                        Xg,
                        W_read.astype(gemm_dtype),
                        preferred_element_type=jnp.float32,
                    ),
                    axes.model,
                )
                G = jax.lax.psum(
                    jnp.einsum(
                        "mhd,mkd->mhk", Xg, Xg, preferred_element_type=jnp.float32
                    ),
                    axes.model,
                )
                dalpha, deltas = jax.vmap(
                    lambda Gm, qm, am, ym, cm, nn, sm: sdca_gram_solve(
                        Gm, qm, am, ym, cm, nn, sm, rho, cfg.lam, loss
                    )
                )(G, q, alpha, y, coords, n_local, sigma_ii)
                r = jnp.einsum("mhd,mh->md", Xs, deltas)
        else:
            dalpha, r = jax.vmap(solver)(
                x, y, alpha, W_read, n_local, sigma_ii, keys
            )
        if axes.pod is not None:
            r = jax.lax.psum(r, axes.pod)
        # delta_b_i = (eta / n_i_global) * sum over ALL of task i's samples
        db = cfg.eta * r / jnp.maximum(n, 1)[:, None].astype(r.dtype)
        return dalpha, db

    return local_solve


def pad_sigma_blocks(sigma_t, omega_t, m: int, m_true: int, jitter: float):
    """Embed the real-task Sigma/Omega into padded (m, m) matrices. Padded
    tasks get an inert jitter-scaled identity block so they stay decoupled.
    Shared by the sync and async engines (their Omega-steps must agree for
    the tau=0 bit-parity anchor)."""
    pad = m - m_true
    if not pad:
        return sigma_t, omega_t
    sigma = jnp.zeros((m, m), sigma_t.dtype)
    sigma = sigma.at[:m_true, :m_true].set(sigma_t)
    sigma = sigma.at[m_true:, m_true:].set(jnp.eye(pad) * jitter)
    omega = jnp.zeros((m, m), omega_t.dtype)
    omega = omega.at[:m_true, :m_true].set(omega_t)
    omega = omega.at[m_true:, m_true:].set(jnp.eye(pad) / jitter)
    return sigma, omega


def pad_sigma_any(sigma_t, omega_t, m: int, m_true: int, jitter: float):
    """pad_sigma_blocks generalized to SigmaView / missing-omega inputs.
    Dense (array, array) pairs go through pad_sigma_blocks unchanged (the
    bit-parity anchor); views pad via their own factor-level embedding."""
    if isinstance(sigma_t, SigmaView):
        sigma = sigma_t.pad(m, jitter)
        omega = omega_t.pad(m, 1.0 / jitter) if isinstance(omega_t, SigmaView) else None
        return sigma, omega
    if omega_t is None:
        sigma, _ = pad_sigma_blocks(sigma_t, sigma_t, m, m_true, jitter)
        return sigma, None
    return pad_sigma_blocks(sigma_t, omega_t, m, m_true, jitter)


def device_put_sigma(sigma, mesh: Mesh, axes: MeshAxes):
    """Shard a padded Sigma onto the mesh: dense rows get the historical
    P(data, None) row-sharding; a LowRankDiagSigma shards its task-indexed
    leaves (U rows / d) over the data axis with the r x r core replicated.
    SparseSigma has no mesh-native round yet — it densifies here (the
    documented small-m fallback; host transports keep it structured)."""
    if isinstance(sigma, LowRankDiagSigma):
        return LowRankDiagSigma(
            U=jax.device_put(sigma.U, NamedSharding(mesh, P(axes.data, None))),
            core=jax.device_put(sigma.core, NamedSharding(mesh, P())),
            d=jax.device_put(sigma.d, NamedSharding(mesh, P(axes.data))),
        )
    if isinstance(sigma, SigmaView):
        sigma = sigma.dense()
    return jax.device_put(sigma, NamedSharding(mesh, P(axes.data, None)))


def device_put_omega(omega, mesh: Mesh, axes: MeshAxes):
    if omega is None:
        return None
    return device_put_sigma(omega, mesh, axes)


def install_initial_state(
    state: "DistributedState",
    raw: MTLData,
    data: MTLData,
    m: int,
    cfg: DMTRLConfig,
    mesh: Mesh,
    axes: MeshAxes,
    reg,
    init,
    w_from_alpha,
) -> "DistributedState":
    """Install a warm start (``init``) or a custom-init regularizer's Sigma
    into freshly padded mesh state, rederiving W(alpha). Shared by the sync
    and async engines so their tau=0 bit-parity anchor cannot drift."""
    if init is None and not reg.custom_init and not reg.structured:
        return state
    if init is not None:
        if isinstance(init.sigma, SigmaView):
            sigma_t = init.sigma
        else:
            sigma_t = jnp.asarray(init.sigma, data.x.dtype)
        omega_t = init.omega
        if omega_t is not None and not isinstance(omega_t, SigmaView):
            omega_t = jnp.asarray(omega_t, data.x.dtype)
    else:
        sigma_t, omega_t = reg.init(raw.m, data.x.dtype)
    sig, om = pad_sigma_any(sigma_t, omega_t, m, raw.m, cfg.omega_jitter)
    state = dataclasses.replace(
        state,
        sigma=device_put_sigma(sig, mesh, axes),
        omega=device_put_omega(om, mesh, axes),
    )
    if init is not None:
        alpha0 = jnp.zeros((m, data.n_max), data.x.dtype)
        alpha0 = alpha0.at[: raw.m, : raw.n_max].set(
            jnp.asarray(init.alpha, data.x.dtype)
        )
        sv = NamedSharding(mesh, P(axes.data, axes.pod))
        state = dataclasses.replace(state, alpha=jax.device_put(alpha0, sv))
        state = dataclasses.replace(
            state, W=w_from_alpha(state.alpha, state.sigma)
        )
    return state


def server_reduce(cfg: DMTRLConfig, axes: MeshAxes, sigma_rows, db):
    """The server half of one round, as a shard_map body fragment:
    all_gather the workers' delta_b rows and apply the Sigma-coupled
    reduce for this shard's W rows. ``db`` may be pre-masked by the async
    engine so that only arrived contributions enter the gather."""
    dB = jax.lax.all_gather(db, axes.data, axis=0, tiled=True)  # (m, d_loc)
    return sigma_rows @ dB / cfg.lam  # (m_loc, d_loc)


def round_shard_map(cfg: DMTRLConfig, axes: MeshAxes, body, mesh, in_specs, out_specs):
    """shard_map a round/tick body, disabling the replication check only
    when the configured backend actually traces a pallas_call into the body
    (jax has no replication rule for pallas_call; with a model axis the
    gram path is used instead, so the check stays on)."""
    if get_backend(cfg.solver).uses_pallas and axes.model is None:
        return shard_map_unchecked(body, mesh, in_specs, out_specs)
    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def make_distributed_round(
    cfg: DMTRLConfig,
    mesh: Mesh,
    axes: MeshAxes,
    m: int,
    n_max: int,
    d: int,
    rho: float,
    structured: bool = False,
):
    """Build the jitted one-round function over sharded global arrays.

    round(x, y, mask, n, alpha, W, sigma, key) -> (alpha, W)

    With ``structured=True`` the sigma argument is a LowRankDiagSigma pytree
    (U/d row-sharded, core replicated) and the server reduce is factored:
    instead of all-gathering the (m, d) delta_b block, each shard psums its
    (r, d) projection U_rows^T db — O(r d) collective bytes per round
    instead of O(m d), the communication win at large m — then applies
    dW_rows = U_rows (C psum) + d_rows * db locally. The dense and factored
    reduces agree to float tolerance (parity-tested).
    """
    structured_specs = LowRankDiagSigma(
        U=P(axes.data, None), core=P(), d=P(axes.data)
    )
    local_solve = make_local_solve(
        cfg, mesh, axes, m, n_max, d, rho,
        sigma_input="diag" if structured else "rows",
    )
    base_specs = round_in_specs(axes)
    if structured:
        base_specs = base_specs[:-1] + (structured_specs,)
    in_specs = base_specs + (P(),)  # + key (replicated)
    out_specs = round_out_specs(axes)

    if structured:

        def round_body(x, y, mask, n, alpha, W, sv, key):
            dalpha, db = local_solve(x, y, n, alpha, W, sv.diag(), key)
            proj = jax.lax.psum(sv.U.T @ db, axes.data)  # (r, d_loc)
            dW = (sv.U @ (sv.core @ proj) + sv.d[:, None] * db) / cfg.lam
            return alpha + cfg.eta * dalpha, W + dW

    else:

        def round_body(x, y, mask, n, alpha, W, sigma_rows, key):
            dalpha, db = local_solve(x, y, n, alpha, W, sigma_rows, key)
            dW = server_reduce(cfg, axes, sigma_rows, db)
            return alpha + cfg.eta * dalpha, W + dW

    shmapped = round_shard_map(cfg, axes, round_body, mesh, in_specs, out_specs)
    return jax.jit(shmapped)


@dataclasses.dataclass
class DistributedState:
    alpha: Array
    W: Array
    # dense row-sharded (m, m) array or a mesh-sharded SigmaView pytree
    sigma: Array
    # precision; None for structured members without a cheap inverse
    omega: Optional[Array]


def init_state(
    data: MTLData, mesh: Mesh, axes: MeshAxes, m: int, d: int
) -> DistributedState:
    sv = NamedSharding(mesh, P(axes.data, axes.pod))
    sw = NamedSharding(mesh, P(axes.data, axes.model))
    sr = NamedSharding(mesh, P(axes.data, None))
    alpha = jax.device_put(jnp.zeros((m, data.n_max), data.x.dtype), sv)
    W = jax.device_put(jnp.zeros((m, d), data.x.dtype), sw)
    sigma, omega = omega_mod.init_sigma(m, data.x.dtype)
    return DistributedState(
        alpha, W, jax.device_put(sigma, sr), jax.device_put(omega, sr)
    )


def fit_distributed(
    cfg: DMTRLConfig,
    raw: MTLData,
    mesh: Mesh,
    axes: Optional[MeshAxes] = None,
    track: bool = True,
    *,
    options: Optional[DistributedOptions] = None,
    init: Optional[WarmStart] = None,
    regularizer=None,
):
    """Full Algorithm 1 on a mesh. Semantically equal to dmtrl.fit when
    pod axis is absent (tested); with pods the CoCoA block structure is finer
    (m*pods blocks) so iterates differ but convergence is preserved.

    ``options`` overrides the legacy per-engine config fields; ``init``
    warm-starts from raw-shaped (alpha, sigma, omega); ``regularizer``
    overrides the Omega family member (see core.omega_regularizers).
    """
    if axes is None:
        # an explicit axes argument wins; otherwise the options object may
        # carry the mesh mapping (the estimator path resolves it the same way)
        axes = options.axes if options is not None else MeshAxes()
    if options is not None:
        cfg = options.merge_into(cfg)
    reg = omega_reg.resolve_regularizer(cfg, regularizer, m=raw.m)
    loss = get_loss(cfg.loss)
    data, m, d = shard_mtl_data(raw, mesh, axes)
    state = init_state(data, mesh, axes, m, d)
    key = jax.random.PRNGKey(cfg.seed)

    # the synchronous engine IS the degenerate tau=0 transport: every round
    # commits all G workers as one barriered event with zero staleness/lag,
    # accounted through the same CommitReceipt path as the async transports
    # (core/transport.py) so convergence.staleness_summary reads one stream.
    from .transport import CommitReceipt, new_event_history, record_receipt

    n_pods = _axis_size(mesh, axes.pod)
    n_workers = _axis_size(mesh, axes.data)
    hist = new_event_history()
    rounds_seen = 0

    @jax.jit
    def objectives(alpha, sigma):
        dd = dual_mod.dual_objective(data, alpha, sigma, cfg.lam, loss)
        pp = dual_mod.primal_objective_from_alpha(data, alpha, sigma, cfg.lam, loss)
        return dd, pp

    @jax.jit
    def w_from_alpha(alpha, sigma):
        return dual_mod.weights_from_alpha(data, alpha, sigma, cfg.lam)

    state = install_initial_state(
        state, raw, data, m, cfg, mesh, axes, reg, init, w_from_alpha
    )

    for p in range(cfg.outer_iters):
        rho = _rho_value(cfg, state.sigma, n_blocks_scale=float(n_pods), reg=reg)
        round_fn = make_distributed_round(
            cfg, mesh, axes, m, data.n_max, d, rho,
            structured=isinstance(state.sigma, LowRankDiagSigma),
        )
        # same key schedule as dmtrl.fit/w_step => bit-equal coordinate draws
        key, outer_key = jax.random.split(key)
        round_keys = jax.random.split(outer_key, cfg.rounds)
        for t in range(cfg.rounds):
            sub = round_keys[t]
            alpha, W = round_fn(
                data.x,
                data.y,
                data.mask,
                data.n,
                state.alpha,
                state.W,
                state.sigma,
                sub,
            )
            state = dataclasses.replace(state, alpha=alpha, W=W)
            commit = rounds_seen + t + 1
            for g in range(n_workers):
                record_receipt(
                    hist,
                    CommitReceipt(
                        worker=g, round=rounds_seen + t, staleness=0, lag=0,
                        tick=commit, version=commit, tau=0,
                    ),
                )
            hist["tau_trace"].append(0)
            hist["gate_refusals"].append(0)
            if track:
                dd, pp = objectives(state.alpha, state.sigma)
                hist["round"].append(commit)
                hist["tick"].append(commit)
                hist["dual"].append(float(dd))
                hist["primal"].append(float(pp))
                hist["gap"].append(float(pp - dd))
                hist["min_round"].append(rounds_seen + t + 1)
        rounds_seen += cfg.rounds
        if reg.learns:
            # Omega-step must see only the REAL tasks: padded (inert) tasks
            # would otherwise distort the trace-1 normalization.
            W_true = state.W[: raw.m]
            sigma_t, omega_t = reg.step(W_true, cfg.omega_jitter)
            sigma, omega = pad_sigma_any(
                sigma_t, omega_t, m, raw.m, cfg.omega_jitter
            )
            state = dataclasses.replace(
                state,
                sigma=device_put_sigma(sigma, mesh, axes),
                omega=device_put_omega(omega, mesh, axes),
            )
            state = dataclasses.replace(
                state, W=w_from_alpha(state.alpha, state.sigma)
            )

    hist_np = {k: np.asarray(v) for k, v in hist.items()}
    # un-pad the task axis before returning
    W = np.asarray(state.W)[: raw.m, : raw.d]
    if isinstance(state.sigma, SigmaView):
        from .sigma_view import maybe_dense

        sigma = maybe_dense(state.sigma.unpad(raw.m))
    else:
        sigma = np.asarray(state.sigma)[: raw.m, : raw.m]
    return W, sigma, state, hist_np
