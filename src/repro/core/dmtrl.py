"""DMTRL Algorithm 1 — single-process reference driver.

Implements the alternating procedure exactly as in the paper:

  for p in 1..P:                      (alternating iterations)
    for t in 1..T:                    (W-step rounds == communication rounds)
      for each task i in parallel:    (vmap == the paper's workers)
        dalpha_[i] <- LocalSDCA(alpha_[i], w_i, sigma_ii)     (H inner iters)
        alpha_[i] += eta * dalpha_[i]
        delta_b_i  = (eta/n_i) X_i^T dalpha_[i]
      server: w_i += (1/lambda) sum_i' delta_b_i' sigma_ii'   (the reduce)
    server: Sigma, Omega <- omega_step(W); broadcast sigma rows
    rho <- Lemma-10 bound on the new Sigma (paper Section 7.1)

The distributed (shard_map) version in ``distributed.py`` reuses the same
per-round math; this module is the semantic oracle it is tested against.
"""
from __future__ import annotations

import dataclasses
import numbers
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import dual as dual_mod
from . import omega_regularizers as omega_reg
from . import sigma_view as sigma_view_mod
from .losses import get_loss
from .mtl_data import MTLData
from .sigma_view import SigmaView
from .solver_backends import get_backend

Array = jax.Array


def validate_tau(tau) -> None:
    """Eagerly reject malformed staleness bounds (e.g. tau="fast") so the
    error surfaces at config/option construction, not mid-fit."""
    if tau == "auto":
        return
    if not isinstance(tau, int) or isinstance(tau, bool):
        raise ValueError(f'tau must be an int >= 0 or "auto", got {tau!r}')
    if tau < 0:
        raise ValueError(f"tau must be >= 0, got {tau}")


def validate_topology(topology) -> None:
    """Eagerly reject malformed gossip topologies. Named topologies are
    checked against the known set; an explicit adjacency must be a square
    symmetric 0/1 matrix (connectivity is checked at transport setup,
    where the worker count is known)."""
    if isinstance(topology, str):
        if topology not in ("ring", "torus", "complete"):
            raise ValueError(
                f"topology must be 'ring' | 'torus' | 'complete' or an "
                f"explicit adjacency matrix, got {topology!r}"
            )
        return
    adj = np.asarray(topology)
    if adj.ndim != 2 or adj.shape[0] != adj.shape[1] or adj.shape[0] < 1:
        raise ValueError(
            f"adjacency topology must be a square matrix, got shape "
            f"{adj.shape}"
        )
    if not np.array_equal(adj, adj.T):
        raise ValueError("adjacency topology must be symmetric")
    if not np.isin(adj, (0, 1)).all():
        raise ValueError("adjacency topology entries must be 0/1")


def validate_async_fields(
    tau,
    tau_max,
    async_delays,
    omega_delay,
    transport="simulated",
    n_workers=None,
    staleness_budget=None,
    topology="complete",
    codec="none",
) -> None:
    """Shared eager validation for DMTRLConfig (legacy surface) and
    AsyncOptions (the new home of these knobs)."""
    validate_tau(tau)
    if not isinstance(transport, str):
        raise ValueError(
            f"transport must be a core.transport member name, got {transport!r}"
        )
    validate_topology(topology)
    if not isinstance(codec, str):
        raise ValueError(
            f"codec must be a core.wire codec name, got {codec!r}"
        )
    from .wire import available_codecs  # local: wire is numpy-only

    if codec not in available_codecs():
        raise ValueError(
            f"unknown wire codec {codec!r}; have {sorted(available_codecs())}"
        )
    if n_workers is not None and (
        not isinstance(n_workers, numbers.Integral)
        or isinstance(n_workers, bool)
        or n_workers < 1
    ):
        raise ValueError(f"n_workers must be an int >= 1 or None, got {n_workers!r}")
    if staleness_budget is not None and (
        isinstance(staleness_budget, bool)
        or not isinstance(staleness_budget, numbers.Real)
        or staleness_budget < 0
    ):
        raise ValueError(
            f"staleness_budget must be a float >= 0 or None, got "
            f"{staleness_budget!r}"
        )
    if staleness_budget is not None and tau != "auto":
        raise ValueError(
            f'staleness_budget only drives the tau="auto" controller; it '
            f"would be silently ignored with tau={tau!r}"
        )
    if not isinstance(tau_max, int) or isinstance(tau_max, bool) or tau_max < 0:
        raise ValueError(f"tau_max must be an int >= 0, got {tau_max!r}")
    if (
        not isinstance(omega_delay, int)
        or isinstance(omega_delay, bool)
        or omega_delay < 0
    ):
        raise ValueError(f"omega_delay must be an int >= 0, got {omega_delay!r}")
    if async_delays is not None:
        # numbers.Integral admits numpy ints (delay schedules are often
        # built from numpy arrays); _worker_delays coerces them with int()
        bad = [
            v
            for v in async_delays
            if not isinstance(v, numbers.Integral)
            or isinstance(v, bool)
            or v < 1
        ]
        if bad:
            raise ValueError(
                f"async_delays entries must be ints >= 1, got {async_delays!r}"
            )


@dataclasses.dataclass(frozen=True)
class DMTRLConfig:
    """Core algorithm config shared by every engine.

    The per-engine knobs at the bottom (async staleness, distributed gram
    options) are the LEGACY kitchen-sink surface kept for the deprecated
    ``fit_*`` entry points; the estimator facade takes them as typed
    ``AsyncOptions`` / ``DistributedOptions`` instead and rejects them here
    (core/estimator.py).
    """

    loss: str = "hinge"
    lam: float = 1e-3  # lambda in Eq. (1)
    eta: float = 1.0  # aggregation parameter (paper uses 1.0)
    outer_iters: int = 5  # P
    rounds: int = 20  # T (communication rounds per W-step)
    local_iters: int = 0  # H; 0 => n_max (one local epoch per round)
    solver: str = "block_gram"  # local-SDCA backend name, resolved through
    #               core.solver_backends: "naive" | "block_gram" |
    #               "pallas_block" | "pallas_round"
    block_size: int = 64
    rho_mode: str = "lemma10"  # "lemma10" | "spectral" | "fixed"
    rho_fixed: float = 1.0
    omega_jitter: float = 1e-6
    learn_omega: bool = True  # False => STL-style fixed Sigma (legacy alias
    #               for omega_regularizer="identity_stl")
    omega_regularizer: str = "trace_constraint"  # family member name,
    #               resolved through core.omega_regularizers
    seed: int = 0
    gram_bf16: bool = False  # bf16 MXU inputs in the distributed gram build
    dist_block_hoisted: bool = False  # hoisted block-Gram distributed round
    track_every: int = 1  # record objectives every k rounds
    # --- async engine (legacy; see async_dmtrl.AsyncOptions) ---------------
    tau: Union[int, str] = 0  # staleness bound: a worker may run at most tau
    #               rounds ahead of the slowest worker (0 == bulk-
    #               synchronous); "auto" adapts the bound online from the
    #               observed staleness histogram (async_dmtrl._adapt_tau)
    tau_max: int = 8  # upper bound for the tau="auto" adaptation
    async_delays: Optional[tuple] = None  # per-worker solve duration in
    #               simulated ticks; None == all 1 (homogeneous workers)
    omega_delay: int = 0  # server commits the Omega-step install waits
    #               for; >0 lets the first commits of the next W-step run
    #               against the stale Sigma (0 == barrier, same as sync)
    transport: str = "simulated"  # snapshot/commit protocol substrate,
    #               resolved through core.transport: "simulated" |
    #               "threaded" | "multiprocess"
    n_workers: Optional[int] = None  # host-transport worker count; None ==
    #               derive from the mesh data axis (simulated always does)
    staleness_budget: Optional[float] = None  # tau="auto" cost target:
    #               narrow when windowed mean commit staleness exceeds it
    topology: Union[str, tuple] = "complete"  # gossip neighbor graph:
    #               "ring" | "torus" | "complete" or an explicit symmetric
    #               0/1 adjacency (nested tuples); gossip transport only
    codec: str = "none"  # wire codec for (delta_w, Sigma) messages,
    #               resolved through core.wire: "none" | "bf16" | "int8";
    #               host + gossip transports only

    def __post_init__(self):
        validate_async_fields(
            self.tau,
            self.tau_max,
            self.async_delays,
            self.omega_delay,
            transport=self.transport,
            n_workers=self.n_workers,
            staleness_budget=self.staleness_budget,
            topology=self.topology,
            codec=self.codec,
        )
        if self.omega_regularizer not in omega_reg.available_regularizers():
            raise ValueError(
                f"unknown omega_regularizer {self.omega_regularizer!r}; "
                f"have {sorted(omega_reg.available_regularizers())}"
            )


@dataclasses.dataclass(frozen=True)
class WarmStart:
    """Prior state to continue training from (estimator.partial_fit).

    ``alpha``: (m, n_max) dual variables, ``sigma``/``omega``: (m, m) task
    covariance/precision — all at the RAW (unpadded) problem size. W is
    always rederived as W(alpha) under sigma, never carried separately.
    Structured runs may carry a SigmaView for ``sigma`` and None (or a
    view) for ``omega``.
    """

    alpha: Array
    sigma: Array
    omega: Optional[Array] = None


@dataclasses.dataclass
class DMTRLResult:
    W: Array  # (m, d)
    alpha: Array  # (m, n_max)
    sigma: Array  # (m, m) dense, or a SigmaView when m is huge
    omega: Optional[Array]  # (m, m); None for structured members w/o inverse
    history: Dict[str, np.ndarray]
    rho_per_outer: List[float]
    # the structured representation itself, when the run used one
    sigma_view: Optional[SigmaView] = None


def _rho_value(
    cfg: DMTRLConfig,
    sigma: Array,
    n_blocks_scale: float = 1.0,
    reg: Optional[omega_reg.OmegaRegularizer] = None,
) -> float:
    """rho safety bound for the current Sigma, via the regularizer family
    (every member supplies its bound; the default is the paper's)."""
    if reg is None:
        reg = omega_reg.resolve_regularizer(cfg)
    rho = reg.rho(sigma, cfg.eta, cfg.rho_mode, cfg.rho_fixed)
    if cfg.rho_mode == "fixed":
        return float(rho)
    return float(rho) * n_blocks_scale


def make_w_step_round(cfg: DMTRLConfig, data: MTLData, rho: float):
    """One communication round: local updates (vmap over tasks) + reduce.

    Returns round(alpha, W, sigma, key) -> (alpha, W). jit-able.
    """
    loss = get_loss(cfg.loss)
    backend = get_backend(cfg.solver)
    H = backend.round_local_iters(cfg.local_iters or data.n_max, cfg.block_size)
    solver = backend.make(loss, rho, cfg.lam, H, block=cfg.block_size)

    def round_fn(alpha, W, sigma, key):
        # same per-(task, pod=0) key derivation as distributed.py so the
        # single-process reference and the mesh version produce bit-equal
        # coordinate samples (tested).
        tids = jnp.arange(data.m, dtype=jnp.int32)
        keys = jax.vmap(
            lambda t: jax.random.fold_in(jax.random.fold_in(key, t), 0)
        )(tids)
        if isinstance(sigma, SigmaView):
            sigma_diag = sigma.diag()
        else:
            sigma_diag = jnp.diag(sigma)
        dalpha, r = jax.vmap(solver)(
            data.x, data.y, alpha, W, data.n, sigma_diag, keys
        )
        alpha = alpha + cfg.eta * dalpha
        # delta_b rows: (m, d); server reduce: W += (1/lam) Sigma @ dB
        db = cfg.eta * r / data.n[:, None].astype(r.dtype)
        if isinstance(sigma, SigmaView):
            W = W + sigma.matvec(db) / cfg.lam
        else:
            W = W + (sigma @ db) / cfg.lam
        return alpha, W

    return round_fn


def w_step(
    cfg: DMTRLConfig,
    data: MTLData,
    alpha: Array,
    W: Array,
    sigma: Array,
    rho: float,
    key: Array,
    track: bool = True,
) -> tuple[Array, Array, Dict[str, np.ndarray]]:
    """Run cfg.rounds communication rounds; returns updated alpha, W, history."""
    loss = get_loss(cfg.loss)
    round_fn = jax.jit(make_w_step_round(cfg, data, rho))

    @jax.jit
    def objectives(alpha):
        d = dual_mod.dual_objective(data, alpha, sigma, cfg.lam, loss)
        p = dual_mod.primal_objective_from_alpha(data, alpha, sigma, cfg.lam, loss)
        return d, p

    hist = {"round": [], "dual": [], "primal": [], "gap": []}
    keys = jax.random.split(key, cfg.rounds)
    for t in range(cfg.rounds):
        alpha, W = round_fn(alpha, W, sigma, keys[t])
        if track and (t % cfg.track_every == 0 or t == cfg.rounds - 1):
            d, p = objectives(alpha)
            hist["round"].append(t + 1)
            hist["dual"].append(float(d))
            hist["primal"].append(float(p))
            hist["gap"].append(float(p - d))
    return alpha, W, {k: np.asarray(v) for k, v in hist.items()}


def fit(
    cfg: DMTRLConfig,
    data: MTLData,
    track: bool = True,
    *,
    init: Optional[WarmStart] = None,
    regularizer=None,
) -> DMTRLResult:
    """Full Algorithm 1: P alternations of (W-step, Omega-step).

    ``init`` warm-starts from a prior (alpha, sigma, omega) — W is rederived
    as W(alpha); ``regularizer`` overrides the Omega family member resolved
    from the config (an ``OmegaRegularizer`` instance or name).
    """
    reg = omega_reg.resolve_regularizer(cfg, regularizer, m=data.m)
    key = jax.random.PRNGKey(cfg.seed)
    m, n_max = data.m, data.n_max
    if init is not None:
        alpha = jnp.asarray(init.alpha, data.x.dtype)
        if isinstance(init.sigma, SigmaView):
            sigma = init.sigma
        else:
            sigma = jnp.asarray(init.sigma, data.x.dtype)
        omega = init.omega
        if omega is not None and not isinstance(omega, SigmaView):
            omega = jnp.asarray(omega, data.x.dtype)
        W = dual_mod.weights_from_alpha(data, alpha, sigma, cfg.lam)
    else:
        alpha = jnp.zeros((m, n_max), data.x.dtype)
        W = jnp.zeros((m, data.d), data.x.dtype)
        sigma, omega = reg.init(m, data.x.dtype)

    history: Dict[str, List[np.ndarray]] = {
        "round": [],
        "dual": [],
        "primal": [],
        "gap": [],
        "outer": [],
    }
    rhos: List[float] = []
    rounds_seen = 0
    for p in range(cfg.outer_iters):
        rho = _rho_value(cfg, sigma, reg=reg)
        rhos.append(rho)
        key, sub = jax.random.split(key)
        alpha, W, hist = w_step(cfg, data, alpha, W, sigma, rho, sub, track=track)
        if track:
            history["round"].append(hist["round"] + rounds_seen)
            history["dual"].append(hist["dual"])
            history["primal"].append(hist["primal"])
            history["gap"].append(hist["gap"])
            history["outer"].append(np.full_like(hist["round"], p))
        rounds_seen += cfg.rounds
        if reg.learns:
            # Algorithm 1 row 11 runs after every W-step, including the last.
            sigma, omega = reg.step(W, cfg.omega_jitter)
            # Sigma changed => the dual problem (K) changed; W(alpha) must be
            # recomputed under the new Sigma (B is Sigma-independent).
            W = dual_mod.weights_from_alpha(data, alpha, sigma, cfg.lam)

    hist_np = {
        k: (np.concatenate(v) if v else np.zeros((0,))) for k, v in history.items()
    }
    sigma_out, omega_out, sv = sigma_view_mod.result_sigma_omega(sigma, omega)
    return DMTRLResult(
        W=W,
        alpha=alpha,
        sigma=sigma_out,
        omega=omega_out,
        history=hist_np,
        rho_per_outer=rhos,
        sigma_view=sv,
    )
