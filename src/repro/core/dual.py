"""Dual/primal objectives, the primal-dual map W(alpha), and the duality gap.

Notation (paper Thm. 1):
    b_i        = (1/n_i) X_i^T alpha_[i]                      (d,)
    B          = [b_1 ... b_m]                                (d, m)
    w_i(alpha) = (1/lambda) sum_i' b_i' sigma_ii'  =>  W = (1/lambda) B Sigma
    alpha^T K alpha = tr(Sigma B^T B)
    D(alpha) = -(1/2 lambda) tr(Sigma B^T B) - sum_i (1/n_i) sum_j l*(-alpha_j^i)
    P(W)     = sum_i (1/n_i) sum_j l(w_i^T x_j^i) + (lambda/2) tr(W Omega W^T)

For W = W(alpha) the regularizer simplifies:
    tr(W Omega W^T) = (1/lambda^2) tr(Sigma B^T B)     (since Sigma Omega Sigma = Sigma)
so the duality gap never needs Omega explicitly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .losses import Loss
from .mtl_data import MTLData
from .sigma_view import SigmaView

Array = jax.Array


def compute_B(data: MTLData, alpha: Array) -> Array:
    """B matrix, columns b_i = (1/n_i) X_i^T alpha_[i].  alpha: (m, n_max)."""
    masked = alpha * data.mask  # safety: padding contributes nothing
    b = jnp.einsum("mnd,mn->md", data.x, masked) / data.n[:, None].astype(data.x.dtype)
    return b.T  # (d, m)


def weights_from_alpha(data: MTLData, alpha: Array, sigma, lam: float) -> Array:
    """W(alpha) = (1/lambda) B Sigma, returned as (m, d) rows = tasks.

    ``sigma`` may be a dense (m, m) array or a SigmaView; the dense branch
    keeps the historical expression bit-identical."""
    B = compute_B(data, alpha)  # (d, m)
    if isinstance(sigma, SigmaView):
        return sigma.matvec(B.T) / lam  # Sigma symmetric: (B Sigma)^T = Sigma B^T
    return (B @ sigma).T / lam  # (m, d)


def quad_term(data: MTLData, alpha: Array, sigma) -> Array:
    """alpha^T K alpha = tr(Sigma B^T B).

    For a SigmaView, tr(Sigma B^T B) = sum_{i,d} (B^T)_{id} (Sigma B^T)_{id}
    — two factor matvecs, never a dense Sigma."""
    B = compute_B(data, alpha)
    if isinstance(sigma, SigmaView):
        Bt = B.T  # (m, d)
        return jnp.sum(Bt * sigma.matvec(Bt))
    return jnp.einsum("ij,ji->", sigma, B.T @ B)


def dual_objective(
    data: MTLData, alpha: Array, sigma: Array, lam: float, loss: Loss
) -> Array:
    """D(alpha) of Eq. (2)."""
    quad = quad_term(data, alpha, sigma)
    conj = loss.conjugate(-alpha, data.y) * data.mask
    conj_term = jnp.sum(conj / data.n[:, None].astype(conj.dtype))
    return -quad / (2.0 * lam) - conj_term


def primal_objective(
    data: MTLData, W: Array, omega: Array, lam: float, loss: Loss
) -> Array:
    """P(W) of Eq. (1) with explicit Omega (precision matrix). W: (m, d)."""
    z = jnp.einsum("mnd,md->mn", data.x, W)
    emp = jnp.sum(loss.value(z, data.y) * data.mask / data.n[:, None].astype(z.dtype))
    reg = 0.5 * lam * jnp.einsum("id,ij,jd->", W, omega, W)
    return emp + reg


def primal_objective_from_alpha(
    data: MTLData, alpha: Array, sigma: Array, lam: float, loss: Loss
) -> Array:
    """P(W(alpha)) using tr(W Omega W^T) = tr(Sigma B^T B)/lambda^2."""
    W = weights_from_alpha(data, alpha, sigma, lam)
    z = jnp.einsum("mnd,md->mn", data.x, W)
    emp = jnp.sum(loss.value(z, data.y) * data.mask / data.n[:, None].astype(z.dtype))
    reg = quad_term(data, alpha, sigma) / (2.0 * lam)
    return emp + reg


def duality_gap(
    data: MTLData, alpha: Array, sigma: Array, lam: float, loss: Loss
) -> Array:
    """G(alpha) = P(W(alpha)) - D(alpha) >= 0 (weak duality)."""
    return primal_objective_from_alpha(data, alpha, sigma, lam, loss) - dual_objective(
        data, alpha, sigma, lam, loss
    )


def local_subproblem_objective(
    data: MTLData,
    i: int,
    dalpha_i: Array,
    alpha: Array,
    w_i: Array,
    sigma_ii: Array,
    rho: float,
    lam: float,
    loss: Loss,
    m: int,
) -> Array:
    """D_i^rho of Eq. (4) for one task (used in tests / Theta measurement).

    D_i^rho = -(1/n_i) sum_j l*(-(alpha_j + dalpha_j))
              -(1/n_i) sum_j dalpha_j w_i^T x_j
              -(1/(2 lam m)) alpha^T K alpha
              -(rho/(2 lam)) dalpha^T K_[ii] dalpha
    with K_[ii] = (sigma_ii/n_i^2) X_i X_i^T.
    """
    xi, yi, mi = data.x[i], data.y[i], data.mask[i]
    ni = data.n[i].astype(xi.dtype)
    quad_global = quad_term(data, alpha, _sigma_placeholder(sigma_ii, alpha, data))
    # NOTE: callers that need the exact constant term pass the full sigma via
    # local_subproblem_objective_full; the constant does not affect argmax.
    del quad_global
    conj = loss.conjugate(-(alpha[i] + dalpha_i), yi) * mi
    t1 = -jnp.sum(conj) / ni
    t2 = -jnp.sum(dalpha_i * (xi @ w_i) * mi) / ni
    r = xi.T @ (dalpha_i * mi)
    t3 = -(rho * sigma_ii / (2.0 * lam * ni**2)) * jnp.sum(r * r)
    return t1 + t2 + t3


def _sigma_placeholder(sigma_ii, alpha, data):
    return jnp.eye(data.m, dtype=alpha.dtype)


def local_subproblem_objective_full(
    data: MTLData,
    i: int,
    dalpha_i: Array,
    alpha: Array,
    w_i: Array,
    sigma: Array,
    rho: float,
    lam: float,
    loss: Loss,
) -> Array:
    """D_i^rho including the constant -(1/(2 lam m)) alpha^T K alpha term."""
    base = local_subproblem_objective(
        data, i, dalpha_i, alpha, w_i, sigma[i, i], rho, lam, loss, data.m
    )
    const = -quad_term(data, alpha, sigma) / (2.0 * lam * data.m)
    return base + const


def predictions(data: MTLData, W: Array) -> Array:
    """z_j^i = w_i^T x_j^i, (m, n_max)."""
    return jnp.einsum("mnd,md->mn", data.x, W)


def task_scores(W: Array, X: Array, tasks: Array) -> Array:
    """Per-row scores z_n = w_{tasks[n]}^T x_n for flat request batches.

    The single scoring kernel shared by the estimator's predict path and
    the batched serving engine (serve/mtl.py) — W: (m, d), X: (n, d),
    tasks: (n,) int -> (n,)."""
    return jnp.einsum("nd,nd->n", X, W[tasks])


def error_rate(data: MTLData, W: Array) -> Array:
    """Masked averaged-over-tasks classification error (paper's metric)."""
    z = predictions(data, W)
    wrong = (jnp.sign(z) != jnp.sign(data.y)).astype(jnp.float32) * data.mask
    per_task = jnp.sum(wrong, axis=1) / jnp.maximum(jnp.sum(data.mask, axis=1), 1.0)
    return jnp.mean(per_task)


def rmse(data: MTLData, W: Array) -> Array:
    """Masked global RMSE over all test points (School metric)."""
    z = predictions(data, W)
    se = (z - data.y) ** 2 * data.mask
    return jnp.sqrt(jnp.sum(se) / jnp.maximum(jnp.sum(data.mask), 1.0))


def explained_variance(data: MTLData, W: Array) -> Array:
    """Explained variance as in Argyriou et al. (School): 1 - SSE/Var(y)."""
    z = predictions(data, W)
    msk = data.mask
    tot = jnp.maximum(jnp.sum(msk), 1.0)
    ybar = jnp.sum(data.y * msk) / tot
    sse = jnp.sum((z - data.y) ** 2 * msk)
    svar = jnp.sum((data.y - ybar) ** 2 * msk)
    return 1.0 - sse / jnp.maximum(svar, 1e-12)
