"""Training-engine registry: one facade contract over the three drivers.

Mirrors the solver-backend registry (docs/DESIGN.md §5): a config names an
engine, the estimator resolves it with ``get_engine`` and calls the uniform

    engine.run(cfg, data, mesh=..., axes=..., options=..., regularizer=...,
               init=..., track=...) -> EngineResult

contract. The registered engines wrap the existing drivers bit-identically
(the adapters only normalize signatures and returns — parity-tested):

  reference    single-process Algorithm 1 (core/dmtrl.py:fit); the
               semantic oracle. No mesh, no options.
  distributed  parameter-server W-step on a JAX mesh
               (core/distributed.py:fit_distributed); DistributedOptions.
  async        bounded-staleness SSP engine
               (core/async_dmtrl.py:fit_async); AsyncOptions (+ the
               distributed knobs via DistributedOptions merged upstream).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from .async_dmtrl import AsyncOptions, fit_async as _fit_async
from .distributed import (
    DistributedOptions,
    MeshAxes,
    fit_distributed as _fit_distributed,
)
from .dmtrl import DMTRLConfig, WarmStart, fit as _fit_reference
from .mtl_data import MTLData
from .sigma_view import SigmaView, maybe_dense
from ..obs.trace import span


@dataclasses.dataclass
class EngineResult:
    """Engine-agnostic fit result, always at the RAW (unpadded) problem
    size regardless of mesh padding — what the estimator stores."""

    W: np.ndarray  # (m, d) task weight rows
    alpha: np.ndarray  # (m, n_max) dual variables
    sigma: np.ndarray  # (m, m) task covariance; a SigmaView at huge m
    omega: Optional[np.ndarray]  # (m, m) task precision; None when the
    #               structured member has no cheap inverse at this size
    history: Dict[str, np.ndarray]
    rho_per_outer: Optional[List[float]] = None  # reference engine only
    # structured runs also expose the factors (SigmaView) directly
    sigma_view: Optional[SigmaView] = None


@dataclasses.dataclass(frozen=True)
class Engine:
    """A named way to run Algorithm 1 end to end."""

    name: str
    description: str
    needs_mesh: bool
    options_cls: Optional[type]
    # run(cfg, data, *, mesh, axes, options, regularizer, init, track)
    run: Callable[..., EngineResult]


_REGISTRY: Dict[str, Engine] = {}


def register_engine(engine: Engine) -> Engine:
    _REGISTRY[engine.name] = engine
    return engine


def get_engine(name: str) -> Engine:
    try:
        return _REGISTRY[name]
    except KeyError as e:
        raise KeyError(
            f"unknown engine {name!r}; have {sorted(_REGISTRY)}"
        ) from e


def available_engines() -> Dict[str, Engine]:
    return dict(sorted(_REGISTRY.items()))


def _default_mesh(axes: MeshAxes):
    """A 1-device mesh so mesh engines stay usable without ceremony."""
    return jax.make_mesh((1,), (axes.data,))


def _unpad_state(state, raw: MTLData) -> tuple:
    """(alpha, omega) rows/cols of the REAL tasks from padded mesh state."""
    alpha = np.asarray(state.alpha)[: raw.m, : raw.n_max]
    if state.omega is None:
        omega = None
    elif isinstance(state.omega, SigmaView):
        omega = maybe_dense(state.omega.unpad(raw.m))
    else:
        omega = np.asarray(state.omega)[: raw.m, : raw.m]
    return alpha, omega


def _run_reference(
    cfg: DMTRLConfig,
    data: MTLData,
    *,
    mesh=None,
    axes: Optional[MeshAxes] = None,
    options: Any = None,
    regularizer=None,
    init: Optional[WarmStart] = None,
    track: bool = True,
) -> EngineResult:
    if mesh is not None or axes is not None or options is not None:
        raise ValueError(
            "the reference engine runs single-process: mesh/axes/options "
            'are distributed-only (use engine="distributed" or "async")'
        )
    with span("engine_run", cat="driver", engine="reference"):
        res = _fit_reference(
            cfg, data, track=track, init=init, regularizer=regularizer
        )
    return EngineResult(
        W=np.asarray(res.W),
        alpha=np.asarray(res.alpha),
        sigma=maybe_dense(res.sigma),
        omega=maybe_dense(res.omega),
        history=res.history,
        rho_per_outer=list(res.rho_per_outer),
        sigma_view=res.sigma_view,
    )


def _make_mesh_run(
    fit_fn: Callable, engine_name: str
) -> Callable[..., EngineResult]:
    """One adapter for both mesh engines: resolve a default mesh, forward
    to the driver (which resolves axes itself), unpad, pack EngineResult."""

    def run(
        cfg: DMTRLConfig,
        data: MTLData,
        *,
        mesh=None,
        axes: Optional[MeshAxes] = None,
        options=None,
        regularizer=None,
        init: Optional[WarmStart] = None,
        track: bool = True,
    ) -> EngineResult:
        if mesh is None:
            ax = axes or getattr(options, "axes", None) or MeshAxes()
            mesh = _default_mesh(ax)
        with span("engine_run", cat="driver", engine=engine_name):
            W, sigma, state, hist = fit_fn(
                cfg, data, mesh, axes, track=track,
                options=options, init=init, regularizer=regularizer,
            )
        alpha, omega = _unpad_state(state, data)
        sigma_view = None
        if isinstance(state.sigma, SigmaView):
            sigma_view = state.sigma.unpad(data.m)
        return EngineResult(
            W=np.asarray(W), alpha=alpha, sigma=maybe_dense(sigma),
            omega=omega, history=hist, sigma_view=sigma_view,
        )

    return run


_run_distributed = _make_mesh_run(_fit_distributed, "distributed")
_run_async = _make_mesh_run(_fit_async, "async")


register_engine(
    Engine(
        name="reference",
        description="single-process Algorithm 1 (vmap over tasks); the "
        "semantic oracle the mesh engines are tested against",
        needs_mesh=False,
        options_cls=None,
        run=_run_reference,
    )
)
register_engine(
    Engine(
        name="distributed",
        description="parameter-server W-step sharded over a JAX mesh "
        "(data/model/pod axes); bulk-synchronous rounds",
        needs_mesh=True,
        options_cls=DistributedOptions,
        run=_run_distributed,
    )
)
register_engine(
    Engine(
        name="async",
        description="bounded-staleness (SSP) engine: workers commit "
        "against snapshots at most tau rounds stale over a pluggable "
        "transport (simulated/threaded/multiprocess); tau=0 == distributed",
        needs_mesh=True,
        options_cls=AsyncOptions,
        run=_run_async,
    )
)
