"""DMTRLEstimator — the engine-agnostic training/serving facade.

One object covers what used to take three divergent entry points
(``fit`` / ``fit_distributed`` / ``fit_async``) plus hand-rolled predict
code:

    est = DMTRLEstimator(engine="async", mesh=mesh,
                         async_options=AsyncOptions(tau=2),
                         loss="hinge", lam=1e-4, rounds=8)
    est.fit(train).score(test)
    z = est.decision_function(x_batch, tasks=task_ids)

Design (docs/DESIGN.md §8):
  * engines resolve through ``core.engines`` (same registry pattern as the
    solver backends) — the estimator is bit-identical to the engine's
    deprecated direct entry point (parity-tested);
  * per-engine knobs arrive as typed ``DistributedOptions`` /
    ``AsyncOptions`` objects; passing them as core config fields raises so
    async-only knobs can no longer leak into the reference engine;
  * the Omega regularizer is a named family member
    (``core.omega_regularizers``) — the paper's trace_constraint by
    default;
  * ``partial_fit`` warm-starts from the previous (alpha, Sigma) so
    training continues instead of restarting;
  * ``predict``/``decision_function``/``score`` serve the fitted W, and
    ``scoring_engine()`` wires it into the batched serving surface
    (serve/mtl.py).
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Dict, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from . import dual as dual_mod
from .async_dmtrl import AsyncOptions
from .distributed import DistributedOptions, MeshAxes
from .dmtrl import DMTRLConfig, WarmStart
from .engines import Engine, EngineResult, get_engine
from .losses import get_loss
from .mtl_data import MTLData
from .omega_regularizers import OmegaRegularizer, get_regularizer
from .sigma_view import SigmaView

# engine-specific legacy config fields the facade refuses as core params
_ASYNC_FIELDS = frozenset(
    {
        "tau",
        "tau_max",
        "async_delays",
        "omega_delay",
        "transport",
        "n_workers",
        "staleness_budget",
        "topology",
        "codec",
    }
)
_DIST_FIELDS = frozenset({"dist_block_hoisted", "gram_bf16"})
_CONFIG_FIELDS = frozenset(f.name for f in dataclasses.fields(DMTRLConfig))

# history keys that index time and must continue, not restart, across
# partial_fit calls (value added to the new segment = last max seen)
_TIME_KEYS = ("round", "tick", "w_tick", "gate_refusals")
# 0-based counters: continue at prev_max + 1
_COUNTER_KEYS = ("outer", "w_round", "min_round")


class NotFittedError(RuntimeError):
    pass


class DMTRLEstimator:
    """Engine-agnostic DMTRL estimator with an sklearn-flavoured surface.

    Parameters
    ----------
    engine : "reference" | "distributed" | "async" (core.engines registry)
    config : optional pre-built core DMTRLConfig; core field kwargs
        (``loss=``, ``lam=``, ``rounds=`` ...) override it. Engine-specific
        legacy fields (``tau``, ``dist_block_hoisted``, ...) are rejected
        here — pass ``async_options=AsyncOptions(...)`` /
        ``distributed=DistributedOptions(...)`` instead.
    mesh / axes : mesh engines only; a 1-device mesh is built when omitted.
    regularizer : Omega family member name or OmegaRegularizer instance
        (core.omega_regularizers); ``regularizer_params`` configure named
        members (e.g. ``{"adjacency": A}`` for graph_laplacian).

    Fitted attributes (trailing underscore): ``W_``, ``alpha_``,
    ``sigma_``, ``omega_``, ``history_``, ``rho_per_outer_``;
    structured regularizers additionally set ``sigma_view_`` (the
    SigmaView factors; ``sigma_`` stays the view itself at huge m instead
    of a dense (m, m), ``omega_`` may be None).
    """

    def __init__(
        self,
        engine: str = "reference",
        *,
        config: Optional[DMTRLConfig] = None,
        mesh=None,
        axes: Optional[MeshAxes] = None,
        distributed: Optional[DistributedOptions] = None,
        async_options: Optional[AsyncOptions] = None,
        regularizer: Union[str, OmegaRegularizer, None] = None,
        regularizer_params: Optional[dict] = None,
        **params,
    ):
        self.engine: Engine = get_engine(engine)

        leaked = sorted((_ASYNC_FIELDS | _DIST_FIELDS) & params.keys())
        if leaked:
            raise ValueError(
                f"{leaked} are per-engine options, not core config fields; "
                "pass async_options=AsyncOptions(...) / "
                "distributed=DistributedOptions(...) instead"
            )
        unknown = sorted(params.keys() - _CONFIG_FIELDS)
        if unknown:
            raise ValueError(
                f"unknown config fields {unknown}; valid core fields: "
                f"{sorted(_CONFIG_FIELDS - _ASYNC_FIELDS - _DIST_FIELDS)}"
            )
        cfg = config if config is not None else DMTRLConfig()
        if params:
            cfg = dataclasses.replace(cfg, **params)
        self.config: DMTRLConfig = cfg

        if self.engine.name == "reference":
            if mesh is not None or axes is not None:
                raise ValueError(
                    'engine="reference" is single-process; mesh/axes need '
                    'engine="distributed" or "async"'
                )
            if distributed is not None or async_options is not None:
                raise ValueError(
                    'engine="reference" takes no DistributedOptions/'
                    "AsyncOptions — the facade keeps per-engine knobs out "
                    "of the reference path"
                )
        if async_options is not None and self.engine.name != "async":
            raise ValueError(
                f'AsyncOptions need engine="async", got engine='
                f"{self.engine.name!r}"
            )
        if distributed is not None and not isinstance(
            distributed, DistributedOptions
        ):
            raise TypeError(
                f"distributed= takes DistributedOptions, got "
                f"{type(distributed).__name__}"
            )
        if async_options is not None and not isinstance(
            async_options, AsyncOptions
        ):
            raise TypeError(
                f"async_options= takes AsyncOptions, got "
                f"{type(async_options).__name__}"
            )
        self.mesh = mesh
        self.axes = axes
        self.distributed_options = distributed
        self.async_options = async_options

        if regularizer is None:
            # legacy learn_omega=False maps to the identity_stl member, same
            # as the deprecated entry points (resolve_regularizer precedence)
            regularizer = (
                cfg.omega_regularizer if cfg.learn_omega else "identity_stl"
            )
        if isinstance(regularizer, str):
            regularizer = get_regularizer(
                regularizer, **(regularizer_params or {})
            )
        elif regularizer_params:
            raise ValueError(
                "regularizer_params only apply when regularizer is a name"
            )
        self.regularizer: OmegaRegularizer = regularizer
        self._loss = get_loss(cfg.loss)
        self._fitted = False
        self.sigma_view_: Optional[SigmaView] = None
        self.history_: Dict[str, np.ndarray] = {}
        self.rho_per_outer_: list = []
        self.n_fit_calls_: int = 0
        # serving surface: model version bumps on every install, and every
        # engine/scheduler built from this estimator gets the new snapshot
        # pushed (weak refs: serving objects own their own lifetime)
        self._model_version: int = 0
        self._model_refs: list = []

    # -- training -----------------------------------------------------------
    def _engine_kwargs(self) -> dict:
        options = None
        if self.engine.options_cls is AsyncOptions:
            options = self.async_options
        elif self.engine.options_cls is DistributedOptions:
            options = self.distributed_options
        cfg = self.config
        if (
            self.engine.name == "async"
            and self.distributed_options is not None
        ):
            # async reuses the distributed round internals; its gram knobs
            # ride in through the merged config
            cfg = self.distributed_options.merge_into(cfg)
        axes = self.axes
        if axes is None and self.distributed_options is not None:
            axes = self.distributed_options.axes
        return dict(cfg=cfg, mesh=self.mesh, axes=axes, options=options)

    def _run(self, data: MTLData, init: Optional[WarmStart], track: bool):
        kw = self._engine_kwargs()
        cfg = kw.pop("cfg")
        res: EngineResult = self.engine.run(
            cfg, data, regularizer=self.regularizer, init=init, track=track, **kw
        )
        self._install(res, continued=init is not None)
        return res

    def _install(self, res: EngineResult, continued: bool) -> None:
        self.W_ = res.W
        self.alpha_ = res.alpha
        self.sigma_ = res.sigma
        self.omega_ = res.omega
        self.sigma_view_ = res.sigma_view
        if continued and self.history_:
            self.history_ = _merge_histories(self.history_, res.history)
        else:
            self.history_ = dict(res.history)
        if res.rho_per_outer is not None:
            if continued:
                self.rho_per_outer_.extend(res.rho_per_outer)
            else:
                self.rho_per_outer_ = list(res.rho_per_outer)
        self._fitted = True
        self.n_fit_calls_ += 1
        self._model_version += 1
        self._publish_model()

    def fit(self, data: MTLData, track: bool = True) -> "DMTRLEstimator":
        """Run the full alternating procedure from scratch. Returns self."""
        self.n_fit_calls_ = 0
        self._run(data, init=None, track=track)
        return self

    def partial_fit(self, data: MTLData, track: bool = True) -> "DMTRLEstimator":
        """Continue training from the current (alpha, Sigma) state.

        The first call behaves like ``fit``; later calls warm-start every
        engine from the previous dual variables and task covariance (W is
        rederived as W(alpha)), appending to ``history_``.
        """
        init = None
        if self._fitted:
            # structured fits warm-start from the factors, never a dense
            # (m, m); dense fits keep the historical array path
            sigma = (
                self.sigma_view_
                if self.sigma_view_ is not None
                else self.sigma_
            )
            if not isinstance(sigma, SigmaView):
                sigma = jnp.asarray(sigma)
            omega = self.omega_
            if omega is not None and not isinstance(omega, SigmaView):
                omega = jnp.asarray(omega)
            init = WarmStart(
                alpha=jnp.asarray(self.alpha_),
                sigma=sigma,
                omega=omega,
            )
        self._run(data, init=init, track=track)
        return self

    # -- inference ----------------------------------------------------------
    def _check_fitted(self) -> None:
        if not self._fitted:
            raise NotFittedError(
                "this DMTRLEstimator is not fitted yet; call fit(data) first"
            )

    def decision_function(
        self,
        X: Union[MTLData, np.ndarray],
        tasks: Union[int, Sequence[int], None] = None,
    ) -> np.ndarray:
        """Raw scores z = w_task^T x.

        ``X`` may be an MTLData (returns the (m, n_max) masked score matrix)
        or an (n, d) / (d,) array with ``tasks`` a scalar or (n,) task ids.
        """
        self._check_fitted()
        W = jnp.asarray(self.W_)
        if isinstance(X, MTLData):
            if tasks is not None:
                raise ValueError(
                    "tasks= only applies to array inputs; an MTLData is "
                    "scored per task already (rows of the returned matrix)"
                )
            return np.asarray(dual_mod.predictions(X, W) * X.mask)
        X = jnp.atleast_2d(jnp.asarray(X))
        if X.shape[-1] != W.shape[1]:
            raise ValueError(
                f"X has {X.shape[-1]} features, the fitted W has {W.shape[1]}"
            )
        if tasks is None:
            raise ValueError(
                "array inputs need tasks= (scalar task id or one per row)"
            )
        t = np.broadcast_to(np.asarray(tasks, np.int32), (X.shape[0],))
        if t.size and (t.min() < 0 or t.max() >= W.shape[0]):
            raise ValueError(
                f"task ids must be in [0, {W.shape[0]}), got "
                f"[{t.min()}, {t.max()}]"
            )
        return np.asarray(dual_mod.task_scores(W, X, jnp.asarray(t)))

    def predict(
        self,
        X: Union[MTLData, np.ndarray],
        tasks: Union[int, Sequence[int], None] = None,
    ) -> np.ndarray:
        """Class labels (+-1) for classification losses, raw scores for
        regression losses."""
        z = self.decision_function(X, tasks)
        if self._loss.is_classification:
            return np.where(z >= 0.0, 1.0, -1.0).astype(z.dtype)
        return z

    def score(self, data: MTLData) -> float:
        """Masked mean-per-task accuracy for classification losses,
        explained variance for regression losses (paper's School metric)."""
        self._check_fitted()
        W = jnp.asarray(self.W_)
        if self._loss.is_classification:
            return 1.0 - float(dual_mod.error_rate(data, W))
        return float(dual_mod.explained_variance(data, W))

    @property
    def history(self) -> Dict[str, np.ndarray]:
        """Objective/staleness traces accumulated over fit/partial_fit."""
        self._check_fitted()
        return self.history_

    # -- serving ------------------------------------------------------------
    def model_snapshot(self):
        """The current servable model as a versioned ModelSnapshot
        (serve/scheduler.py): (W, Sigma, version). The version bumps on
        every ``fit``/``partial_fit`` install, so serving consumers can
        tell stale weights from current ones."""
        self._check_fitted()
        from ..serve.scheduler import ModelSnapshot

        sigma = self.sigma_view_ if self.sigma_view_ is not None else self.sigma_
        if not isinstance(sigma, SigmaView):
            sigma = np.asarray(sigma)
        return ModelSnapshot(
            version=self._model_version,
            W=np.asarray(self.W_),
            sigma=sigma,
        )

    def _publish_model(self) -> None:
        """Push the new snapshot to every live serving object built from
        this estimator (hot-swap: engines/schedulers switch weights
        without draining; in-flight tiles finish on the old snapshot).
        Uses the restamping ``publish_weights`` surface so a consumer
        whose version counter ran ahead (manual ``swap``, a transport
        subscription on the same scheduler) still installs the newly
        trained weights instead of colliding."""
        targets = [obj for obj in (r() for r in self._model_refs) if obj is not None]
        self._model_refs = [weakref.ref(obj) for obj in targets]
        if not targets:
            return
        snap = self.model_snapshot()
        for obj in targets:
            obj.publish_weights(snap.W, snap.sigma, snap.version)

    def scoring_engine(self, batch: int = 32, *, gather_sigma_rows: bool = False):
        """Batched MTL scoring engine over the fitted W (serve/mtl.py).

        The engine is version-bound and SUBSCRIBED: a later
        ``partial_fit`` pushes the new weights into it (and ``refresh()``
        pulls them), so it never silently serves stale weights.  The
        fitted Sigma (structured factors when available) rides on the
        snapshot; ``gather_sigma_rows=True`` makes every served tile
        attach each request's task-relatedness row.
        """
        self._check_fitted()
        from ..serve.mtl import MTLScoringEngine

        snap = self.model_snapshot()
        engine = MTLScoringEngine(
            self.W_,
            batch=batch,
            classify=self._loss.is_classification,
            version=self._model_version,
            source=self,
            sigma=snap.sigma,
            gather_sigma_rows=gather_sigma_rows,
        )
        self._model_refs.append(weakref.ref(engine))
        return engine

    def serving_scheduler(
        self,
        batch: int = 32,
        *,
        slo_s: Optional[float] = None,
        policy: str = "edf",
        max_queue: Optional[int] = None,
        clock=None,
        metrics=None,
    ):
        """Continuous-batching scheduler over a fresh scoring engine
        (serve/scheduler.py), subscribed to this estimator's snapshots:
        ``partial_fit`` hot-swaps the served weights between tiles."""
        from ..serve.scheduler import ContinuousBatchingScheduler

        engine = self.scoring_engine(batch=batch)
        kwargs = dict(slo_s=slo_s, policy=policy, max_queue=max_queue,
                      metrics=metrics)
        if clock is not None:
            kwargs["clock"] = clock
        scheduler = ContinuousBatchingScheduler(engine, **kwargs)
        self._model_refs.append(weakref.ref(scheduler))
        return scheduler

    def serving_fleet(
        self,
        n_replicas: int = 2,
        batch: int = 32,
        *,
        slo_s: Optional[float] = None,
        policy: str = "edf",
        max_queue: Optional[int] = None,
        clock=None,
        tile_cost_s: Optional[float] = None,
        spill_depth: Optional[int] = None,
    ):
        """A ``FleetRouter`` over ``n_replicas`` fresh scheduler replicas
        (serve/fleet.py), each wrapping its own scoring engine over the
        fitted model — the multi-host mirror of ``serving_scheduler``.

        Only the ROUTER subscribes to this estimator: a later
        ``partial_fit`` pushes new weights through the router's rolling
        ``publish_weights`` (one replica per router step, monotonic reads
        preserved), never to replicas individually — direct per-replica
        pushes would restamp versions divergently and break the fleet's
        shared version space.  ``slo_s`` doubles as the router's shed
        budget for deadline-less requests; give ``tile_cost_s`` to enable
        backlog-estimate shedding.
        """
        self._check_fitted()
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        from ..serve.fleet import FleetRouter
        from ..serve.mtl import MTLScoringEngine
        from ..serve.scheduler import ContinuousBatchingScheduler

        snap = self.model_snapshot()
        kwargs = dict(slo_s=slo_s, policy=policy, max_queue=max_queue)
        if clock is not None:
            kwargs["clock"] = clock
        replicas = []
        for _ in range(n_replicas):
            engine = MTLScoringEngine(
                self.W_,
                batch=batch,
                classify=self._loss.is_classification,
                version=self._model_version,
                sigma=snap.sigma,
            )
            replicas.append(ContinuousBatchingScheduler(engine, **kwargs))
        router = FleetRouter(
            replicas,
            slo_s=slo_s,
            tile_cost_s=tile_cost_s,
            spill_depth=spill_depth,
        )
        self._model_refs.append(weakref.ref(router))
        return router

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        state = "fitted" if self._fitted else "unfitted"
        return (
            f"DMTRLEstimator(engine={self.engine.name!r}, "
            f"loss={self.config.loss!r}, "
            f"regularizer={self.regularizer.name!r}, {state})"
        )


def _merge_histories(
    old: Dict[str, np.ndarray], new: Dict[str, np.ndarray]
) -> Dict[str, np.ndarray]:
    """Append a continuation run's history: time-like keys are offset so
    they continue where the previous run stopped, the rest concatenate."""
    merged: Dict[str, np.ndarray] = {}
    for k in new.keys() | old.keys():
        if k not in old:
            merged[k] = np.asarray(new[k])
            continue
        if k not in new:
            merged[k] = np.asarray(old[k])
            continue
        o, n = np.asarray(old[k]), np.asarray(new[k])
        if o.shape[1:] != n.shape[1:]:
            raise ValueError(
                f"history key {k!r} changed shape across partial_fit calls: "
                f"{o.shape} vs {n.shape}"
            )
        if o.size and n.size and o.ndim == 1 and (
            k in _TIME_KEYS or k in _COUNTER_KEYS
        ):
            n = n + o.max() + (1 if k in _COUNTER_KEYS else 0)
        merged[k] = np.concatenate([o, n], axis=0)
    return merged
