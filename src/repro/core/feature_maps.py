"""Explicit feature maps phi(.) (paper Section 4).

The paper recommends explicit feature maps over implicit kernels in the
distributed setting (the n x n multi-task kernel matrix K is never
materializable across workers). Provided maps:

 * linear          -- identity (the paper's experimental choice)
 * rff             -- random Fourier features approximating the RBF kernel
                      (Rahimi & Recht 2007), drawn with a shared seed so all
                      workers use the SAME map without communication.
 * backbone        -- final-hidden-state features of any repro.models
                      backbone (the bridge used by repro/train/mtl_head.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FeatureMap:
    name: str
    dim_out: int
    apply: Callable[[Array], Array]  # (n, d_in) -> (n, dim_out)


def linear_map(d_in: int) -> FeatureMap:
    return FeatureMap("linear", d_in, lambda x: x)


def rff_map(
    d_in: int, d_out: int, gamma: float = 1.0, seed: int = 0, dtype=jnp.float32
) -> FeatureMap:
    """phi(x) = sqrt(2/D) cos(x @ Omega + b), Omega ~ N(0, 2*gamma I).

    Unbiased approximation of k(x,x') = exp(-gamma ||x - x'||^2); the map is
    deterministic given the seed, so geo-distributed workers construct it
    locally with zero communication.
    """
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    Wm = jax.random.normal(k1, (d_in, d_out), dtype) * jnp.sqrt(2.0 * gamma)
    b = jax.random.uniform(k2, (d_out,), dtype, 0.0, 2.0 * jnp.pi)
    scale = jnp.sqrt(2.0 / d_out).astype(dtype)

    def apply(x):
        return scale * jnp.cos(x @ Wm + b)

    return FeatureMap("rff", d_out, apply)


def backbone_map(forward_fn: Callable[[Array], Array], dim_out: int) -> FeatureMap:
    """Wrap a backbone's pooled final hidden state as phi."""
    return FeatureMap("backbone", dim_out, forward_fn)


def apply_to_tasks(fmap: FeatureMap, xs: list[np.ndarray]) -> list[np.ndarray]:
    return [np.asarray(fmap.apply(jnp.asarray(x))) for x in xs]
