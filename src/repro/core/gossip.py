"""Decentralized gossip transport: neighbor averaging instead of a server.

The paper's Algorithm 1 assumes a star topology — every worker commits its
``(delta_alpha, delta_b)`` to one parameter server that owns the coupled
state ``W = X diag(alpha) Sigma / lam``.  arXiv:2410.03403 (Distributed
Networked Multi-task Learning) analyzes the serverless regime the ROADMAP
names as the top open item: each node keeps a *replica* of the shared
state and averages it with graph neighbors under a doubly-stochastic
mixing matrix.  This module is that regime, shaped so the rest of the
stack cannot tell the difference:

  * ``GossipTransport`` registers as the ``gossip`` member of the
    ``core.transport`` registry and exposes the exact
    ``gate/snapshot/commit/install_sigma`` surface — all three engines,
    the cross-transport parity tests, and the serving fleet's model
    subscribers work unchanged.
  * Topologies: ``ring`` / ``torus`` / ``complete`` / an explicit
    adjacency matrix (``cfg.topology``); the mixing matrix is the
    Metropolis–Hastings weighting, symmetric and doubly stochastic by
    construction, with ``spectral_gap`` introspection (the 1 - |lambda_2|
    quantity that rates how fast consensus contracts).

Protocol (why it matches the server member)
-------------------------------------------
Node ``g`` owns task rows ``rows_g`` and holds a full replica
``W_nodes[g]`` of the coupled state.  A commit applies the **G-scaled**
local update

    W_nodes[g] += G * Sigma[:, rows_g] @ delta_b_g / lam

so the replica *mean* moves by exactly the server's update.  At every
round boundary (SSP floor advance) one synchronous gossip exchange runs:

    W_nodes <- M @ W_nodes

and because M is doubly stochastic the exchange preserves the replica
mean exactly.  Invariant: ``mean_g W_nodes[g]`` equals the server's ``W``
trajectory at every round boundary (up to float association).  On a
complete graph the Metropolis weights degenerate to uniform ``1/G``, one
exchange reaches exact consensus, and every node serves the same boundary
state the ``threaded`` server would — the acceptance anchor (final
objective within 1e-5 of ``threaded`` on the parity fixture).  On sparser
graphs nodes solve against *locally averaged* state whose disagreement
contracts at rate ``1 - spectral_gap`` per exchange — the bounded
perturbation of the paper's fixed point that arXiv:1609.09563's analysis
tolerates.

Sigma stays driver-installed (the Omega-step is a centralized spectral
update over ``w_true()``, the replica mean); a Sigma install recomputes
``W`` from the exact global dual state and broadcasts it, resetting
consensus.  Decentralizing the Omega-step itself is a ROADMAP follow-up.

Wire accounting: the neighbor exchanges are the gossip wire.  Each node
ships its (codec-encoded, error-feedback-corrected — ``core.wire``)
replica to each neighbor per exchange; ``wire_stats['mix_bytes']``
/ ``raw_mix_bytes`` make the compression measurable, and under lossy
codecs each node keeps its own replica exact (only neighbor contributions
are quantized).  Per-edge staleness (``|completed[g] - completed[h]|`` at
each exchange) lands in the event history (``e_src/e_dst/e_stal/e_tick``)
and is summarized by ``convergence.staleness_summary``.
"""
from __future__ import annotations

import logging
import time
from typing import List, Tuple, Union

import jax.numpy as jnp
import numpy as np

from .sigma_view import SigmaView
from .transport import (
    CommitReceipt,
    Snapshot,
    ThreadedTransport,
    TransportSpec,
    record_receipt,
    register_transport,
)
from .wire import ErrorFeedback
from ..obs.metrics import get_registry
from ..obs.trace import span

logger = logging.getLogger(__name__)

__all__ = [
    "GossipTransport",
    "build_adjacency",
    "mixing_matrix",
    "spectral_gap",
]

Topology = Union[str, tuple, list, np.ndarray]


# ---------------------------------------------------------------------------
# topology -> adjacency -> mixing matrix
# ---------------------------------------------------------------------------
def _torus_sides(G: int) -> Tuple[int, int]:
    """Largest a <= sqrt(G) with a | G; (a, G // a).  a == 1 degenerates
    to a ring (every G has the trivial divisor)."""
    a = 1
    for c in range(2, int(np.sqrt(G)) + 1):
        if G % c == 0:
            a = c
    return a, G // a


def build_adjacency(topology: Topology, G: int) -> np.ndarray:
    """(G, G) symmetric 0/1 adjacency, zero diagonal, connected.

    ``ring``     node i <-> i +- 1 (mod G).
    ``torus``    a x b wrap-around grid with a the largest divisor of G
                 not above sqrt(G); degenerates to a ring for prime G.
    ``complete`` all pairs — the server-equivalent anchor.
    explicit     any square 0/1 array-like; symmetrized view is checked
                 for symmetry, zero diagonal, and connectivity.
    """
    if G < 1:
        raise ValueError(f"need G >= 1 nodes, got {G}")
    adj = np.zeros((G, G), dtype=np.int64)
    if isinstance(topology, str):
        if topology == "complete":
            adj[:] = 1
            np.fill_diagonal(adj, 0)
        elif topology == "ring":
            for i in range(G):
                adj[i, (i + 1) % G] = adj[(i + 1) % G, i] = 1
            np.fill_diagonal(adj, 0)  # G <= 2 self-loops
        elif topology == "torus":
            a, b = _torus_sides(G)
            if a == 1:
                return build_adjacency("ring", G)
            for i in range(G):
                r, c = divmod(i, b)
                for rr, cc in (
                    (r, (c + 1) % b),
                    (r, (c - 1) % b),
                    ((r + 1) % a, c),
                    ((r - 1) % a, c),
                ):
                    j = rr * b + cc
                    if j != i:
                        adj[i, j] = adj[j, i] = 1
        else:
            raise ValueError(
                f"unknown gossip topology {topology!r}; have "
                "'ring' | 'torus' | 'complete' | explicit adjacency matrix"
            )
    else:
        A = np.asarray(topology)
        if A.shape != (G, G):
            raise ValueError(
                f"explicit adjacency must be ({G}, {G}) for {G} workers; "
                f"got shape {A.shape}"
            )
        if not np.array_equal(A, A.T):
            raise ValueError("explicit adjacency must be symmetric")
        if not np.all((A == 0) | (A == 1)):
            raise ValueError("explicit adjacency entries must be 0/1")
        if np.any(np.diag(A) != 0):
            raise ValueError("explicit adjacency must have a zero diagonal")
        adj = A.astype(np.int64)
    if G > 1:
        # BFS connectivity: gossip on a disconnected graph never reaches
        # consensus, so fail loudly at setup, not as silent divergence
        seen = {0}
        frontier = [0]
        while frontier:
            i = frontier.pop()
            for j in np.flatnonzero(adj[i]):
                if int(j) not in seen:
                    seen.add(int(j))
                    frontier.append(int(j))
        if len(seen) != G:
            raise ValueError(
                f"gossip topology is disconnected: reachable component "
                f"from node 0 has {len(seen)} of {G} nodes"
            )
    return adj


def mixing_matrix(adj: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings weights: symmetric, doubly stochastic.

    M[g, h] = 1 / (1 + max(deg_g, deg_h)) on edges, diagonal takes the
    slack.  Doubly stochastic => the gossip exchange preserves the replica
    mean exactly; symmetric => real eigenvalues, so the spectral gap below
    is well defined.  On a complete graph every weight is exactly 1/G.
    """
    G = adj.shape[0]
    deg = adj.sum(axis=1)
    M = np.zeros((G, G), dtype=np.float64)
    for g in range(G):
        for h in np.flatnonzero(adj[g]):
            M[g, h] = 1.0 / (1.0 + max(deg[g], deg[h]))
    np.fill_diagonal(M, 1.0 - M.sum(axis=1))
    return M


def spectral_gap(M: np.ndarray) -> float:
    """1 - |lambda_2(M)|: the per-exchange contraction rate of the
    disagreement (consensus error shrinks by (1 - gap) each exchange).
    1.0 for a complete graph (one exchange = exact consensus), -> 0 for
    long rings."""
    ev = np.sort(np.abs(np.linalg.eigvalsh(M)))[::-1]
    if ev.size < 2:
        return 1.0
    return float(1.0 - ev[1])


# ---------------------------------------------------------------------------
# the transport member
# ---------------------------------------------------------------------------
class GossipTransport(ThreadedTransport):
    """Serverless neighbor-averaging transport (see module docstring).

    Subclasses the threaded member for its worker fan-out, SSP gate, and
    tau machinery; replaces the shared server ``W`` with per-node replicas
    ``W_nodes`` mixed at every round boundary.
    """

    name = "gossip"

    def setup(self, cfg, raw, *, mesh, axes, reg, init, track):
        super().setup(
            cfg, raw, mesh=mesh, axes=axes, reg=reg, init=init, track=track
        )
        topology = getattr(cfg, "topology", "complete")
        self.adjacency = build_adjacency(topology, self.G)
        self.M = mixing_matrix(self.adjacency)
        self.spectral_gap = spectral_gap(self.M)
        self._deg = self.adjacency.sum(axis=1).astype(int)
        self._edges: List[Tuple[int, int]] = [
            (g, h)
            for g in range(self.G)
            for h in range(g + 1, self.G)
            if self.adjacency[g, h]
        ]
        dtype = self.W.dtype
        # split M into diagonal + off-diagonal: a node's own replica never
        # rides the wire, so under lossy codecs only the neighbor terms
        # see quantization
        self._M_diag = jnp.asarray(np.diag(self.M), dtype)
        self._M_off = jnp.asarray(self.M - np.diag(np.diag(self.M)), dtype)
        self._mix_ef = ErrorFeedback(self.codec)
        self.W_nodes = jnp.asarray(
            jnp.broadcast_to(self.W, (self.G,) + self.W.shape)
        )
        self._boundary_nodes = self.W_nodes
        # gossip-only event-history keys (per-edge staleness at each
        # exchange); staleness_summary picks them up when present
        for k in ("e_src", "e_dst", "e_stal", "e_tick"):
            self.hist[k] = []
        self.wire_stats["topology"] = (
            topology if isinstance(topology, str) else "explicit"
        )
        self.wire_stats["spectral_gap"] = self.spectral_gap
        logger.info(
            "gossip transport: %d nodes, topology %s (%d edges), "
            "spectral gap %.4f, codec %s",
            self.G,
            self.wire_stats["topology"],
            len(self._edges),
            self.spectral_gap,
            self.codec.name,
        )
        get_registry().gauge(
            "repro_gossip_spectral_gap",
            "1 - |lambda_2| of the mixing matrix (consensus contraction "
            "per exchange)",
            labels=("topology",),
        ).set(self.spectral_gap, topology=self.wire_stats["topology"])

    # -- consensus ----------------------------------------------------------
    def _consensus_w(self):
        return jnp.mean(self.W_nodes, axis=0)

    def _mix(self, tick: float) -> None:
        """One synchronous gossip exchange (called under the lock at a
        round boundary): record per-edge staleness, ship each replica to
        its neighbors through the codec, contract with M."""
        with span(
            "mix",
            cat="gossip",
            n_edges=len(self._edges),
            exchange=self.wire_stats["n_exchanges"],
        ):
            self._mix_locked(tick)

    def _mix_locked(self, tick: float) -> None:
        for g, h in self._edges:
            self.hist["e_src"].append(g)
            self.hist["e_dst"].append(h)
            self.hist["e_stal"].append(
                abs(self.completed[g] - self.completed[h])
            )
            self.hist["e_tick"].append(tick)
        per_node_raw = int(
            np.prod(self.W_nodes.shape[1:])
        ) * self.W_nodes.dtype.itemsize
        if self.codec.name == "none" or not self._edges:
            q = self.W_nodes
            enc_nbytes = [per_node_raw] * self.G
        else:
            qs, enc_nbytes = [], []
            for g in range(self.G):
                enc = self._mix_ef.encode(g, np.asarray(self.W_nodes[g]))
                qs.append(self.codec.decode(enc))
                enc_nbytes.append(enc.nbytes)
            q = jnp.asarray(np.stack(qs), self.W_nodes.dtype)
        self.wire_stats["n_exchanges"] += 1
        self.wire_stats["mix_bytes"] += sum(
            enc_nbytes[g] * int(self._deg[g]) for g in range(self.G)
        )
        self.wire_stats["raw_mix_bytes"] += per_node_raw * int(
            self._deg.sum()
        )
        self.W_nodes = (
            self._M_diag[:, None, None] * self.W_nodes
            + jnp.einsum("gh,hmd->gmd", self._M_off, q)
        )
        self.W = self._consensus_w()

    # -- protocol overrides (all under the server condition variable) -------
    def snapshot(self, worker):
        with span("snapshot", cat="transport", worker=worker), self.cond:
            self._check_abort()
            self._maybe_install(worker)
            rows = self._rows(worker)
            self._snap_version[worker] = self._boundary_version
            self._snap_lag[worker] = self.completed[worker] - min(
                self.completed
            )
            _W_b, sigma_b = self._boundary
            W_b = self._boundary_nodes[worker]  # node-LOCAL replica
            if isinstance(sigma_b, SigmaView):
                return Snapshot(
                    W_rows=W_b[rows],
                    sigma_rows=None,
                    alpha_rows=self.alpha[rows],
                    version=self._boundary_version,
                    sigma_diag=sigma_b.diag()[rows],
                )
            return Snapshot(
                W_rows=W_b[rows],
                sigma_rows=sigma_b[rows],
                alpha_rows=self.alpha[rows],
                version=self._boundary_version,
            )

    def commit(self, worker, rnd, delta):
        dalpha, db = delta
        with span("commit", cat="transport", worker=worker, round=rnd), self.cond:
            self._check_abort()
            self._maybe_install(worker)
            cfg = self.cfg
            rows = self._rows(worker)
            # alpha rows are node-owned dual state, identical to the server
            self.alpha = self.alpha.at[rows].add(cfg.eta * dalpha)
            if isinstance(self.sigma, SigmaView):
                upd = self.sigma.col_block_matvec(rows.start, db) / cfg.lam
            else:
                upd = (jnp.swapaxes(self.sigma[rows], 0, 1) @ db) / cfg.lam
            # G-scaled LOCAL apply: the replica mean moves by exactly the
            # server's W update (module docstring invariant)
            self.W_nodes = self.W_nodes.at[worker].add(self.G * upd)
            stal = self.commits_total - self._snap_version[worker]
            self.commits_total += 1
            self.commits_outer += 1
            floor_before = min(self.completed)
            self.completed[worker] += 1
            tick = time.monotonic() - self._t0
            if min(self.completed) > floor_before:
                # round boundary: one gossip exchange, then freeze the
                # per-node boundary replicas later starters will read
                self._mix(tick)
                self._boundary = (self.W, self.sigma)
                self._boundary_nodes = self.W_nodes
                self._boundary_version = self.commits_total
            receipt = CommitReceipt(
                worker=worker,
                round=self.p * self.R + rnd,
                staleness=stal,
                lag=self._snap_lag[worker],
                tick=tick,
                version=self.commits_total,
                tau=self.tau,
            )
            record_receipt(self.hist, receipt)
            self._after_commit_event(tick, self.alpha, self.sigma)
            self.cond.notify_all()
            return receipt

    def _install(self, sig, om):
        with span("install_sigma", cat="transport", transport=self.name):
            self.sigma, self.omega = sig, om
            # consensus reset: W is recomputed from the exact global dual
            # state and broadcast, so all replicas agree and any accumulated
            # quantization residual refers to dead state
            self.W = self._w_from_alpha(self.alpha, self.sigma)
            self.W_nodes = jnp.asarray(
                jnp.broadcast_to(self.W, (self.G,) + self.W.shape)
            )
            self._commit_ef.reset()
            self._mix_ef.reset()
            self._boundary = (self.W, self.sigma)
            self._boundary_nodes = self.W_nodes
            self._boundary_version = self.commits_total
            if isinstance(self.sigma, SigmaView):
                sigma_raw = self.sigma.unpad(self.raw.m)
            else:
                sigma_raw = self.sigma[: self.raw.m, : self.raw.m]
            self._notify_model(self.W[: self.raw.m, : self.raw.d], sigma_raw)

    # -- driver lifecycle ---------------------------------------------------
    def _begin_w_step(self, p):
        with self.cond:
            self.W = self._consensus_w()
            super()._begin_w_step(p)
            self._boundary_nodes = self.W_nodes

    def w_true(self):
        with self.lock:
            return self._consensus_w()[: self.raw.m]

    def result(self):
        with self.lock:
            self.W = self._consensus_w()
        return super().result()


register_transport(
    TransportSpec(
        name="gossip",
        description="serverless neighbor averaging over a configurable "
        "topology (ring/torus/complete/explicit): per-node W replicas, "
        "Metropolis mixing at round boundaries; complete graph matches "
        "the threaded server",
        needs_mesh=False,
        factory=GossipTransport,
    )
)
