"""Convex losses, their conjugates, and closed-form SDCA coordinate updates.

The paper (Thm. 1) derives the dual of the MTRL W-step for *any* convex loss
``l(z, y)`` with conjugate ``l*(u, y) = sup_z (u z - l(z, y))``.  Local SDCA
(Algorithm 2) maximizes, per sampled coordinate j of task i, the scalar
concave function (after multiplying the local subproblem by ``n_i``):

    f(delta) = -l*(-(atilde + delta)) - c * delta - (a / 2) * delta**2

with
    atilde = alpha_j + dalpha_j                (current dual value)
    c      = w_i^T x_j + kappa * x_j^T r       (current "margin")
    a      = kappa * ||x_j||^2                 (curvature)
    kappa  = rho * sigma_ii / (lambda * n_i)
    r      = X_i^T dalpha_[i]                  (running block correction)

Every loss below supplies the closed-form (or Newton) argmax ``delta``.

Losses are registered by name so configs stay declarative. Conventions:
 - classification labels y in {-1, +1}; regression y real.
 - ``smoothness mu``: l is (1/mu)-smooth (None => non-smooth).
 - ``lipschitz L``: l is L-Lipschitz (None => not globally Lipschitz).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class Loss:
    """A convex loss with everything SDCA / duality-gap evaluation needs."""

    name: str
    value: Callable[[Array, Array], Array]          # l(z, y)
    conjugate: Callable[[Array, Array], Array]      # l*(u, y)
    sdca_delta: Callable[[Array, Array, Array, Array], Array]
    #   sdca_delta(atilde, c, a, y) -> delta maximizing f above.
    dual_feasible: Callable[[Array, Array], Array]  # project alpha into dom(l*(-.))
    subgradient: Callable[[Array, Array], Array]    # an element of dl/dz at z
    smoothness_mu: Optional[float] = None           # l is (1/mu)-smooth
    lipschitz: Optional[float] = None               # l is L-Lipschitz
    is_classification: bool = True


_REGISTRY: Dict[str, Loss] = {}


def register(loss: Loss) -> Loss:
    _REGISTRY[loss.name] = loss
    return loss


def get_loss(name: str) -> Loss:
    try:
        return _REGISTRY[name]
    except KeyError as e:
        raise KeyError(f"unknown loss {name!r}; have {sorted(_REGISTRY)}") from e


def registered_losses():
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# hinge:  l(z) = max(0, 1 - y z).        L = 1 Lipschitz, non-smooth.
#   l*(u) = y u   for  y u in [-1, 0], +inf otherwise
#   => -l*(-alpha) = y alpha, feasible iff y alpha in [0, 1].
# closed form: unconstrained max of  y(atilde+delta) - c delta - a/2 delta^2
#   delta_u = (y - c) / a ; project alpha_new into y*alpha in [0,1].
# ---------------------------------------------------------------------------
def _hinge_value(z, y):
    return jnp.maximum(0.0, 1.0 - y * z)


def _hinge_conj(u, y):
    # l*(u) = u*y on the feasible set; caller is responsible for feasibility
    # (dual iterates produced by _hinge_delta always are).
    return u * y


def _hinge_delta(atilde, c, a, y):
    a = jnp.maximum(a, _EPS)
    anew = y * jnp.clip(y * (atilde + (y - c) / a), 0.0, 1.0)
    return anew - atilde


def _hinge_feasible(alpha, y):
    return y * jnp.clip(y * alpha, 0.0, 1.0)


def _hinge_subgrad(z, y):
    return jnp.where(y * z < 1.0, -y, 0.0)


register(
    Loss(
        name="hinge",
        value=_hinge_value,
        conjugate=_hinge_conj,
        sdca_delta=_hinge_delta,
        dual_feasible=_hinge_feasible,
        subgradient=_hinge_subgrad,
        smoothness_mu=None,
        lipschitz=1.0,
        is_classification=True,
    )
)


# ---------------------------------------------------------------------------
# squared:  l(z) = 0.5 (z - y)^2.   (1/mu)-smooth with mu = 1.
#   l*(u) = 0.5 u^2 + u y   =>  -l*(-alpha) = -0.5 alpha^2 + alpha y
#   delta = (y - c - atilde) / (1 + a)
# ---------------------------------------------------------------------------
def _sq_value(z, y):
    return 0.5 * (z - y) ** 2


def _sq_conj(u, y):
    return 0.5 * u**2 + u * y


def _sq_delta(atilde, c, a, y):
    return (y - c - atilde) / (1.0 + a)


def _sq_feasible(alpha, y):
    return alpha


def _sq_subgrad(z, y):
    return z - y


register(
    Loss(
        name="squared",
        value=_sq_value,
        conjugate=_sq_conj,
        sdca_delta=_sq_delta,
        dual_feasible=_sq_feasible,
        subgradient=_sq_subgrad,
        smoothness_mu=1.0,
        lipschitz=None,
        is_classification=False,
    )
)


# ---------------------------------------------------------------------------
# smoothed hinge (gamma = 0.5):
#   l(z) = 0                      if y z >= 1
#        = 1 - y z - gamma/2      if y z <= 1 - gamma
#        = (1 - y z)^2 / (2 gamma) otherwise
#   (1/gamma)-smooth and 1-Lipschitz.
#   l*(u) = y u + gamma/2 u^2  for y u in [-1, 0]
#   delta_u = (y - c - gamma atilde) / (gamma + a); project y alpha in [0,1].
# ---------------------------------------------------------------------------
_GAMMA = 0.5


def _sh_value(z, y):
    m = 1.0 - y * z
    return jnp.where(
        m <= 0.0, 0.0, jnp.where(m >= _GAMMA, m - _GAMMA / 2.0, m**2 / (2.0 * _GAMMA))
    )


def _sh_conj(u, y):
    return u * y + _GAMMA / 2.0 * u**2


def _sh_delta(atilde, c, a, y):
    anew_u = atilde + (y - c - _GAMMA * atilde) / (_GAMMA + a)
    anew = y * jnp.clip(y * anew_u, 0.0, 1.0)
    return anew - atilde


def _sh_feasible(alpha, y):
    return y * jnp.clip(y * alpha, 0.0, 1.0)


def _sh_subgrad(z, y):
    m = 1.0 - y * z
    return jnp.where(m <= 0.0, 0.0, jnp.where(m >= _GAMMA, -y, -y * m / _GAMMA))


register(
    Loss(
        name="smoothed_hinge",
        value=_sh_value,
        conjugate=_sh_conj,
        sdca_delta=_sh_delta,
        dual_feasible=_sh_feasible,
        subgradient=_sh_subgrad,
        smoothness_mu=_GAMMA,
        lipschitz=1.0,
        is_classification=True,
    )
)


# ---------------------------------------------------------------------------
# logistic:  l(z) = log(1 + exp(-y z)).  (1/4)-smooth... precisely 4-smooth:
# l'' <= 1/4 so it is (1/mu)-smooth with mu = 4. Also 1-Lipschitz.
#   l*(u): with s = -u y in (0,1):  s log s + (1-s) log(1-s)
#   => -l*(-alpha), s = y alpha in (0,1): binary entropy (negative).
# No closed form => a few guarded Newton steps on
#   f(delta) = -[s log s + (1-s)log(1-s)] - c delta - a/2 delta^2,  s=y(atilde+delta)
#   f'(delta) = -y log(s/(1-s)) - c - a delta
#   f''(delta) = -1/(s(1-s)) - a
# ---------------------------------------------------------------------------
_NEWTON_STEPS = 12
_S_EPS = 1e-6


def _log_value(z, y):
    # numerically stable log(1 + exp(-yz))
    m = -y * z
    return jnp.logaddexp(0.0, m)


def _xlogx(s):
    return jnp.where(s > 0.0, s * jnp.log(jnp.maximum(s, _EPS)), 0.0)


def _log_conj(u, y):
    s = jnp.clip(-u * y, 0.0, 1.0)
    return _xlogx(s) + _xlogx(1.0 - s)


def _log_delta(atilde, c, a, y):
    def body(_, delta):
        s = jnp.clip(y * (atilde + delta), _S_EPS, 1.0 - _S_EPS)
        g = -y * (jnp.log(s) - jnp.log1p(-s)) - c - a * delta
        h = -1.0 / (s * (1.0 - s)) - a
        step = g / h
        delta_new = delta - step
        # keep iterate strictly feasible: y * alpha_new in (0, 1)
        anew = y * jnp.clip(y * (atilde + delta_new), _S_EPS, 1.0 - _S_EPS)
        return anew - atilde

    # start from a feasible point (pull atilde inside the open interval)
    a0 = y * jnp.clip(y * atilde, _S_EPS, 1.0 - _S_EPS)
    delta0 = a0 - atilde
    return jax.lax.fori_loop(0, _NEWTON_STEPS, body, delta0)


def _log_feasible(alpha, y):
    return y * jnp.clip(y * alpha, _S_EPS, 1.0 - _S_EPS)


def _log_subgrad(z, y):
    return -y * jax.nn.sigmoid(-y * z)


register(
    Loss(
        name="logistic",
        value=_log_value,
        conjugate=_log_conj,
        sdca_delta=_log_delta,
        dual_feasible=_log_feasible,
        subgradient=_log_subgrad,
        smoothness_mu=4.0,
        lipschitz=1.0,
        is_classification=True,
    )
)


# ---------------------------------------------------------------------------
# epsilon-insensitive:  l(z) = max(0, |z - y| - eps).  1-Lipschitz, non-smooth.
# (used by the paper's PMTL comparison; provided for completeness)
#   l*(u) = u y + eps |u|  for |u| <= 1
#   f(delta) = (atilde+delta) y - eps|atilde+delta| - c delta - a/2 delta^2
# piecewise quadratic in alpha_new = atilde + delta over [-1, 1]:
#   on alpha_new > 0:  opt at (y - eps - c + a atilde)/a
#   on alpha_new < 0:  opt at (y + eps - c + a atilde)/a
# evaluate both candidates (clipped to their half-interval) plus 0, pick best.
# ---------------------------------------------------------------------------
_EPS_TUBE = 0.1


def _ei_value(z, y):
    return jnp.maximum(0.0, jnp.abs(z - y) - _EPS_TUBE)


def _ei_conj(u, y):
    return u * y + _EPS_TUBE * jnp.abs(u)


def _ei_obj(anew, atilde, c, a, y):
    delta = anew - atilde
    return anew * y - _EPS_TUBE * jnp.abs(anew) - c * delta - 0.5 * a * delta**2


def _ei_delta(atilde, c, a, y):
    a_ = jnp.maximum(a, _EPS)
    cand_pos = jnp.clip((y - _EPS_TUBE - c + a_ * atilde) / a_, 0.0, 1.0)
    cand_neg = jnp.clip((y + _EPS_TUBE - c + a_ * atilde) / a_, -1.0, 0.0)
    cands = jnp.stack([cand_pos, cand_neg, jnp.zeros_like(cand_pos)])
    vals = _ei_obj(cands, atilde, c, a, y)
    anew = cands[jnp.argmax(vals)]
    return anew - atilde


def _ei_feasible(alpha, y):
    return jnp.clip(alpha, -1.0, 1.0)


def _ei_subgrad(z, y):
    d = z - y
    return jnp.where(d > _EPS_TUBE, 1.0, jnp.where(d < -_EPS_TUBE, -1.0, 0.0))


register(
    Loss(
        name="eps_insensitive",
        value=_ei_value,
        conjugate=_ei_conj,
        sdca_delta=_ei_delta,
        dual_feasible=_ei_feasible,
        subgradient=_ei_subgrad,
        smoothness_mu=None,
        lipschitz=1.0,
        is_classification=False,
    )
)
