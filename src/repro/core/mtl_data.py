"""Padded multi-task dataset container.

Tasks have unequal sample counts n_i; to vmap/shard over tasks we pad every
task to ``n_max`` and carry a validity mask. Padded coordinates never get
sampled by SDCA (indices are drawn in [0, n_i)) and carry zero weight in all
objective evaluations.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MTLData:
    """m tasks padded to a common n_max.

    x:    (m, n_max, d) float  features (phi already applied)
    y:    (m, n_max)    float  labels (+-1 classification / real regression)
    mask: (m, n_max)    float  1.0 on real samples, 0.0 on padding
    n:    (m,)          int32  true per-task sample counts
    """

    x: Array
    y: Array
    mask: Array
    n: Array

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        return (self.x, self.y, self.mask, self.n), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- accessors ----------------------------------------------------------
    @property
    def m(self) -> int:
        return self.x.shape[0]

    @property
    def n_max(self) -> int:
        return self.x.shape[1]

    @property
    def d(self) -> int:
        return self.x.shape[2]

    def task(self, i: int) -> Tuple[Array, Array, int]:
        ni = int(self.n[i])
        return self.x[i, :ni], self.y[i, :ni], ni

    def pad_tasks(self, m_new: int) -> "MTLData":
        """Pad the task axis to ``m_new`` with empty (all-masked) tasks."""
        if m_new == self.m:
            return self
        assert m_new > self.m
        pad = m_new - self.m
        z = lambda a: jnp.concatenate(
            [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0
        )
        # n=1 on padded tasks keeps 1/n_i finite; mask stays 0 so they are inert.
        n_pad = jnp.concatenate([self.n, jnp.ones((pad,), self.n.dtype)])
        return MTLData(z(self.x), z(self.y), z(self.mask), n_pad)


def from_task_list(
    xs: Sequence[np.ndarray], ys: Sequence[np.ndarray], n_max: int | None = None
) -> MTLData:
    """Build padded MTLData from per-task (n_i, d) / (n_i,) numpy arrays."""
    m = len(xs)
    assert m == len(ys) and m > 0
    d = xs[0].shape[1]
    ns = [int(x.shape[0]) for x in xs]
    n_max = n_max or max(ns)
    X = np.zeros((m, n_max, d), np.float32)
    Y = np.zeros((m, n_max), np.float32)
    M = np.zeros((m, n_max), np.float32)
    for i, (x, y) in enumerate(zip(xs, ys)):
        ni = ns[i]
        assert ni <= n_max, f"task {i} has {ni} > n_max={n_max}"
        X[i, :ni] = x
        Y[i, :ni] = np.asarray(y).reshape(-1)
        M[i, :ni] = 1.0
    return MTLData(
        jnp.asarray(X), jnp.asarray(Y), jnp.asarray(M), jnp.asarray(ns, jnp.int32)
    )


def normalize_rows(data: MTLData, max_norm: float = 1.0) -> MTLData:
    """Scale every sample to ||x|| <= max_norm (the theory in Lemma 7 assumes
    normalized features; the algorithm itself does not require it)."""
    norms = jnp.linalg.norm(data.x, axis=-1, keepdims=True)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norms, 1e-12))
    return MTLData(data.x * scale, data.y, data.mask, data.n)


def train_test_split_tasks(
    xs: List[np.ndarray],
    ys: List[np.ndarray],
    frac_train: float,
    seed: int,
) -> Tuple[List[np.ndarray], List[np.ndarray], List[np.ndarray], List[np.ndarray]]:
    rng = np.random.RandomState(seed)
    xtr, ytr, xte, yte = [], [], [], []
    for x, y in zip(xs, ys):
        n = x.shape[0]
        perm = rng.permutation(n)
        k = max(1, int(round(frac_train * n)))
        k = min(k, n - 1) if n > 1 else 1
        tr, te = perm[:k], perm[k:]
        xtr.append(x[tr]), ytr.append(y[tr])
        xte.append(x[te]), yte.append(y[te])
    return xtr, ytr, xte, yte
