"""Omega-step: closed-form update of the task precision matrix.

Zhang & Yeung (2010) show that with W fixed, the minimizer of
    tr(W Omega W^T)  s.t.  Omega^{-1} >= 0, tr(Omega^{-1}) = 1
is
    Sigma = Omega^{-1} = (W^T W)^{1/2} / tr((W^T W)^{1/2}).

We compute it via the m x m eigendecomposition (the paper notes distributed
SVD could be used for very large m; here m x m is host-trivial up to ~8k
tasks). A jitter keeps Sigma invertible when W is rank-deficient (e.g. the
very first alternation where W may be near 0); trace is renormalized to 1 so
the constraint still holds exactly.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def omega_step(W: Array, jitter: float = 1e-6) -> Tuple[Array, Array]:
    """W: (m, d) rows = task weight vectors. Returns (sigma, omega).

    sigma = Omega^{-1} (covariance), omega = precision; both (m, m),
    symmetric PD, tr(sigma) == 1.
    """
    m = W.shape[0]
    M = W @ W.T  # (m, m) = W^T W in the paper's (d, m) column convention
    M = 0.5 * (M + M.T)
    evals, evecs = jnp.linalg.eigh(M)
    s = jnp.sqrt(jnp.maximum(evals, 0.0))
    tr = jnp.sum(s)
    # degenerate W (all zeros) -> fall back to Sigma = I/m (the init).
    safe = tr > 1e-30
    s_n = jnp.where(safe, s / jnp.maximum(tr, 1e-30), jnp.ones_like(s) / m)
    s_n = s_n + jitter
    s_n = s_n / jnp.sum(s_n)  # renormalize trace to exactly 1
    sigma = (evecs * s_n) @ evecs.T
    omega = (evecs * (1.0 / s_n)) @ evecs.T
    sigma = 0.5 * (sigma + sigma.T)
    omega = 0.5 * (omega + omega.T)
    return sigma, omega


def init_sigma(m: int, dtype=jnp.float32) -> Tuple[Array, Array]:
    """Paper's Algorithm 1 init: Omega = m I, Sigma = I/m."""
    sigma = jnp.eye(m, dtype=dtype) / m
    omega = jnp.eye(m, dtype=dtype) * m
    return sigma, omega


def correlation_from_sigma(sigma: Array) -> Array:
    """Task correlation matrix from the covariance Sigma (for Fig. 2)."""
    dd = jnp.sqrt(jnp.maximum(jnp.diag(sigma), 1e-30))
    return sigma / (dd[:, None] * dd[None, :])


def rho_lemma10(sigma: Array, eta: float = 1.0) -> Array:
    """Paper Lemma 10 upper bound: eta * max_i sum_i' |sigma_ii'| / sigma_ii.

    This is what the paper's experiments use for rho (Section 7.1).
    """
    dd = jnp.maximum(jnp.diag(sigma), 1e-30)
    return eta * jnp.max(jnp.sum(jnp.abs(sigma), axis=1) / dd)


def rho_spectral(sigma: Array, eta: float = 1.0) -> Array:
    """Tighter bound: eta * lambda_max(D^{-1/2} Sigma D^{-1/2}), D = diag(Sigma).

    alpha^T K alpha = sum_{ii'} sigma_ii' b_i . b_i' and the block-diagonal
    denominator is sum_i sigma_ii ||b_i||^2; the sup over independent b_i of the ratio
    equals the max eigenvalue of the diagonally-rescaled Sigma (attained with
    collinear b_i). Always <= Lemma 10's bound; still an upper bound on
    rho_min of Eq. (5). Beyond-paper refinement used by the optimized path.
    """
    dd = jnp.sqrt(jnp.maximum(jnp.diag(sigma), 1e-30))
    S = sigma / (dd[:, None] * dd[None, :])
    ev = jnp.linalg.eigvalsh(0.5 * (S + S.T))
    return eta * ev[-1]
