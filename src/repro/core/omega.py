"""Omega-step: closed-form update of the task precision matrix.

Zhang & Yeung (2010) show that with W fixed, the minimizer of
    tr(W Omega W^T)  s.t.  Omega^{-1} >= 0, tr(Omega^{-1}) = 1
is
    Sigma = Omega^{-1} = (W^T W)^{1/2} / tr((W^T W)^{1/2}).

We compute it via the m x m eigendecomposition (the paper notes distributed
SVD could be used for very large m; here m x m is host-trivial up to ~8k
tasks). A jitter keeps Sigma invertible when W is rank-deficient (e.g. the
very first alternation where W may be near 0); trace is renormalized to 1 so
the constraint still holds exactly.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def omega_step(W: Array, jitter: float = 1e-6) -> Tuple[Array, Array]:
    """W: (m, d) rows = task weight vectors. Returns (sigma, omega).

    sigma = Omega^{-1} (covariance), omega = precision; both (m, m),
    symmetric PD, tr(sigma) == 1.
    """
    m = W.shape[0]
    M = W @ W.T  # (m, m) = W^T W in the paper's (d, m) column convention
    M = 0.5 * (M + M.T)
    evals, evecs = jnp.linalg.eigh(M)
    s = jnp.sqrt(jnp.maximum(evals, 0.0))
    tr = jnp.sum(s)
    # degenerate W (all zeros) -> fall back to Sigma = I/m (the init).
    safe = tr > 1e-30
    s_n = jnp.where(safe, s / jnp.maximum(tr, 1e-30), jnp.ones_like(s) / m)
    s_n = s_n + jitter
    s_n = s_n / jnp.sum(s_n)  # renormalize trace to exactly 1
    sigma = (evecs * s_n) @ evecs.T
    omega = (evecs * (1.0 / s_n)) @ evecs.T
    sigma = 0.5 * (sigma + sigma.T)
    omega = 0.5 * (omega + omega.T)
    return sigma, omega


@functools.partial(jax.jit, static_argnums=(1, 2))
def omega_step_lowrank(
    W: Array, rank: int, iters: int = 8, jitter: float = 1e-6
) -> Tuple[Array, Array, Array]:
    """Rank-r Zhang-Yeung Omega-step without ever forming M = W W^T.

    Subspace iteration with W-matvecs only (V <- W (W^T V), QR) followed by
    Rayleigh-Ritz on the r-dimensional subspace gives the top-r eigenpairs
    of M; sqrt of the Ritz values are the leading singular values of the
    paper's (W^T W)^{1/2}. The trailing spectral mass is folded into a
    per-task residual diagonal d_i = sqrt(max(M_ii - sum_k lam_k U_ik^2, 0))
    so the trace constraint still holds exactly after normalization.

    Cost: O(m d r) per iteration + an r x r eigh — no m x m anything.
    Exact at r >= rank(M) (in particular r = m), where it reproduces
    ``omega_step``'s Sigma: jitter is applied to the diagonal and the trace
    renormalized by the same (1 + m*jitter) split as the dense path.

    Returns ``(U, s, d)`` with U (m, r) orthonormal, s (r,) >= 0 Ritz-sqrt
    weights and d (m,) > 0: Sigma = U diag(s) U^T + diag(d), tr == 1.
    """
    m = W.shape[0]
    r = min(rank, m)
    V = jax.random.normal(jax.random.PRNGKey(17), (m, r), W.dtype)
    V, _ = jnp.linalg.qr(V)

    def body(V, _):
        V = W @ (W.T @ V)
        V, _ = jnp.linalg.qr(V)
        return V, None

    V, _ = jax.lax.scan(body, V, None, length=iters)
    T = V.T @ (W @ (W.T @ V))
    evals, S = jnp.linalg.eigh(0.5 * (T + T.T))
    U = V @ S
    lam = jnp.maximum(evals, 0.0)
    s = jnp.sqrt(lam)
    # residual diagonal: spectral mass M_ii not captured by the subspace
    M_diag = jnp.sum(W * W, axis=1)
    captured = jnp.sum((U * U) * lam[None, :], axis=1)
    d_raw = jnp.sqrt(jnp.maximum(M_diag - captured, 0.0))
    tr = jnp.sum(s) + jnp.sum(d_raw)
    safe = tr > 1e-30
    s_n = jnp.where(safe, s / jnp.maximum(tr, 1e-30), jnp.zeros_like(s))
    d_n = jnp.where(safe, d_raw / jnp.maximum(tr, 1e-30), jnp.ones_like(d_raw) / m)
    d_n = d_n + jitter
    renorm = jnp.sum(s_n) + jnp.sum(d_n)
    return U, s_n / renorm, d_n / renorm


def init_sigma(m: int, dtype=jnp.float32) -> Tuple[Array, Array]:
    """Paper's Algorithm 1 init: Omega = m I, Sigma = I/m."""
    sigma = jnp.eye(m, dtype=dtype) / m
    omega = jnp.eye(m, dtype=dtype) * m
    return sigma, omega


def correlation_from_sigma(sigma: Array) -> Array:
    """Task correlation matrix from the covariance Sigma (for Fig. 2)."""
    dd = jnp.sqrt(jnp.maximum(jnp.diag(sigma), 1e-30))
    return sigma / (dd[:, None] * dd[None, :])


def rho_lemma10(sigma: Array, eta: float = 1.0) -> Array:
    """Paper Lemma 10 upper bound: eta * max_i sum_i' |sigma_ii'| / sigma_ii.

    This is what the paper's experiments use for rho (Section 7.1).
    """
    dd = jnp.maximum(jnp.diag(sigma), 1e-30)
    return eta * jnp.max(jnp.sum(jnp.abs(sigma), axis=1) / dd)


def rho_spectral(sigma: Array, eta: float = 1.0) -> Array:
    """Tighter bound: eta * lambda_max(D^{-1/2} Sigma D^{-1/2}), D = diag(Sigma).

    alpha^T K alpha = sum_{ii'} sigma_ii' b_i . b_i' and the block-diagonal
    denominator is sum_i sigma_ii ||b_i||^2; the sup over independent b_i of the ratio
    equals the max eigenvalue of the diagonally-rescaled Sigma (attained with
    collinear b_i). Always <= Lemma 10's bound; still an upper bound on
    rho_min of Eq. (5). Beyond-paper refinement used by the optimized path.
    """
    dd = jnp.sqrt(jnp.maximum(jnp.diag(sigma), 1e-30))
    S = sigma / (dd[:, None] * dd[None, :])
    ev = jnp.linalg.eigvalsh(0.5 * (S + S.T))
    return eta * ev[-1]
