"""Pluggable Omega-regularizer family (the paper's general dual form).

The paper's dual derivation (Thm. 1) never uses the *specific* Zhang-Yeung
trace-constrained Omega: any symmetric PD task-coupling Sigma yields the
same dual problem, local subproblems, and rho-bounded aggregation. What
distinguishes family members is only

  * how Sigma is INITIALIZED,
  * whether/how Sigma is UPDATED after each W-step (Algorithm 1 row 11),
  * the rho upper bound fed to the local subproblems (Lemma 10 / spectral
    both apply to any PD Sigma, so the default bound is shared).

This registry names the family members so every engine (``fit``,
``fit_distributed``, ``fit_async``) and the duality-gap code consume them
uniformly — mirroring the solver-backend registry (docs/DESIGN.md §5).

Registered members:

  trace_constraint  the paper / Zhang & Yeung (2010): closed-form
                    Sigma = (W^T W)^{1/2} / tr((W^T W)^{1/2}) after every
                    W-step (core/omega.py:omega_step). The default.
  graph_laplacian   fixed task-graph coupling (Wang et al.,
                    arXiv:1802.03830): Omega = coupling * L + eps I from a
                    known task graph; Sigma never updates.
  identity_stl      Sigma fixed at I/m — independent ridge-regularized
                    tasks; subsumes ``DMTRLConfig.learn_omega=False``.
  frobenius_shrunk  trace_constraint update shrunk toward I/m:
                    Sigma = (1-g) Sigma_ZY + g I/m (trace stays 1). A
                    shared-representation-flavoured member in the spirit of
                    arXiv:1603.02185: task couplings are learned but
                    bounded away from rank collapse.

Usage:

    reg = get_regularizer("graph_laplacian", adjacency=A)
    est = DMTRLEstimator(regularizer="frobenius_shrunk",
                         regularizer_params={"shrinkage": 0.3})
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import omega as omega_mod

Array = jax.Array


def default_rho_bound(
    sigma: Array, eta: float = 1.0, mode: str = "lemma10", fixed: float = 1.0
) -> float:
    """The paper's rho bounds; valid for ANY symmetric PD Sigma, so every
    family member shares it unless it can prove something tighter."""
    if mode == "fixed":
        return float(fixed)
    if mode == "spectral":
        return float(omega_mod.rho_spectral(sigma, eta))
    return float(omega_mod.rho_lemma10(sigma, eta))


@dataclasses.dataclass(frozen=True)
class OmegaRegularizer:
    """One named member of the regularizer family.

    ``init(m, dtype) -> (sigma, omega)`` supplies the starting coupling;
    ``step(W, jitter) -> (sigma, omega)`` is the post-W-step update (only
    when ``learns``); ``rho(sigma, eta, mode, fixed)`` the aggregation
    safety bound matching this member's Sigma.
    """

    name: str
    description: str
    # Sigma updates after each W-step (Algorithm 1 row 11); False => the
    # coupling is fixed for the whole run and engines skip the Omega-step.
    learns: bool
    init: Callable[..., Tuple[Array, Array]]
    step: Optional[Callable[..., Tuple[Array, Array]]] = None
    rho: Callable[..., float] = default_rho_bound
    # init differs from the paper's I/m: distributed engines must pad this
    # member's true-task Sigma instead of initializing at the padded size.
    custom_init: bool = False

    def __post_init__(self):
        if self.learns and self.step is None:
            raise ValueError(f"regularizer {self.name!r}: learns=True needs a step")


# factory(**params) -> OmegaRegularizer; params are member-specific
_REGISTRY: Dict[str, Callable[..., OmegaRegularizer]] = {}
_DESCRIPTIONS: Dict[str, str] = {}


def register_regularizer(
    name: str, factory: Callable[..., OmegaRegularizer], description: str
) -> None:
    _REGISTRY[name] = factory
    _DESCRIPTIONS[name] = description


def get_regularizer(name: str, **params) -> OmegaRegularizer:
    """Resolve a family member by name, configured with member params
    (e.g. ``adjacency=`` for graph_laplacian, ``shrinkage=`` for
    frobenius_shrunk)."""
    try:
        factory = _REGISTRY[name]
    except KeyError as e:
        raise KeyError(
            f"unknown omega regularizer {name!r}; have {sorted(_REGISTRY)}"
        ) from e
    return factory(**params)


def available_regularizers() -> Dict[str, str]:
    return dict(sorted(_DESCRIPTIONS.items()))


def resolve_regularizer(cfg, regularizer=None) -> OmegaRegularizer:
    """Resolve the regularizer an engine should run under.

    Precedence: an explicit ``regularizer`` argument (instance or name) >
    legacy ``cfg.learn_omega=False`` (maps to identity_stl) >
    ``cfg.omega_regularizer``. ``cfg`` is duck-typed: only
    ``learn_omega`` / ``omega_regularizer`` are read.
    """
    if regularizer is not None:
        if isinstance(regularizer, str):
            regularizer = get_regularizer(regularizer)
        if not getattr(cfg, "learn_omega", True) and regularizer.learns:
            raise ValueError(
                f"learn_omega=False conflicts with the learning regularizer "
                f"{regularizer.name!r}; drop learn_omega or pick a fixed member"
            )
        return regularizer
    if not getattr(cfg, "learn_omega", True):
        return get_regularizer("identity_stl")
    name = getattr(cfg, "omega_regularizer", "trace_constraint")
    try:
        return get_regularizer(name)
    except ValueError as e:
        # members needing parameters (graph_laplacian's task graph) cannot
        # be named through the bare config — point at the working route
        raise ValueError(
            f"omega_regularizer={name!r} needs member parameters that the "
            "config cannot carry; pass the member explicitly, e.g. "
            f'DMTRLEstimator(regularizer={name!r}, '
            'regularizer_params={...}) or regularizer=get_regularizer('
            f"{name!r}, ...)"
        ) from e


# ---------------------------------------------------------------------------
# trace_constraint — the paper (Zhang & Yeung closed form); the default
# ---------------------------------------------------------------------------
def _trace_constraint() -> OmegaRegularizer:
    return OmegaRegularizer(
        name="trace_constraint",
        description=_DESCRIPTIONS["trace_constraint"],
        learns=True,
        init=omega_mod.init_sigma,
        step=omega_mod.omega_step,
    )


# ---------------------------------------------------------------------------
# identity_stl — fixed Sigma = I/m (independent ridge tasks)
# ---------------------------------------------------------------------------
def _identity_stl() -> OmegaRegularizer:
    return OmegaRegularizer(
        name="identity_stl",
        description=_DESCRIPTIONS["identity_stl"],
        learns=False,
        init=omega_mod.init_sigma,
    )


# ---------------------------------------------------------------------------
# graph_laplacian — fixed Sigma from a known task graph (arXiv:1802.03830)
# ---------------------------------------------------------------------------
def _graph_laplacian(
    adjacency=None,
    laplacian=None,
    coupling: float = 1.0,
    eps: float = 1e-3,
) -> OmegaRegularizer:
    """Omega = coupling * L + eps I, Sigma = Omega^{-1}, trace-normalized to 1
    so rho and lambda stay on the same scale as the learned members.

    Pass either ``adjacency`` (symmetric non-negative weights; L = D - A) or
    ``laplacian`` directly.
    """
    if (adjacency is None) == (laplacian is None):
        raise ValueError(
            "graph_laplacian needs exactly one of adjacency= or laplacian="
        )
    if laplacian is None:
        A = np.asarray(adjacency, np.float64)
        if A.ndim != 2 or A.shape[0] != A.shape[1]:
            raise ValueError(f"adjacency must be square, got {A.shape}")
        if not np.allclose(A, A.T):
            raise ValueError("adjacency must be symmetric")
        if A.min() < 0:
            raise ValueError("adjacency weights must be non-negative")
        L = np.diag(A.sum(axis=1)) - A
    else:
        L = np.asarray(laplacian, np.float64)
        if L.ndim != 2 or L.shape[0] != L.shape[1]:
            raise ValueError(f"laplacian must be square, got {L.shape}")
    if eps <= 0 or coupling <= 0:
        raise ValueError("graph_laplacian needs eps > 0 and coupling > 0")
    m_graph = L.shape[0]
    omega0 = coupling * L + eps * np.eye(m_graph)
    omega0 = 0.5 * (omega0 + omega0.T)
    sigma0 = np.linalg.inv(omega0)
    sigma0 = 0.5 * (sigma0 + sigma0.T)
    tr = float(np.trace(sigma0))
    sigma0 /= tr
    omega0 *= tr  # keep Sigma @ Omega = I after the trace normalization

    def init(m: int, dtype=jnp.float32) -> Tuple[Array, Array]:
        if m != m_graph:
            raise ValueError(
                f"graph_laplacian was built for {m_graph} tasks but the "
                f"dataset has {m}"
            )
        return jnp.asarray(sigma0, dtype), jnp.asarray(omega0, dtype)

    return OmegaRegularizer(
        name="graph_laplacian",
        description=_DESCRIPTIONS["graph_laplacian"],
        learns=False,
        init=init,
        custom_init=True,
    )


# ---------------------------------------------------------------------------
# frobenius_shrunk — ZY update shrunk toward I/m (trace preserved)
# ---------------------------------------------------------------------------
def _frobenius_shrunk(shrinkage: float = 0.5) -> OmegaRegularizer:
    if not 0.0 <= shrinkage <= 1.0:
        raise ValueError(f"shrinkage must be in [0, 1], got {shrinkage}")

    def step(W: Array, jitter: float = 1e-6) -> Tuple[Array, Array]:
        sigma_zy, _ = omega_mod.omega_step(W, jitter)
        m = W.shape[0]
        sigma = (1.0 - shrinkage) * sigma_zy + shrinkage * jnp.eye(
            m, dtype=sigma_zy.dtype
        ) / m
        sigma = 0.5 * (sigma + sigma.T)
        evals, evecs = jnp.linalg.eigh(sigma)
        evals = jnp.maximum(evals, 1e-30)
        omega = (evecs * (1.0 / evals)) @ evecs.T
        return sigma, 0.5 * (omega + omega.T)

    return OmegaRegularizer(
        name="frobenius_shrunk",
        description=_DESCRIPTIONS["frobenius_shrunk"],
        learns=True,
        init=omega_mod.init_sigma,
        step=step,
    )


register_regularizer(
    "trace_constraint",
    _trace_constraint,
    "paper / Zhang-Yeung closed form: Sigma = (W^T W)^{1/2} trace-normalized "
    "to 1, recomputed after every W-step (the default)",
)
register_regularizer(
    "identity_stl",
    _identity_stl,
    "fixed Sigma = I/m: independent ridge-regularized tasks (subsumes "
    "learn_omega=False)",
)
register_regularizer(
    "graph_laplacian",
    _graph_laplacian,
    "fixed Sigma = (coupling*L + eps I)^{-1} from a known task graph "
    "(arXiv:1802.03830), trace-normalized to 1",
)
register_regularizer(
    "frobenius_shrunk",
    _frobenius_shrunk,
    "Zhang-Yeung update shrunk toward I/m by a shrinkage factor in [0, 1] "
    "(trace stays 1; couplings bounded away from rank collapse)",
)
