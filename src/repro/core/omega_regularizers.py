"""Pluggable Omega-regularizer family (the paper's general dual form).

The paper's dual derivation (Thm. 1) never uses the *specific* Zhang-Yeung
trace-constrained Omega: any symmetric PD task-coupling Sigma yields the
same dual problem, local subproblems, and rho-bounded aggregation. What
distinguishes family members is only

  * how Sigma is INITIALIZED,
  * whether/how Sigma is UPDATED after each W-step (Algorithm 1 row 11),
  * the rho upper bound fed to the local subproblems (Lemma 10 / spectral
    both apply to any PD Sigma, so the default bound is shared).

This registry names the family members so every engine (``fit``,
``fit_distributed``, ``fit_async``) and the duality-gap code consume them
uniformly — mirroring the solver-backend registry (docs/DESIGN.md §5).

Registered members:

  trace_constraint  the paper / Zhang & Yeung (2010): closed-form
                    Sigma = (W^T W)^{1/2} / tr((W^T W)^{1/2}) after every
                    W-step (core/omega.py:omega_step). The default.
  graph_laplacian   fixed task-graph coupling (Wang et al.,
                    arXiv:1802.03830): Omega = coupling * L + eps I from a
                    known task graph; Sigma never updates.
  identity_stl      Sigma fixed at I/m — independent ridge-regularized
                    tasks; subsumes ``DMTRLConfig.learn_omega=False``.
  frobenius_shrunk  trace_constraint update shrunk toward I/m:
                    Sigma = (1-g) Sigma_ZY + g I/m (trace stays 1). A
                    shared-representation-flavoured member in the spirit of
                    arXiv:1603.02185: task couplings are learned but
                    bounded away from rank collapse.

Usage:

    reg = get_regularizer("graph_laplacian", adjacency=A)
    est = DMTRLEstimator(regularizer="frobenius_shrunk",
                         regularizer_params={"shrinkage": 0.3})
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import omega as omega_mod
from .sigma_view import LowRankDiagSigma, SigmaView, SparseSigma

Array = jax.Array


def default_rho_bound(
    sigma, eta: float = 1.0, mode: str = "lemma10", fixed: float = 1.0
) -> float:
    """The paper's rho bounds; valid for ANY symmetric PD Sigma, so every
    family member shares it unless it can prove something tighter.

    Accepts a dense (m, m) array or any SigmaView; structured views use
    their factor-aware bounds (Lemma 10 exact for sparse, a safe triangle-
    inequality over-bound for low-rank; spectral via power iteration)."""
    if mode == "fixed":
        return float(fixed)
    if isinstance(sigma, SigmaView):
        if mode == "spectral":
            return float(sigma.rho_spectral(eta))
        return float(sigma.rho_lemma10(eta))
    if mode == "spectral":
        return float(omega_mod.rho_spectral(sigma, eta))
    return float(omega_mod.rho_lemma10(sigma, eta))


def _check_finite_w(W, name: str) -> None:
    """Raise before a NaN/inf W can flow through an Omega-step into Sigma.

    jnp.linalg.eigh on non-finite input silently yields NaN eigenvectors,
    which would propagate through install_sigma into live serving
    snapshots; fail loudly at the regularizer step() boundary instead."""
    if not bool(jnp.all(jnp.isfinite(W))):
        raise ValueError(
            f"omega regularizer {name!r}: step() received a non-finite W "
            "(NaN/inf) — refusing to produce a corrupt Sigma. Check the "
            "W-step inputs (labels/features) or lower eta/rho."
        )


@dataclasses.dataclass(frozen=True)
class OmegaRegularizer:
    """One named member of the regularizer family.

    ``init(m, dtype) -> (sigma, omega)`` supplies the starting coupling;
    ``step(W, jitter) -> (sigma, omega)`` is the post-W-step update (only
    when ``learns``); ``rho(sigma, eta, mode, fixed)`` the aggregation
    safety bound matching this member's Sigma.
    """

    name: str
    description: str
    # Sigma updates after each W-step (Algorithm 1 row 11); False => the
    # coupling is fixed for the whole run and engines skip the Omega-step.
    learns: bool
    init: Callable[..., Tuple[Array, Array]]
    step: Optional[Callable[..., Tuple[Array, Array]]] = None
    rho: Callable[..., float] = default_rho_bound
    # init differs from the paper's I/m: distributed engines must pad this
    # member's true-task Sigma instead of initializing at the padded size.
    custom_init: bool = False
    # init/step produce SigmaView pytrees (low_rank_diag / graphical_lasso)
    # instead of dense (m, m) arrays; engines keep the factors end-to-end.
    structured: bool = False

    def __post_init__(self):
        if self.learns and self.step is None:
            raise ValueError(f"regularizer {self.name!r}: learns=True needs a step")
        if self.step is not None:
            base_step = self.step
            if not getattr(base_step, "_finite_w_guarded", False):
                name = self.name

                def guarded_step(W, jitter: float = 1e-6):
                    _check_finite_w(W, name)
                    return base_step(W, jitter)

                guarded_step._finite_w_guarded = True
                object.__setattr__(self, "step", guarded_step)


# factory(**params) -> OmegaRegularizer; params are member-specific
_REGISTRY: Dict[str, Callable[..., OmegaRegularizer]] = {}
_DESCRIPTIONS: Dict[str, str] = {}


def register_regularizer(
    name: str, factory: Callable[..., OmegaRegularizer], description: str
) -> None:
    _REGISTRY[name] = factory
    _DESCRIPTIONS[name] = description


def get_regularizer(name: str, **params) -> OmegaRegularizer:
    """Resolve a family member by name, configured with member params
    (e.g. ``adjacency=`` for graph_laplacian, ``shrinkage=`` for
    frobenius_shrunk)."""
    try:
        factory = _REGISTRY[name]
    except KeyError as e:
        raise KeyError(
            f"unknown omega regularizer {name!r}; have {sorted(_REGISTRY)}"
        ) from e
    return factory(**params)


def available_regularizers() -> Dict[str, str]:
    return dict(sorted(_DESCRIPTIONS.items()))


# dense-Sigma members above this many tasks get a one-time nudge toward the
# structured members (m^2 floats + O(m^3) eigh stop being host-trivial)
DENSE_SIGMA_WARN_THRESHOLD = int(os.environ.get("REPRO_DENSE_SIGMA_WARN_M", "2048"))
_dense_scale_warned: set = set()


def _warn_if_dense_at_scale(reg: OmegaRegularizer, m, threshold) -> None:
    if m is None or reg.structured:
        return
    limit = DENSE_SIGMA_WARN_THRESHOLD if threshold is None else int(threshold)
    if m <= limit or reg.name in _dense_scale_warned:
        return
    _dense_scale_warned.add(reg.name)
    warnings.warn(
        f"omega regularizer {reg.name!r} materializes a dense {m}x{m} Sigma "
        f"(m > {limit}): storage is m^2 floats and the Omega-step is O(m^3). "
        "Consider the structured members 'low_rank_diag' (Sigma ~ U U^T + D) "
        "or 'graphical_lasso' (sparse coupling) which scale to huge m. "
        "Raise REPRO_DENSE_SIGMA_WARN_M to silence.",
        stacklevel=3,
    )


def resolve_regularizer(
    cfg, regularizer=None, m=None, dense_warn_threshold=None
) -> OmegaRegularizer:
    """Resolve the regularizer an engine should run under.

    Precedence: an explicit ``regularizer`` argument (instance or name) >
    legacy ``cfg.learn_omega=False`` (maps to identity_stl) >
    ``cfg.omega_regularizer``. ``cfg`` is duck-typed: only
    ``learn_omega`` / ``omega_regularizer`` are read. When the caller
    knows the task count it passes ``m`` so a dense member requested at
    scale gets a one-time structured-member warning.
    """
    if regularizer is not None:
        if isinstance(regularizer, str):
            regularizer = get_regularizer(regularizer)
        if not isinstance(regularizer, OmegaRegularizer):
            raise TypeError(
                f"regularizer must be a name or OmegaRegularizer instance, "
                f"got {type(regularizer).__name__}; parameterized members "
                "are built via get_regularizer(name, **params)"
            )
        if not getattr(cfg, "learn_omega", True) and regularizer.learns:
            raise ValueError(
                f"learn_omega=False conflicts with the learning regularizer "
                f"{regularizer.name!r}; drop learn_omega or pick a fixed member"
            )
        _warn_if_dense_at_scale(regularizer, m, dense_warn_threshold)
        return regularizer
    if not getattr(cfg, "learn_omega", True):
        return get_regularizer("identity_stl")
    name = getattr(cfg, "omega_regularizer", "trace_constraint")
    try:
        reg = get_regularizer(name)
        _warn_if_dense_at_scale(reg, m, dense_warn_threshold)
        return reg
    except ValueError as e:
        # members needing parameters (graph_laplacian's task graph) cannot
        # be named through the bare config — point at the working route
        raise ValueError(
            f"omega_regularizer={name!r} needs member parameters that the "
            "config cannot carry; pass the member explicitly, e.g. "
            f'DMTRLEstimator(regularizer={name!r}, '
            'regularizer_params={...}) or regularizer=get_regularizer('
            f"{name!r}, ...)"
        ) from e


# ---------------------------------------------------------------------------
# trace_constraint — the paper (Zhang & Yeung closed form); the default
# ---------------------------------------------------------------------------
def _trace_constraint() -> OmegaRegularizer:
    return OmegaRegularizer(
        name="trace_constraint",
        description=_DESCRIPTIONS["trace_constraint"],
        learns=True,
        init=omega_mod.init_sigma,
        step=omega_mod.omega_step,
    )


# ---------------------------------------------------------------------------
# identity_stl — fixed Sigma = I/m (independent ridge tasks)
# ---------------------------------------------------------------------------
def _identity_stl() -> OmegaRegularizer:
    return OmegaRegularizer(
        name="identity_stl",
        description=_DESCRIPTIONS["identity_stl"],
        learns=False,
        init=omega_mod.init_sigma,
    )


# ---------------------------------------------------------------------------
# graph_laplacian — fixed Sigma from a known task graph (arXiv:1802.03830)
# ---------------------------------------------------------------------------
def _graph_laplacian(
    adjacency=None,
    laplacian=None,
    coupling: float = 1.0,
    eps: float = 1e-3,
) -> OmegaRegularizer:
    """Omega = coupling * L + eps I, Sigma = Omega^{-1}, trace-normalized to 1
    so rho and lambda stay on the same scale as the learned members.

    Pass either ``adjacency`` (symmetric non-negative weights; L = D - A) or
    ``laplacian`` directly.
    """
    if (adjacency is None) == (laplacian is None):
        raise ValueError(
            "graph_laplacian needs exactly one of adjacency= or laplacian="
        )
    if laplacian is None:
        A = np.asarray(adjacency, np.float64)
        if A.ndim != 2 or A.shape[0] != A.shape[1]:
            raise ValueError(f"adjacency must be square, got {A.shape}")
        if not np.allclose(A, A.T):
            raise ValueError("adjacency must be symmetric")
        if A.min() < 0:
            raise ValueError("adjacency weights must be non-negative")
        L = np.diag(A.sum(axis=1)) - A
    else:
        L = np.asarray(laplacian, np.float64)
        if L.ndim != 2 or L.shape[0] != L.shape[1]:
            raise ValueError(f"laplacian must be square, got {L.shape}")
    if eps <= 0 or coupling <= 0:
        raise ValueError("graph_laplacian needs eps > 0 and coupling > 0")
    m_graph = L.shape[0]
    omega0 = coupling * L + eps * np.eye(m_graph)
    omega0 = 0.5 * (omega0 + omega0.T)
    sigma0 = np.linalg.inv(omega0)
    sigma0 = 0.5 * (sigma0 + sigma0.T)
    tr = float(np.trace(sigma0))
    sigma0 /= tr
    omega0 *= tr  # keep Sigma @ Omega = I after the trace normalization

    def init(m: int, dtype=jnp.float32) -> Tuple[Array, Array]:
        if m != m_graph:
            raise ValueError(
                f"graph_laplacian was built for {m_graph} tasks but the "
                f"dataset has {m}"
            )
        return jnp.asarray(sigma0, dtype), jnp.asarray(omega0, dtype)

    return OmegaRegularizer(
        name="graph_laplacian",
        description=_DESCRIPTIONS["graph_laplacian"],
        learns=False,
        init=init,
        custom_init=True,
    )


# ---------------------------------------------------------------------------
# frobenius_shrunk — ZY update shrunk toward I/m (trace preserved)
# ---------------------------------------------------------------------------
def _frobenius_shrunk(shrinkage: float = 0.5) -> OmegaRegularizer:
    if not 0.0 <= shrinkage <= 1.0:
        raise ValueError(f"shrinkage must be in [0, 1], got {shrinkage}")

    def step(W: Array, jitter: float = 1e-6) -> Tuple[Array, Array]:
        sigma_zy, _ = omega_mod.omega_step(W, jitter)
        m = W.shape[0]
        sigma = (1.0 - shrinkage) * sigma_zy + shrinkage * jnp.eye(
            m, dtype=sigma_zy.dtype
        ) / m
        sigma = 0.5 * (sigma + sigma.T)
        evals, evecs = jnp.linalg.eigh(sigma)
        evals = jnp.maximum(evals, 1e-30)
        omega = (evecs * (1.0 / evals)) @ evecs.T
        return sigma, 0.5 * (omega + omega.T)

    return OmegaRegularizer(
        name="frobenius_shrunk",
        description=_DESCRIPTIONS["frobenius_shrunk"],
        learns=True,
        init=omega_mod.init_sigma,
        step=step,
    )


# ---------------------------------------------------------------------------
# low_rank_diag — structured Zhang-Yeung: Sigma = U diag(s) U^T + diag(d)
# ---------------------------------------------------------------------------
def _low_rank_diag(rank: int = 32, iters: int = 8) -> OmegaRegularizer:
    """Rank-r subspace-iteration Omega-step (core/omega.py:
    omega_step_lowrank): O(m*r) storage, O(m*d*r) step, no m x m ever.
    Exact Zhang-Yeung at r >= rank(W W^T) (in particular r = m), so the
    dense-parity tests pin it against trace_constraint."""
    if rank < 1:
        raise ValueError(f"low_rank_diag needs rank >= 1, got {rank}")
    if iters < 1:
        raise ValueError(f"low_rank_diag needs iters >= 1, got {iters}")

    def init(m: int, dtype=jnp.float32):
        r = min(rank, m)
        # Sigma = I/m: empty factor + uniform diagonal (Algorithm 1 init)
        sigma = LowRankDiagSigma(
            U=jnp.zeros((m, r), dtype),
            core=jnp.zeros((r, r), dtype),
            d=jnp.full((m,), 1.0 / m, dtype),
        )
        omega = LowRankDiagSigma(
            U=jnp.zeros((m, r), dtype),
            core=jnp.zeros((r, r), dtype),
            d=jnp.full((m,), float(m), dtype),
        )
        return sigma, omega

    def step(W: Array, jitter: float = 1e-6):
        U, s, d = omega_mod.omega_step_lowrank(W, rank, iters, jitter)
        sigma = LowRankDiagSigma(U=U, core=jnp.diag(s), d=d)
        return sigma, sigma.precision()

    return OmegaRegularizer(
        name="low_rank_diag",
        description=_DESCRIPTIONS["low_rank_diag"],
        learns=True,
        init=init,
        step=step,
        structured=True,
    )


# ---------------------------------------------------------------------------
# graphical_lasso — soft-thresholded sparse task coupling (arXiv:1802.03830)
# ---------------------------------------------------------------------------
def _graphical_lasso(
    penalty: float = 0.5, block: int = 2048, max_nnz: Optional[int] = None
) -> OmegaRegularizer:
    """Learned sparse task graph: the normalized coupling S = W W^T / tr is
    soft-thresholded off-diagonally at lambda = penalty/m (i.e. ``penalty``
    in units of the mean diagonal), one coordinate at a time, then stored
    as diagonal + ELL sparse rows (SparseSigma).

    PSD is preserved analytically: thresholding removes a symmetric error
    matrix E with ||E||_2 <= ||E||_inf = max_i sum_j min(|s_ij|, lambda),
    and that bound is added back onto the diagonal before trace
    renormalization — so Sigma stays PD for any penalty, and at penalty=0
    the boost is zero and Sigma equals the dense trace-normalized coupling
    (the dense-parity anchor).

    The Gram coupling is built blockwise on the host (O(block * m) peak,
    never m x m); ``max_nnz`` optionally caps per-row off-diagonal entries
    (keeping the largest-magnitude ones).
    """
    if penalty < 0:
        raise ValueError(f"graphical_lasso needs penalty >= 0, got {penalty}")
    if block < 1:
        raise ValueError(f"graphical_lasso needs block >= 1, got {block}")

    def init(m: int, dtype=jnp.float32):
        sigma = SparseSigma(
            diag_v=jnp.full((m,), 1.0 / m, dtype),
            cols=jnp.zeros((m, 0), jnp.int32),
            vals=jnp.zeros((m, 0), dtype),
        )
        omega = SparseSigma(
            diag_v=jnp.full((m,), float(m), dtype),
            cols=jnp.zeros((m, 0), jnp.int32),
            vals=jnp.zeros((m, 0), dtype),
        )
        return sigma, omega

    def step(W: Array, jitter: float = 1e-6):
        Wn = np.asarray(W, np.float64)
        m = Wn.shape[0]
        dtype = np.asarray(W).dtype
        tr = float((Wn * Wn).sum())  # tr(W W^T)
        if tr <= 1e-30:  # degenerate W -> fall back to Sigma = I/m
            return init(m, dtype)
        lam_abs = penalty / m
        diag_s = (Wn * Wn).sum(axis=1) / tr
        row_cols: list = []
        row_vals: list = []
        boost = 0.0
        for lo in range(0, m, block):
            hi = min(lo + block, m)
            S_blk = (Wn[lo:hi] @ Wn.T) / tr  # (b, m) coupling rows
            for i in range(lo, hi):
                row = S_blk[i - lo].copy()
                row[i] = 0.0  # off-diagonal only
                removed = np.minimum(np.abs(row), lam_abs).sum()
                boost = max(boost, removed)
                keep = np.nonzero(np.abs(row) > lam_abs)[0]
                v = np.sign(row[keep]) * (np.abs(row[keep]) - lam_abs)
                if max_nnz is not None and keep.size > max_nnz:
                    top = np.argsort(-np.abs(v))[:max_nnz]
                    keep, v = keep[top], v[top]
                row_cols.append(keep.astype(np.int32))
                row_vals.append(v)
        k_max = max((c.size for c in row_cols), default=0)
        cols = np.zeros((m, k_max), np.int32)
        vals = np.zeros((m, k_max), np.float64)
        for i, (c, v) in enumerate(zip(row_cols, row_vals)):
            cols[i, : c.size] = c
            vals[i, : v.size] = v
        diag_f = diag_s + boost + jitter
        total = diag_f.sum()  # off-diagonals don't contribute to the trace
        sigma = SparseSigma(
            diag_v=jnp.asarray(diag_f / total, dtype),
            cols=jnp.asarray(cols),
            vals=jnp.asarray(vals / total, dtype),
        )
        return sigma, None  # sparse Sigma has no cheap structured inverse

    return OmegaRegularizer(
        name="graphical_lasso",
        description=_DESCRIPTIONS["graphical_lasso"],
        learns=True,
        init=init,
        step=step,
        structured=True,
    )


register_regularizer(
    "trace_constraint",
    _trace_constraint,
    "paper / Zhang-Yeung closed form: Sigma = (W^T W)^{1/2} trace-normalized "
    "to 1, recomputed after every W-step (the default)",
)
register_regularizer(
    "identity_stl",
    _identity_stl,
    "fixed Sigma = I/m: independent ridge-regularized tasks (subsumes "
    "learn_omega=False)",
)
register_regularizer(
    "graph_laplacian",
    _graph_laplacian,
    "fixed Sigma = (coupling*L + eps I)^{-1} from a known task graph "
    "(arXiv:1802.03830), trace-normalized to 1",
)
register_regularizer(
    "frobenius_shrunk",
    _frobenius_shrunk,
    "Zhang-Yeung update shrunk toward I/m by a shrinkage factor in [0, 1] "
    "(trace stays 1; couplings bounded away from rank collapse)",
)
register_regularizer(
    "low_rank_diag",
    _low_rank_diag,
    "structured Zhang-Yeung: Sigma = U diag(s) U^T + diag(d) via rank-r "
    "subspace iteration — O(m*r) storage, no m x m eigh; exact at r = m",
)
register_regularizer(
    "graphical_lasso",
    _graphical_lasso,
    "learned sparse task graph (arXiv:1802.03830): soft-thresholded "
    "coupling stored as diagonal + ELL sparse rows; PSD by diagonal "
    "compensation, dense-equal at penalty=0",
)
