"""Local SDCA (paper Algorithm 2) — naive and block-Gram forms.

Both act on ONE task's (padded) arrays and are vmapped over tasks by the
driver. Given the task's current dual block ``alpha_i`` and weight vector
``w_i``, they produce the approximate subproblem solution ``dalpha`` and the
un-normalized update direction ``r = X_i^T dalpha`` (so that
``delta_b_i = eta * r / n_i``).

naive      : literal Algorithm 2 — one coordinate per step, each step does a
             d-dim inner product + axpy. Reference semantics.
block_gram : TPU adaptation (see docs/DESIGN.md §4). H steps are processed in
             blocks of B sampled coordinates: the d-dim work becomes three
             matmuls per block (q = X_blk w, G = X_blk X_blk^T,
             r += X_blk^T delta) and the sequential part runs on the B x B
             Gram block only. Produces the *exact same iterate sequence* as
             naive for the same sampled coordinate order (duplicates within a
             block included), because inner products are corrected
             incrementally through G.

Engines do not call these functions directly: they resolve a named backend
through ``repro.core.solver_backends`` (docs/DESIGN.md §5), which wraps the
math here (and the Pallas kernels in repro.kernels.sdca) behind one
``solve(...)`` contract.

Sharding: when ``axis_name`` is given (feature dim d sharded over a mesh
axis), the d-contractions are psum'ed. naive then needs 2 collectives per
coordinate; block_gram needs 3 per block — this is the communication
argument for the block form (docs/DESIGN.md §7).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .losses import Loss

Array = jax.Array


def sample_coords(key: Array, H: int, n_i: Array, n_max: int) -> Array:
    """H coordinate indices uniform in [0, n_i) (paper: with replacement)."""
    u = jax.random.uniform(key, (H,))
    return jnp.minimum((u * n_i.astype(u.dtype)).astype(jnp.int32), n_i - 1)


def _psum(x, axis_name):
    return jax.lax.psum(x, axis_name) if axis_name is not None else x


def local_sdca_naive(
    x: Array,  # (n_max, d)    [d possibly a shard]
    y: Array,  # (n_max,)
    alpha_i: Array,  # (n_max,)
    w_i: Array,  # (d,)
    n_i: Array,  # scalar int
    sigma_ii: Array,  # scalar
    coords: Array,  # (H,) int32
    rho: float,
    lam: float,
    loss: Loss,
    axis_name: Optional[str] = None,
) -> Tuple[Array, Array]:
    """Algorithm 2, one coordinate at a time. Returns (dalpha, r)."""
    nf = jnp.maximum(n_i.astype(x.dtype), 1.0)
    kappa = rho * sigma_ii / (lam * nf)

    def body(h, carry):
        dalpha, r = carry
        j = coords[h]
        xj = x[j]
        # d-contractions (collective per coordinate when d is sharded)
        wx = _psum(jnp.dot(xj, w_i), axis_name)
        xr = _psum(jnp.dot(xj, r), axis_name)
        xx = _psum(jnp.dot(xj, xj), axis_name)
        c = wx + kappa * xr
        a = kappa * xx
        atilde = alpha_i[j] + dalpha[j]
        delta = loss.sdca_delta(atilde, c, a, y[j])
        dalpha = dalpha.at[j].add(delta)
        r = r + delta * xj
        return dalpha, r

    H = coords.shape[0]
    dalpha0 = jnp.zeros_like(alpha_i) + y[0] * 0
    # + x[0]*0 keeps the carry's varying-manual-axes equal to the loop
    # output's under shard_map (x may vary over a 'pod' sample axis)
    r0 = jnp.zeros_like(w_i) + x[0] * 0
    return jax.lax.fori_loop(0, H, body, (dalpha0, r0))


def local_sdca_block(
    x: Array,
    y: Array,
    alpha_i: Array,
    w_i: Array,
    n_i: Array,
    sigma_ii: Array,
    coords: Array,  # (H,) int32; H must be a multiple of block
    rho: float,
    lam: float,
    loss: Loss,
    block: int = 64,
    axis_name: Optional[str] = None,
) -> Tuple[Array, Array]:
    """Block-Gram Local SDCA. Same iterates as naive, MXU-shaped."""
    H = coords.shape[0]
    assert H % block == 0, f"H={H} must be a multiple of block={block}"
    nb = H // block
    coords_b = coords.reshape(nb, block)
    nf = jnp.maximum(n_i.astype(x.dtype), 1.0)
    kappa = rho * sigma_ii / (lam * nf)

    def blk_fn(carry, cb):
        dalpha, r = carry
        xb = x[cb]  # (B, d)
        q = _psum(xb @ w_i, axis_name)  # (B,)
        xr = _psum(xb @ r, axis_name)  # (B,)
        G = _psum(xb @ xb.T, axis_name)  # (B, B)
        yb = y[cb]

        def inner(k, inner_carry):
            dalpha_, deltas = inner_carry
            j = cb[k]
            # c_k = q_k + kappa * (x_k^T r + sum_{k'<k} G[k,k'] delta_k')
            corr = jnp.dot(G[k], deltas)  # deltas[k:] are still 0
            c = q[k] + kappa * (xr[k] + corr)
            a = kappa * G[k, k]
            atilde = alpha_i[j] + dalpha_[j]
            delta = loss.sdca_delta(atilde, c, a, yb[k])
            dalpha_ = dalpha_.at[j].add(delta)
            deltas = deltas.at[k].set(delta)
            return dalpha_, deltas

        # derive from q so the carry carries the same varying-manual-axes
        # type as the inputs under shard_map
        deltas0 = q * 0.0
        dalpha, deltas = jax.lax.fori_loop(0, block, inner, (dalpha, deltas0))
        r = r + xb.T @ deltas
        return (dalpha, r), None

    dalpha0 = jnp.zeros_like(alpha_i) + y[0] * 0
    r0 = jnp.zeros_like(w_i) + x[0] * 0  # see local_sdca_naive note
    (dalpha, r), _ = jax.lax.scan(blk_fn, (dalpha0, r0), coords_b)
    return dalpha, r


def sdca_gram_solve(
    G: Array,  # (H, H) full Gram of sampled rows (already psum'ed)
    q: Array,  # (H,)   X_H @ w (already psum'ed)
    alpha_i: Array,
    y: Array,
    coords: Array,
    n_i: Array,
    sigma_ii: Array,
    rho: float,
    lam: float,
    loss: Loss,
) -> Tuple[Array, Array]:
    """The collective-free scalar recursion of full-Gram SDCA.

    Returns (dalpha, deltas); r = X_H^T deltas is computed by the caller on
    its local feature shard."""
    H = coords.shape[0]
    nf = jnp.maximum(n_i.astype(q.dtype), 1.0)
    kappa = rho * sigma_ii / (lam * nf)

    def body(k, carry):
        dalpha, deltas = carry
        corr = jnp.dot(G[k], deltas)  # deltas[k:] still zero
        c = q[k] + kappa * corr
        a = kappa * G[k, k]
        j = coords[k]
        atilde = alpha_i[j] + dalpha[j]
        delta = loss.sdca_delta(atilde, c, a, y[j])
        return dalpha.at[j].add(delta), deltas.at[k].set(delta)

    dalpha0 = jnp.zeros_like(alpha_i) + q[0] * 0.0
    deltas0 = q * 0.0
    return jax.lax.fori_loop(0, H, body, (dalpha0, deltas0))


def local_sdca_gram(
    x: Array,
    y: Array,
    alpha_i: Array,
    w_i: Array,
    n_i: Array,
    sigma_ii: Array,
    coords: Array,  # (H,)
    rho: float,
    lam: float,
    loss: Loss,
    axis_name: Optional[str] = None,
) -> Tuple[Array, Array]:
    """Full-Gram Local SDCA: same iterate sequence as naive/block, but ALL
    d-contractions are hoisted out of the sequential loop:

        q = psum(X_H @ w),  G = psum(X_H X_H^T)     (2 collectives TOTAL)
        H scalar steps entirely on the H x H Gram   (no collectives)
        r = X_H^T deltas                             (local per shard)

    vs 3 collectives PER BLOCK for the block mode — this is the
    communication-optimal form for a model-sharded feature dim and the one
    the distributed path uses (docs/DESIGN.md §7)."""
    Xs = x[coords]  # (H, d_shard)
    q = _psum(Xs @ w_i, axis_name)  # (H,)
    G = _psum(
        jax.lax.dot_general(Xs, Xs, (((1,), (1,)), ((), ()))), axis_name
    )  # (H, H)
    dalpha, deltas = sdca_gram_solve(
        G, q, alpha_i, y, coords, n_i, sigma_ii, rho, lam, loss
    )
    r = Xs.T @ deltas  # local shard of X^T dalpha
    return dalpha, r


def sdca_block_solve(
    G: Array,  # (B, B) Gram of this block's rows (psum'ed)
    q: Array,  # (B,)   X_blk @ w (psum'ed)
    xr: Array,  # (B,)   X_blk @ r_prev (psum'ed)
    dalpha: Array,
    alpha_i: Array,
    y: Array,
    cb: Array,  # (B,) coords of this block
    kappa: Array,
    loss: Loss,
) -> Tuple[Array, Array]:
    """Collective-free scalar recursion for ONE block (hoisted-psum form).
    Returns (dalpha, deltas)."""
    B = cb.shape[0]

    def body(k, carry):
        dalpha_, deltas = carry
        corr = jnp.dot(G[k], deltas)
        c = q[k] + kappa * (xr[k] + corr)
        a = kappa * G[k, k]
        j = cb[k]
        atilde = alpha_i[j] + dalpha_[j]
        delta = loss.sdca_delta(atilde, c, a, y[j])
        return dalpha_.at[j].add(delta), deltas.at[k].set(delta)

    deltas0 = q * 0.0
    return jax.lax.fori_loop(0, B, body, (dalpha, deltas0))
