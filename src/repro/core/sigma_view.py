"""SigmaView — structured task-covariance representations engines can share.

At m=16 tasks the m x m Sigma is host-trivial; at the 10k-1M regime the
ROADMAP targets (one task per cohort/tenant), a dense Sigma is 4 bytes * m^2
and the eigendecomposition behind the Zhang-Yeung Omega-step is O(m^3) —
both dead. The engines, transports and the serve path never actually need
the dense matrix though: between them they consume exactly

    diag()           per-task sigma_ii for the local SDCA subproblems
    matvec(V)        Sigma @ V — the server reduce (W += Sigma dB / lam),
                     weights_from_alpha and the duality-gap quad term
    rows(idx)        a few gathered rows (serve tiles, worker snapshots)
    logdet_bound()   a cheap upper bound for diagnostics
    rho bounds       Lemma 10 / spectral aggregation safety bounds

``SigmaView`` names that contract. Three members:

  DenseSigma        wraps the existing (m, m) array — the small-m fallback,
                    bit-identical to the historical dense path (parity
                    pinned by tests).
  LowRankDiagSigma  Sigma = U C U^T + diag(d) with U (m, r), C (r, r),
                    d (m,): O(m r) storage, O(m r) matvec. Produced by the
                    ``low_rank_diag`` regularizer's subspace-iteration
                    Omega-step; the matrix-determinant lemma gives an exact
                    logdet and Woodbury an (approximate) precision.
  SparseSigma       diagonal + ELL-packed sparse off-diagonal coupling
                    (cols/vals (m, k_max), zero-padded rows): the
                    graph-sparse member of arXiv:1802.03830 with O(nnz)
                    storage/matvec and exact Lemma-10 row sums.

Every member is a registered JAX pytree, so a view can be passed straight
through ``jit``/``shard_map`` boundaries as an argument (engines pass the
factors, never a materialized matrix) and sharded leaf-by-leaf on a mesh
(U/d/diag row-sharded over the data axis, the r x r core replicated).

``factors()``/``view_from_factors`` define the structured snapshot wire
format (numpy leaves + a ``kind`` tag) used by transports and serving
publishes — a few KB instead of m^2 floats per install.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# engines materialize dense (sigma, omega) result arrays only up to this
# many tasks; above it results carry the structured view itself
MATERIALIZE_LIMIT = 4096
# SparseSigma.precision() densifies; refuse beyond this
_PRECISION_DENSE_LIMIT = 4096


class SigmaView:
    """Contract every structured Sigma representation implements.

    All methods are jit-traceable (members are registered pytrees); the
    float-returning bounds are used eagerly by the rho machinery.
    """

    kind: str = "?"

    @property
    def m(self) -> int:
        raise NotImplementedError

    def diag(self) -> Array:
        raise NotImplementedError

    def matvec(self, v: Array) -> Array:
        """Sigma @ v for v of shape (m,) or (m, k)."""
        raise NotImplementedError

    def rows(self, idx: Array) -> Array:
        """Dense gathered rows Sigma[idx, :], shape (len(idx), m)."""
        raise NotImplementedError

    def dense(self) -> Array:
        return self.rows(jnp.arange(self.m, dtype=jnp.int32))

    def trace(self) -> Array:
        return jnp.sum(self.diag())

    def nbytes(self) -> int:
        """Persistent storage of the representation (the factors)."""
        return int(sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(self)))

    def logdet_bound(self) -> float:
        """An upper bound on logdet(Sigma) (exact where cheap)."""
        raise NotImplementedError

    def col_block_matvec(self, lo: int, db: Array) -> Array:
        """Sigma[:, lo:lo+k] @ db for db (k, d) — one worker's commit
        reduce (Sigma symmetric => equals Sigma[lo:lo+k, :].T @ db)."""
        raise NotImplementedError

    def pad(self, m_new: int, jitter: float) -> "SigmaView":
        """Embed into m_new >= m tasks; padded tasks get an inert
        jitter-scaled diagonal (mirrors distributed.pad_sigma_blocks)."""
        raise NotImplementedError

    def unpad(self, m_true: int) -> "SigmaView":
        """Drop padded tasks again (rows [m_true:] must be decoupled)."""
        raise NotImplementedError

    def precision(self) -> Optional["SigmaView"]:
        """Sigma^{-1} where representable, else None."""
        return None

    # -- rho safety bounds (must be UPPER bounds; see core/omega.py) --------
    def rho_lemma10(self, eta: float = 1.0) -> Array:
        raise NotImplementedError

    # exact spectral rho densifies + eighs; do that only up to this size
    _SPECTRAL_EXACT_LIMIT = 2048

    def rho_spectral(self, eta: float = 1.0, iters: int = 24) -> Array:
        """eta * lambda_max(D^-1/2 Sigma D^-1/2): exact (dense eigvalsh) at
        small m; beyond that a power-iteration estimate with a safety
        factor, clamped into [eta, rho_lemma10] so it stays a valid upper
        bound (Lemma 10 always is; the rescaled lambda_max is always >= 1
        for PSD Sigma with positive diagonal)."""
        dd = jnp.sqrt(jnp.maximum(self.diag(), 1e-30))
        if self.m <= self._SPECTRAL_EXACT_LIMIT:
            S = self.dense() / (dd[:, None] * dd[None, :])
            ev = jnp.linalg.eigvalsh(0.5 * (S + S.T))
            return eta * ev[-1]
        v = jnp.ones((self.m,), dd.dtype) / jnp.sqrt(float(self.m))
        for _ in range(iters):
            v = self.matvec(v / dd) / dd
            v = v / jnp.maximum(jnp.linalg.norm(v), 1e-30)
        lam = jnp.vdot(v, self.matvec(v / dd) / dd)
        est = eta * lam * 1.05  # power iteration under-estimates from below
        return jnp.clip(est, eta, self.rho_lemma10(eta))

    def factors(self) -> Dict[str, object]:
        """Wire format: numpy leaves + the member tag."""
        out = {"kind": self.kind}
        for f in dataclasses.fields(self):
            out[f.name] = np.asarray(getattr(self, f.name))
        return out


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DenseSigma(SigmaView):
    """The historical dense (m, m) array behind the shared interface."""

    sigma: Array
    kind = "dense"

    def tree_flatten(self):
        return (self.sigma,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def m(self) -> int:
        return int(self.sigma.shape[0])

    def diag(self) -> Array:
        return jnp.diagonal(self.sigma)

    def matvec(self, v: Array) -> Array:
        return self.sigma @ v

    def rows(self, idx: Array) -> Array:
        return self.sigma[idx]

    def dense(self) -> Array:
        return self.sigma

    def col_block_matvec(self, lo: int, db: Array) -> Array:
        return jnp.swapaxes(self.sigma[lo : lo + db.shape[0]], 0, 1) @ db

    def logdet_bound(self) -> float:
        ev = jnp.linalg.eigvalsh(self.sigma)
        return float(jnp.sum(jnp.log(jnp.maximum(ev, 1e-30))))

    def pad(self, m_new: int, jitter: float) -> "DenseSigma":
        padn = m_new - self.m
        if not padn:
            return self
        s = jnp.zeros((m_new, m_new), self.sigma.dtype)
        s = s.at[: self.m, : self.m].set(self.sigma)
        s = s.at[self.m :, self.m :].set(jnp.eye(padn, dtype=self.sigma.dtype) * jitter)
        return DenseSigma(s)

    def unpad(self, m_true: int) -> "DenseSigma":
        return DenseSigma(self.sigma[:m_true, :m_true])

    def precision(self) -> "DenseSigma":
        ev, Q = jnp.linalg.eigh(0.5 * (self.sigma + self.sigma.T))
        ev = jnp.maximum(ev, 1e-30)
        om = (Q * (1.0 / ev)) @ Q.T
        return DenseSigma(0.5 * (om + om.T))

    def rho_lemma10(self, eta: float = 1.0) -> Array:
        dd = jnp.maximum(self.diag(), 1e-30)
        return eta * jnp.max(jnp.sum(jnp.abs(self.sigma), axis=1) / dd)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class LowRankDiagSigma(SigmaView):
    """Sigma = U C U^T + diag(d): O(m r) storage, O(m r) matvec.

    ``C`` is a small (r, r) symmetric core (a diagonal eigenvalue core for
    the low_rank_diag Omega-step; a full negative-definite correction for
    the Woodbury precision). On a mesh, U and d shard by task rows
    (P(data, None) / P(data)) while C replicates — the factored server
    reduce psums the (r, d) projection instead of all-gathering (m, d)
    deltas, which is the communication win at scale.
    """

    U: Array  # (m, r)
    core: Array  # (r, r)
    d: Array  # (m,)
    kind = "low_rank_diag"

    def tree_flatten(self):
        return (self.U, self.core, self.d), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def m(self) -> int:
        return int(self.U.shape[0])

    @property
    def rank(self) -> int:
        return int(self.U.shape[1])

    def diag(self) -> Array:
        return jnp.sum((self.U @ self.core) * self.U, axis=1) + self.d

    def matvec(self, v: Array) -> Array:
        proj = self.core @ (self.U.T @ v)
        if v.ndim == 1:
            return self.U @ proj + self.d * v
        return self.U @ proj + self.d[:, None] * v

    def rows(self, idx: Array) -> Array:
        out = (self.U[idx] @ self.core) @ self.U.T
        k = idx.shape[0]
        return out.at[jnp.arange(k), idx].add(self.d[idx])

    def col_block_matvec(self, lo: int, db: Array) -> Array:
        hi = lo + db.shape[0]
        out = self.U @ (self.core @ (self.U[lo:hi].T @ db))
        return out.at[lo:hi].add(self.d[lo:hi, None] * db)

    def logdet_bound(self) -> float:
        # matrix determinant lemma: logdet(D) + logdet(I_r + C U^T D^-1 U)
        d = jnp.maximum(self.d, 1e-30)
        inner = jnp.eye(self.rank, dtype=self.U.dtype) + self.core @ (
            self.U.T @ (self.U / d[:, None])
        )
        _, ld = jnp.linalg.slogdet(inner)
        return float(jnp.sum(jnp.log(d)) + ld)

    def pad(self, m_new: int, jitter: float) -> "LowRankDiagSigma":
        padn = m_new - self.m
        if not padn:
            return self
        U = jnp.zeros((m_new, self.rank), self.U.dtype).at[: self.m].set(self.U)
        d = jnp.full((m_new,), jitter, self.d.dtype).at[: self.m].set(self.d)
        return LowRankDiagSigma(U, self.core, d)

    def unpad(self, m_true: int) -> "LowRankDiagSigma":
        return LowRankDiagSigma(self.U[:m_true], self.core, self.d[:m_true])

    def precision(self) -> "LowRankDiagSigma":
        """Woodbury: (U C U^T + D)^-1 = D^-1 - D^-1 U (C^-1 + U^T D^-1 U)^-1
        U^T D^-1. Exact when the factorization is exact (r = m); directions
        with (near-)zero core eigenvalues degrade gracefully to D^-1."""
        d = jnp.maximum(self.d, 1e-30)
        Ud = self.U / d[:, None]
        core_s = self.core + jnp.eye(self.rank, dtype=self.core.dtype) * 1e-30
        inner = jnp.linalg.inv(core_s) + self.U.T @ Ud
        corr = -jnp.linalg.inv(0.5 * (inner + inner.T))
        return LowRankDiagSigma(Ud, 0.5 * (corr + corr.T), 1.0 / d)

    # exact Lemma-10 row sums are O(m^2 r) flops; compute them (blockwise,
    # never materializing (m, m)) up to this many tasks, fall back to the
    # O(m r) factored over-bound beyond it (looser rho = smaller, still
    # safe, aggregation steps)
    _RHO_EXACT_LIMIT = 8192

    def rho_lemma10(self, eta: float = 1.0) -> Array:
        dd = jnp.maximum(self.diag(), 1e-30)
        if self.m <= self._RHO_EXACT_LIMIT:
            best = None
            for lo in range(0, self.m, 1024):
                idx = jnp.arange(lo, min(lo + 1024, self.m), dtype=jnp.int32)
                ratio = jnp.max(jnp.sum(jnp.abs(self.rows(idx)), axis=1) / dd[idx])
                best = ratio if best is None else jnp.maximum(best, ratio)
            return eta * best
        # triangle inequality on the factored rows: sum_j |sigma_ij| <=
        # sum_k |(UC)_ik| * sum_j |U_jk| + d_i  — always >= the exact
        # Lemma-10 value, so still a safe aggregation bound
        UC = jnp.abs(self.U @ self.core)
        colabs = jnp.sum(jnp.abs(self.U), axis=0)
        rowbound = UC @ colabs + self.d
        return eta * jnp.max(rowbound / dd)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SparseSigma(SigmaView):
    """Diagonal + ELL-packed sparse off-diagonal coupling.

    ``cols``/``vals`` are (m, k_max) with per-row zero padding (val 0,
    col 0): row i couples to tasks cols[i, :nnz_i]. Storage and matvec are
    O(m k_max); the Lemma-10 row sums are exact. Produced by the
    ``graphical_lasso`` member's soft-thresholded coupling estimate
    (arXiv:1802.03830's sparse task graph).
    """

    diag_v: Array  # (m,)
    cols: Array  # (m, k_max) int32
    vals: Array  # (m, k_max)
    kind = "sparse"

    def tree_flatten(self):
        return (self.diag_v, self.cols, self.vals), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def m(self) -> int:
        return int(self.diag_v.shape[0])

    @property
    def k_max(self) -> int:
        return int(self.cols.shape[1])

    def diag(self) -> Array:
        return self.diag_v

    def matvec(self, v: Array) -> Array:
        if v.ndim == 1:
            off = jnp.einsum("mk,mk->m", self.vals, v[self.cols])
            return self.diag_v * v + off
        off = jnp.einsum("mk,mkj->mj", self.vals, v[self.cols])
        return self.diag_v[:, None] * v + off

    def rows(self, idx: Array) -> Array:
        k = idx.shape[0]
        out = jnp.zeros((k, self.m), self.vals.dtype)
        out = out.at[jnp.arange(k)[:, None], self.cols[idx]].add(self.vals[idx])
        return out.at[jnp.arange(k), idx].add(self.diag_v[idx])

    def col_block_matvec(self, lo: int, db: Array) -> Array:
        hi = lo + db.shape[0]
        sub_cols = self.cols[lo:hi].reshape(-1)
        contrib = (self.vals[lo:hi, :, None] * db[:, None, :]).reshape(
            -1, db.shape[1]
        )
        out = jnp.zeros((self.m, db.shape[1]), db.dtype).at[sub_cols].add(contrib)
        return out.at[lo:hi].add(self.diag_v[lo:hi, None] * db)

    def logdet_bound(self) -> float:
        # Hadamard's inequality for PSD matrices: det <= prod(diag)
        return float(jnp.sum(jnp.log(jnp.maximum(self.diag_v, 1e-30))))

    def pad(self, m_new: int, jitter: float) -> "SparseSigma":
        padn = m_new - self.m
        if not padn:
            return self
        dg = jnp.full((m_new,), jitter, self.diag_v.dtype).at[: self.m].set(
            self.diag_v
        )
        cols = jnp.zeros((m_new, self.k_max), self.cols.dtype).at[: self.m].set(
            self.cols
        )
        vals = jnp.zeros((m_new, self.k_max), self.vals.dtype).at[: self.m].set(
            self.vals
        )
        return SparseSigma(dg, cols, vals)

    def unpad(self, m_true: int) -> "SparseSigma":
        return SparseSigma(
            self.diag_v[:m_true], self.cols[:m_true], self.vals[:m_true]
        )

    def precision(self) -> Optional[DenseSigma]:
        if self.m > _PRECISION_DENSE_LIMIT:
            return None
        return DenseSigma(self.dense()).precision()

    def rho_lemma10(self, eta: float = 1.0) -> Array:
        dd = jnp.maximum(self.diag_v, 1e-30)
        rowsum = dd + jnp.sum(jnp.abs(self.vals), axis=1)
        return eta * jnp.max(rowsum / dd)


_KINDS = {
    "dense": DenseSigma,
    "low_rank_diag": LowRankDiagSigma,
    "sparse": SparseSigma,
}


def as_view(sigma) -> SigmaView:
    """Wrap a raw (m, m) array; pass views through unchanged."""
    if isinstance(sigma, SigmaView):
        return sigma
    return DenseSigma(jnp.asarray(sigma))


def view_from_factors(factors: Dict[str, object]) -> SigmaView:
    """Decode the ``SigmaView.factors()`` wire format."""
    kind = factors["kind"]
    try:
        cls = _KINDS[kind]
    except KeyError as e:
        raise ValueError(f"unknown SigmaView kind {kind!r}") from e
    kwargs = {
        f.name: jnp.asarray(factors[f.name]) for f in dataclasses.fields(cls)
    }
    return cls(**kwargs)


def maybe_dense(sigma, limit: int = MATERIALIZE_LIMIT):
    """Materialize a view to a dense numpy array when small enough; large
    views (and None) pass through so huge-m results never densify."""
    if sigma is None:
        return None
    if isinstance(sigma, SigmaView):
        if sigma.m <= limit:
            return np.asarray(sigma.dense())
        return sigma
    return np.asarray(sigma)


def result_sigma_omega(sigma, omega, limit: int = MATERIALIZE_LIMIT):
    """Normalize an engine's final (sigma, omega) for its result object:
    returns (sigma_out, omega_out, sigma_view). Dense arrays pass through;
    small views materialize (deriving a missing omega from the view's
    precision); huge views stay structured with omega possibly None."""
    if not isinstance(sigma, SigmaView):
        return sigma, omega, None
    view = sigma
    if omega is None and view.m <= limit:
        omega = view.precision()
    return maybe_dense(view, limit), maybe_dense(omega, limit), view
