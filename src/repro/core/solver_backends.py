"""Pluggable local-SDCA solver backends (docs/DESIGN.md §5).

Every engine — ``fit`` (core/dmtrl.py), ``fit_distributed``
(core/distributed.py) and the async engine (core/async_dmtrl.py) — reaches
the local subproblem (paper Algorithm 2) through this registry: a config
names a backend (``DMTRLConfig.solver``), the engine resolves it with
``get_backend`` and builds a per-task solver with ``backend.make``. All
backends share the contract

    solve(x, y, alpha_i, w_i, n_i, sigma_ii, key) -> (dalpha, r)

acting on ONE task's (padded) arrays, vmappable over the task axis, with
the H coordinate draws derived from ``key`` exactly as
``sdca.sample_coords`` does — so every backend produces the SAME sampled
coordinate order and (up to float-op ordering) the same iterate sequence.

Registered backends:

  naive        literal Algorithm 2, one coordinate per step (oracle).
  block_gram   jnp block-Gram form (docs/DESIGN.md §4): same iterates,
               MXU-shaped; supports a sharded feature dim via psum.
  pallas_block per-block Pallas kernel: one pallas_call per H-block,
               ``w``/``r`` re-streamed from HBM every block.
  pallas_round fused Pallas round kernel: ALL H/B blocks in one
               pallas_call, ``w``/``r`` VMEM-resident across blocks,
               coordinate sampling on-device (docs/DESIGN.md §6).

Pallas backends fall back to their jnp reference for losses without a
closed-form kernel delta (see ``kernels.sdca.SUPPORTED_LOSSES``), so every
backend is total over the loss registry.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .losses import Loss
from .sdca import (
    local_sdca_block,
    local_sdca_naive,
    sample_coords,
)

Array = jax.Array

# solve(x, y, alpha_i, w_i, n_i, sigma_ii, key) -> (dalpha, r)
Solver = Callable[..., Tuple[Array, Array]]


@dataclasses.dataclass(frozen=True)
class SolverBackend:
    """A named way to run one task's local SDCA round."""

    name: str
    description: str
    # H must be rounded up to a multiple of the block size
    block_aligned: bool
    # can psum its d-contractions over a sharded feature axis
    supports_sharded_features: bool
    # make(loss, rho, lam, H, block=..., axis_name=...) -> Solver
    make: Callable[..., Solver]
    # pallas_call launches per local round for given (H, block)
    pallas_calls: Callable[[int, int], int] = lambda H, block: 0
    # solve body contains pallas_call ops: shard_map engines must disable
    # replication checking around it (compat.shard_map_unchecked)
    uses_pallas: bool = False

    def round_local_iters(self, H: int, block: int) -> int:
        """Round H up to this backend's alignment requirement."""
        if self.block_aligned:
            return int(np.ceil(H / block)) * block
        return H

    def pallas_calls_per_round(self, H: int, block: int) -> int:
        return self.pallas_calls(self.round_local_iters(H, block), block)


_REGISTRY: Dict[str, SolverBackend] = {}


def register_backend(backend: SolverBackend) -> SolverBackend:
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> SolverBackend:
    try:
        return _REGISTRY[name]
    except KeyError as e:
        raise KeyError(
            f"unknown solver backend {name!r}; have {sorted(_REGISTRY)}"
        ) from e


def available_backends() -> Dict[str, SolverBackend]:
    return dict(sorted(_REGISTRY.items()))


def _kappa(rho: float, lam: float, n_i: Array, sigma_ii: Array, dtype) -> Array:
    nf = jnp.maximum(n_i.astype(dtype), 1.0)
    return rho * sigma_ii / (lam * nf)


# ---------------------------------------------------------------------------
# naive — literal Algorithm 2 (reference semantics)
# ---------------------------------------------------------------------------
def _make_naive(
    loss: Loss,
    rho: float,
    lam: float,
    H: int,
    block: int = 64,
    axis_name: Optional[str] = None,
) -> Solver:
    def solve(x, y, alpha_i, w_i, n_i, sigma_ii, key):
        coords = sample_coords(key, H, n_i, x.shape[0])
        return local_sdca_naive(
            x, y, alpha_i, w_i, n_i, sigma_ii, coords, rho, lam, loss, axis_name
        )

    return solve


# ---------------------------------------------------------------------------
# block_gram — jnp block-Gram form (docs/DESIGN.md §4)
# ---------------------------------------------------------------------------
def _make_block_gram(
    loss: Loss,
    rho: float,
    lam: float,
    H: int,
    block: int = 64,
    axis_name: Optional[str] = None,
) -> Solver:
    def solve(x, y, alpha_i, w_i, n_i, sigma_ii, key):
        coords = sample_coords(key, H, n_i, x.shape[0])
        return local_sdca_block(
            x, y, alpha_i, w_i, n_i, sigma_ii, coords, rho, lam, loss,
            block=block, axis_name=axis_name,
        )

    return solve


# ---------------------------------------------------------------------------
# pallas_block — per-block Pallas kernel (one pallas_call per H-block)
# ---------------------------------------------------------------------------
def _make_pallas_block(
    loss: Loss,
    rho: float,
    lam: float,
    H: int,
    block: int = 64,
    axis_name: Optional[str] = None,
) -> Solver:
    if axis_name is not None:
        raise ValueError(
            "the pallas_block backend computes its own d-contractions; with "
            "a sharded feature dim use block_gram (psum'ed) instead"
        )
    from repro.kernels.sdca import ops as sdca_ops  # lazy: kernel layer

    def solve(x, y, alpha_i, w_i, n_i, sigma_ii, key):
        coords = sample_coords(key, H, n_i, x.shape[0])
        coords_b = coords.reshape(H // block, block)
        kappa = _kappa(rho, lam, n_i, sigma_ii, x.dtype)

        def blk_fn(carry, cb):
            dalpha, r = carry
            xb = x[cb]  # (B, d) gather
            at0 = alpha_i[cb] + dalpha[cb]
            deltas = sdca_ops.sdca_block_apply(
                xb, w_i, r, at0, y[cb], cb, kappa, loss.name
            ).astype(x.dtype)
            dalpha = dalpha.at[cb].add(deltas)
            return (dalpha, r + xb.T @ deltas), None

        dalpha0 = jnp.zeros_like(alpha_i) + y[0] * 0
        r0 = jnp.zeros_like(w_i) + x[0] * 0  # see local_sdca_naive note
        (dalpha, r), _ = jax.lax.scan(blk_fn, (dalpha0, r0), coords_b)
        return dalpha, r

    return solve


# ---------------------------------------------------------------------------
# pallas_round — fused whole-round Pallas kernel (ONE pallas_call)
# ---------------------------------------------------------------------------
def _make_pallas_round(
    loss: Loss,
    rho: float,
    lam: float,
    H: int,
    block: int = 64,
    axis_name: Optional[str] = None,
) -> Solver:
    if axis_name is not None:
        raise ValueError(
            "the pallas_round backend computes its own d-contractions; with "
            "a sharded feature dim use block_gram (psum'ed) instead"
        )
    from repro.kernels.sdca import ops as sdca_ops  # lazy: kernel layer

    def solve(x, y, alpha_i, w_i, n_i, sigma_ii, key):
        # the kernel maps the key-derived uniform stream to coordinates
        # on-device with sample_coords' exact arithmetic (bit-equal draws)
        u = jax.random.uniform(key, (H,))
        kappa = _kappa(rho, lam, n_i, sigma_ii, x.dtype)
        dalpha, r = sdca_ops.sdca_round(
            x, y, alpha_i, w_i, u, n_i, kappa, loss.name, block=block
        )
        return dalpha.astype(alpha_i.dtype), r.astype(w_i.dtype)

    return solve


register_backend(
    SolverBackend(
        name="naive",
        description="literal Algorithm 2: one coordinate per step, d-dim "
        "inner product + axpy each (reference semantics)",
        block_aligned=False,
        supports_sharded_features=True,
        make=_make_naive,
    )
)
register_backend(
    SolverBackend(
        name="block_gram",
        description="jnp block-Gram form: three matmuls per B-block plus a "
        "B-step scalar recursion on the Gram block; same iterates as naive",
        block_aligned=True,
        supports_sharded_features=True,
        make=_make_block_gram,
    )
)
register_backend(
    SolverBackend(
        name="pallas_block",
        description="per-block Pallas kernel: one pallas_call per H-block, "
        "w/r re-streamed from HBM each block",
        block_aligned=True,
        supports_sharded_features=False,
        make=_make_pallas_block,
        pallas_calls=lambda H, block: H // block,
        uses_pallas=True,
    )
)
register_backend(
    SolverBackend(
        name="pallas_round",
        description="fused Pallas round kernel: all H/B blocks in one "
        "pallas_call, w/r VMEM-resident, on-device coordinate sampling",
        block_aligned=True,
        supports_sharded_features=False,
        make=_make_pallas_round,
        pallas_calls=lambda H, block: 1,
        uses_pallas=True,
    )
)
