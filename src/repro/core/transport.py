"""Pluggable transport layer — the parameter-server snapshot/commit protocol.

The paper's parameter-server paradigm is a *protocol*, not an execution
substrate: workers solve local dual subproblems against a bounded-stale
snapshot of ``(W, Sigma)`` and exchange only ``(delta_w, Sigma)``-shaped
messages with the server (arXiv:1609.09563, arXiv:1802.03830 make the same
split for their async/graph-regularized variants). This module factors
that protocol out of ``async_dmtrl.py`` behind one surface so the *same*
driver (``fit_async``) runs over any substrate:

    spec = get_transport("simulated" | "threaded" | "multiprocess")

Protocol (the ``Transport`` base class)
---------------------------------------
Worker-facing primitives — the portable object:

  * ``gate(worker, round) -> bool``       SSP admission: may ``worker``
    start ``round``?  True iff ``round <= min(completed) + tau``.  Host
    transports BLOCK until the gate opens; the simulated transport returns
    the decision to its deterministic event loop.
  * ``snapshot(worker) -> Snapshot``      versioned read of the worker's
    ``(W_rows, sigma_rows, alpha_rows)`` — the solve it later commits is
    computed against exactly this snapshot.
  * ``commit(worker, round, delta) -> CommitReceipt``  apply one worker's
    ``(dalpha_rows, db_rows)`` to the server state; the receipt carries the
    observed staleness (server commits between snapshot and apply) and lag
    (rounds ahead of the slowest worker at start).
  * ``install_sigma(sigma, omega, defer=...)``  Omega-step result install;
    with ``defer=True`` it lands only after ``cfg.omega_delay`` commits of
    the next W-step (overlapped Omega-step), else immediately.

Driver-facing lifecycle: ``setup`` / ``run_w_step`` / ``w_true`` /
``pad_sigma`` / ``result`` / ``close``, plus clock/staleness introspection
(``clock()``, ``staleness()``).  All staleness/lag accounting flows through
one path: ``CommitReceipt -> record_receipt -> history ->
convergence.staleness_summary`` — the synchronous engine's
``server_reduce`` is the degenerate ``tau=0`` member of the same family
(``fit_distributed`` emits one all-active commit event per round through
``record_receipt`` too).

Members
-------
``simulated``     bit-identical extraction of the deterministic per-worker
                  clock machinery that used to live inside ``fit_async``:
                  virtual workers advance on simulated ticks, every commit
                  event executes one fused masked SPMD round
                  (``make_async_tick``), runs are bit-reproducible (golden
                  event histories in ``tests/golden/``).
``threaded``      a real in-host parameter server: the server state lives
                  behind a lock/condition pair, G worker *threads* gate,
                  snapshot, solve and commit concurrently.  Arrival order
                  is genuinely nondeterministic but SSP-gate-correct
                  (observed lag can never exceed tau).  ``async_delays``
                  become sleep pacing so straggler schedules remain
                  expressible.
``multiprocess``  a small socket/pickle parameter-server shim: the same
                  server state machine, with G worker *processes* driving
                  it over length-prefixed pickle frames on a loopback
                  socket (one handler thread per connection).  This is the
                  cross-host RPC shape with the host boundary faked by
                  localhost — the prerequisite step the ROADMAP names.
                  Trusted-local only: pickle framing is not an
                  authentication boundary.
``gossip``        (``core/gossip.py``) serverless neighbor averaging over a
                  configurable topology: per-node W replicas, Metropolis
                  mixing at round boundaries; on a complete graph it
                  matches the threaded server.

Wire formats (``core/wire.py``): ``cfg.codec`` picks the snapshot/commit
codec (``none`` / ``bf16`` / ``int8`` + error feedback) for the host
transports and the gossip exchanges; the multiprocess frames carry a
version byte so protocol skew raises ``TransportProtocolError``.

The simulated member snapshots/commits whole worker groups as fused SPMD
calls for efficiency (that is what makes it bit-reproducible and fast on a
mesh); its ``snapshot``/``commit`` methods are still real so a generic
protocol driver can run it one worker at a time (tested).
"""
from __future__ import annotations

import dataclasses
import logging
import os
import pickle
import socket
import struct
import subprocess
import sys
import tempfile
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from . import convergence as conv_mod
from . import dual as dual_mod
from . import omega as omega_mod
from .distributed import (
    DistributedState,
    MeshAxes,
    _axis_size,
    init_state,
    install_initial_state,
    make_local_solve,
    pad_sigma_any,
    pad_sigma_blocks,
    pad_to_multiple,
    round_in_specs,
    round_out_specs,
    round_shard_map,
    server_reduce,
    shard_mtl_data,
)
from .dmtrl import DMTRLConfig
from .losses import get_loss
from .sigma_view import SigmaView, maybe_dense
from .solver_backends import get_backend
from .wire import (
    WIRE_VERSION,
    Codec,
    Encoded,
    ErrorFeedback,
    TransportProtocolError,
    check_wire_version,
    get_codec,
)
from ..obs.trace import span

Array = jax.Array

logger = logging.getLogger(__name__)

# sleep pacing of one simulated delay tick for the host transports (so the
# async_delays straggler schedules remain meaningful under real clocks)
PACE_SECONDS = 0.005

# ---------------------------------------------------------------------------
# unified wire_stats schema
# ---------------------------------------------------------------------------
# ONE key union across every transport, so dashboards, bench checks, and
# the obs bridge (obs.metrics.publish_wire_stats) never KeyError on a
# transport switch.  Gossip-only keys (topology / spectral_gap /
# n_exchanges / *mix_bytes) are present everywhere with inert defaults;
# star transports simply never move them.
WIRE_STATS_SCHEMA: Dict[str, object] = {
    "codec": "none",  # wire codec name (str label, not a counter)
    "topology": "star",  # neighbor graph; "star" = parameter server
    "spectral_gap": 0.0,  # mixing-matrix contraction rate (gossip)
    "n_snapshots": 0,
    "n_commits": 0,
    "n_exchanges": 0,  # gossip edge exchanges
    "snapshot_bytes": 0,  # bytes actually shipped per snapshot
    "commit_bytes": 0,  # bytes actually shipped per delta_w
    "mix_bytes": 0,  # gossip neighbor-exchange bytes
    "raw_snapshot_bytes": 0,  # what the none codec would have sent
    "raw_commit_bytes": 0,
    "raw_mix_bytes": 0,
}


def new_wire_stats(**overrides) -> Dict[str, object]:
    """A fresh ``wire_stats`` dict carrying the full unified schema.

    ``overrides`` must stay inside the documented key union — a typo'd
    counter name here would silently fork the schema, so it raises."""
    unknown = set(overrides) - set(WIRE_STATS_SCHEMA)
    if unknown:
        raise ValueError(
            f"unknown wire_stats key(s) {sorted(unknown)}; the schema is "
            f"{sorted(WIRE_STATS_SCHEMA)}"
        )
    ws = dict(WIRE_STATS_SCHEMA)
    ws.update(overrides)
    return ws


# ---------------------------------------------------------------------------
# protocol messages
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Snapshot:
    """A versioned bounded-staleness read of one worker's server rows.

    ``alpha_rows`` are the worker's own dual coordinates — conceptually
    worker-owned state (only its commits ever move them); the in-host
    servers keep them centrally so ``weights_from_alpha`` stays one call.

    Structured-Sigma wire format: when the server holds a SigmaView the
    snapshot ships ``sigma_diag`` — the (m_loc,) diagonal entries the local
    solver actually reads — and ``sigma_rows`` is None, shrinking the
    per-snapshot Sigma payload from m_loc * m to m_loc floats.  Dense
    servers keep populating ``sigma_rows`` (the historical wire shape), so
    payload comparisons between the two modes stay honest.
    """

    W_rows: Array  # (m_loc, d) weight rows of the worker's tasks
    sigma_rows: Array  # (m_loc, m) Sigma rows; None under a structured view
    alpha_rows: Array  # (m_loc, n_max) the worker's dual coordinates
    version: int  # server commit count when the snapshot was taken
    sigma_diag: Optional[Array] = None  # (m_loc,) view-mode Sigma diagonal


def payload_nbytes(snap: Snapshot, codec=None) -> int:
    """Array bytes one snapshot puts on the wire (bench metric).

    Without a codec this is the historical raw wire: every populated field
    (W rows, Sigma rows/diag, the worker's alpha rows) at full precision.
    With a codec (name or ``wire.Codec``) it is the steady-state
    compressed wire: the ``(W, Sigma)`` payload encoded, and NO alpha —
    under a codec the dual rows are worker-cached state shipped once at
    init, not per-snapshot traffic (see DESIGN.md §13).
    """
    if codec is None or getattr(codec, "name", codec) == "none":
        return sum(
            int(np.asarray(a).nbytes)
            for a in (
                snap.W_rows, snap.sigma_rows, snap.alpha_rows, snap.sigma_diag
            )
            if a is not None
        )
    if not isinstance(codec, Codec):
        codec = get_codec(codec)
    return sum(
        codec.encode(np.asarray(a)).nbytes
        for a in (snap.W_rows, snap.sigma_rows, snap.sigma_diag)
        if a is not None
    )


def decode_snapshot_payload(payload: dict, codec: Codec) -> Snapshot:
    """Worker-side decode of ``_HostServerTransport._encode_snapshot``'s
    wire payload. ``alpha_rows`` is None when the server elided it (the
    worker holds its own cached copy)."""
    def dec(field):
        enc = payload[field]
        return None if enc is None else codec.decode(enc)

    alpha = payload["alpha_rows"]
    return Snapshot(
        W_rows=dec("W_rows"),
        sigma_rows=dec("sigma_rows"),
        alpha_rows=None if alpha is None else np.asarray(alpha),
        version=payload["version"],
        sigma_diag=dec("sigma_diag"),
    )


@dataclasses.dataclass(frozen=True)
class CommitReceipt:
    """Server acknowledgement of one applied contribution.

    ``staleness`` = server commit events between the contribution's
    snapshot and its apply; ``lag`` = rounds it ran ahead of the slowest
    worker at start.  ``tick`` is the transport clock (simulated ticks for
    ``simulated``, wall seconds for the host transports, round index for
    the degenerate synchronous member).
    """

    worker: int
    round: int  # global round index (p * R + r)
    staleness: int
    lag: int
    tick: float
    version: int  # server commit count after the apply (1-based)
    tau: int  # SSP bound in effect at the apply


def new_event_history() -> Dict[str, list]:
    """The engine history skeleton every transport (and the degenerate
    synchronous path) fills: objective samples + per-commit events."""
    return {
        "round": [],  # server commit index of each objective sample
        "tick": [],  # transport clock of each objective sample
        "dual": [],
        "primal": [],
        "gap": [],
        "min_round": [],  # slowest worker's completed rounds at each sample
        "w_worker": [],  # one entry per applied contribution:
        "w_round": [],  # which worker / its round index
        "w_staleness": [],  # commits between its snapshot and its apply
        "w_lag": [],  # rounds ahead of the slowest worker at start
        "w_tick": [],
        "tau_trace": [],  # SSP bound in effect at each commit event
        "gate_refusals": [],  # cumulative gate-refusal episodes at each event
    }


def record_receipt(hist: Dict[str, list], r: CommitReceipt) -> None:
    """THE staleness/lag accounting path: every transport (and the sync
    engine's degenerate tau=0 commits) lands here, so
    ``convergence.staleness_summary`` reads one uniform event stream."""
    hist["w_worker"].append(r.worker)
    hist["w_round"].append(r.round)
    hist["w_staleness"].append(r.staleness)
    hist["w_lag"].append(r.lag)
    hist["w_tick"].append(r.tick)


# ---------------------------------------------------------------------------
# tau="auto" controller (shared by every transport)
# ---------------------------------------------------------------------------
def _adapt_tau(
    tau: int,
    gate_blocks: int,
    window_summary: dict,
    tau_max: int,
    staleness_budget: Optional[float] = None,
) -> int:
    """One step of the tau="auto" controller.

    Cost-aware rule (ROADMAP "adaptive staleness" follow-up): when a
    ``staleness_budget`` is set and the window's observed mean commit
    staleness exceeds it, narrow — even if the gate never refused a start
    (budget violations outrank throughput).  Otherwise: widen when the SSP
    gate actually blocked a worker during the window (``gate_blocks``
    refusal episodes: a worker entering the blocked state counts once, not
    once per tick it stays blocked); narrow when nothing was blocked AND
    the observed per-commit lag (``staleness_summary``'s ``max_lag`` over
    the window) stayed strictly under the current bound, i.e. the slack
    went unused.  Clamped to [0, tau_max].
    """
    if (
        staleness_budget is not None
        and window_summary.get("mean_staleness", 0.0) > staleness_budget
    ):
        return max(tau - 1, 0)
    if gate_blocks > 0:
        return min(tau + 1, tau_max)
    if window_summary["max_lag"] < tau:
        return max(tau - 1, 0)
    return tau


def _worker_delays(cfg: DMTRLConfig, n_workers: int) -> tuple:
    delays = (
        (1,) * n_workers if cfg.async_delays is None else cfg.async_delays
    )
    delays = tuple(int(v) for v in delays)
    if len(delays) != n_workers:
        raise ValueError(
            f"async_delays has {len(delays)} entries for {n_workers} workers"
        )
    if min(delays) < 1:
        raise ValueError(f"async_delays must be >= 1, got {delays}")
    return delays


# ---------------------------------------------------------------------------
# fused SPMD tick of the simulated transport
# ---------------------------------------------------------------------------
def make_async_tick(
    cfg: DMTRLConfig,
    mesh,
    axes: MeshAxes,
    m: int,
    n_max: int,
    d: int,
    rho: float,
):
    """Build the jitted one-tick function of the simulated transport.

    tick(x, y, mask, n, alpha, W, sigma, W_snap, sigma_snap, keys, active)
        -> (alpha, W)

    ``W_snap``/``sigma_snap`` hold each worker group's bounded-staleness
    snapshot rows; ``keys`` is one PRNG key per worker (for the round that
    worker is currently solving); ``active`` masks which workers' results
    commit this tick. Workers solve against their snapshot; the server
    reduce uses the live sigma and only the active contributions.
    """
    local_solve = make_local_solve(cfg, mesh, axes, m, n_max, d, rho)
    in_specs = round_in_specs(axes) + (
        P(axes.data, axes.model),  # W_snap
        P(axes.data, None),  # sigma_snap rows
        P(axes.data, None),  # keys (workers, 2)
        P(axes.data),  # active (workers,)
    )
    out_specs = round_out_specs(axes)

    def tick_body(
        x, y, mask, n, alpha, W, sigma_rows, W_snap, sigma_snap, keys, active
    ):
        key = keys[0]
        a = active[0]
        dalpha, db = local_solve(x, y, n, alpha, W_snap, sigma_snap, key)
        dW = server_reduce(cfg, axes, sigma_rows, db * a)
        return alpha + cfg.eta * (dalpha * a), W + dW

    shmapped = round_shard_map(cfg, axes, tick_body, mesh, in_specs, out_specs)
    return jax.jit(shmapped)


@jax.jit
def _refresh_rows(dst, src, rowmask):
    """Refresh snapshot rows of (re)starting workers: rowmask is (m,) bool."""
    return jnp.where(rowmask[:, None], src, dst)


def _densify_pair(sig, om):
    """Small-m dense fallback of the simulated transport: its fused SPMD
    tick shards dense Sigma rows, so structured views materialize here (the
    host transports keep the factors end-to-end).  A missing Omega (no
    cheap structured inverse) becomes the dense inverse of the jittered
    Sigma — only ever evaluated under ``MATERIALIZE_LIMIT``-sized fallbacks.
    """
    if isinstance(sig, SigmaView):
        sig = sig.dense()
        if om is None:
            om = jnp.linalg.inv(sig)
    if isinstance(om, SigmaView):
        om = om.dense()
    return sig, om


# ---------------------------------------------------------------------------
# host-side per-worker local solve (threaded / multiprocess workers)
# ---------------------------------------------------------------------------
def make_block_solver(cfg: DMTRLConfig, n_max: int, rho: float) -> Callable:
    """The worker half of one round for a host transport: a jitted vmap of
    the configured solver backend over the worker's task block, with the
    same per-(task, pod=0) key derivation as the reference and mesh engines
    (=> bit-equal coordinate draws for the same round key).

    solve(x, y, alpha_rows, W_rows, n, sigma_rows, tids, key)
        -> (dalpha_rows, db_rows)

    ``sigma_rows`` dispatches on rank at trace time: a 2-D array is the
    historical (m_loc, m) row block (dense snapshots), a 1-D array is the
    (m_loc,) ``Snapshot.sigma_diag`` of a structured server — the solver
    only ever reads the diagonal, so the signature stays put.
    """
    loss = get_loss(cfg.loss)
    backend = get_backend(cfg.solver)
    H = backend.round_local_iters(cfg.local_iters or n_max, cfg.block_size)
    solver = backend.make(loss, rho, cfg.lam, H, block=cfg.block_size)

    @jax.jit
    def solve(x, y, alpha_rows, W_rows, n, sigma_rows, tids, key):
        keys = jax.vmap(
            lambda t: jax.random.fold_in(jax.random.fold_in(key, t), 0)
        )(tids)
        if sigma_rows.ndim == 1:
            sigma_ii = sigma_rows
        else:
            sigma_ii = jnp.take_along_axis(
                sigma_rows, tids[:, None], axis=1
            )[:, 0]
        dalpha, r = jax.vmap(solver)(x, y, alpha_rows, W_rows, n, sigma_ii, keys)
        # delta_b_i = (eta / n_i) * X_i^T dalpha_i (padded tasks have n=1,
        # x=0 => inert); eta pre-applied exactly like the mesh local solve
        db = cfg.eta * r / jnp.maximum(n, 1)[:, None].astype(r.dtype)
        return dalpha, db

    return solve


# ---------------------------------------------------------------------------
# Transport base
# ---------------------------------------------------------------------------
class Transport:
    """Base class: protocol + driver lifecycle every member implements."""

    name: str = "?"
    needs_mesh: bool = False
    n_pods: int = 1  # rho n_blocks_scale (pod sharding: simulated only)

    def __init__(self):
        self._model_subscribers: List[Callable] = []
        self._model_version = 0
        # worker whose gate/snapshot/commit triggered the install in
        # flight (None for driver-initiated installs) — log context only
        self._install_worker: Optional[int] = None

    # -- model snapshot subscription (serving hot-swap hook) ----------------
    def subscribe(self, callback: Callable) -> Callable:
        """Register ``callback(W, sigma, version)`` to fire after every
        Sigma install — the point where a new servable ``(W, Sigma)``
        exists. Arrays arrive at the RAW problem size (padding stripped),
        versions strictly increase across the run. The serving scheduler's
        ``publish_weights`` has exactly this signature, so

            transport.subscribe(scheduler.publish_weights)

        hot-swaps live training commits into a serving queue, and a
        ``serve.fleet.FleetRouter`` is a drop-in SECOND subscriber tier:

            transport.subscribe(router.publish_weights)

        rolls every install across a whole replica fleet (one replica per
        router step) while the router's per-client tokens keep reads
        monotonic mid-roll. Callbacks run on the installing thread (under
        the server lock for host members): keep them quick and NEVER call
        back into the transport.
        """
        self._model_subscribers.append(callback)
        return callback

    def unsubscribe(self, callback: Callable) -> bool:
        """Deregister a ``subscribe``d callback (identity match, first
        occurrence). Returns True when removed, False when the callback
        was not registered — so tearing down a serving tier (a drained
        scheduler, a decommissioned fleet router) is an idempotent
        operation, not an error path."""
        try:
            self._model_subscribers.remove(callback)
            return True
        except ValueError:
            return False

    def _notify_model(self, W: Array, sigma) -> None:
        self._model_version += 1
        if not self._model_subscribers:
            return
        W = np.asarray(W)
        # structured Sigma ships as the view itself (factors, a few KB) —
        # subscribers (serve/scheduler.py publish_weights) treat it opaquely
        if not isinstance(sigma, SigmaView):
            sigma = np.asarray(sigma)
        # per-subscriber isolation: one raising callback (a broken serving
        # tier) must never unwind the Sigma-install path or starve the
        # other subscribers — log it, drop it, keep installing
        failed = []
        for cb in list(self._model_subscribers):
            try:
                cb(W, sigma, self._model_version)
            except Exception:
                logger.exception(
                    "transport %r: model subscriber %r raised at snapshot "
                    "version %d (install triggered by worker %s); dropping "
                    "it (installs continue)",
                    self.name,
                    cb,
                    self._model_version,
                    "driver"
                    if self._install_worker is None
                    else self._install_worker,
                )
                failed.append(cb)
        for cb in failed:
            self.unsubscribe(cb)

    # -- driver lifecycle ---------------------------------------------------
    def setup(self, cfg, raw, *, mesh, axes, reg, init, track) -> None:
        raise NotImplementedError

    def run_w_step(self, p: int, rho: float, outer_key: Array) -> None:
        """Drive all workers through cfg.rounds rounds of the protocol,
        then apply any still-pending Sigma install at the barrier."""
        raise NotImplementedError

    def w_true(self) -> Array:
        """Current W rows of the REAL tasks (for the Omega-step)."""
        raise NotImplementedError

    def rho_sigma(self) -> Array:
        """Sigma the next W-step's rho bound should be computed from."""
        raise NotImplementedError

    def pad_sigma(self, sigma_t: Array, omega_t: Array) -> Tuple[Array, Array]:
        raise NotImplementedError

    def result(self):
        """(W, sigma, state, hist) at the raw problem size, like the
        legacy ``fit_async`` return."""
        raise NotImplementedError

    def close(self) -> None:  # idempotent; called by the driver's finally
        pass

    # -- worker-facing protocol --------------------------------------------
    def gate(self, worker: int, rnd: int) -> bool:
        raise NotImplementedError

    def snapshot(self, worker: int) -> Snapshot:
        raise NotImplementedError

    def commit(self, worker: int, rnd: int, delta) -> CommitReceipt:
        raise NotImplementedError

    def install_sigma(self, sigma: Array, omega: Array, *, defer: bool) -> None:
        raise NotImplementedError

    # -- introspection ------------------------------------------------------
    def clock(self) -> float:
        """Transport time: simulated ticks / wall seconds since setup."""
        raise NotImplementedError

    def staleness(self) -> Dict[str, object]:
        """``convergence.staleness_summary`` over the commits so far."""
        return conv_mod.staleness_summary(
            {k: np.asarray(v) for k, v in self.hist.items()}
        )

    # -- shared per-commit-event bookkeeping --------------------------------
    def _after_commit_event(self, tick, alpha, sigma) -> None:
        """tau trace + tau="auto" adapt window + track_every objective
        sampling after ONE server commit event.  Shared by every member so
        the adaptive controller and the recorded histories can never drift
        between transports (the cross-transport tests rely on that).
        Caller guarantees exclusivity: the simulated event loop is single-
        threaded, the host servers call this under the server lock."""
        cfg, hist = self.cfg, self.hist
        hist["tau_trace"].append(self.tau)
        hist["gate_refusals"].append(self.gate_refusals_total)
        if self.tau_auto and self.commits_total % self.adapt_window == 0:
            win = {
                k: np.asarray(hist[k][self.win_start :])
                for k in ("w_staleness", "w_lag", "w_worker")
            }
            self.tau = _adapt_tau(
                self.tau,
                self.gate_blocks,
                conv_mod.staleness_summary(win),
                cfg.tau_max,
                cfg.staleness_budget,
            )
            self.gate_blocks = 0
            self.refused = set()  # a still-blocked worker re-counts
            self.win_start = len(hist["w_worker"])
        done = min(self.completed) >= self.R
        if self.track and (self.commits_total % cfg.track_every == 0 or done):
            dd, pp = self._objectives(alpha, sigma)
            hist["round"].append(self.commits_total)
            hist["tick"].append(tick)
            hist["dual"].append(float(dd))
            hist["primal"].append(float(pp))
            hist["gap"].append(float(pp - dd))
            hist["min_round"].append(self.p * self.R + min(self.completed))


# ---------------------------------------------------------------------------
# simulated — deterministic per-worker clocks, fused SPMD commits
# ---------------------------------------------------------------------------
class SimulatedTransport(Transport):
    """Bit-identical extraction of the legacy in-process clock simulation.

    Virtual workers advance on a deterministic simulated clock (worker g
    takes ``async_delays[g]`` ticks per local solve); every commit event
    executes one fused masked SPMD round over the whole mesh, so runs are
    bit-reproducible (the golden event histories in ``tests/golden/`` and
    the tau=0 bit-parity anchor against ``fit_distributed`` pin it).
    """

    name = "simulated"
    needs_mesh = True

    def setup(self, cfg, raw, *, mesh, axes, reg, init, track):
        if mesh is None:
            raise ValueError("the simulated transport needs a mesh")
        codec = getattr(cfg, "codec", "none")
        if codec != "none":
            raise ValueError(
                "transport='simulated' is the bit-parity anchor and has no "
                f"wire; codec={codec!r} needs a host transport "
                "('threaded' / 'multiprocess' / 'gossip')"
            )
        topology = getattr(cfg, "topology", "complete")
        if not (isinstance(topology, str) and topology == "complete"):
            raise ValueError(
                "topology= is a gossip-transport option; transport="
                "'simulated' has no neighbor graph (use transport='gossip')"
            )
        G = _axis_size(mesh, axes.data)
        if cfg.n_workers is not None and cfg.n_workers != G:
            raise ValueError(
                f"transport='simulated' derives its workers from the mesh "
                f"data axis (= {G}); n_workers={cfg.n_workers} conflicts"
            )
        self.cfg, self.raw, self.mesh, self.axes = cfg, raw, mesh, axes
        self.reg, self.track = reg, track
        loss = get_loss(cfg.loss)
        data, m, d = shard_mtl_data(raw, mesh, axes)
        self.data, self.m, self.d = data, m, d
        self.state = init_state(data, mesh, axes, m, d)
        self.G = G
        self.m_loc = m // G
        self.delays = _worker_delays(cfg, G)
        self.n_pods = _axis_size(mesh, axes.pod)
        self.R = cfg.rounds
        self._sr = NamedSharding(mesh, P(axes.data, None))
        self.hist = new_event_history()

        @jax.jit
        def objectives(alpha, sigma):
            dd = dual_mod.dual_objective(data, alpha, sigma, cfg.lam, loss)
            pp = dual_mod.primal_objective_from_alpha(
                data, alpha, sigma, cfg.lam, loss
            )
            return dd, pp

        @jax.jit
        def w_from_alpha(alpha, sigma):
            return dual_mod.weights_from_alpha(data, alpha, sigma, cfg.lam)

        self._objectives = objectives
        self._w_from_alpha = w_from_alpha
        self.state = install_initial_state(
            self.state, raw, data, m, cfg, mesh, axes, reg, init, w_from_alpha
        )
        if isinstance(self.state.sigma, SigmaView) or isinstance(
            self.state.omega, SigmaView
        ):
            sig0, om0 = _densify_pair(self.state.sigma, self.state.omega)
            self.state = dataclasses.replace(
                self.state,
                sigma=jax.device_put(sig0, self._sr),
                omega=jax.device_put(om0, self._sr),
                W=w_from_alpha(self.state.alpha, jax.device_put(sig0, self._sr)),
            )

        # snapshots start in sync with the live state
        self.W_snap = self.state.W
        self.sigma_snap = self.state.sigma
        self.commits_total = 0
        self._clock = 0  # global simulated time, accumulated across W-steps
        self.pending = None  # (sigma, omega) awaiting overlap installation
        # tau="auto": start bulk-synchronous, adapt once per G-commit window
        self.tau_auto = cfg.tau == "auto"
        self.tau = 0 if self.tau_auto else cfg.tau
        self.adapt_window = G
        self.gate_blocks = 0  # refusal EPISODES this window (a worker
        #   entering the blocked state counts once until it unblocks or the
        #   window rolls over, not once per simulation tick)
        self.gate_refusals_total = 0
        self.refused: set = set()
        self.win_start = 0  # w_* index where the adapt window began
        # per-worker protocol bookkeeping (reset each W-step)
        self.completed = [0] * G
        self.cur_round = [0] * G
        self.snap_commit = [0] * G
        self.snap_lag = [0] * G
        self.commits_outer = 0
        self.p = 0
        # no wire at all (in-mesh SPMD), but the unified schema still
        # applies: every counter sits at its zero default
        self.wire_stats = new_wire_stats(topology="complete")

    # -- protocol -----------------------------------------------------------
    def gate(self, worker, rnd):
        """SSP admission (non-blocking): the deterministic event loop polls
        the decision instead of parking a thread on it."""
        return rnd <= min(self.completed) + self.tau

    def _rows(self, worker):
        return slice(worker * self.m_loc, (worker + 1) * self.m_loc)

    def snapshot(self, worker):
        rows = self._rows(worker)
        self.snap_commit[worker] = self.commits_total
        self.snap_lag[worker] = self.completed[worker] - min(self.completed)
        return Snapshot(
            W_rows=self.state.W[rows],
            sigma_rows=self.state.sigma[rows],
            alpha_rows=self.state.alpha[rows],
            version=self.commits_total,
        )

    def commit(self, worker, rnd, delta):
        """Apply ONE worker's (dalpha_rows, db_rows) immediately.

        The deterministic event loop in ``run_w_step`` does not use this —
        it fuses all same-tick arrivals into one masked SPMD reduce (that
        is what makes the simulation bit-reproducible); this method makes
        the protocol complete so a generic driver can run the simulated
        member one worker at a time (tested for equivalence at tau=0).
        """
        self._maybe_install(worker)
        dalpha, db = delta
        rows = self._rows(worker)
        cfg = self.cfg
        alpha = self.state.alpha.at[rows].add(cfg.eta * dalpha)
        W = self.state.W + (
            jnp.swapaxes(self.state.sigma[rows], 0, 1) @ db
        ) / cfg.lam
        self.state = dataclasses.replace(self.state, alpha=alpha, W=W)
        self.commits_total += 1
        self.commits_outer += 1
        self.completed[worker] += 1
        receipt = CommitReceipt(
            worker=worker,
            round=self.p * self.R + rnd,
            staleness=self.commits_total - 1 - self.snap_commit[worker],
            lag=self.snap_lag[worker],
            tick=self._clock + self.commits_outer,
            version=self.commits_total,
            tau=self.tau,
        )
        record_receipt(self.hist, receipt)
        self._after_commit_event(
            receipt.tick, self.state.alpha, self.state.sigma
        )
        return receipt

    def install_sigma(self, sigma, omega, *, defer):
        if defer:
            self.pending = (sigma, omega)
        else:
            self._install(sigma, omega)

    def _install(self, sig, om):
        with span("install_sigma", cat="transport", transport=self.name):
            sig, om = _densify_pair(sig, om)
            st = dataclasses.replace(
                self.state,
                sigma=jax.device_put(sig, self._sr),
                omega=jax.device_put(om, self._sr),
            )
            self.state = dataclasses.replace(
                st, W=self._w_from_alpha(st.alpha, st.sigma)
            )
            self._notify_model(
                self.state.W[: self.raw.m, : self.raw.d],
                self.state.sigma[: self.raw.m, : self.raw.m],
            )

    def _maybe_install(self, worker=None):
        if self.pending is not None and self.commits_outer >= self.cfg.omega_delay:
            self._install_worker = worker
            try:
                self._install(*self.pending)
            finally:
                self._install_worker = None
            self.pending = None

    # -- driver lifecycle ---------------------------------------------------
    def w_true(self):
        return self.state.W[: self.raw.m]

    def rho_sigma(self):
        return self.state.sigma

    def pad_sigma(self, sigma_t, omega_t):
        return pad_sigma_any(
            sigma_t, omega_t, self.m, self.raw.m, self.cfg.omega_jitter
        )

    def clock(self):
        return self._clock

    def _row_mask(self, workers):
        mask = np.zeros((self.m,), bool)
        for g in workers:
            mask[g * self.m_loc : (g + 1) * self.m_loc] = True
        return jnp.asarray(mask)

    def run_w_step(self, p, rho, outer_key):
        cfg, G, R = self.cfg, self.G, self.R
        self.p = p
        tick_fn = make_async_tick(
            cfg, self.mesh, self.axes, self.m, self.data.n_max, self.d, rho
        )
        # same key schedule as fit_distributed => bit-equal coordinate draws
        round_keys = jax.random.split(outer_key, R)  # (R, 2)

        self.completed = [0] * G
        self.cur_round = [0] * G
        busy = [False] * G
        finish_at = [0] * G
        tick = 0
        self.commits_outer = 0
        hist = self.hist

        while min(self.completed) < R:
            # --- overlapped Omega-step installation --------------------
            self._maybe_install()
            # --- starts: idle workers gated by the SSP staleness bound --
            floor = min(self.completed)
            newly = [
                g
                for g in range(G)
                if not busy[g]
                and self.completed[g] < R
                and self.gate(g, self.completed[g])
            ]
            blocked = {
                g
                for g in range(G)
                if not busy[g]
                and self.completed[g] < R
                and not self.gate(g, self.completed[g])
            }
            fresh_blocks = len(blocked - self.refused)
            self.gate_blocks += fresh_blocks
            self.gate_refusals_total += fresh_blocks
            self.refused = blocked
            if newly:
                rm = self._row_mask(newly)
                self.W_snap = _refresh_rows(self.W_snap, self.state.W, rm)
                self.sigma_snap = _refresh_rows(
                    self.sigma_snap, self.state.sigma, rm
                )
                for g in newly:
                    busy[g] = True
                    self.cur_round[g] = self.completed[g]
                    finish_at[g] = tick + self.delays[g]
                    self.snap_commit[g] = self.commits_total
                    self.snap_lag[g] = self.completed[g] - floor
            # --- advance the clock to the next finish event ------------
            tick = min(finish_at[g] for g in range(G) if busy[g])
            active = [g for g in range(G) if busy[g] and finish_at[g] == tick]
            keys_arr = round_keys[
                np.clip(np.asarray(self.cur_round, np.int32), 0, R - 1)
            ]  # (G, 2)
            active_arr = jnp.zeros((G,), self.data.x.dtype).at[
                jnp.asarray(active, jnp.int32)
            ].set(1.0)
            alpha, W = tick_fn(
                self.data.x,
                self.data.y,
                self.data.mask,
                self.data.n,
                self.state.alpha,
                self.state.W,
                self.state.sigma,
                self.W_snap,
                self.sigma_snap,
                keys_arr,
                active_arr,
            )
            self.state = dataclasses.replace(self.state, alpha=alpha, W=W)
            self.commits_total += 1
            self.commits_outer += 1
            for g in active:
                busy[g] = False
                record_receipt(
                    hist,
                    CommitReceipt(
                        worker=g,
                        round=p * R + self.cur_round[g],
                        staleness=self.commits_total - 1 - self.snap_commit[g],
                        lag=self.snap_lag[g],
                        tick=self._clock + tick,
                        version=self.commits_total,
                        tau=self.tau,
                    ),
                )
                self.completed[g] += 1
            self._after_commit_event(
                self._clock + tick, self.state.alpha, self.state.sigma
            )

        self._clock += tick
        # --- W-step boundary: a pending Sigma must never be dropped ----
        if self.pending is not None:
            self._install(*self.pending)
            self.pending = None

    def result(self):
        hist_np = {k: np.asarray(v) for k, v in self.hist.items()}
        W = np.asarray(self.state.W)[: self.raw.m, : self.raw.d]
        sigma = np.asarray(self.state.sigma)[: self.raw.m, : self.raw.m]
        return W, sigma, self.state, hist_np


# ---------------------------------------------------------------------------
# host parameter server — shared by the threaded and multiprocess members
# ---------------------------------------------------------------------------
class _HostServerTransport(Transport):
    """Lock-protected versioned parameter-server state.

    The server owns (alpha, W, sigma, omega) plus the SSP bookkeeping
    behind one condition variable; ``gate`` BLOCKS the calling worker
    (thread or connection handler) until admission, ``snapshot``/``commit``
    are single critical sections.  Subclasses differ only in who the
    workers are (threads vs socket-connected processes).

    Snapshot versioning: workers read the newest ROUND-BOUNDARY version of
    ``(W, sigma)`` — the state frozen when ``min(completed)`` last advanced
    (or the W-step began) — not the live arrays, so a worker admitted late
    into a round sees the same read set as one admitted first.  At tau=0
    this is exactly the bulk-synchronous read set, which makes the final
    iterates order-independent up to float association (the parity anchor
    against the ``reference`` engine).  A worker's own dual rows
    (``alpha_rows``) are always current: only its own commits move them.
    Receipt staleness is stamped from the commit count at which the served
    boundary was frozen — the true age of the data read — so the metric
    stays comparable with the simulated member (up to G-1 within a round
    at tau=0, exactly like the fused-tick accounting documents).
    """

    needs_mesh = False

    def setup(self, cfg, raw, *, mesh, axes, reg, init, track):
        axes = axes or MeshAxes()
        if mesh is not None and (
            _axis_size(mesh, axes.model) > 1 or _axis_size(mesh, axes.pod) > 1
        ):
            raise ValueError(
                f"transport={self.name!r} shards tasks over workers only; "
                "model/pod mesh axes need transport='simulated'"
            )
        G = cfg.n_workers
        if G is None:
            G = _axis_size(mesh, axes.data) if mesh is not None else 1
        self.cfg, self.raw, self.reg, self.track = cfg, raw, reg, track
        self.G = G
        self.m = pad_to_multiple(raw.m, G)
        self.m_loc = self.m // G
        self.data = raw.pad_tasks(self.m)
        self.delays = _worker_delays(cfg, G)
        self.pace = 0.0 if cfg.async_delays is None else PACE_SECONDS
        self.R = cfg.rounds
        data, dtype = self.data, self.data.x.dtype
        loss = get_loss(cfg.loss)

        @jax.jit
        def objectives(alpha, sigma):
            dd = dual_mod.dual_objective(data, alpha, sigma, cfg.lam, loss)
            pp = dual_mod.primal_objective_from_alpha(
                data, alpha, sigma, cfg.lam, loss
            )
            return dd, pp

        @jax.jit
        def w_from_alpha(alpha, sigma):
            return dual_mod.weights_from_alpha(data, alpha, sigma, cfg.lam)

        self._objectives = objectives
        self._w_from_alpha = w_from_alpha

        self.alpha = jnp.zeros((self.m, data.n_max), dtype)
        self.W = jnp.zeros((self.m, data.d), dtype)
        self.sigma, self.omega = omega_mod.init_sigma(self.m, dtype)
        # warm start / custom-init regularizer (mirrors the mesh engines'
        # install_initial_state so cross-transport parity holds); structured
        # members install their SigmaView init and the server keeps the
        # factors end-to-end — no dense (m, m) ever lives on the host path
        sigma_t = omega_t = None
        if init is not None:
            if isinstance(init.sigma, SigmaView):
                sigma_t = init.sigma
            else:
                sigma_t = jnp.asarray(init.sigma, dtype)
            omega_t = init.omega
            if omega_t is not None and not isinstance(omega_t, SigmaView):
                omega_t = jnp.asarray(omega_t, dtype)
        elif reg.custom_init or reg.structured:
            sigma_t, omega_t = reg.init(raw.m, dtype)
        if sigma_t is not None:
            self.sigma, self.omega = pad_sigma_any(
                sigma_t, omega_t, self.m, raw.m, cfg.omega_jitter
            )
        if init is not None:
            alpha0 = jnp.zeros((self.m, data.n_max), dtype)
            self.alpha = alpha0.at[: raw.m, : raw.n_max].set(
                jnp.asarray(init.alpha, dtype)
            )
            self.W = w_from_alpha(self.alpha, self.sigma)

        self.lock = threading.RLock()
        self.cond = threading.Condition(self.lock)
        self.completed = [0] * G
        self.commits_total = 0
        self.commits_outer = 0
        self.pending = None
        self.tau_auto = cfg.tau == "auto"
        self.tau = 0 if self.tau_auto else cfg.tau
        self.adapt_window = G
        self.gate_blocks = 0
        self.gate_refusals_total = 0
        self.refused: set = set()
        self.win_start = 0
        self._snap_version = [0] * G
        self._snap_lag = [0] * G
        self._boundary = (self.W, self.sigma)
        self._boundary_version = 0
        self.hist = new_event_history()
        self.abort: Optional[BaseException] = None
        self._shutdown = False  # set by close(); unparks gate waiters
        self._t0 = time.monotonic()
        self.p = 0
        # --- wire codec (core/wire.py) ---------------------------------
        topology = getattr(cfg, "topology", "complete")
        if self.name in ("threaded", "multiprocess") and not (
            isinstance(topology, str) and topology == "complete"
        ):
            raise ValueError(
                f"topology= is a gossip-transport option; transport="
                f"{self.name!r} is a star topology (use transport='gossip')"
            )
        self.codec: Codec = get_codec(getattr(cfg, "codec", "none"))
        self._commit_ef = ErrorFeedback(self.codec)
        self._alpha_cache: Dict[int, np.ndarray] = {}
        self.wire_stats = new_wire_stats(codec=self.codec.name)

    # -- protocol (all under the server condition variable) -----------------
    def _rows(self, worker):
        return slice(worker * self.m_loc, (worker + 1) * self.m_loc)

    def _check_abort(self):
        if self.abort is not None:
            raise RuntimeError(
                f"transport {self.name!r} aborted: {self.abort!r}"
            ) from self.abort

    def gate(self, worker, rnd):
        """Block until the SSP gate admits ``worker`` to start ``rnd``."""
        with span("gate", cat="transport", worker=worker, round=rnd), self.cond:
            while True:
                self._check_abort()
                if self._shutdown:
                    raise RuntimeError(
                        f"transport {self.name!r} shut down while worker "
                        f"{worker} was waiting at the gate"
                    )
                self._maybe_install(worker)
                if rnd <= min(self.completed) + self.tau:
                    self.refused.discard(worker)
                    return True
                # refusal EPISODES, matching the simulated member: count on
                # entering the blocked state, and again after an adapt-window
                # rollover clears ``refused`` while this worker still waits
                if worker not in self.refused:
                    self.refused.add(worker)
                    self.gate_blocks += 1
                    self.gate_refusals_total += 1
                self.cond.wait(timeout=0.05)

    def snapshot(self, worker):
        with span("snapshot", cat="transport", worker=worker), self.cond:
            self._check_abort()
            self._maybe_install(worker)
            rows = self._rows(worker)
            # staleness is the age of the DATA served (the boundary freeze),
            # not of the snapshot call itself
            self._snap_version[worker] = self._boundary_version
            self._snap_lag[worker] = self.completed[worker] - min(self.completed)
            W_b, sigma_b = self._boundary
            if isinstance(sigma_b, SigmaView):
                # structured server: ship only the diagonal the local
                # solver reads — m_loc floats instead of m_loc * m
                return Snapshot(
                    W_rows=W_b[rows],
                    sigma_rows=None,
                    alpha_rows=self.alpha[rows],
                    version=self._boundary_version,
                    sigma_diag=sigma_b.diag()[rows],
                )
            return Snapshot(
                W_rows=W_b[rows],
                sigma_rows=sigma_b[rows],
                alpha_rows=self.alpha[rows],
                version=self._boundary_version,
            )

    def commit(self, worker, rnd, delta):
        dalpha, db = delta
        with span("commit", cat="transport", worker=worker, round=rnd), self.cond:
            self._check_abort()
            self._maybe_install(worker)
            cfg = self.cfg
            rows = self._rows(worker)
            # the Sigma-coupled server reduce for ONE worker's delta_b rows:
            # W += Sigma[:, rows] @ db / lam  (sigma is symmetric)
            self.alpha = self.alpha.at[rows].add(cfg.eta * dalpha)
            if isinstance(self.sigma, SigmaView):
                self.W = self.W + self.sigma.col_block_matvec(
                    rows.start, db
                ) / cfg.lam
            else:
                self.W = self.W + (
                    jnp.swapaxes(self.sigma[rows], 0, 1) @ db
                ) / cfg.lam
            stal = self.commits_total - self._snap_version[worker]
            self.commits_total += 1
            self.commits_outer += 1
            floor_before = min(self.completed)
            self.completed[worker] += 1
            if min(self.completed) > floor_before:
                # round boundary: freeze the snapshot version later starters
                # of the next round will read (see class docstring)
                self._boundary = (self.W, self.sigma)
                self._boundary_version = self.commits_total
            tick = time.monotonic() - self._t0
            receipt = CommitReceipt(
                worker=worker,
                round=self.p * self.R + rnd,
                staleness=stal,
                lag=self._snap_lag[worker],
                tick=tick,
                version=self.commits_total,
                tau=self.tau,
            )
            record_receipt(self.hist, receipt)
            self._after_commit_event(tick, self.alpha, self.sigma)
            self.cond.notify_all()
            return receipt

    def install_sigma(self, sigma, omega, *, defer):
        with self.cond:
            if defer:
                self.pending = (sigma, omega)
            else:
                self._install(sigma, omega)

    def _install(self, sig, om):
        with span("install_sigma", cat="transport", transport=self.name):
            self.sigma, self.omega = sig, om
            self.W = self._w_from_alpha(self.alpha, self.sigma)
            # W was just recomputed from exact (full-precision) alpha, so any
            # pending quantization residual no longer refers to live state
            self._commit_ef.reset()
            # the install must reach the NEXT snapshot, not wait for the next
            # floor advance: refresh the served boundary (matches the simulated
            # member, whose post-install starters read the live state)
            self._boundary = (self.W, self.sigma)
            self._boundary_version = self.commits_total
            if isinstance(self.sigma, SigmaView):
                sigma_raw = self.sigma.unpad(self.raw.m)
            else:
                sigma_raw = self.sigma[: self.raw.m, : self.raw.m]
            self._notify_model(self.W[: self.raw.m, : self.raw.d], sigma_raw)

    def _maybe_install(self, worker=None):
        if self.pending is not None and self.commits_outer >= self.cfg.omega_delay:
            self._install_worker = worker
            try:
                self._install(*self.pending)
            finally:
                self._install_worker = None
            self.pending = None

    def _fail(self, exc: BaseException):
        with self.cond:
            if self.abort is None:
                self.abort = exc
            self.cond.notify_all()

    # -- wire codec (snapshot/commit serialization) -------------------------
    def _encode_snapshot(self, worker: int, have_alpha: bool) -> dict:
        """Take one snapshot and encode it for the wire.

        ``(W, Sigma)`` fields go through the codec; the worker's alpha
        rows are its own dual state — under a lossy codec they ship
        exactly ONCE (``have_alpha=False``) and then live worker-side
        (the worker replays its own ``eta * dalpha`` commits), under the
        ``none`` codec they ship raw every time (the historical wire).
        Updates ``wire_stats`` under the server lock.
        """
        snap = self.snapshot(worker)
        with span("snapshot_encode", cat="transport", worker=worker):
            raw = payload_nbytes(snap)
            payload: dict = {"version": snap.version}
            nb = 0
            for field in ("W_rows", "sigma_rows", "sigma_diag"):
                a = getattr(snap, field)
                if a is None:
                    payload[field] = None
                    continue
                enc = self.codec.encode(np.asarray(a))
                payload[field] = enc
                nb += enc.nbytes
            ship_alpha = self.codec.name == "none" or not have_alpha
            if ship_alpha:
                alpha = np.asarray(snap.alpha_rows)
                payload["alpha_rows"] = alpha
                nb += int(alpha.nbytes)
            else:
                payload["alpha_rows"] = None
            with self.lock:
                self.wire_stats["n_snapshots"] += 1
                self.wire_stats["raw_snapshot_bytes"] += raw
                self.wire_stats["snapshot_bytes"] += nb
            return payload

    def wire_snapshot(self, worker: int) -> Snapshot:
        """Snapshot as seen through the codec round-trip (the in-host
        mirror of what a remote worker would decode off the socket)."""
        have = self.codec.name != "none" and worker in self._alpha_cache
        payload = self._encode_snapshot(worker, have_alpha=have)
        with span("snapshot_decode", cat="transport", worker=worker):
            snap = decode_snapshot_payload(payload, self.codec)
        if snap.alpha_rows is None:
            snap = dataclasses.replace(
                snap, alpha_rows=self._alpha_cache[worker]
            )
        elif self.codec.name != "none":
            self._alpha_cache[worker] = np.asarray(snap.alpha_rows)
        return snap

    def wire_commit(self, worker: int, rnd: int, delta) -> CommitReceipt:
        """Commit through the codec: delta_w (``db``) is encoded with
        per-worker error feedback and the server applies the DECODED
        delta — exactly what a remote peer would receive. ``dalpha`` is
        the worker's own dual state (shipped raw for the in-host server's
        central bookkeeping; not part of the delta_w wire metric)."""
        dalpha, db = delta
        if self.codec.name == "none":
            raw = int(np.asarray(db).nbytes)
            with self.lock:
                self.wire_stats["n_commits"] += 1
                self.wire_stats["raw_commit_bytes"] += raw
                self.wire_stats["commit_bytes"] += raw
            return self.commit(worker, rnd, (dalpha, db))
        with span("commit_encode", cat="transport", worker=worker):
            enc = self._commit_ef.encode(("db", worker), np.asarray(db))
            db_dec = jnp.asarray(self.codec.decode(enc))
        if worker in self._alpha_cache:
            # keep the worker-side alpha mirror exact: same f32 arithmetic
            # as the server's alpha.at[rows].add(eta * dalpha)
            self._alpha_cache[worker] = np.asarray(
                self._alpha_cache[worker] + self.cfg.eta * np.asarray(dalpha)
            )
        with self.lock:
            self.wire_stats["n_commits"] += 1
            self.wire_stats["raw_commit_bytes"] += int(np.asarray(db).nbytes)
            self.wire_stats["commit_bytes"] += enc.nbytes
        return self.commit(worker, rnd, (dalpha, db_dec))

    # -- driver lifecycle ---------------------------------------------------
    def _begin_w_step(self, p):
        with self.cond:
            self._check_abort()
            self.p = p
            self.completed = [0] * self.G
            self.commits_outer = 0
            self._boundary = (self.W, self.sigma)
            self._boundary_version = self.commits_total

    def _end_w_step(self):
        with self.cond:
            self._check_abort()
            if self.pending is not None:  # barrier: never drop a Sigma
                self._install(*self.pending)
                self.pending = None

    def w_true(self):
        with self.lock:
            return self.W[: self.raw.m]

    def rho_sigma(self):
        with self.lock:
            return self.sigma

    def pad_sigma(self, sigma_t, omega_t):
        return pad_sigma_any(
            sigma_t, omega_t, self.m, self.raw.m, self.cfg.omega_jitter
        )

    def clock(self):
        return time.monotonic() - self._t0

    def result(self):
        with self.lock:
            hist_np = {k: np.asarray(v) for k, v in self.hist.items()}
            W = np.asarray(self.W)[: self.raw.m, : self.raw.d]
            if isinstance(self.sigma, SigmaView):
                sigma = maybe_dense(self.sigma.unpad(self.raw.m))
            else:
                sigma = np.asarray(self.sigma)[: self.raw.m, : self.raw.m]
            state = DistributedState(
                alpha=self.alpha, W=self.W, sigma=self.sigma, omega=self.omega
            )
        return W, sigma, state, hist_np


class ThreadedTransport(_HostServerTransport):
    """Real in-host parameter server: G worker threads against the locked
    server state.  Arrival order is genuinely nondeterministic (OS
    scheduling), the SSP gate still bounds lag by tau.  ``async_delays``
    pace the workers (``PACE_SECONDS`` per simulated tick) so straggler
    schedules remain expressible under real clocks."""

    name = "threaded"

    def run_w_step(self, p, rho, outer_key):
        self._begin_w_step(p)
        round_keys = jax.random.split(outer_key, self.R)
        solve = make_block_solver(self.cfg, self.data.n_max, rho)
        blocks = [
            (
                self.data.x[self._rows(g)],
                self.data.y[self._rows(g)],
                self.data.n[self._rows(g)],
                jnp.arange(
                    g * self.m_loc, (g + 1) * self.m_loc, dtype=jnp.int32
                ),
            )
            for g in range(self.G)
        ]
        # compile once before fanning out (all workers share one shape)
        x0, y0, n0, t0 = blocks[0]
        snap0 = self.snapshot(0)
        sig0 = (
            snap0.sigma_rows if snap0.sigma_rows is not None else snap0.sigma_diag
        )
        jax.block_until_ready(
            solve(
                x0, y0, snap0.alpha_rows, snap0.W_rows, n0,
                sig0, t0, round_keys[0],
            )
        )

        def worker(g):
            try:
                x, y, n, tids = blocks[g]
                for r in range(self.R):
                    with span("round", cat="transport", worker=g, round=r):
                        self.gate(g, r)
                        snap = self.wire_snapshot(g)
                        sig = (
                            snap.sigma_rows
                            if snap.sigma_rows is not None
                            else snap.sigma_diag
                        )
                        with span("solve", cat="transport", worker=g, round=r):
                            dalpha, db = solve(
                                x, y, jnp.asarray(snap.alpha_rows),
                                jnp.asarray(snap.W_rows), n,
                                jnp.asarray(sig), tids, round_keys[r],
                            )
                            dalpha = jax.block_until_ready(dalpha)
                        if self.pace:
                            time.sleep(self.pace * self.delays[g])
                        self.wire_commit(g, r, (dalpha, db))
            except BaseException as e:  # propagate into the driver
                self._fail(e)

        threads = [
            threading.Thread(
                target=worker, args=(g,), name=f"dmtrl-worker-{g}", daemon=True
            )
            for g in range(self.G)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        self._end_w_step()


# ---------------------------------------------------------------------------
# multiprocess — socket/pickle parameter-server shim, per-worker processes
# ---------------------------------------------------------------------------
def _send_msg(sock: socket.socket, obj) -> None:
    """One frame: version byte + 8-byte length + pickle payload. The
    leading ``WIRE_VERSION`` byte makes protocol/codec skew between the
    two ends fail as a ``TransportProtocolError`` at the frame boundary
    instead of a pickle garbage crash mid-payload (wire.py)."""
    buf = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(struct.pack("!BQ", WIRE_VERSION, len(buf)) + buf)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        b = sock.recv(min(n, 1 << 20))
        if not b:
            raise ConnectionError("transport peer closed the connection")
        chunks.append(b)
        n -= len(b)
    return b"".join(chunks)


def _recv_msg(sock: socket.socket):
    version, n = struct.unpack("!BQ", _recv_exact(sock, 9))
    check_wire_version(version)
    return pickle.loads(_recv_exact(sock, n))


class MultiprocessTransport(_HostServerTransport):
    """The threaded server state machine driven over a loopback socket by
    per-worker *processes* (length-prefixed pickle frames, one handler
    thread per connection) — the cross-host RPC shape with the host
    boundary faked by localhost.  Trusted-local shim only: pickle framing
    is not an authentication boundary."""

    name = "multiprocess"

    def setup(self, cfg, raw, *, mesh, axes, reg, init, track):
        super().setup(cfg, raw, mesh=mesh, axes=axes, reg=reg, init=init, track=track)
        self._listener: Optional[socket.socket] = None
        self._procs: List[subprocess.Popen] = []
        self._conns: Dict[int, socket.socket] = {}
        self._handlers: List[threading.Thread] = []
        self._stderr_files: List = []
        self._step_seq = 0
        self._step_payload = None
        self._step_sent = [0] * self.G
        self._stepdone = 0
        self._shutdown = False

    def _ensure_workers(self):
        if self._procs:
            return
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.bind(("127.0.0.1", 0))
        self._listener.listen(self.G)
        port = self._listener.getsockname()[1]
        src_root = os.path.dirname(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        )
        for g in range(self.G):
            env = dict(os.environ)
            env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
            env["REPRO_MP_ADDR"] = f"127.0.0.1:{port}"
            env["REPRO_MP_WORKER"] = str(g)
            env["JAX_PLATFORMS"] = "cpu"
            # workers are single-device hosts; don't inherit a forced count
            env.pop("XLA_FLAGS", None)
            errf = tempfile.TemporaryFile()
            self._stderr_files.append(errf)
            self._procs.append(
                subprocess.Popen(
                    [
                        sys.executable,
                        "-c",
                        "from repro.core.transport import _mp_worker_main; "
                        "_mp_worker_main()",
                    ],
                    env=env,
                    stdout=subprocess.DEVNULL,
                    stderr=errf,
                )
            )
        self._listener.settimeout(120.0)
        for _ in range(self.G):
            conn, _addr = self._listener.accept()
            tag, g = _recv_msg(conn)
            assert tag == "hello", tag
            rows = self._rows(g)
            _send_msg(
                conn,
                (
                    "init",
                    dict(
                        cfg=self.cfg,
                        x=np.asarray(self.data.x[rows]),
                        y=np.asarray(self.data.y[rows]),
                        n=np.asarray(self.data.n[rows]),
                        tids=np.arange(rows.start, rows.stop, dtype=np.int32),
                        n_max=self.data.n_max,
                        R=self.R,
                        sleep_s=self.pace * self.delays[g],
                    ),
                ),
            )
            self._conns[g] = conn
            h = threading.Thread(
                target=self._serve_conn, args=(g, conn),
                name=f"dmtrl-ps-conn-{g}", daemon=True,
            )
            self._handlers.append(h)
            h.start()

    def _serve_conn(self, g: int, conn: socket.socket):
        try:
            while True:
                msg = _recv_msg(conn)
                op = msg[0]
                if op == "next":
                    with self.cond:
                        while (
                            self._step_seq <= self._step_sent[g]
                            and not self._shutdown
                        ):
                            self.cond.wait(timeout=0.1)
                        if self._shutdown and self._step_seq <= self._step_sent[g]:
                            _send_msg(conn, ("done",))
                            return
                        self._step_sent[g] = self._step_seq
                        payload = self._step_payload
                    _send_msg(conn, ("wstep", payload))
                elif op == "gate":
                    self.gate(g, msg[1])
                    _send_msg(conn, ("ok",))
                elif op == "snapshot":
                    # codec-encoded payload dict: (W, Sigma) through the
                    # wire codec, alpha elided once the worker caches it
                    # (``have_alpha`` rides on the request); the wire
                    # ships whichever Sigma field is populated — (m_loc,
                    # m) rows for dense servers, (m_loc,) diag for
                    # structured ones
                    have_alpha = bool(msg[1]) if len(msg) > 1 else False
                    _send_msg(
                        conn, ("snap", self._encode_snapshot(g, have_alpha))
                    )
                elif op == "commit":
                    r, dalpha, db_wire = msg[1], msg[2], msg[3]
                    if isinstance(db_wire, Encoded):
                        db = self.codec.decode(db_wire)
                        nb = db_wire.nbytes
                    else:
                        db = db_wire
                        nb = int(np.asarray(db).nbytes)
                    with self.lock:
                        self.wire_stats["n_commits"] += 1
                        self.wire_stats["raw_commit_bytes"] += int(
                            np.asarray(db).nbytes
                        )
                        self.wire_stats["commit_bytes"] += nb
                    rc = self.commit(
                        g, r, (jnp.asarray(dalpha), jnp.asarray(db))
                    )
                    _send_msg(conn, ("receipt", rc.staleness, rc.lag, rc.version))
                elif op == "stepdone":
                    with self.cond:
                        self._stepdone += 1
                        self.cond.notify_all()
                    _send_msg(conn, ("ok",))
                elif op == "error":
                    raise RuntimeError(f"worker {g} failed:\n{msg[1]}")
                elif op == "bye":
                    return
                else:  # pragma: no cover - protocol guard
                    raise RuntimeError(f"unknown transport op {op!r}")
        except BaseException as e:
            if not self._shutdown:
                self._fail(e)

    def _check_procs(self):
        for g, proc in enumerate(self._procs):
            if proc.poll() is not None and not self._shutdown:
                errf = self._stderr_files[g]
                errf.seek(0)
                tail = errf.read()[-2000:].decode(errors="replace")
                exc = RuntimeError(
                    f"multiprocess worker {g} died "
                    f"(returncode {proc.returncode}):\n{tail}"
                )
                # route through abort so handler threads parked in gate()
                # unwind instead of waiting on a floor that never advances
                self._fail(exc)
                raise exc

    def run_w_step(self, p, rho, outer_key):
        self._ensure_workers()
        self._begin_w_step(p)
        round_keys = np.asarray(jax.random.split(outer_key, self.R))
        with self.cond:
            self._step_seq += 1
            self._step_payload = dict(p=p, rho=float(rho), round_keys=round_keys)
            self._stepdone = 0
            self.cond.notify_all()
            while self._stepdone < self.G:
                self._check_abort()
                self._check_procs()
                self.cond.wait(timeout=0.2)
        self._end_w_step()

    def close(self):
        with self.cond:
            self._shutdown = True
            self.cond.notify_all()
        for h in self._handlers:
            h.join(timeout=10.0)
        for conn in self._conns.values():
            try:
                conn.close()
            except OSError:
                pass
        if self._listener is not None:
            self._listener.close()
        for proc in self._procs:
            try:
                proc.wait(timeout=10.0)
            except subprocess.TimeoutExpired:
                proc.kill()
        for errf in self._stderr_files:
            errf.close()
        self._procs, self._handlers, self._conns = [], [], {}


def _mp_worker_main():  # pragma: no cover - runs in worker subprocesses
    """Entry point of a multiprocess-transport worker process: connect to
    the parameter server named by REPRO_MP_ADDR, receive this worker's
    task block, then loop gate -> snapshot -> local solve -> commit."""
    import traceback

    host, port = os.environ["REPRO_MP_ADDR"].rsplit(":", 1)
    g = int(os.environ["REPRO_MP_WORKER"])
    sock = socket.create_connection((host, int(port)), timeout=300.0)
    try:
        _send_msg(sock, ("hello", g))
        tag, init = _recv_msg(sock)
        assert tag == "init", tag
        cfg: DMTRLConfig = init["cfg"]
        x = jnp.asarray(init["x"])
        y = jnp.asarray(init["y"])
        n = jnp.asarray(init["n"])
        tids = jnp.asarray(init["tids"])
        R, sleep_s = init["R"], init["sleep_s"]
        codec = get_codec(getattr(cfg, "codec", "none"))
        commit_ef = ErrorFeedback(codec)
        # worker-side alpha mirror under lossy codecs: alpha ships once,
        # then the worker replays its own exact eta*dalpha f32 adds — the
        # identical arithmetic the server performs, so the mirror stays
        # bitwise equal to server state and alpha never rides the wire
        alpha_loc: Optional[np.ndarray] = None
        while True:
            _send_msg(sock, ("next",))
            msg = _recv_msg(sock)
            if msg[0] == "done":
                break
            payload = msg[1]
            solve = make_block_solver(cfg, init["n_max"], payload["rho"])
            round_keys = payload["round_keys"]
            for r in range(R):
                _send_msg(sock, ("gate", r))
                _recv_msg(sock)
                have_alpha = codec.name != "none" and alpha_loc is not None
                _send_msg(sock, ("snapshot", have_alpha))
                _tag, payload = _recv_msg(sock)
                snap = decode_snapshot_payload(payload, codec)
                if snap.alpha_rows is not None:
                    alpha_loc = np.asarray(snap.alpha_rows, dtype=np.float32)
                sig = (
                    snap.sigma_rows
                    if snap.sigma_rows is not None
                    else snap.sigma_diag
                )
                dalpha, db = solve(
                    x, y, jnp.asarray(alpha_loc), jnp.asarray(snap.W_rows),
                    n, jnp.asarray(sig), tids, jnp.asarray(round_keys[r]),
                )
                dalpha = np.asarray(dalpha)
                db = np.asarray(db)
                if sleep_s:
                    time.sleep(sleep_s)
                if codec.name == "none":
                    db_wire = db
                else:
                    db_wire = commit_ef.encode("db", db)
                    # replay the server's alpha update in identical f32
                    # arithmetic so next round's have_alpha elision holds
                    alpha_loc = np.asarray(
                        alpha_loc + np.float32(cfg.eta) * dalpha,
                        dtype=np.float32,
                    )
                _send_msg(sock, ("commit", r, dalpha, db_wire))
                _recv_msg(sock)
            _send_msg(sock, ("stepdone",))
            _recv_msg(sock)
        _send_msg(sock, ("bye",))
    except Exception:
        try:
            _send_msg(sock, ("error", traceback.format_exc()))
        except OSError:
            pass
        raise
    finally:
        sock.close()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TransportSpec:
    """A named way to run the snapshot/commit protocol."""

    name: str
    description: str
    needs_mesh: bool
    factory: Callable[[], Transport]


_REGISTRY: Dict[str, TransportSpec] = {}


def register_transport(spec: TransportSpec) -> TransportSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get_transport(name: str) -> TransportSpec:
    try:
        return _REGISTRY[name]
    except KeyError as e:
        raise KeyError(
            f"unknown transport {name!r}; have {sorted(_REGISTRY)}"
        ) from e


def available_transports() -> Dict[str, TransportSpec]:
    return dict(sorted(_REGISTRY.items()))


register_transport(
    TransportSpec(
        name="simulated",
        description="deterministic in-process clock simulation; fused "
        "masked SPMD commits on a JAX mesh; bit-reproducible",
        needs_mesh=True,
        factory=SimulatedTransport,
    )
)
register_transport(
    TransportSpec(
        name="threaded",
        description="real in-host parameter server: G worker threads over "
        "lock-protected versioned state; nondeterministic arrival order, "
        "SSP-gate-correct",
        needs_mesh=False,
        factory=ThreadedTransport,
    )
)
register_transport(
    TransportSpec(
        name="multiprocess",
        description="socket/pickle parameter-server shim with per-worker "
        "processes on localhost (the cross-host RPC shape)",
        needs_mesh=False,
        factory=MultiprocessTransport,
    )
)

# the gossip member lives in its own module (core/gossip.py) and registers
# itself on import; importing it HERE — after every name it needs from this
# module exists — keeps `get_transport("gossip")` working without the
# caller having to know about the submodule, cycle-free
from . import gossip as _gossip_registration  # noqa: E402,F401
