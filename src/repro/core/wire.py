"""Wire formats for the transport layer: codecs + frame versioning.

The paper's communication-efficiency claim is about the ``(delta_w,
Sigma)`` messages the workers exchange; this module makes their wire cost
an explicit, measurable object instead of "whatever pickle does to a
float32 array".  Two independent pieces:

Codecs (``get_codec``)
----------------------
A ``Codec`` turns a float array into an ``Encoded`` payload and back:

  ``none``   float32 passthrough — the historical wire format.
  ``bf16``   bfloat16 truncation (round-to-nearest-even on the mantissa
             boundary), 2 bytes/element.  Deterministic, no state.
  ``int8``   symmetric per-block quantization: the flat array is split
             into ``block``-element blocks, each shipped as int8 codes
             plus one float32 scale (absmax / 127).  ~4x on the data plus
             a 1/block scale overhead.

Quantization is lossy, so repeated lossy *updates* (the ``delta_w``
commits, the gossip mixing exchanges) go through ``ErrorFeedback``: the
residual of every encode is added back into the next value before
encoding, which turns a biased per-step error into a bounded accumulated
one (the standard EF-SGD / CHOCO-style correction — see
arXiv:1609.09563's perturbed-fixed-point view for why the fixed point
tolerates exactly this kind of bounded perturbation).  State reads
(snapshot ``W_rows`` / Sigma rows) are re-encoded fresh each time and
need no feedback.

Frame versioning (``WIRE_VERSION``)
-----------------------------------
The multiprocess transport's length-prefixed pickle frames carry a
leading version byte.  A codec/protocol mismatch between two ends (old
worker binary against a new server, a frame from a foreign protocol)
surfaces as a clear ``TransportProtocolError`` instead of a pickle
garbage crash: legacy frames started with the high byte of a 64-bit
length — 0x00 for any sane message — which can never equal a valid
version (versions start at 1).

Everything here is numpy-only (no jax) so worker subprocesses can encode
and decode without touching the device runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

# bump when the frame layout or the Encoded schema changes incompatibly
WIRE_VERSION = 2
# what the first byte of a legacy (pre-version-byte) frame looks like
_LEGACY_FIRST_BYTE = 0


class TransportProtocolError(RuntimeError):
    """A transport peer speaks a different wire protocol/codec version."""


def check_wire_version(got: int) -> None:
    """Validate the leading frame byte; raise with a diagnosis on skew."""
    if got == WIRE_VERSION:
        return
    if got == _LEGACY_FIRST_BYTE:
        raise TransportProtocolError(
            f"transport frame has no version byte (first byte 0x00): the "
            f"peer speaks the legacy unversioned framing; this end expects "
            f"wire version {WIRE_VERSION}. Upgrade both ends together."
        )
    raise TransportProtocolError(
        f"transport wire version mismatch: peer sent version {got}, this "
        f"end expects {WIRE_VERSION}. Upgrade both ends together."
    )


# ---------------------------------------------------------------------------
# encoded payloads
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Encoded:
    """One array as it travels on the wire.

    ``data`` holds the codec's element payload (float32 / uint16 / int8),
    ``scales`` the int8 per-block scales (None otherwise).  ``nbytes`` is
    the array payload the frame actually carries — the measurable quantity
    ``payload_nbytes`` and the transport wire counters report.
    """

    codec: str
    shape: Tuple[int, ...]
    dtype: str  # original dtype string, restored on decode
    data: np.ndarray
    scales: Optional[np.ndarray] = None

    @property
    def nbytes(self) -> int:
        n = int(self.data.nbytes)
        if self.scales is not None:
            n += int(self.scales.nbytes)
        return n


class Codec:
    """Base codec: encode/decode one array. Stateless; lossy codecs pair
    with ``ErrorFeedback`` for repeated delta encodes."""

    name: str = "?"
    lossy: bool = False

    def encode(self, x) -> Encoded:
        raise NotImplementedError

    def decode(self, enc: Encoded) -> np.ndarray:
        raise NotImplementedError


class NoneCodec(Codec):
    name = "none"
    lossy = False

    def encode(self, x) -> Encoded:
        x = np.asarray(x)
        return Encoded(
            codec=self.name,
            shape=tuple(x.shape),
            dtype=str(x.dtype),
            data=np.ascontiguousarray(x),
        )

    def decode(self, enc: Encoded) -> np.ndarray:
        return enc.data.reshape(enc.shape).astype(enc.dtype, copy=False)


def _f32_to_bf16_bits(x: np.ndarray) -> np.ndarray:
    """Round-to-nearest-even truncation of float32 to bfloat16 bit
    patterns (uint16). Matches hardware bf16 casts; NaN payloads are
    normalized by the rounding add, which is fine for weight traffic."""
    u = np.ascontiguousarray(x, dtype=np.float32).view(np.uint32)
    bias = ((u >> 16) & np.uint32(1)) + np.uint32(0x7FFF)
    return ((u + bias) >> 16).astype(np.uint16)


def _bf16_bits_to_f32(bits: np.ndarray) -> np.ndarray:
    return (bits.astype(np.uint32) << 16).view(np.float32)


class BF16Codec(Codec):
    name = "bf16"
    lossy = True

    def encode(self, x) -> Encoded:
        x = np.asarray(x)
        return Encoded(
            codec=self.name,
            shape=tuple(x.shape),
            dtype=str(x.dtype),
            data=_f32_to_bf16_bits(x),
        )

    def decode(self, enc: Encoded) -> np.ndarray:
        out = _bf16_bits_to_f32(enc.data).reshape(enc.shape)
        return out.astype(enc.dtype, copy=False)


class Int8Codec(Codec):
    """Symmetric per-block int8: codes in [-127, 127] plus one float32
    scale per ``block`` flat elements (absmax/127; all-zero blocks get
    scale 0 and decode exactly to zeros)."""

    name = "int8"
    lossy = True

    def __init__(self, block: int = 256):
        if block < 1:
            raise ValueError(f"int8 block must be >= 1, got {block}")
        self.block = int(block)

    def encode(self, x) -> Encoded:
        x = np.asarray(x)
        flat = np.ascontiguousarray(x, dtype=np.float32).ravel()
        n = flat.size
        pad = (-n) % self.block
        if pad:
            flat = np.concatenate([flat, np.zeros((pad,), np.float32)])
        blocks = flat.reshape(-1, self.block)
        absmax = np.max(np.abs(blocks), axis=1)
        scales = (absmax / 127.0).astype(np.float32)
        safe = np.where(scales > 0.0, scales, 1.0)
        q = np.clip(np.rint(blocks / safe[:, None]), -127, 127).astype(np.int8)
        # ship exactly n codes: the pad exists only for the blocked
        # quantization math, not on the wire (a tiny array must not cost
        # a whole block)
        return Encoded(
            codec=self.name,
            shape=tuple(x.shape),
            dtype=str(x.dtype),
            data=np.ascontiguousarray(q.ravel()[:n]),
            scales=scales,
        )

    def decode(self, enc: Encoded) -> np.ndarray:
        n = enc.data.size
        pad = (-n) % self.block
        codes = enc.data
        if pad:
            codes = np.concatenate([codes, np.zeros((pad,), np.int8)])
        blocks = codes.reshape(-1, self.block).astype(np.float32)
        out = (blocks * enc.scales[:, None]).ravel()[:n].reshape(enc.shape)
        return out.astype(enc.dtype, copy=False)


_CODECS: Dict[str, Codec] = {}


def register_codec(codec: Codec) -> Codec:
    _CODECS[codec.name] = codec
    return codec


def get_codec(name: str) -> Codec:
    try:
        return _CODECS[name]
    except KeyError as e:
        raise KeyError(
            f"unknown wire codec {name!r}; have {sorted(_CODECS)}"
        ) from e


def available_codecs() -> Dict[str, Codec]:
    return dict(sorted(_CODECS.items()))


register_codec(NoneCodec())
register_codec(BF16Codec())
register_codec(Int8Codec())


def roundtrip(codec: Codec, x) -> np.ndarray:
    """What the receiving end sees: decode(encode(x))."""
    return codec.decode(codec.encode(x))


# ---------------------------------------------------------------------------
# error feedback for repeated lossy delta encodes
# ---------------------------------------------------------------------------
class ErrorFeedback:
    """Residual accumulation around a lossy codec, keyed per stream.

    ``encode(key, x)`` encodes ``x + residual[key]`` and stores the new
    residual ``(x + r) - decode(enc)``; the receiver applies plain
    ``decode``.  Over a run, the *sum* of decoded deltas tracks the sum of
    true deltas to within one quantization step, so quantized commits and
    gossip mixing perturb the fixed point boundedly instead of drifting.
    For the exact ``none`` codec this degenerates to a passthrough with no
    stored state.
    """

    def __init__(self, codec: Codec):
        self.codec = codec
        self._resid: Dict[object, np.ndarray] = {}

    def encode(self, key, x) -> Encoded:
        x = np.asarray(x, dtype=np.float32)
        if not self.codec.lossy:
            return self.codec.encode(x)
        r = self._resid.get(key)
        if r is not None:
            x = x + r
        enc = self.codec.encode(x)
        self._resid[key] = x - self.codec.decode(enc).astype(np.float32)
        return enc

    def reset(self, key=None) -> None:
        """Drop residual state — after a Sigma install resets the consensus
        (the accumulated error no longer refers to live state)."""
        if key is None:
            self._resid.clear()
        else:
            self._resid.pop(key, None)
