"""Data pipelines: paper MTL datasets (synthetic + offline real-world
stand-ins) and the sharded LM token pipeline for the backbone substrate."""
from . import synthetic, tokens

__all__ = ["synthetic", "tokens"]
