"""Paper datasets (Section 7.1), reproduced generatively.

This container is offline, so the three real-world datasets are replaced by
statistically matched stand-ins with the SAME shape/statistics as Table 1
(task counts, instance counts, dims, per-task imbalance) and the same
qualitative structure the paper's claims rely on:

 * synthetic1 / synthetic2  -- exactly the paper's recipe (3 parent tasks,
   children = +-parent + noise, logistic labels); synthetic2 re-draws the
   parents with strong mutual correlation so that rho is larger.
 * school_like   -- 139 regression tasks, d=27(+bias)=28, ~83 train/task,
   task weights drawn from a 3-cluster prior + per-school noise, continuous
   exam-score-like targets.
 * mnist_like    -- 10 one-vs-all binary tasks over d=784 with large
   per-task sample counts (data-rich regime where STL ~ MTL, the paper's
   MNIST observation). Digits are synthesized as class-template blobs +
   pixel noise in [0,1]^784.
 * mds_like      -- 22 sentiment tasks, d=10,000 sparse (0.9% density),
   n_i ranging 314..20,751 (heavy imbalance — the regime where the paper
   reports DMTRL >> STL because small tasks borrow strength).
"""
from __future__ import annotations

import dataclasses
import numpy as np

from repro.core.mtl_data import MTLData, from_task_list, train_test_split_tasks


@dataclasses.dataclass
class MTLSplits:
    train: MTLData
    test: MTLData
    W_true: np.ndarray | None = None  # ground-truth weights when synthetic
    corr_true: np.ndarray | None = None  # ground-truth task correlation


def _logistic_labels(z: np.ndarray, rng: np.random.RandomState) -> np.ndarray:
    p = 1.0 / (1.0 + np.exp(-z))
    return np.where(rng.uniform(size=z.shape) < p, 1.0, -1.0).astype(np.float32)


def _normalize(x: np.ndarray) -> np.ndarray:
    nrm = np.linalg.norm(x, axis=1, keepdims=True)
    return x / np.maximum(nrm, 1e-12)


def synthetic(
    variant: int = 1,
    m: int = 16,
    d: int = 100,
    n_train_avg: int = 1894,
    n_test_avg: int = 811,
    seed: int = 0,
) -> MTLSplits:
    """Paper Synthetic 1 / 2.

    Variant 1: parents {w1, w6, w11} ~ N(0, I) (nearly orthogonal =>
    weaker cross-group correlation, smaller rho).
    Variant 2: same data xs but parents drawn with strong mutual correlation
    (parents share a common component) => larger rho. The paper reports
    rho = 6.24 (syn1) vs 12.95 (syn2).
    """
    rng = np.random.RandomState(seed)
    n_parents = 3
    parent_ids = [0, 5, 10]

    parents = rng.randn(n_parents, d).astype(np.float32)
    if variant == 2:
        common = rng.randn(1, d).astype(np.float32)
        parents = 0.35 * parents + 1.0 * common  # strongly correlated parents
    parents = _normalize(parents) * 3.0

    W = np.zeros((m, d), np.float32)
    signs = np.zeros(m)
    assign = np.zeros(m, int)
    for i in range(m):
        if i in parent_ids:
            k, s = parent_ids.index(i), +1.0
        else:
            k = rng.randint(n_parents)
            s = rng.choice([+1.0, -1.0])
        assign[i], signs[i] = k, s
        W[i] = s * parents[k] + 0.1 * rng.randn(d)
    corr_true = np.corrcoef(W)

    # per-task sample counts around the paper's averages
    n_tr = np.maximum(50, rng.poisson(n_train_avg, m))
    n_te = np.maximum(20, rng.poisson(n_test_avg, m))

    def draw(n_i, wi):
        x = rng.randn(n_i, d).astype(np.float32) / np.sqrt(d)
        y = _logistic_labels(x @ wi * np.sqrt(d) * 0.6, rng)
        return _normalize(x).astype(np.float32), y

    xtr, ytr, xte, yte = [], [], [], []
    for i in range(m):
        x, y = draw(int(n_tr[i]), W[i])
        xtr.append(x), ytr.append(y)
        x, y = draw(int(n_te[i]), W[i])
        xte.append(x), yte.append(y)

    return MTLSplits(
        train=from_task_list(xtr, ytr),
        test=from_task_list(xte, yte),
        W_true=W,
        corr_true=corr_true,
    )


def school_like(
    m: int = 139, d: int = 27, n_avg: int = 111, seed: int = 0
) -> MTLSplits:
    """School-like regression: m tasks, d features (+1 bias appended = 28),
    70/30-ish split matching ~83 train / ~28 test per task."""
    rng = np.random.RandomState(seed + 1)
    n_clusters = 3
    centers = rng.randn(n_clusters, d + 1).astype(np.float32) * 1.5
    xs, ys, Wt = [], [], np.zeros((m, d + 1), np.float32)
    for i in range(m):
        k = rng.randint(n_clusters)
        wi = centers[k] + 0.4 * rng.randn(d + 1)
        Wt[i] = wi
        n_i = max(20, rng.poisson(n_avg))
        x = rng.randn(n_i, d).astype(np.float32)
        x = np.concatenate([x, np.ones((n_i, 1), np.float32)], axis=1)  # bias
        x = _normalize(x)
        y = x @ wi + 0.35 * rng.randn(n_i)
        xs.append(x.astype(np.float32)), ys.append(y.astype(np.float32))
    xtr, ytr, xte, yte = train_test_split_tasks(xs, ys, 0.75, seed)
    return MTLSplits(
        train=from_task_list(xtr, ytr),
        test=from_task_list(xte, yte, n_max=from_task_list(xtr, ytr).n_max),
        W_true=Wt,
        corr_true=np.corrcoef(Wt),
    )


def mnist_like(
    n_classes: int = 10,
    d: int = 784,
    n_per_task_train: int = 12000,
    n_per_task_test: int = 2000,
    seed: int = 0,
    scale: float = 1.0,
) -> MTLSplits:
    """10 one-vs-all tasks, data-rich (paper: STL ~ DMTRL here)."""
    rng = np.random.RandomState(seed + 2)
    side = int(np.sqrt(d))
    templates = np.zeros((n_classes, d), np.float32)
    for c in range(n_classes):
        img = np.zeros((side, side), np.float32)
        # class-specific blob pattern: a few gaussian bumps per class
        for _ in range(3 + c % 4):
            cx, cy = rng.randint(4, side - 4, size=2)
            xx, yy = np.meshgrid(np.arange(side), np.arange(side))
            img += np.exp(-((xx - cx) ** 2 + (yy - cy) ** 2) / (2.0 * 2.5**2))
        templates[c] = img.reshape(-1) / max(img.max(), 1e-6)

    n_tr = int(n_per_task_train * scale)
    n_te = int(n_per_task_test * scale)

    def draw_task(c, n_i):
        half = n_i // 2
        pos = templates[c][None, :] + 0.55 * rng.rand(half, d).astype(np.float32)
        neg_classes = rng.choice([k for k in range(n_classes) if k != c], n_i - half)
        neg = templates[neg_classes] + 0.55 * rng.rand(n_i - half, d).astype(np.float32)
        x = np.concatenate([pos, neg]).astype(np.float32)
        y = np.concatenate([np.ones(half), -np.ones(n_i - half)]).astype(np.float32)
        # ~3% label noise keeps the task non-degenerate (error > 0)
        flip = rng.uniform(size=n_i) < 0.03
        y = np.where(flip, -y, y).astype(np.float32)
        p = rng.permutation(n_i)
        return _normalize(x[p]), y[p]

    xtr, ytr, xte, yte = [], [], [], []
    for c in range(n_classes):
        x, y = draw_task(c, n_tr)
        xtr.append(x), ytr.append(y)
        x, y = draw_task(c, n_te)
        xte.append(x), yte.append(y)
    ntr = from_task_list(xtr, ytr)
    return MTLSplits(
        train=ntr, test=from_task_list(xte, yte, n_max=ntr.n_max)
    )


def mds_like(
    m: int = 22,
    d: int = 10000,
    density: float = 0.009,
    n_min: int = 314,
    n_max_task: int = 20751,
    seed: int = 0,
    scale: float = 1.0,
) -> MTLSplits:
    """22 sparse sentiment-like tasks with heavy size imbalance.

    A shared global sentiment direction + per-domain deviations: the regime
    where the paper reports DMTRL >> STL (small tasks borrow strength).
    ``scale`` shrinks n_i and d for fast CI runs while keeping imbalance.
    """
    rng = np.random.RandomState(seed + 3)
    d = max(64, int(d * scale))
    shared = rng.randn(d).astype(np.float32)
    shared /= np.linalg.norm(shared)

    # log-uniform task sizes in [n_min, n_max_task]
    sizes = np.exp(
        rng.uniform(np.log(n_min), np.log(n_max_task), size=m)
    ).astype(int)
    sizes = np.maximum(8, (sizes * scale).astype(int))

    nnz = max(8, int(3 * density * d))  # "review length" in active features
    # "sentiment lexicon": a quarter of the vocabulary carries a strong
    # SHARED polarity (+-1); per-domain deviation is mild. This is the
    # regime the paper's MDS experiment exercises: small domains cannot
    # estimate the lexicon alone and borrow strength through Sigma.
    lex = rng.choice(d, d // 4, replace=False)
    w_shared = np.zeros(d, np.float32)
    w_shared[lex] = rng.choice([-1.0, 1.0], size=lex.shape[0]).astype(np.float32)
    xs, ys = [], []
    for i in range(m):
        wi = w_shared + 0.3 * rng.randn(d).astype(np.float32)
        n_i = int(sizes[i])
        rows = np.zeros((n_i, d), np.float32)
        for r in range(n_i):
            idx = rng.choice(d, nnz, replace=False)
            rows[r, idx] = rng.rand(nnz).astype(np.float32) + 0.2
        rows = _normalize(rows)
        y = _logistic_labels(10.0 * rows @ wi, rng)
        xs.append(rows), ys.append(y)
    xtr, ytr, xte, yte = train_test_split_tasks(xs, ys, 0.7, seed)
    ntr = from_task_list(xtr, ytr)
    return MTLSplits(
        train=ntr,
        test=from_task_list(xte, yte, n_max=max(ntr.n_max, max(len(v) for v in yte))),
    )


DATASETS = {
    "synthetic1": lambda **kw: synthetic(1, **kw),
    "synthetic2": lambda **kw: synthetic(2, **kw),
    "school_like": school_like,
    "mnist_like": mnist_like,
    "mds_like": mds_like,
}
