"""Synthetic LM token pipeline for the backbone substrate.

Deterministic, seeded, shardable. Emulates a production data loader:
per-host shard assignment, fixed-length packed sequences, label shifting,
and (for the VLM/audio archs) the precomputed-embedding side inputs that the
stub frontends produce.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-chain order-1 synthetic text: makes loss curves non-trivial
    n_states: int = 256


class SyntheticTokenPipeline:
    """Order-1 Markov token stream; learnable structure so a few hundred
    training steps produce a visibly decreasing loss."""

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        k = min(cfg.n_states, cfg.vocab_size)
        self._k = k
        # sparse-ish row-stochastic transition matrix over k "hot" tokens
        logits = rng.randn(k, k).astype(np.float32) * 2.0
        self._P = np.exp(logits) / np.exp(logits).sum(1, keepdims=True)
        self._cum = np.cumsum(self._P, axis=1)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState((cfg.seed * 1_000_003 + step) % (2**31 - 1))
        b, s = cfg.global_batch, cfg.seq_len
        toks = np.zeros((b, s + 1), np.int32)
        toks[:, 0] = rng.randint(0, self._k, size=b)
        u = rng.rand(b, s)
        for t in range(s):
            toks[:, t + 1] = np.argmax(
                self._cum[toks[:, t]] > u[:, t : t + 1], axis=1
            )
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((b, s), np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def host_shard(batch: Dict[str, np.ndarray], host_id: int, n_hosts: int):
    """Slice the global batch for one host (production loaders feed each
    host its slice; under jit + NamedSharding we form global arrays)."""
    out = {}
    for k, v in batch.items():
        b = v.shape[0]
        assert b % n_hosts == 0
        sl = slice(host_id * (b // n_hosts), (host_id + 1) * (b // n_hosts))
        out[k] = v[sl]
    return out


def embedding_side_inputs(
    kind: str, batch: int, d_model: int, seed: int = 0, frames: int = 1500
) -> Optional[np.ndarray]:
    """Stub modality frontends (spec carve-out): precomputed frame/patch
    embeddings for audio (whisper) and VLM (chameleon uses VQ token ids in
    vocab, so returns None)."""
    if kind == "audio":
        rng = np.random.RandomState(seed)
        return rng.randn(batch, frames, d_model).astype(np.float32) * 0.02
    return None
