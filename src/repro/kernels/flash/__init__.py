from . import ops, ref
from .flash_kernel import flash_attention

__all__ = ["ops", "ref", "flash_attention"]
