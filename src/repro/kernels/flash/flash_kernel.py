"""Pallas TPU flash-attention forward kernel (causal / sliding-window).

Grid: (batch, heads, num_q_blocks, num_kv_blocks) with the kv dimension
innermost (sequential on TPU); online-softmax running stats live in VMEM
scratch that persists across the kv loop:

    m (BQ,)       running row max
    l (BQ,)       running denominator
    acc (BQ, HD)  running numerator

BlockSpecs stage (BQ, HD) query tiles and (BK, HD) key/value tiles in VMEM;
the (BQ, BK) score tile exists only in VMEM/VREGs — the HBM score-tile
traffic of the jnp reference path (see docs/DESIGN.md §7) disappears.
Causal masking is positional; fully-masked kv blocks still execute in this
baseline kernel (the block-skip optimization is measured separately).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, 1, BQ, HD)
    k_ref,  # (1, 1, BK, HD)
    v_ref,  # (1, 1, BK, HD)
    o_ref,  # (1, 1, BQ, HD)
    m_scr,  # (BQ,)
    l_scr,  # (BQ,)
    acc_scr,  # (BQ, HD)
    *,
    bq: int,
    bk: int,
    nk: int,
    causal: bool,
    window: int,
    scale: float,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (BQ, BK)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v_ref[0, 0].astype(jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: Array,  # (B, H, S, HD)
    k: Array,  # (B, H, Sk, HD)
    v: Array,  # (B, H, Sk, HD)
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> Array:
    B, H, S, HD = q.shape
    Sk = k.shape[2]
    bq = min(block_q, S)
    bk = min(block_k, Sk)
    assert S % bq == 0 and Sk % bk == 0, "pad seq to block multiples first"
    nq, nk = S // bq, Sk // bk
    scale = 1.0 / (HD**0.5)

    kern = functools.partial(
        _kernel, bq=bq, bk=bk, nk=nk, causal=causal, window=window, scale=scale
    )
    return pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, HD), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, HD), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, HD), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, HD), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, HD), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, HD), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
