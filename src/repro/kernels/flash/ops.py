"""jit'd wrapper: layout adaptation (B,S,H,HD) <-> (B,H,S,HD) + padding."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .flash_kernel import flash_attention

Array = jax.Array
INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def flash_attention_bshd(
    q: Array,  # (B, S, H, HD) — model layout
    k: Array,
    v: Array,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
) -> Array:
    B, S, H, HD = q.shape
    Sk = k.shape[1]
    bq = min(block_q, S)
    bk = min(block_k, Sk)
    pad_q = (-S) % bq
    pad_k = (-Sk) % bk
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        # padded keys sit at positions >= Sk; causal masking handles them for
        # decoder use; for non-causal padding would need an explicit mask.
        assert causal, "non-causal padding unsupported; pre-pad inputs"
    out = flash_attention(
        qt, kt, vt, causal, window, bq, bk, interpret=INTERPRET
    )
    out = out[:, :, :S] if pad_q else out
    return jnp.moveaxis(out, 1, 2)
