"""Pure-jnp oracle: dense softmax attention with the same masking."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def attention_ref(
    q: Array,  # (B, H, S, HD)
    k: Array,
    v: Array,
    causal: bool = True,
    window: int = 0,
) -> Array:
    B, H, S, HD = q.shape
    Sk = k.shape[2]
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(HD).astype(jnp.float32)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((S, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
