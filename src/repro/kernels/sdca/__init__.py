from . import ops, ref
from .sdca_kernel import SUPPORTED_LOSSES, sdca_block_kernel, sdca_round_kernel

__all__ = [
    "ops",
    "ref",
    "SUPPORTED_LOSSES",
    "sdca_block_kernel",
    "sdca_round_kernel",
]
