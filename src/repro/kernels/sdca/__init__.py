from . import ops, ref
from .sdca_kernel import SUPPORTED_LOSSES, sdca_block_kernel

__all__ = ["ops", "ref", "SUPPORTED_LOSSES", "sdca_block_kernel"]
