"""jit-level entry points for the SDCA Pallas kernels.

Used by the solver-backend registry (repro.core.solver_backends):

  * ``sdca_block_apply``  — one H-block of sampled coordinates; backs the
    ``pallas_block`` backend (one pallas_call per block).
  * ``sdca_round``        — one fused local round (all H/B blocks in a
    single pallas_call); backs the ``pallas_round`` backend.

Losses outside ``SUPPORTED_LOSSES`` (no closed-form delta in the kernel)
fall back to the pure-jnp reference with identical iterate semantics.
"""
from __future__ import annotations

import os

import jax

from .ref import sdca_block_ref, sdca_round_ref
from .sdca_kernel import SUPPORTED_LOSSES, sdca_block_kernel, sdca_round_kernel

Array = jax.Array

# interpret=True on CPU (this container); on TPU set REPRO_PALLAS_INTERPRET=0
INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def sdca_block_apply(
    xb: Array,  # (B, d) sampled rows
    w: Array,  # (d,)
    r: Array,  # (d,) running block correction
    at0: Array,  # (B,) initial alpha~ per slot
    y: Array,  # (B,)
    cb: Array,  # (B,) coordinate ids (duplicate detection)
    kappa: Array,  # scalar
    loss_name: str,
) -> Array:
    """Deltas for ONE block; the caller scatters them and updates r."""
    if loss_name in SUPPORTED_LOSSES:
        return sdca_block_kernel(
            xb, w, r, at0, y, cb, kappa, loss_name, interpret=INTERPRET
        )
    return sdca_block_ref(xb, w, r, at0, y, cb, kappa, loss_name)


def sdca_round(
    x: Array,  # (n_max, d) full task block
    y: Array,  # (n_max,)
    alpha_i: Array,  # (n_max,)
    w: Array,  # (d,)
    u: Array,  # (H,) per-round uniform stream
    n_i: Array,  # scalar int
    kappa: Array,  # scalar
    loss_name: str,
    block: int = 64,
):
    """(dalpha, r) for one fused local round (single pallas_call)."""
    if loss_name in SUPPORTED_LOSSES:
        return sdca_round_kernel(
            x, y, alpha_i, w, u, n_i, kappa, loss_name,
            block=block, interpret=INTERPRET,
        )
    return sdca_round_ref(x, y, alpha_i, w, u, n_i, kappa, loss_name)
