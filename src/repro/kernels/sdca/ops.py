"""jit'd wrapper used by repro.core.sdca when use_kernel=True."""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .sdca_kernel import SUPPORTED_LOSSES, sdca_block_kernel
from .ref import sdca_block_ref

Array = jax.Array

# interpret=True on CPU (this container); on TPU set REPRO_PALLAS_INTERPRET=0
INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def sdca_block_update(
    G_unused: Array,
    q_unused: Array,
    xr_unused: Array,
    at0: Array,
    y: Array,
    cb: Array,
    kappa: Array,
    loss_name: str,
    *,
    xb: Array = None,
    w: Array = None,
    r: Array = None,
) -> Array:
    """Compatibility shim: repro.core.sdca precomputes (G, q, xr) for the
    jnp path; the kernel recomputes them from (xb, w, r) with its own d-tile
    accumulation. When xb/w/r are not provided, fall back to the reference.
    """
    if xb is not None:
        if loss_name in SUPPORTED_LOSSES:
            return sdca_block_kernel(
                xb, w, r, at0, y, cb, kappa, loss_name, interpret=INTERPRET
            )
        return sdca_block_ref(xb, w, r, at0, y, cb, kappa, loss_name)
    # reference solve directly from the precomputed Gram pieces
    return _solve_from_gram(G_unused, q_unused, xr_unused, at0, y, cb, kappa, loss_name)


def _solve_from_gram(G, q, xr, at0, y, cb, kappa, loss_name):
    from repro.core.losses import get_loss

    loss = get_loss(loss_name)
    B = q.shape[0]

    def body(k, deltas):
        corr = jnp.dot(G[k], deltas)
        c = q[k] + kappa * (xr[k] + corr)
        a = kappa * G[k, k]
        dup = jnp.sum(jnp.where(cb == cb[k], deltas, 0.0))
        atilde = at0[k] + dup
        return deltas.at[k].set(loss.sdca_delta(atilde, c, a, y[k]))

    return jax.lax.fori_loop(0, B, body, jnp.zeros((B,), q.dtype))
