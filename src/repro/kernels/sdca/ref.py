"""Pure-jnp oracle for the SDCA block kernel: literal sequential updates."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.losses import get_loss

Array = jax.Array


def sdca_block_ref(
    xb: Array,  # (B, d)
    w: Array,  # (d,)
    r: Array,  # (d,)
    at0: Array,  # (B,)
    y: Array,  # (B,)
    cb: Array,  # (B,) int32 coordinate ids
    kappa: Array,  # scalar
    loss_name: str,
) -> Array:
    """Sequential coordinate-at-a-time reference (recomputes the exact
    inner products each step; no Gram shortcut)."""
    loss = get_loss(loss_name)
    B = xb.shape[0]
    xb = xb.astype(jnp.float32)
    w = w.astype(jnp.float32)
    r0 = r.astype(jnp.float32)

    def body(k, carry):
        deltas, r_cur = carry
        xj = xb[k]
        c = jnp.dot(xj, w) + kappa * jnp.dot(xj, r_cur)
        a = kappa * jnp.dot(xj, xj)
        dup = jnp.sum(jnp.where(cb == cb[k], deltas, 0.0))
        atilde = at0[k] + dup
        d = loss.sdca_delta(atilde, c, a, y[k])
        deltas = deltas.at[k].set(d)
        return deltas, r_cur + d * xj

    deltas, _ = jax.lax.fori_loop(
        0, B, body, (jnp.zeros((B,), jnp.float32), r0)
    )
    return deltas


def sdca_round_ref(
    x: Array,  # (n_max, d)
    y: Array,  # (n_max,)
    alpha_i: Array,  # (n_max,)
    w: Array,  # (d,)
    u: Array,  # (H,) per-round uniform stream
    n_i: Array,  # scalar int
    kappa: Array,  # scalar
    loss_name: str,
):
    """Sequential coordinate-at-a-time oracle for the fused round kernel:
    same coordinate mapping (min(floor(u * n), n - 1)), literal Algorithm-2
    updates, no Gram shortcut. Returns (dalpha, r) in float32."""
    loss = get_loss(loss_name)
    H = u.shape[0]
    x = x.astype(jnp.float32)
    yv = y.astype(jnp.float32)
    al = alpha_i.astype(jnp.float32)
    w = w.astype(jnp.float32)
    n = jnp.asarray(n_i, jnp.int32)
    coords = jnp.minimum((u * n.astype(u.dtype)).astype(jnp.int32), n - 1)

    def body(h, carry):
        dalpha, r = carry
        j = coords[h]
        xj = x[j]
        c = jnp.dot(xj, w) + kappa * jnp.dot(xj, r)
        a = kappa * jnp.dot(xj, xj)
        atilde = al[j] + dalpha[j]
        delta = loss.sdca_delta(atilde, c, a, yv[j])
        return dalpha.at[j].add(delta), r + delta * xj

    dalpha0 = jnp.zeros_like(al)
    r0 = jnp.zeros_like(w)
    return jax.lax.fori_loop(0, H, body, (dalpha0, r0))
