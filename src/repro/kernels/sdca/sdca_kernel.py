"""Pallas TPU kernel for the block-Gram SDCA inner update (DESIGN.md §4).

Pipeline per H-block of sampled coordinates (B = block size):
  phase A (grid over d tiles, MXU):  q += X_blk_tile @ w_tile
                                     xr += X_blk_tile @ r_tile
                                     G += X_blk_tile @ X_blk_tile^T
  phase B (last tile, VPU/scalar):   sequential fori_loop over the B
        coordinates entirely on the VMEM-resident Gram block:
            c_k = q_k + kappa * (xr_k + G[k, :] . deltas)
            a_k = kappa * G[k, k]
            delta_k = closed-form argmax (hinge / squared / smoothed hinge)
        (duplicate coordinates within a block are handled through an
        equality mask against the coordinate ids, so atilde stays exact.)

Inputs:
  xb   (B, d)   sampled rows of the local data matrix
  w    (d,)     current task weight vector
  r    (d,)     running block correction X^T dalpha
  at0  (B,)     initial alpha~ per slot
  y    (B,)     labels for the sampled coordinates
  cb   (B,)     coordinate ids (duplicate detection)
  kappa scalar  rho * sigma_ii / (lambda * n_i)
Output:
  deltas (B,)

The d dimension is tiled with BlockSpec (VMEM working set: B x DT tile +
B x B Gram + O(B) vectors); B and DT should be multiples of the 128-lane
layout for MXU alignment on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

_GAMMA = 0.5  # smoothed-hinge knee (must match core.losses)
_EPS = 1e-12


def _delta_hinge(atilde, c, a, y):
    a = jnp.maximum(a, _EPS)
    anew = y * jnp.clip(y * (atilde + (y - c) / a), 0.0, 1.0)
    return anew - atilde


def _delta_squared(atilde, c, a, y):
    return (y - c - atilde) / (1.0 + a)


def _delta_smoothed_hinge(atilde, c, a, y):
    anew_u = atilde + (y - c - _GAMMA * atilde) / (_GAMMA + a)
    anew = y * jnp.clip(y * anew_u, 0.0, 1.0)
    return anew - atilde


_DELTAS = {
    "hinge": _delta_hinge,
    "squared": _delta_squared,
    "smoothed_hinge": _delta_smoothed_hinge,
}
SUPPORTED_LOSSES = tuple(_DELTAS)


def _kernel(
    xb_ref,  # (B, DT) tile
    w_ref,  # (DT,)
    r_ref,  # (DT,)
    at0_ref,  # (B,)
    y_ref,  # (B,)
    cb_ref,  # (B,)
    kappa_ref,  # (1, 1) in SMEM
    out_ref,  # (B,)
    q_acc,  # scratch (B,)
    xr_acc,  # scratch (B,)
    g_acc,  # scratch (B, B)
    *,
    loss: str,
    n_tiles: int,
):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        q_acc[...] = jnp.zeros_like(q_acc)
        xr_acc[...] = jnp.zeros_like(xr_acc)
        g_acc[...] = jnp.zeros_like(g_acc)

    xb = xb_ref[...]
    # phase A: accumulate the three d-contractions on the MXU
    q_acc[...] += xb @ w_ref[...]
    xr_acc[...] += xb @ r_ref[...]
    g_acc[...] += jax.lax.dot_general(
        xb, xb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ti == n_tiles - 1)
    def _solve():
        B = q_acc.shape[0]
        kappa = kappa_ref[0, 0]
        q = q_acc[...]
        xr = xr_acc[...]
        G = g_acc[...]
        at0 = at0_ref[...]
        y = y_ref[...]
        cb = cb_ref[...]
        delta_fn = _DELTAS[loss]

        def body(k, deltas):
            grow = jax.lax.dynamic_slice(G, (k, 0), (1, B))[0]  # (B,)
            corr = jnp.sum(grow * deltas)
            c = q[k] + kappa * (xr[k] + corr)
            a = kappa * grow[k]
            # duplicate handling: alpha~ includes earlier deltas on same coord
            dup = jnp.sum(jnp.where(cb == cb[k], deltas, 0.0))
            atilde = at0[k] + dup
            d = delta_fn(atilde, c, a, y[k])
            return deltas.at[k].set(d)

        deltas = jax.lax.fori_loop(0, B, body, jnp.zeros((B,), jnp.float32))
        out_ref[...] = deltas

    @pl.when(ti < n_tiles - 1)
    def _noop():
        out_ref[...] = jnp.zeros_like(out_ref)


def sdca_block_kernel(
    xb: Array,  # (B, d)
    w: Array,  # (d,)
    r: Array,  # (d,)
    at0: Array,  # (B,)
    y: Array,  # (B,)
    cb: Array,  # (B,) int32
    kappa: Array,  # scalar
    loss: str,
    d_tile: int = 512,
    interpret: bool = True,
) -> Array:
    assert loss in _DELTAS, f"kernel supports {SUPPORTED_LOSSES}, got {loss}"
    B, d = xb.shape
    d_tile = min(d_tile, d)
    pad = (-d) % d_tile
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad)))
        w = jnp.pad(w, (0, pad))
        r = jnp.pad(r, (0, pad))
    n_tiles = (d + pad) // d_tile

    f32 = lambda a: a.astype(jnp.float32)
    kappa2d = jnp.reshape(f32(kappa), (1, 1))
    kern = functools.partial(_kernel, loss=loss, n_tiles=n_tiles)
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((B, d_tile), lambda i: (0, i)),
            pl.BlockSpec((d_tile,), lambda i: (i,)),
            pl.BlockSpec((d_tile,), lambda i: (i,)),
            pl.BlockSpec((B,), lambda i: (0,)),
            pl.BlockSpec((B,), lambda i: (0,)),
            pl.BlockSpec((B,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((B,), jnp.float32),
            pltpu.VMEM((B,), jnp.float32),
            pltpu.VMEM((B, B), jnp.float32),
        ],
        interpret=interpret,
    )(f32(xb), f32(w), f32(r), f32(at0), f32(y), f32(cb), kappa2d)
