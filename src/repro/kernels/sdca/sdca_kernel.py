"""Pallas TPU kernels for the block-Gram SDCA inner update (docs/DESIGN.md §4).

Two entry points:

``sdca_block_kernel`` — ONE H-block per ``pallas_call`` (the ``pallas_block``
backend). ``w``/``r`` are re-streamed from HBM on every call, so a local
round of H iterations costs H/B kernel launches.

``sdca_round_kernel`` — ALL H-blocks of one local round fused into a single
``pallas_call`` (the ``pallas_round`` backend, docs/DESIGN.md §6): the task's
data block, ``w`` and the running correction ``r`` stay VMEM-resident across
blocks, coordinate sampling happens on-device from the round's uniform
stream, and only ``(dalpha, r)`` leave the kernel.

Per-block pipeline (B = block size), shared by both kernels:
  phase A (grid over d tiles, MXU):  q += X_blk_tile @ w_tile
                                     xr += X_blk_tile @ r_tile
                                     G += X_blk_tile @ X_blk_tile^T
  phase B (last tile, VPU/scalar):   sequential fori_loop over the B
        coordinates entirely on the VMEM-resident Gram block:
            c_k = q_k + kappa * (xr_k + G[k, :] . deltas)
            a_k = kappa * G[k, k]
            delta_k = closed-form argmax (hinge / squared / smoothed hinge)
        (duplicate coordinates within a block are handled through an
        equality mask against the coordinate ids, so atilde stays exact.)

Inputs:
  xb   (B, d)   sampled rows of the local data matrix
  w    (d,)     current task weight vector
  r    (d,)     running block correction X^T dalpha
  at0  (B,)     initial alpha~ per slot
  y    (B,)     labels for the sampled coordinates
  cb   (B,)     coordinate ids (duplicate detection)
  kappa scalar  rho * sigma_ii / (lambda * n_i)
Output:
  deltas (B,)

The d dimension is tiled with BlockSpec (VMEM working set: B x DT tile +
B x B Gram + O(B) vectors); B and DT should be multiples of the 128-lane
layout for MXU alignment on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

_GAMMA = 0.5  # smoothed-hinge knee (must match core.losses)
_EPS = 1e-12


def _delta_hinge(atilde, c, a, y):
    a = jnp.maximum(a, _EPS)
    anew = y * jnp.clip(y * (atilde + (y - c) / a), 0.0, 1.0)
    return anew - atilde


def _delta_squared(atilde, c, a, y):
    return (y - c - atilde) / (1.0 + a)


def _delta_smoothed_hinge(atilde, c, a, y):
    anew_u = atilde + (y - c - _GAMMA * atilde) / (_GAMMA + a)
    anew = y * jnp.clip(y * anew_u, 0.0, 1.0)
    return anew - atilde


_DELTAS = {
    "hinge": _delta_hinge,
    "squared": _delta_squared,
    "smoothed_hinge": _delta_smoothed_hinge,
}
SUPPORTED_LOSSES = tuple(_DELTAS)


def _kernel(
    xb_ref,  # (B, DT) tile
    w_ref,  # (DT,)
    r_ref,  # (DT,)
    at0_ref,  # (B,)
    y_ref,  # (B,)
    cb_ref,  # (B,)
    kappa_ref,  # (1, 1) in SMEM
    out_ref,  # (B,)
    q_acc,  # scratch (B,)
    xr_acc,  # scratch (B,)
    g_acc,  # scratch (B, B)
    *,
    loss: str,
    n_tiles: int,
):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        q_acc[...] = jnp.zeros_like(q_acc)
        xr_acc[...] = jnp.zeros_like(xr_acc)
        g_acc[...] = jnp.zeros_like(g_acc)

    xb = xb_ref[...]
    # phase A: accumulate the three d-contractions on the MXU
    q_acc[...] += xb @ w_ref[...]
    xr_acc[...] += xb @ r_ref[...]
    g_acc[...] += jax.lax.dot_general(
        xb, xb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ti == n_tiles - 1)
    def _solve():
        B = q_acc.shape[0]
        kappa = kappa_ref[0, 0]
        q = q_acc[...]
        xr = xr_acc[...]
        G = g_acc[...]
        at0 = at0_ref[...]
        y = y_ref[...]
        cb = cb_ref[...]
        delta_fn = _DELTAS[loss]

        def body(k, deltas):
            grow = jax.lax.dynamic_slice(G, (k, 0), (1, B))[0]  # (B,)
            corr = jnp.sum(grow * deltas)
            c = q[k] + kappa * (xr[k] + corr)
            a = kappa * grow[k]
            # duplicate handling: alpha~ includes earlier deltas on same coord
            dup = jnp.sum(jnp.where(cb == cb[k], deltas, 0.0))
            atilde = at0[k] + dup
            d = delta_fn(atilde, c, a, y[k])
            return deltas.at[k].set(d)

        deltas = jax.lax.fori_loop(0, B, body, jnp.zeros((B,), jnp.float32))
        out_ref[...] = deltas

    @pl.when(ti < n_tiles - 1)
    def _noop():
        out_ref[...] = jnp.zeros_like(out_ref)


def sdca_block_kernel(
    xb: Array,  # (B, d)
    w: Array,  # (d,)
    r: Array,  # (d,)
    at0: Array,  # (B,)
    y: Array,  # (B,)
    cb: Array,  # (B,) int32
    kappa: Array,  # scalar
    loss: str,
    d_tile: int = 512,
    interpret: bool = True,
) -> Array:
    assert loss in _DELTAS, f"kernel supports {SUPPORTED_LOSSES}, got {loss}"
    B, d = xb.shape
    d_tile = min(d_tile, d)
    pad = (-d) % d_tile
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad)))
        w = jnp.pad(w, (0, pad))
        r = jnp.pad(r, (0, pad))
    n_tiles = (d + pad) // d_tile

    f32 = lambda a: a.astype(jnp.float32)
    kappa2d = jnp.reshape(f32(kappa), (1, 1))
    kern = functools.partial(_kernel, loss=loss, n_tiles=n_tiles)
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((B, d_tile), lambda i: (0, i)),
            pl.BlockSpec((d_tile,), lambda i: (i,)),
            pl.BlockSpec((d_tile,), lambda i: (i,)),
            pl.BlockSpec((B,), lambda i: (0,)),
            pl.BlockSpec((B,), lambda i: (0,)),
            pl.BlockSpec((B,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((B,), jnp.float32),
            pltpu.VMEM((B,), jnp.float32),
            pltpu.VMEM((B, B), jnp.float32),
        ],
        interpret=interpret,
    )(f32(xb), f32(w), f32(r), f32(at0), f32(y), f32(cb), kappa2d)


def _round_kernel(
    x_ref,  # (n_max, d)  the task's full (padded) data block
    y_ref,  # (n_max,)
    alpha_ref,  # (n_max,)  current dual block
    w_ref,  # (d,)
    u_ref,  # (H,)  per-round uniform stream (key-derived, data-independent)
    n_ref,  # (1, 1) int32 in SMEM: valid sample count
    kappa_ref,  # (1, 1) in SMEM
    dalpha_ref,  # out (n_max,)
    r_ref,  # out (d,)
    *,
    loss: str,
    n_blocks: int,
    block: int,
):
    # everything is staged into VMEM once and stays resident for the whole
    # round; the H/B block loop below never touches HBM again.
    X = x_ref[...]
    yv = y_ref[...]
    al = alpha_ref[...]
    w = w_ref[...]
    u = u_ref[...]
    n = n_ref[0, 0]
    kappa = kappa_ref[0, 0]
    delta_fn = _DELTAS[loss]
    n_max, d = X.shape

    # on-device coordinate sampling: identical arithmetic to
    # repro.core.sdca.sample_coords so iterates bit-match the jnp backends
    cs = jnp.minimum((u * n.astype(u.dtype)).astype(jnp.int32), n - 1)

    def gather_rows(cb):
        def g(k, xb):
            row = jax.lax.dynamic_slice(X, (cb[k], 0), (1, d))
            return jax.lax.dynamic_update_slice(xb, row, (k, 0))

        return jax.lax.fori_loop(0, block, g, jnp.zeros((block, d), X.dtype))

    def blk(b, carry):
        dalpha, r = carry
        cb = jax.lax.dynamic_slice(cs, (b * block,), (block,))
        xb = gather_rows(cb)
        q = xb @ w
        xr = xb @ r
        G = jax.lax.dot_general(xb, xb, (((1,), (1,)), ((), ())))

        def inner(k, ic):
            dalpha_, deltas = ic
            Gk = jax.lax.dynamic_slice(G, (k, 0), (1, block))[0]
            corr = jnp.dot(Gk, deltas)  # deltas[k:] are still 0
            c = q[k] + kappa * (xr[k] + corr)
            a = kappa * Gk[k]
            j = cb[k]
            atilde = al[j] + dalpha_[j]
            delta = delta_fn(atilde, c, a, yv[j])
            return dalpha_.at[j].add(delta), deltas.at[k].set(delta)

        deltas0 = q * 0.0
        dalpha, deltas = jax.lax.fori_loop(0, block, inner, (dalpha, deltas0))
        return dalpha, r + xb.T @ deltas

    dalpha0 = jnp.zeros((n_max,), jnp.float32)
    r0 = jnp.zeros((d,), jnp.float32)
    dalpha, r = jax.lax.fori_loop(0, n_blocks, blk, (dalpha0, r0))
    dalpha_ref[...] = dalpha
    r_ref[...] = r


def sdca_round_kernel(
    x,  # (n_max, d)
    y,  # (n_max,)
    alpha_i,  # (n_max,)
    w,  # (d,)
    u,  # (H,) uniforms in [0, 1) derived from the per-round key
    n_i,  # scalar int: valid sample count
    kappa,  # scalar: rho * sigma_ii / (lambda * n_i)
    loss: str,
    block: int = 64,
    interpret: bool = True,
):
    """One fused local SDCA round: H = len(u) iterations in H/block Gram
    blocks, ONE pallas_call. Returns (dalpha, r), both float32.

    VMEM working set is the full (n_max, d) task block plus O(B^2); the
    per-task data must fit on-chip (docs/DESIGN.md §6 sizes this — the
    paper's per-worker task blocks do). For larger n_max the block kernel
    with its d-tiled BlockSpec remains the fallback.
    """
    assert loss in _DELTAS, f"kernel supports {SUPPORTED_LOSSES}, got {loss}"
    H = u.shape[0]
    assert H % block == 0, f"H={H} must be a multiple of block={block}"
    n_max, d = x.shape
    f32 = lambda a: a.astype(jnp.float32)
    from jax.experimental.pallas import tpu as pltpu

    kern = functools.partial(
        _round_kernel, loss=loss, n_blocks=H // block, block=block
    )
    n2d = jnp.reshape(jnp.asarray(n_i, jnp.int32), (1, 1))
    kappa2d = jnp.reshape(f32(jnp.asarray(kappa)), (1, 1))
    return pl.pallas_call(
        kern,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),  # x
            pl.BlockSpec(memory_space=pltpu.VMEM),  # y
            pl.BlockSpec(memory_space=pltpu.VMEM),  # alpha_i
            pl.BlockSpec(memory_space=pltpu.VMEM),  # w
            pl.BlockSpec(memory_space=pltpu.VMEM),  # u
            pl.BlockSpec(memory_space=pltpu.SMEM),  # n
            pl.BlockSpec(memory_space=pltpu.SMEM),  # kappa
        ],
        out_specs=(
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n_max,), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32),
        ),
        interpret=interpret,
    )(f32(x), f32(y), f32(alpha_i), f32(w), f32(u), n2d, kappa2d)
