from . import ops, ref
from .ssd_kernel import ssd_chunk_kernel

__all__ = ["ops", "ref", "ssd_chunk_kernel"]
