"""jit'd wrapper: full SSD forward using the Pallas chunk kernel for the
intra-chunk work + XLA associative scan for the inter-chunk recurrence.
Drop-in equivalent of models/ssm.ssd_chunked (tested against it and the
naive recurrence)."""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp

from .ssd_kernel import ssd_chunk_kernel

Array = jax.Array
INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def ssd_forward(
    x: Array,  # (B, L, H, P) fp32
    dt: Array,  # (B, L, H)
    A: Array,  # (H,)
    Bm: Array,  # (B, L, H, N)
    Cm: Array,
    chunk: int = 64,
) -> Tuple[Array, Array]:
    B_, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        pad4 = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        x, dt, Bm, Cm = pad4(x), pad4(dt), pad4(Bm), pad4(Cm)
    nc = (L + pad) // Q

    def to_chunks(a):  # (B, L, ...) -> (B, H, nc, Q, ...)
        a = a.reshape((B_, nc, Q) + a.shape[2:])
        return jnp.moveaxis(a, 3, 1)  # (B, H, nc, Q, ...)

    xc = to_chunks(x)
    dtc = to_chunks(dt[..., None])[..., 0]
    Bc = to_chunks(Bm)
    Cc = to_chunks(Cm)

    Y_intra, S_local, a_tot = ssd_chunk_kernel(
        xc, dtc, A, Bc, Cc, interpret=INTERPRET
    )

    # inter-chunk: associative scan over (a_tot, S_local) along chunk axis
    def combine(left, right):
        a1, s1 = left
        a2, s2 = right
        return a1 * a2, a2[..., None, None] * s1 + s2

    a_inc, S_inc = jax.lax.associative_scan(combine, (a_tot, S_local), axis=2)
    S_prev = jnp.concatenate(
        [jnp.zeros_like(S_inc[:, :, :1]), S_inc[:, :, :-1]], axis=2
    )  # (B, H, nc, N, P)

    la = dtc * A[None, :, None, None]
    cum = jnp.cumsum(la, axis=-1)
    Y_inter = jnp.einsum(
        "bhcqn,bhcnp->bhcqp", Cc * jnp.exp(cum)[..., None], S_prev
    )
    Y = Y_intra + Y_inter  # (B, H, nc, Q, P)
    Y = jnp.moveaxis(Y, 1, 3).reshape(B_, nc * Q, H, P)[:, :L]
    final_state = jnp.swapaxes(S_inc[:, :, -1], -1, -2)  # (B, H, P, N)
    return Y, final_state
