"""Pure-jnp oracles for the SSD chunk kernel.

naive_recurrence: the literal s_t = a_t s_{t-1} + u_t (x) B_t recurrence —
the ground truth for both the chunk kernel and models/ssm.ssd_chunked.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def naive_recurrence(
    x: Array,  # (B, L, H, P) fp32
    dt: Array,  # (B, L, H)
    A: Array,  # (H,) negative
    Bm: Array,  # (B, L, H, N)
    Cm: Array,  # (B, L, H, N)
) -> Tuple[Array, Array]:
    """Returns (Y (B,L,H,P), final_state (B,H,P,N))."""
    B_, L, H, P = x.shape
    N = Bm.shape[-1]

    def step(s, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        a = jnp.exp(dtt * A)  # (B,H)
        u = xt * dtt[..., None]
        s = a[..., None, None] * s + jnp.einsum("bhp,bhn->bhpn", u, bt)
        y = jnp.einsum("bhn,bhpn->bhp", ct, s)
        return s, y

    s0 = jnp.zeros((B_, H, P, N), jnp.float32)
    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bm, 1, 0),
        jnp.moveaxis(Cm, 1, 0),
    )
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_fin


def chunk_ref(
    x: Array,  # (B, H, nc, Q, P)
    dt: Array,  # (B, H, nc, Q)
    A: Array,  # (H,)
    Bm: Array,  # (B, H, nc, Q, N)
    Cm: Array,
):
    """jnp version of exactly what the chunk kernel computes per cell."""
    la = dt * A[None, :, None, None]
    cum = jnp.cumsum(la, axis=-1)
    u = x * dt[..., None]
    diff = cum[..., :, None] - cum[..., None, :]
    Q = x.shape[-2]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(tri, jnp.exp(diff), 0.0)
    CB = jnp.einsum("bhcqn,bhckn->bhcqk", Cm, Bm)
    Y = jnp.einsum("bhcqk,bhckp->bhcqp", CB * M, u)
    decay_end = jnp.exp(cum[..., -1:] - cum)
    S = jnp.einsum("bhcqn,bhcqp->bhcnp", Bm * decay_end[..., None], u)
    a_tot = jnp.exp(cum[..., -1])
    return Y, S, a_tot
