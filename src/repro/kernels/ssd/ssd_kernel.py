"""Pallas TPU kernel for the Mamba2 SSD chunk-local computation.

Per (batch, head, chunk) grid cell, entirely in VMEM (Q<=128, N,P<=128):
    cum      = cumsum(dt * A)                     (Q,)
    M        = exp(cum_t - cum_tau) . tril        (Q, Q)
    Y_intra  = ((C B^T) o M) @ (dt * x)           (Q, P)   two MXU matmuls
    S_local  = (B * exp(cum_Q - cum))^T @ (dt*x)  (N, P)   one MXU matmul
    a_tot    = exp(cum_Q)                         scalar
The inter-chunk recurrence (log-depth associative scan over a_tot/S_local)
stays in XLA — it is O(L/Q) tiny tensors and fuses well there.

Outputs: Y_intra (B,H,nc,Q,P), S_local (B,H,nc,N,P), a_tot (B,H,nc).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(
    x_ref,  # (1, 1, 1, Q, P)
    dt_ref,  # (1, 1, 1, Q)
    a_ref,  # (1, 1)  A scalar for this head (SMEM-ish block)
    b_ref,  # (1, 1, 1, Q, N)
    c_ref,  # (1, 1, 1, Q, N)
    y_ref,  # (1, 1, 1, Q, P)
    s_ref,  # (1, 1, 1, N, P)
    atot_ref,  # (1, 1, 1)
    *,
    q_len: int,
):
    x = x_ref[0, 0, 0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    A = a_ref[0, 0]
    Bm = b_ref[0, 0, 0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0, 0, 0].astype(jnp.float32)

    la = dt * A  # (Q,) log-decay per step (<= 0)
    cum = jnp.cumsum(la)  # inclusive
    u = x * dt[:, None]  # (Q, P)

    diff = cum[:, None] - cum[None, :]  # (Qt, Qtau)
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1)
    )
    M = jnp.where(tri, jnp.exp(diff), 0.0)
    CB = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Qt, Qtau)
    y_ref[0, 0, 0] = ((CB * M) @ u).astype(y_ref.dtype)

    decay_end = jnp.exp(cum[-1] - cum)  # (Q,)
    s_ref[0, 0, 0] = (
        jax.lax.dot_general(
            Bm * decay_end[:, None],
            u,
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    ).astype(s_ref.dtype)  # (N, P)
    atot_ref[0, 0, 0] = jnp.exp(cum[-1])


def ssd_chunk_kernel(
    x: Array,  # (B, H, nc, Q, P) fp32
    dt: Array,  # (B, H, nc, Q)
    A: Array,  # (H,)
    Bm: Array,  # (B, H, nc, Q, N)
    Cm: Array,  # (B, H, nc, Q, N)
    interpret: bool = True,
):
    B, H, nc, Q, P = x.shape
    N = Bm.shape[-1]
    a2d = jnp.tile(A[None, :], (B, 1)).astype(jnp.float32)  # (B, H) block input

    kern = functools.partial(_kernel, q_len=Q)
    return pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (b, h)),
            pl.BlockSpec((1, 1, 1, Q, N), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, N), lambda b, h, c: (b, h, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, N, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, c: (b, h, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, nc, N, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, nc), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a2d, Bm, Cm)
