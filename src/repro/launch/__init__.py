"""Launchers: production mesh, dry-run, training CLI.

NOTE: do NOT import .dryrun here — it sets XLA_FLAGS at import time and must
only be imported as the __main__ module of a fresh process.
"""
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_host_mesh, make_production_mesh

__all__ = [
    "HBM_BW",
    "ICI_BW",
    "PEAK_FLOPS_BF16",
    "make_host_mesh",
    "make_production_mesh",
]
