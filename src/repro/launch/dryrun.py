import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes and extract roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch nemotron-4-15b \
        --shape train_4k --mesh single --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Each run writes one JSON per (arch, shape, mesh) into --out;
benchmarks/bench_roofline.py aggregates those files into the roofline
tables (terms defined in docs/DESIGN.md §Roofline).
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ModelConfig
from repro.launch.input_specs import INPUT_SHAPES, InputShape, input_specs, shape_applicable
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.roofline.analysis import analyze_compiled

SDS = jax.ShapeDtypeStruct


def _sds_like(tree):
    return jax.tree.map(lambda l: SDS(l.shape, l.dtype), tree)


def _bytes_per_device(tree, shardings, mesh: Mesh) -> int:
    """Analytic per-device bytes of a sharded SDS pytree."""
    total = 0
    for leaf, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(shardings)):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        b = n * leaf.dtype.itemsize
        spec = sh.spec if isinstance(sh, NamedSharding) else sh
        denom = 1
        for ax in spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                denom *= mesh.shape[a]
        total += b // max(denom, 1)
    return total


def lower_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    """Build + lower the jitted step for this (arch, shape). Returns
    (lowered, model_flops, arg_bytes_per_device)."""
    import repro.models.transformer as tf
    from repro.models.sharding import (
        decode_cache_pspec,
        param_pspecs,
        param_shardings,
        train_batch_pspec,
    )
    from repro.train.optimizer import AdamW, AdamWState
    from repro.train.loop import make_train_step

    pshapes = tf.param_shapes(cfg)
    mode = "train" if shape.kind == "train" else "serve"
    pshard = param_shardings(cfg, pshapes, mesh, mode=mode)
    N = cfg.param_count()
    N_active = cfg.active_param_count()
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        opt = AdamW()
        opt_shapes = AdamWState(
            step=SDS((), jnp.int32),
            mu=jax.tree.map(lambda l: SDS(l.shape, jnp.float32), pshapes),
            nu=jax.tree.map(lambda l: SDS(l.shape, jnp.float32), pshapes),
        )
        opt_shard = AdamWState(
            step=NamedSharding(mesh, P()), mu=pshard, nu=pshard
        )
        bspec = train_batch_pspec(mesh, shape.global_batch)
        bshard = {
            "tokens": NamedSharding(mesh, bspec),
            "labels": NamedSharding(mesh, bspec),
            "mask": NamedSharding(mesh, bspec),
        }
        if cfg.is_encoder_decoder:
            bshard["frames"] = NamedSharding(mesh, P(bspec[0], None, None))
        # microbatching bounds the L*B*S*d residual saves (see §Perf)
        b0 = bspec[0]
        b0 = (b0,) if isinstance(b0, str) else (b0 or ())
        n_dp = int(np.prod([mesh.shape[a] for a in b0])) if b0 else 1
        b_loc = max(shape.global_batch // max(n_dp, 1), 1)
        # microbatch size = 1 row/device (still seq_len tokens per matmul);
        # bounds the L x B_mb x S x d residual saves to a single batch row.
        # Small models skip it: their saves fit, and the microbatch scan
        # tickles an XLA CPU SPMD bug with hoisted embedding gathers.
        default_micro = max(1, b_loc) if cfg.param_count() > 2e9 else 1
        micro = int(os.environ.get("DRYRUN_MICROBATCHES", default_micro))
        inner_specs = grad_specs = None
        if os.environ.get("DRYRUN_ZERO2") == "1":
            # §Perf: gather params once per step (serve/model-only specs
            # inside), keep grads FSDP-sharded outside
            inner_specs = param_pspecs(cfg, pshapes, mesh, mode="serve")
            grad_specs = param_pspecs(cfg, pshapes, mesh, mode="train")
        step = make_train_step(
            cfg, opt, microbatches=micro,
            inner_param_specs=inner_specs, grad_specs=grad_specs,
        )
        jitted = jax.jit(
            step,
            in_shardings=(pshard, opt_shard, bshard),
            donate_argnums=(0, 1),
        )
        with jax.set_mesh(mesh):
            lowered = jitted.lower(pshapes, opt_shapes, specs)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * N_active * tokens
        arg_bytes = _bytes_per_device(pshapes, pshard, mesh) * 3  # params + mu + nu
        return lowered, model_flops, arg_bytes

    if shape.kind == "prefill":
        bspec = train_batch_pspec(mesh, shape.global_batch)
        bshard: Dict[str, Any] = {"tokens": NamedSharding(mesh, bspec)}
        if cfg.is_encoder_decoder:
            bshard["frames"] = NamedSharding(mesh, P(bspec[0], None, None))

        def step(params, batch):
            return tf.prefill(
                cfg, params, batch["tokens"], batch.get("frames"), extra_len=128
            )

        jitted = jax.jit(step, in_shardings=(pshard, bshard))
        with jax.set_mesh(mesh):
            lowered = jitted.lower(pshapes, specs)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * N_active * tokens
        arg_bytes = _bytes_per_device(pshapes, pshard, mesh)
        return lowered, model_flops, arg_bytes

    # decode
    specs = input_specs(cfg, shape)
    cache_shapes = specs["cache"]
    kinds = cfg.layer_kinds()

    def cache_shardings(cache) -> Any:
        # mirror DecodeCache structure with NamedShardings
        if isinstance(cache.layers, dict):
            kind = "ssm" if cfg.arch_type == "ssm" else "attn"
            spec = decode_cache_pspec(cfg, mesh, shape.global_batch, kind)
            layers = {
                k: NamedSharding(mesh, P(*((None,) + tuple(spec[k]))))
                for k in cache.layers
            }
        else:
            layers = []
            for i, k in enumerate(kinds):
                kind = "ssm" if k == "ssm" else ("local" if k == "local" else "attn")
                spec = decode_cache_pspec(cfg, mesh, shape.global_batch, kind)
                layers.append(
                    {kk: NamedSharding(mesh, spec[kk]) for kk in cache.layers[i]}
                )
        shared = None
        if cache.shared is not None:
            spec = decode_cache_pspec(cfg, mesh, shape.global_batch, "attn")
            shared = [
                {kk: NamedSharding(mesh, spec[kk]) for kk in c} for c in cache.shared
            ]
        cross = None
        if cache.cross is not None:
            bspec = train_batch_pspec(mesh, shape.global_batch)
            ns = NamedSharding(mesh, P(bspec[0], None, None, None))
            cross = [(ns, ns) for _ in cache.cross]
        return tf.DecodeCache(
            layers, NamedSharding(mesh, P()), shared, cross
        )

    cshard = cache_shardings(cache_shapes)
    tok_shard = NamedSharding(mesh, P())  # (B,) tokens tiny: replicate

    def step(params, token, cache):
        return tf.decode_step(cfg, params, token, cache)

    jitted = jax.jit(step, in_shardings=(pshard, tok_shard, cshard), donate_argnums=(2,))
    with jax.set_mesh(mesh):
        lowered = jitted.lower(pshapes, specs["token"], cache_shapes)
    model_flops = 2.0 * cfg.active_param_count() * shape.global_batch
    arg_bytes = _bytes_per_device(pshapes, pshard, mesh) + _bytes_per_device(
        jax.tree.leaves(cache_shapes),
        jax.tree.leaves(cshard),
        mesh,
    )
    return lowered, model_flops, arg_bytes


def run_one(
    arch: str, shape_name: str, mesh_name: str, out_dir: str, compile_: bool = True
) -> Dict[str, Any]:
    cfg = get_config(arch)
    if os.environ.get("DRYRUN_ATTN"):
        cfg = dataclasses.replace(cfg, attn_impl=os.environ["DRYRUN_ATTN"])
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "timestamp": time.time(),
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        _write(out_dir, rec)
        return rec

    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        lowered, model_flops, arg_bytes = lower_step(cfg, shape, mesh)
        t_lower = time.time() - t0
        rec["lower_s"] = round(t_lower, 1)
        if not compile_:
            rec.update(status="lowered")
            _write(out_dir, rec)
            return rec
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        # persist the post-SPMD HLO so analyzer improvements can re-score
        # without recompiling (gzip ~1-3MB each)
        import gzip

        os.makedirs(out_dir, exist_ok=True)
        hlo_path = os.path.join(
            out_dir, f"{arch}__{shape_name}__{mesh_name}.hlo.gz"
        )
        with gzip.open(hlo_path, "wt") as f:
            f.write(compiled.as_text())
        rec["hlo_path"] = hlo_path
        terms = analyze_compiled(
            compiled,
            arch=arch,
            shape=shape_name,
            mesh_name=mesh_name,
            n_chips=n_chips,
            model_flops=model_flops,
            peak_flops=PEAK_FLOPS_BF16,
            hbm_bw=HBM_BW,
            ici_bw=ICI_BW,
        )
        row = terms.to_row()
        row["memory_analysis"] = (row.get("memory_analysis") or "")[:2000]
        rec.update(status="ok", arg_bytes_per_device=arg_bytes, **row)
        # print the spec-mandated artifacts
        print(f"== {arch} / {shape_name} / {mesh_name} ==")
        try:
            print(compiled.memory_analysis())
        except Exception as e:  # CPU backend may not implement it
            print(f"memory_analysis unavailable on this backend: {e}")
            print(f"analytic argument bytes/device: {arg_bytes/1e9:.3f} GB")
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        print({k: ca[k] for k in sorted(ca) if "flops" in k or "bytes" in k})
    except Exception as e:
        if shape.kind == "train" and os.environ.get("DRYRUN_MICROBATCHES") != "1":
            # retry once without microbatching (XLA SPMD hoisted-gather bug)
            os.environ["DRYRUN_MICROBATCHES"] = "1"
            try:
                return run_one(arch, shape_name, mesh_name, out_dir, compile_)
            finally:
                del os.environ["DRYRUN_MICROBATCHES"]
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _write(out_dir, rec)
    return rec


def _write(out_dir: str, rec: Dict[str, Any]) -> None:
    os.makedirs(out_dir, exist_ok=True)
    fn = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, fn), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                fn = os.path.join(args.out, f"{arch}__{shape}__{mesh}.json")
                if args.skip_done and os.path.exists(fn):
                    with open(fn) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            continue
                t0 = time.time()
                rec = run_one(arch, shape, mesh, args.out, not args.no_compile)
                print(
                    f"[{rec['status']:7s}] {arch:20s} {shape:12s} {mesh:6s} "
                    f"({time.time()-t0:.0f}s) {rec.get('error','')}",
                    flush=True,
                )


if __name__ == "__main__":
    main()
