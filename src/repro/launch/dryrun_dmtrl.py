import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)
# ^ MUST precede any jax import.

"""Dry-run of the PAPER'S technique on the production mesh: one DMTRL
communication round (local block-Gram SDCA + delta_b all-gather + Sigma
reduce) lowered and compiled at pod scale.

    PYTHONPATH=src python -m repro.launch.dryrun_dmtrl --mesh both

Configs: m=4096 tasks sharded over 'data' (the paper's workers), feature
dim d=8192 sharded over 'model' (block-Gram psums), and on the multi-pod
mesh each task's samples additionally split over 'pod'.
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dmtrl import DMTRLConfig
from repro.core.distributed import MeshAxes, make_distributed_round
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.roofline.analysis import analyze_compiled

SDS = jax.ShapeDtypeStruct


def run(mesh_name: str, m: int, n_max: int, d: int, out_dir: str,
        H: int = 512, block: int = 128, bf16: bool = False, tag: str = "",
        x_dtype=jnp.float32) -> dict:
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    axes = MeshAxes(
        data="data", model="model", pod="pod" if multi else None
    )
    cfg = DMTRLConfig(
        loss="hinge", lam=1e-4, local_iters=H, solver="block_gram",
        block_size=block, gram_bf16=bf16,
        dist_block_hoisted=os.environ.get("DMTRL_BLOCK_HOISTED", "0") == "1",
    )
    rho = 4.0  # representative learned-Sigma value (Lemma 10 scale)
    round_fn = make_distributed_round(cfg, mesh, axes, m, n_max, d, rho)

    specs = (
        SDS((m, n_max, d), x_dtype),  # x
        SDS((m, n_max), jnp.float32),  # y
        SDS((m, n_max), jnp.float32),  # mask
        SDS((m,), jnp.int32),  # n
        SDS((m, n_max), jnp.float32),  # alpha
        SDS((m, d), jnp.float32),  # W
        SDS((m, m), jnp.float32),  # sigma rows
        SDS((2,), jnp.uint32),  # key
    )
    t0 = time.time()
    lowered = round_fn.lower(*specs)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    n_chips = int(np.prod(list(mesh.shape.values())))
    # useful flops: block-Gram per task per round:
    #   q,xr: 2*H*d*2 ; G: H*B*d... per block: 2*(B*d)*2 + B^2*d*2 ; r upd B*d*2
    nb = H // block
    per_task = nb * (2 * 2 * block * d + 2 * block * block * d + 2 * block * d)
    model_flops = float(m * per_task)
    terms = analyze_compiled(
        compiled,
        arch=f"dmtrl-m{m}-d{d}{tag}",
        shape=f"wstep-H{H}-B{block}",
        mesh_name=mesh_name,
        n_chips=n_chips,
        model_flops=model_flops,
        peak_flops=PEAK_FLOPS_BF16,
        hbm_bw=HBM_BW,
        ici_bw=ICI_BW,
    )
    rec = {"status": "ok", "lower_s": round(t_lower, 1),
           "compile_s": round(t_compile, 1), **terms.to_row()}
    rec["memory_analysis"] = (rec.get("memory_analysis") or "")[:2000]
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"dmtrl{tag}__wstep__{mesh_name}.json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    print(f"== DMTRL W-step round / {mesh_name} ({n_chips} chips) ==")
    try:
        print(compiled.memory_analysis())
    except Exception as e:
        print("memory_analysis unavailable:", e)
    print(
        f"compute {terms.compute_s*1e3:.2f}ms  memory {terms.memory_s*1e3:.2f}ms  "
        f"collective {terms.collective_s*1e3:.2f}ms  dominant={terms.dominant}"
    )
    print("collectives:", terms.collective_breakdown)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--m", type=int, default=4096)
    ap.add_argument("--n-max", type=int, default=2048)
    ap.add_argument("--d", type=int, default=8192)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--bf16", action="store_true")
    ap.add_argument("--x-bf16", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--H", type=int, default=512)
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mn in meshes:
        run(mn, args.m, args.n_max, args.d, args.out, H=args.H,
            bf16=args.bf16, tag=args.tag,
            x_dtype=jnp.bfloat16 if args.x_bf16 else jnp.float32)


if __name__ == "__main__":
    main()
