"""ShapeDtypeStruct stand-ins for every model input, per (arch x shape).

No device allocation: the dry-run lowers jitted steps against these specs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Spec rules: long_500k only for sub-quadratic decode archs."""
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return False, (
            "pure full-attention arch: 500k dense KV decode skipped per spec "
            "(no sliding-window/SSM variant)"
        )
    return True, ""


def train_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": SDS((B, S), jnp.int32),
        "labels": SDS((B, S), jnp.int32),
        "mask": SDS((B, S), jnp.float32),
    }
    if cfg.is_encoder_decoder:
        specs["frames"] = SDS((B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    return specs


def prefill_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {"tokens": SDS((B, S), jnp.int32)}
    if cfg.is_encoder_decoder:
        specs["frames"] = SDS((B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    return specs


def decode_inputs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """token + cache ShapeDtypeStructs (cache of seq_len slots, pos=seq_len-1
    already filled -> the step appends token #seq_len)."""
    from repro.models import init_decode_cache

    B, S = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(
        lambda: init_decode_cache(cfg, B, max_len=S)
    )
    return {"token": SDS((B,), jnp.int32), "cache": cache_shapes}


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_inputs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_inputs(cfg, shape)
    return decode_inputs(cfg, shape)
