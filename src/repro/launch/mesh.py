"""Production mesh construction (function, not module constant, so importing
never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    assert data * model <= n, f"need {data*model} devices, have {n}"
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e-class hardware constants used by the roofline (docs/DESIGN.md §Roofline)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
