"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --reduced \
        --steps 50 --batch 8 --seq 128

On a real pod this would run once per host with jax.distributed.initialize;
on this container it drives the single-host loop (reduced configs) and is
the end-to-end example driver's engine.
"""
from __future__ import annotations

import argparse
import json
import os
import time


from repro.configs import ARCH_IDS, get_config
from repro.data.tokens import SyntheticTokenPipeline, TokenPipelineConfig, embedding_side_inputs
from repro.train import AdamW, TrainLogger, train
from repro.train import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    pipe = SyntheticTokenPipeline(
        TokenPipelineConfig(
            vocab_size=cfg.vocab_size,
            seq_len=args.seq,
            global_batch=args.batch,
            seed=args.seed,
        )
    )

    def data_iter():
        step = 0
        while True:
            b = pipe.batch(step)
            if cfg.is_encoder_decoder:
                b["frames"] = embedding_side_inputs(
                    "audio", args.batch, cfg.d_model, args.seed, cfg.enc_frames
                )
            yield b
            step += 1

    opt = AdamW(lr=args.lr, warmup_steps=max(args.steps // 10, 5), total_steps=args.steps)
    logger = TrainLogger(every=args.log_every)

    ckpt_fn = None
    if args.ckpt_dir:
        def ckpt_fn(step, params, opt_state):
            ckpt.save(os.path.join(args.ckpt_dir, f"step_{step}"), params, step)

    t0 = time.time()
    params, opt_state, history = train(
        cfg,
        opt,
        iter(data_iter()),
        steps=args.steps,
        seed=args.seed,
        logger=logger,
        checkpoint_fn=ckpt_fn,
        checkpoint_every=args.ckpt_every,
    )
    print(f"done in {time.time()-t0:.1f}s; final loss {history[-1]['loss']:.4f}")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f, indent=1)


if __name__ == "__main__":
    main()
