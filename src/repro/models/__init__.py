"""Model substrate: composable transformer families (dense GQA, MoE, Mamba2
SSD, zamba2 hybrid, sliding-window, enc-dec audio, early-fusion VLM)."""
from . import attention, common, mlp, ssm, transformer
from .transformer import (
    DecodeCache,
    decode_step,
    forward_train,
    init_decode_cache,
    init_params,
    loss_fn,
    param_shapes,
    prefill,
)

__all__ = [
    "attention",
    "common",
    "mlp",
    "ssm",
    "transformer",
    "DecodeCache",
    "decode_step",
    "forward_train",
    "init_decode_cache",
    "init_params",
    "loss_fn",
    "param_shapes",
    "prefill",
]
