"""Attention: GQA with RoPE, causal/sliding-window masks, chunked
(online-softmax) computation for bounded memory, KV-cache decode with ring
buffers for local layers, and cross-attention for the enc-dec arch.

The chunked path is the default "reference" implementation: it never
materializes the (S, S) score matrix (a production necessity at 32k) and is
also the jnp oracle for the Pallas flash kernel (same math, same tiling).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import apply_rope, rms_norm

Array = jax.Array

NEG_INF = -1e30


def init_attn_params(keygen, cfg: ModelConfig, dtype) -> Dict[str, Array]:
    from .common import dense_init, zeros_init

    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "wq": dense_init(keygen(), (d, cfg.n_heads * hd), dtype),
        "wk": dense_init(keygen(), (d, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(keygen(), (d, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(keygen(), (cfg.n_heads * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init(None, (cfg.n_heads * hd,), dtype)
        p["bk"] = zeros_init(None, (cfg.n_kv_heads * hd,), dtype)
        p["bv"] = zeros_init(None, (cfg.n_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = zeros_init(None, (hd,), dtype)
        p["k_norm"] = zeros_init(None, (hd,), dtype)
    return p


def _project_qkv(x: Array, p: Dict[str, Array], cfg: ModelConfig):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _expand_kv(k: Array, n_heads: int) -> Array:
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating each kv head."""
    B, S, KV, hd = k.shape
    rep = n_heads // KV
    return jnp.repeat(k, rep, axis=2) if rep > 1 else k


def chunked_attention(
    q: Array,  # (B, Sq, H, hd)
    k: Array,  # (B, Sk, H, hd)
    v: Array,  # (B, Sk, H, hd)
    q_positions: Array,  # (Sq,)
    k_positions: Array,  # (Sk,)
    causal: bool,
    window: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> Array:
    """Online-softmax blockwise attention; O(chunk^2) temporaries only.

    window > 0 restricts to k_pos > q_pos - window (sliding window).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    # pad to multiples
    pad_q = (-Sq) % q_chunk
    pad_k = (-Sk) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad_q), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pad_k), constant_values=2**30)
    nq, nk = q.shape[1] // q_chunk, k.shape[1] // kv_chunk

    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qs = q.reshape(B, nq, q_chunk, H, hd)
    ks = k.reshape(B, nk, kv_chunk, H, hd)
    vs = v.reshape(B, nk, kv_chunk, H, hd)
    qpos = q_positions.reshape(nq, q_chunk)
    kpos = k_positions.reshape(nk, kv_chunk)

    def q_block(carry_none, qi):
        qb = qs[:, qi]  # (B, qc, H, hd)
        qp = qpos[qi]

        def kv_block(carry, ki):
            m, l, acc = carry
            kb, vb, kp = ks[:, ki], vs[:, ki], kpos[ki]
            s = (
                jnp.einsum("bqhd,bkhd->bhqk", qb, kb, preferred_element_type=jnp.float32)
                * scale
            )
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kp[None, :] <= qp[:, None]
            if window > 0:
                mask &= kp[None, :] > qp[:, None] - window
            # position sentinels are invalid everywhere: -1 marks pad
            # queries AND pad keys (prompt padding), 2**30 marks chunk
            # padding on the key side
            mask &= (qp[:, None] >= 0) & (kp[None, :] >= 0) & (kp[None, :] < 2**30)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32)
            )
            return (m_new, l, acc), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, hd), jnp.float32)
        # remat each kv block: backward recomputes the (qc, kc) score tile
        # instead of saving one per scan step (peak mem = one tile, not nk)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_block), (m0, l0, a0), jnp.arange(nk)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return carry_none, out.astype(q.dtype)

    _, outs = jax.lax.scan(jax.checkpoint(q_block), None, jnp.arange(nq))
    # outs: (nq, B, H, qc, hd) -> (B, Sq, H, hd)
    out = jnp.transpose(outs, (1, 0, 3, 2, 4)).reshape(B, nq * q_chunk, H, hd)
    return out[:, :Sq]


def chunked_attention_parallel_q(
    q: Array,  # (B, Sq, H, hd)
    k: Array,  # (B, Sk, H, hd)
    v: Array,
    q_positions: Array,
    k_positions: Array,
    causal: bool,
    window: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 0,
) -> Array:
    import os
    kv_chunk = kv_chunk or int(os.environ.get("REPRO_KV_CHUNK", "1024"))
    """§Perf variant of chunked_attention: q blocks are INDEPENDENT (no
    carry), so they become a mapped dim shardable over 'model' — prefill
    attention compute/memory then split across the tensor-parallel axis even
    when head counts don't divide it (qwen1.5's 40 heads on a 16-way axis).
    kv blocks stay a sequential scan (bounded memory)."""
    from .common import batch_axes, maybe_shard

    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    pad_q = (-Sq) % q_chunk
    pad_k = (-Sk) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad_q), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pad_k), constant_values=2**30)
    nq, nk = q.shape[1] // q_chunk, k.shape[1] // kv_chunk
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    qs = q.reshape(B, nq, q_chunk, H, hd)
    qs = maybe_shard(qs, batch_axes(), "model", None, None, None)
    ks = k.reshape(B, nk, kv_chunk, H, hd)
    vs = v.reshape(B, nk, kv_chunk, H, hd)
    qpos = q_positions.reshape(nq, q_chunk)
    kpos = k_positions.reshape(nk, kv_chunk)

    def kv_block(carry, ki):
        m, l, acc = carry
        kb, vb, kp = ks[:, ki], vs[:, ki], kpos[ki]
        s = (
            jnp.einsum(
                "bnqhd,bkhd->bnhqk", qs, kb, preferred_element_type=jnp.float32
            )
            * scale
        )  # (B, nq, H, qc, kc)
        mask = jnp.ones((nq, q_chunk, kv_chunk), bool)
        if causal:
            mask &= kp[None, None, :] <= qpos[:, :, None]
        if window > 0:
            mask &= kp[None, None, :] > qpos[:, :, None] - window
        mask &= (
            (qpos[:, :, None] >= 0)
            & (kp[None, None, :] >= 0)
            & (kp[None, None, :] < 2**30)
        )
        s = jnp.where(mask[None, :, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bnhqk,bkhd->bnhqd", p, vb.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((B, nq, H, q_chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, H, q_chunk), jnp.float32)
    a0 = jnp.zeros((B, nq, H, q_chunk, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(kv_block), (m0, l0, a0), jnp.arange(nk)
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)  # (B, nq, H, qc, hd)
    out = jnp.transpose(out, (0, 1, 3, 2, 4)).reshape(
        B, nq * q_chunk, H, hd
    ).astype(q.dtype)
    return out[:, :Sq]


def attention_train(
    x: Array,
    p: Dict[str, Array],
    cfg: ModelConfig,
    positions: Array,  # (S,)
    is_local: Array | bool = False,  # scalar/traced flag for this layer
    causal: bool = True,
    return_kv: bool = False,
):
    """Full-sequence attention for train/prefill. When ``is_local`` is a
    traced flag (scan over mixed local/global layers), both mask variants
    are compiled and selected with lax.cond. ``return_kv`` additionally
    returns the post-RoPE (KV-head) k/v for prefill cache assembly."""
    B, S, _ = x.shape
    q, kkv, vkv = _project_qkv(x, p, cfg)
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions[None, :], cfg.rope_theta)
        kkv = apply_rope(kkv, positions[None, :], cfg.rope_theta)
    k = _expand_kv(kkv, cfg.n_heads)
    v = _expand_kv(vkv, cfg.n_heads)

    attn_fn = (
        chunked_attention_parallel_q
        if cfg.attn_impl == "parallel_q"
        else chunked_attention
    )
    if isinstance(is_local, bool):
        window = cfg.window if (is_local and cfg.window) else 0
        out = attn_fn(q, k, v, positions, positions, causal, window)
    else:
        out = jax.lax.cond(
            is_local,
            lambda ops: attn_fn(*ops, causal, cfg.window),
            lambda ops: attn_fn(*ops, causal, 0),
            (q, k, v, positions, positions),
        )
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    out = out @ p["wo"]
    if return_kv:
        return out, (kkv, vkv)
    return out


def cross_attention_train(
    x: Array,  # decoder stream (B, S, d)
    enc: Array,  # encoder output (B, F, d)
    p: Dict[str, Array],
    cfg: ModelConfig,
) -> Array:
    B, S, _ = x.shape
    F = enc.shape[1]
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (enc @ p["wk"]).reshape(B, F, cfg.n_kv_heads, hd)
    v = (enc @ p["wv"]).reshape(B, F, cfg.n_kv_heads, hd)
    k = _expand_kv(k, cfg.n_heads)
    v = _expand_kv(v, cfg.n_heads)
    pos_q = jnp.arange(S)
    pos_k = jnp.arange(F)
    out = chunked_attention(q, k, v, pos_q, pos_k, causal=False)
    return out.reshape(B, S, cfg.n_heads * hd) @ p["wo"]


def cache_from_kv(
    cfg: ModelConfig,
    k: Array,  # (B, S, KV, hd) post-rope
    v: Array,
    is_local: bool,
    max_len: int,
    positions: Array | None = None,  # (S,) int32; -1 marks pad entries
) -> Dict[str, Array]:
    """Assemble a decode cache from prefill k/v, including ring placement
    for local (sliding-window) layers.

    ``positions`` carries the per-entry absolute positions (default
    ``arange(S)``). Entries with position -1 (prompt padding in a
    length-bucketed prefill) land with ``pos = -1`` so ``attention_decode``
    masks them; real entries keep the slot == position layout the decode
    writer assumes (right-padded prompts only).
    """
    B, S = k.shape[:2]
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    positions = positions.astype(jnp.int32)
    if is_local and cfg.window:
        W = min(cfg.window, max_len)
        valid = positions >= 0
        true_len = jnp.sum(valid.astype(jnp.int32))
        # keep the last W real entries; everything else goes to a dump row
        keep = valid & (positions >= true_len - W)
        slots = jnp.where(keep, positions % W, W)
        ck = jnp.zeros((B, W + 1) + k.shape[2:], k.dtype).at[:, slots].set(k)
        cv = jnp.zeros((B, W + 1) + v.shape[2:], v.dtype).at[:, slots].set(v)
        cpos = (
            jnp.full((B, W + 1), -1, jnp.int32)
            .at[:, slots]
            .set(jnp.where(keep, positions, -1)[None])
        )
        return {"k": ck[:, :W], "v": cv[:, :W], "pos": cpos[:, :W]}
    size = max_len
    ck = jnp.zeros((B, size) + k.shape[2:], k.dtype).at[:, :S].set(k)
    cv = jnp.zeros((B, size) + v.shape[2:], v.dtype).at[:, :S].set(v)
    cpos = jnp.full((B, size), -1, jnp.int32).at[:, :S].set(positions[None])
    return {"k": ck, "v": cv, "pos": cpos}


# ---------------------------------------------------------------------------
# decode (one token) with KV cache
# ---------------------------------------------------------------------------
def init_kv_cache(
    cfg: ModelConfig, batch: int, max_len: int, is_local: bool, dtype
) -> Dict[str, Array]:
    """Cache for one attention layer. Local layers get a ring buffer of
    ``window`` slots (the production memory win at 500k context)."""
    size = min(cfg.window, max_len) if (is_local and cfg.window) else max_len
    shape = (batch, size, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        # absolute position of each slot (for masking); -1 = empty
        "pos": jnp.full((batch, size), -1, jnp.int32),
    }


def attention_decode(
    x: Array,  # (B, 1, d) current token
    cache: Dict[str, Array],
    p: Dict[str, Array],
    cfg: ModelConfig,
    position: Array,  # scalar OR (B,) int32 — current absolute position(s)
    is_local: bool,
) -> Tuple[Array, Dict[str, Array]]:
    """One-token decode. ``position`` may be a scalar (all rows at the same
    position — the classic batched-generation shape) or per-row ``(B,)``
    (slot-table continuous batching, where each sequence is at its own
    decode offset). Writes land at ``slot == position`` per row; masking is
    per-row against the cache's per-slot ``pos`` array."""
    B = x.shape[0]
    hd = cfg.head_dim
    q, k, v = _project_qkv(x, p, cfg)  # (B,1,H,hd), (B,1,KV,hd)
    pos_v = jnp.broadcast_to(position, (B,)).astype(jnp.int32)  # (B,)
    if cfg.rope_theta > 0:
        pos_b = pos_v[:, None]  # (B, 1)
        q = apply_rope(q, pos_b, cfg.rope_theta)
        k = apply_rope(k, pos_b, cfg.rope_theta)

    size = cache["k"].shape[1]
    slot = jnp.where(
        jnp.logical_and(is_local, cfg.window > 0), pos_v % size, pos_v
    ).astype(jnp.int32)
    slot = jnp.minimum(slot, size - 1)  # (B,)
    rows = jnp.arange(B)
    ck = cache["k"].at[rows, slot].set(k[:, 0])
    cv = cache["v"].at[rows, slot].set(v[:, 0])
    cpos = cache["pos"].at[rows, slot].set(pos_v)

    kk = _expand_kv(ck, cfg.n_heads)  # (B, size, H, hd)
    vv = _expand_kv(cv, cfg.n_heads)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = (
        jnp.einsum("bqhd,bkhd->bhqk", q, kk, preferred_element_type=jnp.float32)
        * scale
    )  # (B,H,1,size)
    valid = cpos >= 0
    valid &= cpos <= pos_v[:, None]
    if is_local and cfg.window:
        valid &= cpos > pos_v[:, None] - cfg.window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vv.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, 1, cfg.n_heads * hd)
    return out @ p["wo"], {"k": ck, "v": cv, "pos": cpos}


def cross_attention_decode(
    x: Array,
    enc_kv: Tuple[Array, Array],  # precomputed (B, F, H, hd) expanded k, v
    p: Dict[str, Array],
    cfg: ModelConfig,
) -> Array:
    B = x.shape[0]
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, hd)
    k, v = enc_kv
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(x.dtype).reshape(B, 1, cfg.n_heads * hd) @ p["wo"]
