"""Shared model primitives: norms, activations, RoPE, init helpers."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[
        name
    ]


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def gated_rms_norm(x: Array, gate: Array, scale: Array, eps: float = 1e-6) -> Array:
    """Mamba2-style: RMSNorm(x * silu(gate))."""
    return rms_norm(x * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype), scale, eps)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------
def activation(name: str):
    if name == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "silu":
        return jax.nn.silu
    raise ValueError(f"unknown activation {name}")


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float, dtype=jnp.float32) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=dtype) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., seq, n_heads, head_dim); positions: (..., seq) int32."""
    if theta <= 0.0:
        return x
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# initializers (plain functional params; no flax)
# ---------------------------------------------------------------------------
def dense_init(key: Array, shape, dtype, scale: Optional[float] = None) -> Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: Array, shape, dtype) -> Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype) -> Array:
    return jnp.zeros(shape, dtype)


def maybe_shard(x: Array, *spec) -> Array:
    """with_sharding_constraint that degrades to a no-op when there is no
    mesh context, when an axis name is absent, or when a dimension is not
    divisible by the mesh axes assigned to it. `spec` entries: None, axis
    name, or tuple of axis names."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        sizes = dict(mesh.shape)
    except Exception:
        return x
    cleaned = []
    for dim, s in zip(x.shape, spec):
        if s is None:
            cleaned.append(None)
            continue
        names = s if isinstance(s, tuple) else (s,)
        if not all(n in sizes for n in names):
            cleaned.append(None)
            continue
        total = 1
        for n in names:
            total *= sizes[n]
        cleaned.append(s if dim % total == 0 else None)
    cleaned += [None] * (len(x.shape) - len(cleaned))
    from jax.sharding import PartitionSpec as P

    try:
        return jax.lax.with_sharding_constraint(x, P(*cleaned))
    except Exception:
        return x


def batch_axes() -> tuple:
    """('pod','data') when both exist in the current mesh, else ('data',)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = tuple(mesh.axis_names) if mesh is not None else ()
    except Exception:
        names = ()
    return tuple(a for a in ("pod", "data") if a in names)


class KeyGen:
    """Deterministic key splitter for nested init."""

    def __init__(self, key: Array):
        self._key = key

    def __call__(self) -> Array:
        self._key, sub = jax.random.split(self._key)
        return sub
