"""Feed-forward blocks: dense (SwiGLU / squared-ReLU / GELU) and
Mixture-of-Experts with capacity-based scatter dispatch.

The MoE dispatch is the sort-free cumsum/scatter formulation: positions
within each expert's buffer come from a running count over tokens, dispatch
is a scatter into an (E, C, d) buffer (sharded over experts on the 'model'
mesh axis), expert FFNs run as one batched einsum, and the combine gathers
back with the (renormalized) top-k gates. Tokens beyond capacity are
dropped (standard Switch-style), counted in the aux metrics.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import activation, batch_axes, dense_init, maybe_shard

Array = jax.Array


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------
def init_mlp_params(keygen, cfg: ModelConfig, dtype) -> Dict[str, Array]:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "w_gate": dense_init(keygen(), (d, f), dtype),
            "w_up": dense_init(keygen(), (d, f), dtype),
            "w_down": dense_init(keygen(), (f, d), dtype),
        }
    return {
        "w_up": dense_init(keygen(), (d, f), dtype),
        "w_down": dense_init(keygen(), (f, d), dtype),
    }


def mlp(x: Array, p: Dict[str, Array], cfg: ModelConfig) -> Array:
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = activation(cfg.act)(x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
# Dispatch/combine as custom-VJP gathers: the (token,k) -> (expert,slot)
# assignment is a partial bijection, so BOTH directions of BOTH ops are pure
# gathers. Without this, autodiff turns the forward gathers into backward
# scatter-adds, which the SPMD partitioner replicates (hundreds of GB/device
# at 4k x 256 batch; see docs/DESIGN.md §7).


@jax.custom_vjp
def _moe_dispatch(x, slot_src, e_flat, pos_clip):
    """x (B,S,d); slot_src (B,E,C) int32 in [0,S] (S = empty sentinel).
    Returns buf (B,E,C,d)."""
    B, S, d = x.shape
    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    return jax.vmap(lambda t, i: t[i])(x_pad, slot_src)


def _moe_dispatch_fwd(x, slot_src, e_flat, pos_clip):
    return _moe_dispatch(x, slot_src, e_flat, pos_clip), (
        x.shape,
        e_flat,
        pos_clip,
    )


def _moe_dispatch_bwd(res, g):
    (B, S, d), e_flat, pos_clip = res
    K = e_flat.shape[1] // S
    g_pad = jnp.concatenate([g, jnp.zeros(g.shape[:2] + (1, d), g.dtype)], axis=2)
    # vmapped (batch-dim) gather: keeps the batch dim sharded under SPMD
    gx_rep = jax.vmap(lambda t, e, c: t[e, c])(g_pad, e_flat, pos_clip)
    gx = jnp.sum(gx_rep.reshape(B, S, K, d), axis=2)
    return gx, None, None, None


_moe_dispatch.defvjp(_moe_dispatch_fwd, _moe_dispatch_bwd)


@jax.custom_vjp
def _moe_combine(out_buf, e_flat, pos_clip, slot_sk):
    """out_buf (B,E,C,d) -> y_flat (B,SK,d) via per-token (vmapped) gather."""
    B, E, C, d = out_buf.shape
    out_pad = jnp.concatenate(
        [out_buf, jnp.zeros((B, E, 1, d), out_buf.dtype)], axis=2
    )
    return jax.vmap(lambda t, e, c: t[e, c])(out_pad, e_flat, pos_clip)


def _moe_combine_fwd(out_buf, e_flat, pos_clip, slot_sk):
    return _moe_combine(out_buf, e_flat, pos_clip, slot_sk), (
        out_buf.shape,
        slot_sk,
    )


def _moe_combine_bwd(res, g):
    (B, E, C, d), slot_sk = res
    g_pad = jnp.concatenate([g, jnp.zeros((B, 1, d), g.dtype)], axis=1)
    gbuf = jax.vmap(lambda t, i: t[i])(g_pad, slot_sk)  # (B,E,C,d)
    return gbuf, None, None, None


_moe_combine.defvjp(_moe_combine_fwd, _moe_combine_bwd)
def init_moe_params(keygen, cfg: ModelConfig, dtype) -> Dict[str, Array]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": dense_init(keygen(), (d, e), jnp.float32, scale=0.02),
        "w_gate": dense_init(keygen(), (e, d, f), dtype),
        "w_up": dense_init(keygen(), (e, d, f), dtype),
        "w_down": dense_init(keygen(), (e, f, d), dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_gate"] = dense_init(keygen(), (d, fs), dtype)
        p["shared_up"] = dense_init(keygen(), (d, fs), dtype)
        p["shared_down"] = dense_init(keygen(), (fs, d), dtype)
    return p


def _capacity(group_tokens: int, cfg: ModelConfig) -> int:
    """Per-group expert capacity. Groups are batch rows, so all the
    cumsum/scatter dispatch math stays LOCAL to a data shard; only the
    (B, E, C, d) buffer crosses shards (B over 'data', E over 'model') —
    that resharding is the MoE all-to-all."""
    c = int(group_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    c = max(8, ((c + 7) // 8) * 8)
    return min(c, group_tokens * cfg.top_k)


def moe_ffn(
    x: Array, p: Dict[str, Array], cfg: ModelConfig
) -> Tuple[Array, Dict[str, Array]]:
    """x: (B, S, d). Returns (out, aux). Group-wise (per batch row)
    capacity dispatch; tokens beyond a group's per-expert capacity are
    dropped (Switch-style) and counted in aux."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(S, cfg)

    logits = x.astype(jnp.float32) @ p["router"]  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)  # (B, S, K)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    fe = jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1, 2)) / (
        B * S * K
    )
    aux_loss = E * jnp.sum(fe * me)

    # positions within each group's expert buffers (cumsum local to group)
    e_flat = idx.reshape(B, S * K)  # (B, SK)
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)  # (B, SK, E)
    pos_all = jnp.cumsum(oh, axis=1) - oh
    pos = jnp.sum(pos_all * oh, axis=-1)  # (B, SK)
    dropped = pos >= C
    pos_clip = jnp.where(dropped, C, pos)

    # dispatch WITHOUT moving feature vectors through a scatter: scatter only
    # int32 slot maps (tiny), then custom-VJP gathers move the d-vectors.
    ba = batch_axes()
    src_tok = jnp.broadcast_to(
        (jnp.arange(S * K) // K)[None, :], (B, S * K)
    ).astype(jnp.int32)
    sk_idx = jnp.broadcast_to(
        jnp.arange(S * K, dtype=jnp.int32)[None, :], (B, S * K)
    )

    def _slot_scatter(fill, vals):
        init = jnp.full((E, C + 1), fill, jnp.int32)
        return jax.vmap(
            lambda e, c, v: init.at[e, c].set(v, mode="drop")
        )(e_flat, pos_clip, vals)[:, :, :C]

    slot_src = _slot_scatter(S, src_tok)  # (B, E, C) source token per slot
    slot_sk = _slot_scatter(S * K, sk_idx)  # (B, E, C) source (token,k)
    buf = _moe_dispatch(x, slot_src, e_flat, pos_clip)  # (B, E, C, d)
    buf = maybe_shard(buf, ba, "model", None, None)

    # expert FFNs, batched einsums (experts sharded over 'model')
    if cfg.act == "swiglu":
        h = jax.nn.silu(
            jnp.einsum("becd,edf->becf", buf, p["w_gate"])
        ) * jnp.einsum("becd,edf->becf", buf, p["w_up"])
    else:
        h = activation(cfg.act)(jnp.einsum("becd,edf->becf", buf, p["w_up"]))
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])  # (B, E, C, d)
    out_buf = maybe_shard(out_buf, ba, "model", None, None)

    # combine: gather back per group, weight by gates
    y_flat = _moe_combine(out_buf, e_flat, pos_clip, slot_sk)  # (B, SK, d)
    y_flat = maybe_shard(y_flat, ba, None, None)
    w = (gates.reshape(B, S * K) * (~dropped)).astype(x.dtype)
    y = jnp.sum((y_flat * w[..., None]).reshape(B, S, K, d), axis=2)

    if cfg.n_shared_experts:
        sh = jax.nn.silu(x @ p["shared_gate"]) * (x @ p["shared_up"])
        y = y + sh @ p["shared_down"]

    aux = {
        "aux_loss": aux_loss,
        "drop_frac": jnp.mean(dropped.astype(jnp.float32)),
        "router_entropy": -jnp.mean(jnp.sum(probs * jnp.log(probs + 1e-9), axis=-1)),
    }
    return y, aux
