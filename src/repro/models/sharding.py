"""Partition specs for params, optimizer state, inputs and caches.

Megatron-style tensor parallelism over the 'model' axis:
  * attention q heads / kv heads (when divisible) / wo input heads
  * MLP hidden dim, MoE expert dim, SSM heads & inner dim
  * vocab dim of embed-out / lm_head (logits stay vocab-sharded; the CE
    logsumexp reduces across the shard with a collective)
Data parallelism over 'data' (and 'pod' when present) on the batch dim.
Decode caches shard batch over data when divisible, else sequence
(context parallelism — the long_500k B=1 case).

All leaves are matched by their path names, so any pytree produced by
models/transformer.init_params gets specs without manual bookkeeping.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

MODEL_AXIS = "model"


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _model_size(mesh: Mesh) -> int:
    return mesh.shape.get(MODEL_AXIS, 1)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return axes


def _apply_fsdp(
    spec: P, leaf, mesh: Mesh, fsdp_axes: Tuple[str, ...], name: str = ""
) -> P:
    """ZeRO/FSDP: additionally shard the largest un-sharded dim of every
    >=2D parameter over the data(+pod) axes, if divisible. Params and
    optimizer moments then scale with the full chip count, not just the
    model axis (30B+ dense / 1T MoE configs do not fit otherwise).

    The token embedding is special-cased: the SPMD partitioner mishandles
    the token gather when the vocab dim is FSDP-sharded, so we stack the
    fsdp axes onto the d_model dim instead (gather stays pass-through)."""
    if not fsdp_axes or len(leaf.shape) < 2:
        return spec
    if name == "embed":
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        last = entries[-1]
        cur = () if last is None else (last if isinstance(last, tuple) else (last,))
        total = 1
        for a in cur + fsdp_axes:
            total *= mesh.shape[a]
        if leaf.shape[-1] % total == 0:
            entries[-1] = tuple(cur) + tuple(fsdp_axes)
            return P(*entries)
        return spec
    fsdp_size = 1
    for a in fsdp_axes:
        fsdp_size *= mesh.shape[a]
    entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
    # skip the stacked layer dim (leading) when choosing
    cand = [
        (leaf.shape[i], i)
        for i in range(1 if len(leaf.shape) > 2 else 0, len(leaf.shape))
        if entries[i] is None and leaf.shape[i] % fsdp_size == 0
    ]
    if not cand:
        return spec
    _, dim = max(cand)
    entries[dim] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    return P(*entries)


def _spec_for(path: str, leaf, cfg: ModelConfig, msz: int) -> P:
    """Partition spec for one parameter leaf (path is '/'-joined key names).

    Stacked layer leaves have a leading L (or periods/every) dim -> prepend
    None per extra leading axis relative to the unstacked shape.
    """
    shape = leaf.shape
    nd = len(shape)
    name = path.split("/")[-1]
    M = MODEL_AXIS

    def spec(*tail):
        # left-pad with None for stacked leading dims
        pad = nd - len(tail)
        return P(*((None,) * pad + tail))

    # ---- embeddings / head -------------------------------------------------
    if name == "embed":
        return spec(None, M) if _div(cfg.d_model, msz) else spec(None, None)
    if name in ("lm_head",):
        return spec(None, M) if _div(cfg.vocab_padded, msz) else spec(None, None)
    if name in ("enc_pos", "dec_pos"):
        return spec(None, None)

    # ---- attention ---------------------------------------------------------
    if name == "wq":
        return spec(None, M) if _div(cfg.n_heads, msz) else spec(None, None)
    if name in ("wk", "wv"):
        return spec(None, M) if _div(cfg.n_kv_heads, msz) else spec(None, None)
    if name == "wo":
        return spec(M, None) if _div(cfg.n_heads, msz) else spec(None, None)
    if name == "bq":
        return spec(M) if _div(cfg.n_heads, msz) else spec(None)
    if name in ("bk", "bv"):
        return spec(M) if _div(cfg.n_kv_heads, msz) else spec(None)
    if name in ("q_norm", "k_norm"):
        return spec(None)

    # ---- dense MLP ----------------------------------------------------------
    if name in ("w_gate", "w_up") and nd - (len(shape) - 2) >= 0 and "moe" not in path:
        return spec(None, M) if _div(cfg.d_ff, msz) else spec(None, None)
    if name == "w_down" and "moe" not in path:
        return spec(M, None) if _div(cfg.d_ff, msz) else spec(None, None)

    # ---- MoE ----------------------------------------------------------------
    if "moe" in path:
        if name == "router":
            return spec(None, None)
        if name in ("w_gate", "w_up", "w_down"):
            return spec(M, None, None) if _div(cfg.n_experts, msz) else spec(
                None, None, None
            )
        if name in ("shared_gate", "shared_up"):
            fs = cfg.d_ff * max(cfg.n_shared_experts, 1)
            return spec(None, M) if _div(fs, msz) else spec(None, None)
        if name == "shared_down":
            fs = cfg.d_ff * max(cfg.n_shared_experts, 1)
            return spec(M, None) if _div(fs, msz) else spec(None, None)

    # ---- SSM -----------------------------------------------------------------
    if name in ("w_z", "w_x"):
        return spec(None, M) if _div(cfg.ssm_heads, msz) else spec(None, None)
    if name in ("w_B", "w_C"):
        return spec(None, None)  # g*n small; replicate
    if name == "w_dt":
        return spec(None, M) if _div(cfg.ssm_heads, msz) else spec(None, None)
    if name in ("A_log", "D", "dt_bias"):
        return spec(M) if _div(cfg.ssm_heads, msz) else spec(None)
    if name in ("conv_w", "conv_b"):
        return P(*((None,) * nd))  # small depthwise filters: replicate
    if name == "norm" and nd >= 1:
        return spec(M) if _div(cfg.ssm_heads, msz) and shape[-1] == cfg.d_inner else spec(None)
    if name == "out_proj":
        return spec(M, None) if _div(cfg.ssm_heads, msz) else spec(None, None)

    # ---- norms / defaults ----------------------------------------------------
    return P(*((None,) * nd))


def param_pspecs(
    cfg: ModelConfig, params_shape: Any, mesh: Mesh, mode: str = "serve"
) -> Any:
    """mode='serve': tensor-parallel over 'model' only (decode latency).
    mode='train': additionally FSDP over the data(+pod) axes so params and
    AdamW moments scale with the full chip count."""
    msz = _model_size(mesh)
    fsdp = batch_axes(mesh) if mode == "train" else ()
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    treedef = jax.tree_util.tree_structure(params_shape)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(
            getattr(k, "key", getattr(k, "idx", str(k))).__str__() for k in path
        )
        s = _spec_for(pstr, leaf, cfg, msz)
        s = _apply_fsdp(s, leaf, mesh, fsdp, name=pstr.split("/")[-1])
        specs.append(s)
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(
    cfg: ModelConfig, params_shape: Any, mesh: Mesh, mode: str = "serve"
) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        param_pspecs(cfg, params_shape, mesh, mode),
    )


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------
def train_batch_pspec(mesh: Mesh, global_batch: int) -> P:
    dp = batch_axes(mesh)
    dsz = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if _div(global_batch, dsz):
        return P(dp, None)
    return P(None, dp)  # batch too small: shard sequence instead


def decode_cache_pspec(cfg: ModelConfig, mesh: Mesh, batch: int, kind: str) -> Any:
    """Spec dict for one layer's cache. kind: 'attn'|'local'|'ssm'."""
    dp = batch_axes(mesh)
    msz = _model_size(mesh)
    dsz = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    b_ax = dp if _div(batch, dsz) else None
    s_ax = dp if not _div(batch, dsz) else None  # context parallelism (B=1)
    if kind == "ssm":
        h_ax = MODEL_AXIS if _div(cfg.ssm_heads, msz) else None
        return {
            "state": P(b_ax, h_ax, None, None),
            "conv": P(b_ax, None, None),
        }
    kv_ax = MODEL_AXIS if _div(cfg.n_kv_heads, msz) else None
    hd_ax = (
        MODEL_AXIS if (kv_ax is None and _div(cfg.head_dim, msz)) else None
    )
    return {
        "k": P(b_ax, s_ax, kv_ax, hd_ax),
        "v": P(b_ax, s_ax, kv_ax, hd_ax),
        "pos": P(b_ax, s_ax),
    }
