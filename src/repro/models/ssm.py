"""Mamba2 (SSD — state-space duality) block: chunked training forward with a
log-depth associative inter-chunk scan, and O(1)-state single-token decode.

Chunked SSD (Dao & Gu 2024): for per-step decay a_t = exp(dt_t * A_h) and
input u_t = dt_t * x_t, the state recurrence s_t = a_t s_{t-1} + u_t (x) B_t
is evaluated per chunk of Q steps:
    intra:  Y[t] += sum_{tau<=t} (C_t . B_tau) exp(l_t - l_tau) u_tau
    states: S_c   = sum_tau exp(l_Q - l_tau) u_tau (x) B_tau
    inter:  S_c_prev via associative scan over chunks with
            (a2, S2) o (a1, S1) = (a1*a2, a2*S1 + S2)
    Y[t]  += C_t . (exp(l_t) * S_prev)
where l_t is the within-chunk cumulative log-decay. All in fp32.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import dense_init, gated_rms_norm

Array = jax.Array


def init_ssm_params(keygen, cfg: ModelConfig, dtype) -> Dict[str, Array]:
    """Projections are SEPARATE weights (w_z/w_x/w_B/w_C/w_dt) rather than a
    fused in_proj so each can carry its own tensor-parallel PartitionSpec
    without slicing across segment boundaries (see models/sharding.py)."""
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * g * n
    a_init = jnp.log(
        jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
    )  # A = -exp(A_log) in [-16, -1]
    # dt bias: softplus^-1 of dt0 in [1e-3, 1e-1], log-spaced
    dt0 = jnp.exp(
        jnp.linspace(jnp.log(1e-3), jnp.log(1e-1), h, dtype=jnp.float32)
    )
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    return {
        "w_z": dense_init(keygen(), (d, di), dtype),
        "w_x": dense_init(keygen(), (d, di), dtype),
        "w_B": dense_init(keygen(), (d, g * n), dtype),
        "w_C": dense_init(keygen(), (d, g * n), dtype),
        "w_dt": dense_init(keygen(), (d, h), dtype),
        "conv_w": dense_init(keygen(), (cfg.ssm_conv, conv_ch), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": a_init,
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_bias,
        "norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(keygen(), (di, d), dtype),
    }


def _project(x: Array, p: Dict[str, Array], cfg: ModelConfig):
    """Returns (z, xbc_preconv, dt_raw) with xbc = concat(x, B, C)."""
    z = x @ p["w_z"]
    xbc = jnp.concatenate([x @ p["w_x"], x @ p["w_B"], x @ p["w_C"]], axis=-1)
    dt_raw = x @ p["w_dt"]
    return z, xbc, dt_raw


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv along seq: xbc (B, L, ch), w (K, ch)."""
    K = w.shape[0]
    out = xbc * w[-1]
    for k in range(1, K):
        shifted = jnp.pad(xbc, ((0, 0), (k, 0), (0, 0)))[:, : xbc.shape[1]]
        out = out + shifted * w[K - 1 - k]
    return jax.nn.silu(out + b)


def _broadcast_groups(bc: Array, cfg: ModelConfig) -> Array:
    """(B, L, G, N) -> (B, L, H, N)."""
    h, g = cfg.ssm_heads, cfg.ssm_groups
    if g == h:
        return bc
    return jnp.repeat(bc, h // g, axis=2)


def ssd_chunked(
    x: Array,  # (B, L, H, P) fp32
    dt: Array,  # (B, L, H)    fp32 (post-softplus)
    A: Array,  # (H,)         fp32 (negative)
    Bm: Array,  # (B, L, H, N) fp32
    Cm: Array,  # (B, L, H, N) fp32
    chunk: int,
    initial_state: Optional[Array] = None,  # (B, H, P, N)
) -> Tuple[Array, Array]:
    """Returns (Y (B,L,H,P), final_state (B,H,P,N))."""
    B_, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        z3 = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        x, dt, Bm, Cm = z3(x), z3(dt), z3(Bm), z3(Cm)
    Lp = L + pad
    nc = Lp // Q

    xc = x.reshape(B_, nc, Q, H, P)
    dtc = dt.reshape(B_, nc, Q, H)
    Bc = Bm.reshape(B_, nc, Q, H, N)
    Cc = Cm.reshape(B_, nc, Q, H, N)

    la = dtc * A  # (B, nc, Q, H) log decay per step (<= 0)
    cum = jnp.cumsum(la, axis=2)  # inclusive within-chunk cumulative
    u = xc * dtc[..., None]  # (B, nc, Q, H, P)

    # ---- intra-chunk (quadratic within Q) ---------------------------------
    # M[t, tau] = exp(cum_t - cum_tau), tau <= t
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Qt,Qtau,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bcqhn,bckhn->bcqkh", Cc, Bc)  # (B,nc,Qt,Qtau,H)
    Y = jnp.einsum("bcqkh,bckhp->bcqhp", CB * M, u)

    # ---- per-chunk boundary states ---------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    S_local = jnp.einsum("bcqhn,bcqhp->bchpn", Bc * decay_to_end[..., None], u)
    a_tot = jnp.exp(cum[:, :, -1, :])  # (B, nc, H)

    # ---- inter-chunk associative scan -------------------------------------
    def combine(left, right):
        a1, s1 = left
        a2, s2 = right
        return a1 * a2, a2[..., None, None] * s1 + s2

    a_inc, S_inc = jax.lax.associative_scan(combine, (a_tot, S_local), axis=1)
    # train/prefill always start from S0 = 0 (decode carries state instead)
    assert initial_state is None, "chunked SSD starts from zero state"
    S0 = jnp.zeros((B_, H, P, N), x.dtype)
    S_prev = jnp.concatenate([S0[:, None], S_inc[:, :-1]], axis=1)

    Y = Y + jnp.einsum(
        "bcqhn,bchpn->bcqhp", Cc * jnp.exp(cum)[..., None], S_prev
    )
    final_state = S_inc[:, -1]
    Y = Y.reshape(B_, Lp, H, P)[:, :L]
    return Y, final_state


def ssm_block_train(
    x: Array,  # (B, L, d_model)
    p: Dict[str, Array],
    cfg: ModelConfig,
) -> Tuple[Array, Array, Array]:
    """Returns (out (B,L,d), final_state, final_conv_window)."""
    B, L, _ = x.shape
    h, n, g, di = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups, cfg.d_inner
    P = cfg.ssm_head_dim

    z, xbc, dt_raw = _project(x, p, cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di].astype(jnp.float32).reshape(B, L, h, P)
    Bm = xbc[..., di : di + g * n].astype(jnp.float32).reshape(B, L, g, n)
    Cm = xbc[..., di + g * n :].astype(jnp.float32).reshape(B, L, g, n)
    Bm, Cm = _broadcast_groups(Bm, cfg), _broadcast_groups(Cm, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    Y, state = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    Y = Y + xs * p["D"][None, None, :, None]
    y = Y.reshape(B, L, di).astype(x.dtype)
    y = gated_rms_norm(y, z, p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    conv_window = xbc_raw_tail(x, p, cfg)  # last K-1 pre-activation inputs
    return out, state, conv_window


def xbc_raw_tail(x: Array, p: Dict[str, Array], cfg: ModelConfig) -> Array:
    """Last (K-1) pre-conv xbc inputs — the decode conv state.

    Prompts shorter than the conv receptive field are left-padded with
    zeros, matching ``_causal_conv``'s implicit zero history (the
    projections are bias-free, so zero inputs give zero xbc rows): the
    cache keeps its fixed (B, K-1, conv_ch) shape for any prompt length.
    """
    K = cfg.ssm_conv
    L = x.shape[1]
    if L < K - 1:
        x = jnp.pad(x, ((0, 0), (K - 1 - L, 0), (0, 0)))
    _, xbc, _ = _project(x[:, -(K - 1) :], p, cfg)
    return xbc  # (B, K-1, conv_ch)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Array]:
    h, n = cfg.ssm_heads, cfg.ssm_state
    P = cfg.ssm_head_dim
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "state": jnp.zeros((batch, h, P, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }


def ssm_block_decode(
    x: Array,  # (B, 1, d_model)
    cache: Dict[str, Array],
    p: Dict[str, Array],
    cfg: ModelConfig,
) -> Tuple[Array, Dict[str, Array]]:
    B = x.shape[0]
    h, n, g, di = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups, cfg.d_inner
    P = cfg.ssm_head_dim

    z, xbc_t, dt_raw = _project(x[:, 0], p, cfg)
    window = jnp.concatenate([cache["conv"], xbc_t[:, None]], axis=1)  # (B,K,ch)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)

    xs = xbc[..., :di].astype(jnp.float32).reshape(B, h, P)
    Bm = xbc[..., di : di + g * n].astype(jnp.float32).reshape(B, g, n)
    Cm = xbc[..., di + g * n :].astype(jnp.float32).reshape(B, g, n)
    if g != h:
        Bm = jnp.repeat(Bm, h // g, axis=1)
        Cm = jnp.repeat(Cm, h // g, axis=1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B, h)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)  # (B, h)

    u = xs * dt[..., None]  # (B, h, P)
    s_new = a[..., None, None] * cache["state"] + jnp.einsum("bhp,bhn->bhpn", u, Bm)
    y = jnp.einsum("bhn,bhpn->bhp", Cm, s_new) + xs * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = gated_rms_norm(y, z[:, None], p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, {"state": s_new, "conv": window[:, 1:]}
