"""Composable decoder-only transformer covering dense / MoE / SSM / hybrid /
VLM families, with scan-over-layers (stacked params) for train/prefill and a
per-layer Python loop (heterogeneous caches) for decode.

Entry points:
    init_params(cfg, key)                  -> param pytree (or eval_shape)
    forward_train(cfg, params, tokens)     -> (logits, aux)
    loss_fn(cfg, params, batch)            -> (loss, metrics)
    prefill(cfg, params, tokens)           -> (last_logits, DecodeCache)
    decode_step(cfg, params, token, cache) -> (logits, DecodeCache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention as attn_mod
from . import mlp as mlp_mod
from . import ssm as ssm_mod
from .common import (
    KeyGen,
    batch_axes,
    dense_init,
    dtype_of,
    embed_init,
    maybe_shard,
    rms_norm,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_one_layer(cfg: ModelConfig, key: Array, dtype) -> Dict[str, Any]:
    kg = KeyGen(key)
    p: Dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.arch_type in ("ssm", "hybrid"):
        p["ssm"] = ssm_mod.init_ssm_params(kg, cfg, dtype)
        return p
    p["attn"] = attn_mod.init_attn_params(kg, cfg, dtype)
    p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.arch_type == "moe":
        p["moe"] = mlp_mod.init_moe_params(kg, cfg, dtype)
    else:
        p["mlp"] = mlp_mod.init_mlp_params(kg, cfg, dtype)
    return p


def _init_shared_block(cfg: ModelConfig, key: Array, dtype) -> Dict[str, Any]:
    """zamba2-style shared attention+MLP block (one copy, applied every k)."""
    kg = KeyGen(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn_mod.init_attn_params(kg, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": mlp_mod.init_mlp_params(
            kg, dataclasses.replace(cfg, act="swiglu"), dtype
        ),
    }


def init_params(cfg: ModelConfig, key: Array) -> Dict[str, Any]:
    dtype = dtype_of(cfg.dtype)
    kg = KeyGen(key)
    Vp, d = cfg.vocab_padded, cfg.d_model
    params: Dict[str, Any] = {
        "embed": embed_init(kg(), (Vp, d), dtype),
        "final_norm": jnp.zeros((d,), dtype),
        "lm_head": dense_init(kg(), (d, Vp), dtype),
    }
    L = cfg.n_layers
    layer_keys = jax.random.split(kg(), L)
    params["layers"] = jax.vmap(
        lambda k: _init_one_layer(cfg, k, dtype)
    )(layer_keys)
    if cfg.arch_type == "hybrid":
        params["shared"] = _init_shared_block(cfg, kg(), dtype)
    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(kg(), cfg.n_enc_layers)
        enc_cfg = dataclasses.replace(cfg, arch_type="dense")
        params["enc_layers"] = jax.vmap(
            lambda k: _init_one_layer(enc_cfg, k, dtype)
        )(enc_keys)
        params["enc_norm"] = jnp.zeros((d,), dtype)
        params["enc_pos"] = embed_init(kg(), (cfg.enc_frames, d), dtype)
        params["dec_pos"] = embed_init(kg(), (8192, d), dtype)
        # decoder cross-attention params per layer
        params["cross_layers"] = jax.vmap(
            lambda k: {
                "ln": jnp.zeros((d,), dtype),
                "attn": attn_mod.init_attn_params(KeyGen(k), cfg, dtype),
            }
        )(jax.random.split(kg(), L))
    return params


def param_shapes(cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct pytree without allocating (for the dry-run)."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------
def _dense_block(
    cfg: ModelConfig,
    lp: Dict[str, Any],
    h: Array,
    positions: Array,
    is_local,
    collect: bool = False,
):
    h = maybe_shard(h, batch_axes(), None, None)
    att = attn_mod.attention_train(
        rms_norm(h, lp["ln1"], cfg.norm_eps),
        lp["attn"],
        cfg,
        positions,
        is_local,
        return_kv=collect,
    )
    if collect:
        att, kv = att
    h = h + att
    x2 = rms_norm(h, lp["ln2"], cfg.norm_eps)
    if cfg.arch_type == "moe":
        y, aux = mlp_mod.moe_ffn(x2, lp["moe"], cfg)
        h, aux_l = h + y, aux["aux_loss"]
    else:
        h, aux_l = h + mlp_mod.mlp(x2, lp["mlp"], cfg), jnp.float32(0.0)
    if collect:
        return h, aux_l, kv
    return h, aux_l


def _ssm_block(cfg: ModelConfig, lp, h: Array, collect: bool = False):
    h = maybe_shard(h, batch_axes(), None, None)
    out, state, conv = ssm_mod.ssm_block_train(
        rms_norm(h, lp["ln1"], cfg.norm_eps), lp["ssm"], cfg
    )
    if collect:
        return h + out, (state, conv)
    return h + out


def _shared_block(
    cfg: ModelConfig, sp, h: Array, positions: Array, collect: bool = False
):
    att = attn_mod.attention_train(
        rms_norm(h, sp["ln1"], cfg.norm_eps),
        sp["attn"],
        cfg,
        positions,
        False,
        return_kv=collect,
    )
    kv = None
    if collect:
        att, kv = att
    h = h + att
    swi = dataclasses.replace(cfg, act="swiglu")
    h = h + mlp_mod.mlp(rms_norm(h, sp["ln2"], cfg.norm_eps), sp["mlp"], swi)
    if collect:
        return h, kv
    return h


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable) if cfg.remat else fn


def _scan_layers(
    cfg: ModelConfig, params, h: Array, positions: Array, collect: bool = False
):
    """Returns (h, total_aux_loss, collected-or-None).

    ``collect=True`` (prefill) additionally stacks per-layer cache material:
    (k, v) for attention layers, (state, conv) for SSM layers, and the
    shared-block k/v per period for hybrids.
    """
    kinds = cfg.layer_kinds()

    if cfg.arch_type == "hybrid":
        every = cfg.hybrid_attn_every
        periods = cfg.n_layers // every
        stacked = jax.tree.map(
            lambda a: a.reshape((periods, every) + a.shape[1:]), params["layers"]
        )
        sp = params["shared"]

        def period_body(hh, plp):
            def inner(hh2, lp):
                if collect:
                    hh2, sc = _ssm_block(cfg, lp, hh2, collect=True)
                    return hh2, sc
                return _ssm_block(cfg, lp, hh2), None

            hh, inner_ys = jax.lax.scan(inner, hh, plp)
            if collect:
                hh, skv = _shared_block(cfg, sp, hh, positions, collect=True)
                return hh, (inner_ys, skv)
            hh = _shared_block(cfg, sp, hh, positions)
            return hh, None

        body = _maybe_remat(period_body, cfg)
        h, ys = jax.lax.scan(body, h, stacked)
        return h, jnp.float32(0.0), ys

    if cfg.arch_type == "ssm":

        def body(hh, lp):
            if collect:
                return _ssm_block(cfg, lp, hh, collect=True)
            return _ssm_block(cfg, lp, hh), None

        h, ys = jax.lax.scan(_maybe_remat(body, cfg), h, params["layers"])
        return h, jnp.float32(0.0), ys

    # dense / moe / vlm / audio-decoder: attention blocks, maybe local/global
    is_local_flags = jnp.asarray([k == "local" for k in kinds], bool)

    def body(hh, xs):
        lp, flag = xs
        flag_arg = flag if cfg.local_ratio > 0 else False
        if collect:
            hh, aux, kv = _dense_block(cfg, lp, hh, positions, flag_arg, collect=True)
            return hh, (aux, kv)
        hh, aux = _dense_block(cfg, lp, hh, positions, flag_arg)
        return hh, aux

    h, ys = jax.lax.scan(
        _maybe_remat(body, cfg), h, (params["layers"], is_local_flags)
    )
    if collect:
        auxs, kvs = ys
        return h, jnp.sum(auxs), kvs
    return h, jnp.sum(ys), None


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------
def encode_audio(cfg: ModelConfig, params, frames: Array) -> Array:
    """Whisper-style encoder over stub frame embeddings (B, F, d)."""
    F = frames.shape[1]
    h = frames + params["enc_pos"][None, :F]
    positions = jnp.arange(F)

    def body(hh, lp):
        hh = hh + attn_mod.attention_train(
            rms_norm(hh, lp["ln1"], cfg.norm_eps),
            lp["attn"],
            cfg,
            positions,
            False,
            causal=False,
        )
        hh = hh + mlp_mod.mlp(rms_norm(hh, lp["ln2"], cfg.norm_eps), lp["mlp"], cfg)
        return hh, None

    h, _ = jax.lax.scan(_maybe_remat(body, cfg), h, params["enc_layers"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def forward_train(
    cfg: ModelConfig,
    params,
    tokens: Array,  # (B, S)
    side: Optional[Array] = None,  # audio frames (B, F, d) for enc-dec
) -> Tuple[Array, Dict[str, Array]]:
    B, S = tokens.shape
    h = params["embed"][tokens]
    h = maybe_shard(h, batch_axes(), None, None)
    positions = jnp.arange(S)

    if cfg.is_encoder_decoder:
        assert side is not None, "enc-dec arch needs encoder frames"
        enc = encode_audio(cfg, params, side)
        # positions beyond the learned table wrap (structural support for
        # the 32k decode shapes; the real model caps at 448)
        h = h + params["dec_pos"][jnp.arange(S) % params["dec_pos"].shape[0]][None]

        def body(hh, xs):
            lp, cp = xs
            hh, _ = _dense_block(cfg, lp, hh, positions, False)
            hh = hh + attn_mod.cross_attention_train(
                rms_norm(hh, cp["ln"], cfg.norm_eps), enc, cp["attn"], cfg
            )
            return hh, None

        h, _ = jax.lax.scan(
            _maybe_remat(body, cfg), h, (params["layers"], params["cross_layers"])
        )
        aux_loss = jnp.float32(0.0)
    else:
        h, aux_loss, _ = _scan_layers(cfg, params, h, positions)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"]
    return logits, {"aux_loss": aux_loss}


def loss_fn(
    cfg: ModelConfig, params, batch: Dict[str, Array]
) -> Tuple[Array, Dict[str, Array]]:
    logits, aux = forward_train(cfg, params, batch["tokens"], batch.get("frames"))
    logits = logits.astype(jnp.float32)
    labels = jnp.clip(batch["labels"], 0, cfg.vocab_padded - 1)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + cfg.router_aux_coef * aux["aux_loss"]
    return total, {"ce": loss, "aux_loss": aux["aux_loss"]}


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DecodeCache:
    layers: List[Dict[str, Array]]  # per-layer kv / ssm caches
    position: Array  # scalar int32 — next position to write
    shared: Optional[List[Dict[str, Array]]] = None  # hybrid shared-attn caches
    cross: Optional[List[Tuple[Array, Array]]] = None  # enc-dec cross k/v

    def tree_flatten(self):
        return (self.layers, self.position, self.shared, self.cross), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def uniform_layers(cfg: ModelConfig) -> bool:
    """True when every layer has the same block kind and cache shape, so
    decode can lax.scan over stacked caches (compile-time/HLO-size win for
    deep models; heterogeneous archs use the per-layer Python loop)."""
    return (
        cfg.arch_type in ("dense", "moe", "ssm", "vlm")
        and cfg.local_ratio == 0
        and not cfg.is_encoder_decoder
    )


def init_decode_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=None
) -> DecodeCache:
    dtype = dtype or dtype_of(cfg.dtype)
    kinds = cfg.layer_kinds()
    if uniform_layers(cfg):
        if cfg.arch_type == "ssm":
            one = ssm_mod.init_ssm_cache(cfg, batch, dtype)
        else:
            one = attn_mod.init_kv_cache(cfg, batch, max_len, False, dtype)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one
        )
        return DecodeCache(stacked, jnp.zeros((), jnp.int32), None, None)
    layers = []
    for i, k in enumerate(kinds):
        if k == "ssm":
            layers.append(ssm_mod.init_ssm_cache(cfg, batch, dtype))
        else:
            layers.append(
                attn_mod.init_kv_cache(cfg, batch, max_len, k == "local", dtype)
            )
    shared = None
    if cfg.arch_type == "hybrid":
        periods = cfg.n_layers // cfg.hybrid_attn_every
        shared = [
            attn_mod.init_kv_cache(cfg, batch, max_len, False, dtype)
            for _ in range(periods)
        ]
    cross = None
    if cfg.is_encoder_decoder:
        cross = [
            (
                jnp.zeros((batch, cfg.enc_frames, cfg.n_heads, cfg.head_dim), dtype),
                jnp.zeros((batch, cfg.enc_frames, cfg.n_heads, cfg.head_dim), dtype),
            )
            for _ in range(cfg.n_layers)
        ]
    return DecodeCache(layers, jnp.zeros((), jnp.int32), shared, cross)


def _layer_params_at(params, i: int):
    return jax.tree.map(lambda a: a[i], params["layers"])


def _decode_step_scanned(
    cfg: ModelConfig, params, token: Array, cache: DecodeCache
) -> Tuple[Array, DecodeCache]:
    """Uniform-arch decode via lax.scan over stacked layer caches."""
    pos = cache.position
    h = params["embed"][token][:, None, :]

    def body(hh, xs):
        lp, lc = xs
        if cfg.arch_type == "ssm":
            out, new_c = ssm_mod.ssm_block_decode(
                rms_norm(hh, lp["ln1"], cfg.norm_eps), lc, lp["ssm"], cfg
            )
            return hh + out, new_c
        out, new_c = attn_mod.attention_decode(
            rms_norm(hh, lp["ln1"], cfg.norm_eps), lc, lp["attn"], cfg, pos, False
        )
        hh = hh + out
        x2 = rms_norm(hh, lp["ln2"], cfg.norm_eps)
        if cfg.arch_type == "moe":
            y, _ = mlp_mod.moe_ffn(x2, lp["moe"], cfg)
            hh = hh + y
        else:
            hh = hh + mlp_mod.mlp(x2, lp["mlp"], cfg)
        return hh, new_c

    h, new_layers = jax.lax.scan(body, h, (params["layers"], cache.layers))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = (h @ params["lm_head"])[:, 0]
    return logits, DecodeCache(new_layers, pos + 1, None, None)


def decode_step(
    cfg: ModelConfig, params, token: Array, cache: DecodeCache
) -> Tuple[Array, DecodeCache]:
    """One-token decode. token: (B,) int32. Returns (logits (B, Vp), cache).

    ``cache.position`` may be a scalar (whole batch at one offset) or a
    per-row ``(B,)`` vector (slot-table continuous batching)."""
    if uniform_layers(cfg) and isinstance(cache.layers, dict):
        return _decode_step_scanned(cfg, params, token, cache)
    B = token.shape[0]
    pos = cache.position
    h = params["embed"][token][:, None, :]  # (B, 1, d)
    if cfg.is_encoder_decoder:
        p_idx = pos % params["dec_pos"].shape[0]
        pe = params["dec_pos"][p_idx]  # (d,) scalar pos | (B, d) per-row
        h = h + (pe[None, None] if pe.ndim == 1 else pe[:, None])

    kinds = cfg.layer_kinds()
    new_layers: List[Dict[str, Array]] = []
    new_shared = list(cache.shared) if cache.shared is not None else None
    period = cfg.hybrid_attn_every or 0

    for i, kind in enumerate(kinds):
        lp = _layer_params_at(params, i)
        if kind == "ssm":
            out, new_c = ssm_mod.ssm_block_decode(
                rms_norm(h, lp["ln1"], cfg.norm_eps), cache.layers[i], lp["ssm"], cfg
            )
            h = h + out
        else:
            out, new_c = attn_mod.attention_decode(
                rms_norm(h, lp["ln1"], cfg.norm_eps),
                cache.layers[i],
                lp["attn"],
                cfg,
                pos,
                kind == "local",
            )
            h = h + out
            x2 = rms_norm(h, lp["ln2"], cfg.norm_eps)
            if cfg.arch_type == "moe":
                y, _ = mlp_mod.moe_ffn(x2, lp["moe"], cfg)
                h = h + y
            else:
                h = h + mlp_mod.mlp(x2, lp["mlp"], cfg)
        new_layers.append(new_c)

        if cfg.is_encoder_decoder:
            cp = jax.tree.map(lambda a: a[i], params["cross_layers"])
            h = h + attn_mod.cross_attention_decode(
                rms_norm(h, cp["ln"], cfg.norm_eps), cache.cross[i], cp["attn"], cfg
            )

        # hybrid: shared attention block after every `period` ssm layers
        if cfg.arch_type == "hybrid" and period and (i + 1) % period == 0:
            pidx = (i + 1) // period - 1
            sp = params["shared"]
            out, sc = attn_mod.attention_decode(
                rms_norm(h, sp["ln1"], cfg.norm_eps),
                cache.shared[pidx],
                sp["attn"],
                cfg,
                pos,
                False,
            )
            h = h + out
            swi = dataclasses.replace(cfg, act="swiglu")
            h = h + mlp_mod.mlp(rms_norm(h, sp["ln2"], cfg.norm_eps), sp["mlp"], swi)
            new_shared[pidx] = sc

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = (h @ params["lm_head"])[:, 0]
    new_cache = DecodeCache(new_layers, pos + 1, new_shared, cache.cross)
    return logits, new_cache


def prefill(
    cfg: ModelConfig,
    params,
    tokens: Array,
    side: Optional[Array] = None,
    extra_len: int = 1024,
    true_len: Optional[Array] = None,
) -> Tuple[Array, DecodeCache]:
    """Run the full prompt, return last-position logits + a FILLED cache
    (k/v collected from the layer scan; ring placement for local layers;
    SSD final states for SSM layers). Consistency with decode_step is
    covered by tests/test_serve.py.

    ``true_len`` (traced scalar int32) marks a RIGHT-padded prompt: only
    ``tokens[:, :true_len]`` are real, the tail is bucket padding. Pad
    positions are set to -1 so attention masks them on both the query and
    key side (``chunked_attention``) and their cache slots stay invalid
    (``pos = -1``) for decode; the returned logits are taken at
    ``true_len - 1`` and ``cache.position`` starts at ``true_len``. The
    executable is shape-keyed by the BUCKET length, so one compiled
    prefill serves every true length in its bucket. Only attention
    architectures support it: an SSM/hybrid state scan or the enc-dec
    decoder cannot skip pad steps, so those archs must prefill at exact
    length (``true_len=None``).
    """
    B, S = tokens.shape
    max_len = S + extra_len
    dtype = dtype_of(cfg.dtype)
    if true_len is not None and (
        cfg.arch_type in ("ssm", "hybrid") or cfg.is_encoder_decoder
    ):
        raise ValueError(
            "true_len (pad-masked bucketed prefill) is only supported for "
            f"attention architectures, not arch_type={cfg.arch_type!r} / "
            "encoder-decoder; prefill those at exact length"
        )
    h = params["embed"][tokens]
    h = maybe_shard(h, batch_axes(), None, None)
    if true_len is None:
        positions = jnp.arange(S)
    else:
        positions = jnp.where(jnp.arange(S) < true_len, jnp.arange(S), -1)
    kinds = cfg.layer_kinds()

    if cfg.is_encoder_decoder:
        assert side is not None
        enc = encode_audio(cfg, params, side)
        # positions beyond the learned table wrap (structural support for
        # the 32k decode shapes; the real model caps at 448)
        h = h + params["dec_pos"][jnp.arange(S) % params["dec_pos"].shape[0]][None]

        def body(hh, xs):
            lp, cp = xs
            hh, _, kv = _dense_block(cfg, lp, hh, positions, False, collect=True)
            hh = hh + attn_mod.cross_attention_train(
                rms_norm(hh, cp["ln"], cfg.norm_eps), enc, cp["attn"], cfg
            )
            return hh, kv

        h, kvs = jax.lax.scan(body, h, (params["layers"], params["cross_layers"]))
        layers = [
            attn_mod.cache_from_kv(cfg, kvs[0][i], kvs[1][i], False, max_len)
            for i in range(cfg.n_layers)
        ]
        cross = []
        hd = cfg.head_dim
        F = enc.shape[1]
        for i in range(cfg.n_layers):
            cp = jax.tree.map(lambda a: a[i], params["cross_layers"])
            ck = (enc @ cp["attn"]["wk"]).reshape(B, F, cfg.n_kv_heads, hd)
            cv = (enc @ cp["attn"]["wv"]).reshape(B, F, cfg.n_kv_heads, hd)
            ck = attn_mod._expand_kv(ck, cfg.n_heads)
            cv = attn_mod._expand_kv(cv, cfg.n_heads)
            cross.append((ck, cv))
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = h @ params["lm_head"]
        cache = DecodeCache(layers, jnp.asarray(S, jnp.int32), None, cross)
        return logits[:, -1], cache

    h, _, collected = _scan_layers(cfg, params, h, positions, collect=True)

    if true_len is None:
        next_pos = jnp.asarray(S, jnp.int32)
        last_of = lambda logits: logits[:, -1]
    else:
        next_pos = jnp.asarray(true_len, jnp.int32)
        last_of = lambda logits: logits[jnp.arange(B), next_pos - 1]

    if uniform_layers(cfg):
        if cfg.arch_type == "ssm":
            states, convs = collected
            stacked = {"state": states, "conv": convs}
        else:
            k_all, v_all = collected
            stacked = jax.vmap(
                lambda k, v: attn_mod.cache_from_kv(
                    cfg, k, v, False, max_len, positions=positions
                )
            )(k_all, v_all)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = h @ params["lm_head"]
        return last_of(logits), DecodeCache(stacked, next_pos, None, None)

    layers: List[Dict[str, Array]] = []
    shared = None
    if cfg.arch_type == "hybrid":
        (states, convs), (sk, sv) = collected  # (periods, every, ...) / (periods, ...)
        every = cfg.hybrid_attn_every
        periods = cfg.n_layers // every
        for pi in range(periods):
            for li in range(every):
                layers.append({"state": states[pi, li], "conv": convs[pi, li]})
        shared = [
            attn_mod.cache_from_kv(cfg, sk[pi], sv[pi], False, max_len)
            for pi in range(periods)
        ]
    elif cfg.arch_type == "ssm":
        states, convs = collected
        for i in range(cfg.n_layers):
            layers.append({"state": states[i], "conv": convs[i]})
    else:
        k_all, v_all = collected
        for i, kind in enumerate(kinds):
            layers.append(
                attn_mod.cache_from_kv(
                    cfg, k_all[i], v_all[i], kind == "local", max_len,
                    positions=positions,
                )
            )

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"]
    cache = DecodeCache(layers, next_pos, shared, None)
    return last_of(logits), cache
