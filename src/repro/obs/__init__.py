"""Unified observability: span tracing, metrics registry, exporters.

One cross-cutting layer over the training transports and the serving
fleet (DESIGN.md §14):

  * ``obs.trace`` — a low-overhead, thread-safe span tracer.
    ``span("commit", worker=g)`` context managers nest naturally per
    thread, land in a process-wide ring buffer, and export as
    Chrome-trace JSON (``export_chrome``) so a whole async run or fleet
    sim loads in ``chrome://tracing`` / Perfetto.
  * ``obs.metrics`` — named counters / gauges / histograms with label
    sets behind one process-wide registry, plus bridges that absorb the
    pre-existing ad-hoc telemetry (transport ``wire_stats`` dicts,
    ``serve.metrics.ServingMetrics``) into the same schema
    (``repro_<layer>_<name>`` naming).
  * ``obs.export`` — Prometheus text format (optionally served by a tiny
    stdlib HTTP handler) and periodic JSONL snapshots.

Tracing is OFF by default and must stay nearly free when off: ``span``
costs one global flag check and a no-op context manager
(``benchmarks/bench_obs.py`` measures the bound CI enforces).  Metrics
are always recordable — the registry is just dicts behind a lock — but
nothing publishes into it unless an instrumented layer runs.

    from repro import obs

    obs.enable()
    ... run something instrumented ...
    obs.export_chrome("trace.json")       # load in chrome://tracing
    print(obs.to_prometheus())            # scrapeable text format
    obs.disable()
"""
from .trace import (  # noqa: F401
    Tracer,
    disable,
    enable,
    enabled,
    export_chrome,
    get_tracer,
    phase_breakdown,
    set_clock,
    span,
)
from .metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    publish_serving_metrics,
    publish_staleness,
    publish_wire_stats,
)
from .export import (  # noqa: F401
    JsonlExporter,
    MetricsHTTPServer,
    to_prometheus,
)

__all__ = [
    "Tracer",
    "span",
    "enable",
    "disable",
    "enabled",
    "set_clock",
    "get_tracer",
    "export_chrome",
    "phase_breakdown",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "publish_wire_stats",
    "publish_serving_metrics",
    "publish_staleness",
    "to_prometheus",
    "MetricsHTTPServer",
    "JsonlExporter",
]
