"""Exporters for the metrics registry: Prometheus text + JSONL snapshots.

Two consumption paths (DESIGN.md §14):

  * ``to_prometheus(registry)`` renders the standard text exposition
    format (``# HELP`` / ``# TYPE`` / labeled series; histograms as
    cumulative ``_bucket{le=...}`` plus ``_sum`` / ``_count``), and
    ``MetricsHTTPServer`` serves it at ``/metrics`` from a stdlib
    ``http.server`` daemon thread — enough for a local Prometheus scrape
    or a ``curl`` during a long run; no third-party client library.
  * ``JsonlExporter`` appends full registry snapshots (the
    ``MetricsRegistry.as_dict`` shape plus a timestamp) to a ``.jsonl``
    file — one line per snapshot, either on demand (``snapshot()``) or
    periodically from a background thread (``start(interval_s)``).
"""
from __future__ import annotations

import json
import math
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from .metrics import Histogram, MetricsRegistry, get_registry

__all__ = ["to_prometheus", "MetricsHTTPServer", "JsonlExporter"]


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _escape(s: str) -> str:
    return s.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _label_str(labels: dict, extra: Optional[dict] = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    inner = ",".join(f'{k}="{_escape(str(v))}"' for k, v in items.items())
    return "{" + inner + "}"


def to_prometheus(registry: Optional[MetricsRegistry] = None) -> str:
    """Render the registry in the Prometheus text exposition format."""
    reg = registry if registry is not None else get_registry()
    lines = []
    for m in reg.collect():
        if m.help:
            lines.append(f"# HELP {m.name} {_escape(m.help)}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        if isinstance(m, Histogram):
            bounds = list(m.buckets) + [math.inf]
            for labels, st in m.series():
                cum = 0
                for bound, c in zip(bounds, st.counts):
                    cum += c
                    le = _label_str(labels, {"le": _fmt_value(bound)})
                    lines.append(f"{m.name}_bucket{le} {cum}")
                lines.append(
                    f"{m.name}_sum{_label_str(labels)} {_fmt_value(st.sum)}"
                )
                lines.append(
                    f"{m.name}_count{_label_str(labels)} {st.count}"
                )
        else:
            for labels, v in m.series():
                lines.append(
                    f"{m.name}{_label_str(labels)} {_fmt_value(float(v))}"
                )
    return "\n".join(lines) + "\n"


class MetricsHTTPServer:
    """Tiny stdlib HTTP endpoint serving ``to_prometheus`` at ``/metrics``.

        srv = MetricsHTTPServer(port=0)   # 0 = pick a free port
        srv.start()
        ... curl http://localhost:{srv.port}/metrics ...
        srv.stop()
    """

    def __init__(
        self,
        port: int = 9464,
        host: str = "127.0.0.1",
        registry: Optional[MetricsRegistry] = None,
    ):
        self._registry = registry
        self._addr = (host, port)
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    @property
    def port(self) -> int:
        if self._httpd is None:
            raise RuntimeError("server not started")
        return self._httpd.server_address[1]

    def start(self) -> "MetricsHTTPServer":
        registry = self._registry

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (stdlib handler name)
                if self.path.rstrip("/") not in ("", "/metrics"):
                    self.send_error(404)
                    return
                body = to_prometheus(registry).encode()
                self.send_response(200)
                self.send_header(
                    "Content-Type", "text/plain; version=0.0.4; charset=utf-8"
                )
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # keep scrapes out of stderr
                pass

        self._httpd = ThreadingHTTPServer(self._addr, _Handler)
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name="obs-metrics-http",
            daemon=True,
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "MetricsHTTPServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()


class JsonlExporter:
    """Append registry snapshots to a JSONL file, one JSON object per
    line: ``{"t": <unix seconds>, "metrics": <registry.as_dict()>}``."""

    def __init__(
        self,
        path: str,
        registry: Optional[MetricsRegistry] = None,
        clock=time.time,
    ):
        self.path = path
        self._registry = registry
        self._clock = clock
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.n_snapshots = 0

    def snapshot(self) -> dict:
        reg = self._registry if self._registry is not None else get_registry()
        rec = {"t": self._clock(), "metrics": reg.as_dict()}
        with open(self.path, "a") as f:
            f.write(json.dumps(rec) + "\n")
        self.n_snapshots += 1
        return rec

    def start(self, interval_s: float = 15.0) -> "JsonlExporter":
        if self._thread is not None:
            raise RuntimeError("exporter already started")
        self._stop.clear()

        def _loop():
            while not self._stop.wait(interval_s):
                self.snapshot()

        self._thread = threading.Thread(
            target=_loop, name="obs-jsonl-exporter", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, final_snapshot: bool = True) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        if final_snapshot:
            self.snapshot()
