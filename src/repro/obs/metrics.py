"""Metrics registry: named counters / gauges / histograms with label sets.

One process-wide ``MetricsRegistry`` (``get_registry()``) holds every
metric the instrumented layers emit.  Naming convention (DESIGN.md §14):

    repro_<layer>_<name>[_total|_bytes|_seconds]

where ``<layer>`` is ``transport`` / ``gossip`` / ``engine`` / ``serve``
/ ``fleet`` / ``obs``.  Metrics are cheap plain-dict state behind one
registry lock — hot paths that cannot afford even that go through the
span tracer (guarded by ``obs.enable``) or batch-publish via the bridge
functions below.

Bridges absorb the pre-existing ad-hoc telemetry into this schema:

  * ``publish_wire_stats(ws, transport=...)`` — a transport's
    ``wire_stats`` dict (the unified cross-transport schema of
    ``core.transport.WIRE_STATS_SCHEMA``) lands as
    ``repro_transport_*`` gauges labeled by transport/codec/topology.
  * ``publish_serving_metrics(sm, ...)`` — a
    ``serve.metrics.ServingMetrics`` summary lands as ``repro_serve_*``
    gauges (the machine-readable signals the ROADMAP's autoscaling item
    needs: shed/queue depth/tile fill/latency quantiles).
  * ``publish_staleness(summary, ...)`` — a
    ``convergence.staleness_summary`` dict lands as
    ``repro_transport_staleness_*`` gauges.

Everything here is exportable via ``obs.export`` (Prometheus text
format, JSONL snapshots).
"""
from __future__ import annotations

import re
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "publish_wire_stats",
    "publish_serving_metrics",
    "publish_staleness",
]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# default histogram bucket upper bounds: 1us .. 100s, log-spaced
DEFAULT_BUCKETS = tuple(10.0 ** (e / 4.0) for e in range(-24, 9))

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(
    allowed: Tuple[str, ...], labels: Dict[str, object]
) -> LabelKey:
    extra = set(labels) - set(allowed)
    if extra:
        raise ValueError(
            f"unknown label(s) {sorted(extra)}; declared labels are "
            f"{list(allowed)}"
        )
    return tuple((k, str(labels.get(k, ""))) for k in allowed)


class _Metric:
    """Shared label plumbing of the three metric kinds."""

    kind = "?"

    def __init__(self, name: str, help: str, labels: Sequence[str]):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for lbl in labels:
            if not _LABEL_RE.match(lbl):
                raise ValueError(f"invalid label name {lbl!r}")
        self.name = name
        self.help = help
        self.labels = tuple(labels)
        self._lock = threading.Lock()
        self._series: Dict[LabelKey, object] = {}

    def series(self) -> List[Tuple[Dict[str, str], object]]:
        """[(labels_dict, value)] snapshot of every labeled series."""
        with self._lock:
            return [(dict(k), v) for k, v in self._series.items()]


class Counter(_Metric):
    """Monotonically increasing count (``inc`` rejects negative deltas)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter increments must be >= 0, got {amount}")
        key = _label_key(self.labels, labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        key = _label_key(self.labels, labels)
        with self._lock:
            return float(self._series.get(key, 0.0))


class Gauge(_Metric):
    """A value that can go anywhere (``set``/``add``)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        key = _label_key(self.labels, labels)
        with self._lock:
            self._series[key] = float(value)

    def add(self, amount: float, **labels) -> None:
        key = _label_key(self.labels, labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        key = _label_key(self.labels, labels)
        with self._lock:
            return float(self._series.get(key, 0.0))


class _HistState:
    __slots__ = ("counts", "count", "sum")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)  # +inf bucket last
        self.count = 0
        self.sum = 0.0


class Histogram(_Metric):
    """Bucketed distribution (cumulative ``le`` buckets on export, like
    Prometheus); exact count/sum alongside."""

    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str,
        labels: Sequence[str],
        buckets: Sequence[float] = DEFAULT_BUCKETS,
    ):
        super().__init__(name, help, labels)
        bs = tuple(float(b) for b in buckets)
        if list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError("histogram buckets must be strictly increasing")
        self.buckets = bs

    def observe(self, value: float, **labels) -> None:
        v = float(value)
        key = _label_key(self.labels, labels)
        with self._lock:
            st = self._series.get(key)
            if st is None:
                st = self._series[key] = _HistState(len(self.buckets))
            i = 0
            for i, b in enumerate(self.buckets):
                if v <= b:
                    break
            else:
                i = len(self.buckets)
            st.counts[i] += 1
            st.count += 1
            st.sum += v


class MetricsRegistry:
    """Get-or-create home of every named metric (one per process by
    default; tests build private ones)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}

    def _get_or_create(self, cls, name, help, labels, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, tuple(labels), **kwargs)
                self._metrics[name] = m
                return m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"not {cls.kind}"
            )
        if tuple(labels) and m.labels != tuple(labels):
            raise ValueError(
                f"metric {name!r} declared with labels {m.labels}, "
                f"got {tuple(labels)}"
            )
        return m

    def counter(
        self, name: str, help: str = "", labels: Sequence[str] = ()
    ) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(
        self, name: str, help: str = "", labels: Sequence[str] = ()
    ) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(
        self,
        name: str,
        help: str = "",
        labels: Sequence[str] = (),
        buckets: Sequence[float] = DEFAULT_BUCKETS,
    ) -> Histogram:
        return self._get_or_create(
            Histogram, name, help, labels, buckets=buckets
        )

    def collect(self) -> Iterable[_Metric]:
        with self._lock:
            return sorted(self._metrics.values(), key=lambda m: m.name)

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    def as_dict(self) -> Dict[str, object]:
        """JSON-ready snapshot (the JSONL exporter's record shape)."""
        out: Dict[str, object] = {}
        for m in self.collect():
            rows = []
            for labels, v in m.series():
                if isinstance(v, _HistState):
                    rows.append(
                        {
                            "labels": labels,
                            "count": v.count,
                            "sum": v.sum,
                            "buckets": list(v.counts),
                        }
                    )
                else:
                    rows.append({"labels": labels, "value": v})
            out[m.name] = {"type": m.kind, "series": rows}
        return out


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _REGISTRY


# ---------------------------------------------------------------------------
# bridges: absorb the pre-existing ad-hoc telemetry into the registry
# ---------------------------------------------------------------------------
def publish_wire_stats(
    wire_stats: Dict[str, object],
    *,
    transport: str,
    registry: Optional[MetricsRegistry] = None,
) -> None:
    """Publish one transport's ``wire_stats`` dict (the unified schema of
    ``core.transport.WIRE_STATS_SCHEMA``) as ``repro_transport_*`` gauges.

    Gauges, not counters: ``wire_stats`` values are already cumulative
    per transport instance, so re-publishing is idempotent (set, not
    add).  String-valued keys (``codec`` / ``topology``) become labels on
    every series."""
    reg = registry if registry is not None else _REGISTRY
    labels = {
        "transport": transport,
        "codec": str(wire_stats.get("codec", "none")),
        "topology": str(wire_stats.get("topology", "star")),
    }
    for key, value in wire_stats.items():
        if isinstance(value, str):
            continue
        reg.gauge(
            f"repro_transport_{key}",
            f"transport wire_stats[{key}] (cumulative per run)",
            labels=("transport", "codec", "topology"),
        ).set(float(value), **labels)


def publish_staleness(
    summary: Dict[str, object],
    *,
    transport: str,
    registry: Optional[MetricsRegistry] = None,
) -> None:
    """Publish a ``convergence.staleness_summary`` dict as
    ``repro_transport_staleness_*`` gauges (per-worker/per-edge breakdown
    dicts are skipped — those stay in the history/trace)."""
    reg = registry if registry is not None else _REGISTRY
    for key, value in summary.items():
        if isinstance(value, dict):
            continue
        reg.gauge(
            f"repro_transport_staleness_{key}",
            f"staleness_summary[{key}] of the latest run",
            labels=("transport",),
        ).set(float(value), transport=transport)


# ServingMetrics.summary() scalar keys -> gauge suffixes; latency/ttft
# sub-dicts are flattened below
_SERVE_SCALARS = (
    "submitted",
    "completed",
    "rejected",
    "expired",
    "slo_violations",
    "swaps",
    "elapsed_s",
    "throughput_rps",
    "queue_depth_max",
    "tiles",
    "tile_fill",
    "decode_steps",
    "slot_occupancy",
)


def publish_serving_metrics(
    metrics,
    *,
    replica: str = "all",
    registry: Optional[MetricsRegistry] = None,
) -> None:
    """Publish a ``serve.metrics.ServingMetrics`` object as
    ``repro_serve_*`` gauges labeled by replica ("all" for a fleet
    rollup).  These are the autoscaling signals the ROADMAP names:
    queue depth, tile fill, shed/violation counts, latency quantiles."""
    reg = registry if registry is not None else _REGISTRY
    s = metrics.summary()
    for key in _SERVE_SCALARS:
        v = s.get(key)
        if v is None:
            continue
        reg.gauge(
            f"repro_serve_{key}",
            f"ServingMetrics summary[{key}]",
            labels=("replica",),
        ).set(float(v), replica=replica)
    for hist_key in ("latency", "ttft"):
        for q, v in s.get(hist_key, {}).items():
            reg.gauge(
                f"repro_serve_{hist_key}_{q}",
                f"ServingMetrics {hist_key} {q}",
                labels=("replica",),
            ).set(float(v), replica=replica)
