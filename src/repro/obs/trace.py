"""Span tracer: thread-safe, nestable, ring-buffered, Chrome-trace export.

The tracer answers "where does wall-clock go?" for an async training run
or a fleet simulation: every instrumented site wraps its work in

    with span("commit", worker=g, round=r):
        ...

and the closed span lands as one record in a process-wide ring buffer
(bounded memory — old spans fall off, recent history survives).  Spans
nest per thread automatically: Chrome's trace viewer reconstructs the
nesting from time containment of complete ("ph": "X") events on one
thread track, so a worker thread's ``round`` span visually contains its
``gate`` / ``solve`` / ``commit`` children with no explicit parent ids.

Design constraints (measured by ``benchmarks/bench_obs.py``):

  * **nearly free when disabled** — ``span()`` is one module-global flag
    check returning a shared no-op context manager; no allocation, no
    lock, no clock read.  ``obs.disable()`` is the production default.
  * **injectable clock** — ``set_clock`` swaps ``time.perf_counter`` for
    a virtual clock so deterministic fleet sims trace in virtual time.
  * **thread-safe** — the only shared mutation is the ring-buffer append
    and the thread-id table, both under one small lock taken at span
    EXIT (never while a caller's own lock ordering matters: the tracer
    never calls back out).

Export is the Chrome trace-event JSON format (``ph: "X"`` complete
events, microsecond timestamps, per-thread tracks with ``M`` metadata
names) — loadable in ``chrome://tracing`` and Perfetto.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional

__all__ = [
    "Tracer",
    "span",
    "enable",
    "disable",
    "enabled",
    "set_clock",
    "get_tracer",
    "export_chrome",
    "phase_breakdown",
]

DEFAULT_CAPACITY = 262_144  # ring-buffer slots (one dict per closed span)


class Tracer:
    """Process-wide span sink: ring buffer + thread-id table + export."""

    def __init__(
        self,
        capacity: int = DEFAULT_CAPACITY,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.clock = clock
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=self.capacity)
        self._dropped = 0
        self._tids: Dict[int, int] = {}  # thread ident -> small stable tid
        self._tid_names: Dict[int, str] = {}
        self._pid = os.getpid()

    # -- recording ----------------------------------------------------------
    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            tid = len(self._tids)
            self._tids[ident] = tid
            self._tid_names[tid] = threading.current_thread().name
        return tid

    def record(
        self, name: str, cat: str, t0: float, dur: float, args: Optional[dict]
    ) -> None:
        ev = {
            "name": name,
            "cat": cat,
            "ph": "X",
            "ts": t0 * 1e6,  # Chrome wants microseconds
            "dur": dur * 1e6,
            "pid": self._pid,
        }
        if args:
            ev["args"] = args
        with self._lock:
            ev["tid"] = self._tid()
            if len(self._events) == self.capacity:
                self._dropped += 1
            self._events.append(ev)

    # -- introspection ------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    @property
    def dropped(self) -> int:
        """Spans that fell off the ring buffer (capacity exceeded)."""
        with self._lock:
            return self._dropped

    def events(self) -> List[dict]:
        """A snapshot copy of the buffered span records (ts order within
        each thread; cross-thread order is append order)."""
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._dropped = 0

    def set_clock(self, clock: Callable[[], float]) -> None:
        self.clock = clock

    # -- export -------------------------------------------------------------
    def export_chrome(self, path: str) -> int:
        """Write the buffer as Chrome trace-event JSON; returns the number
        of span events written.  Thread tracks are named with ``M``
        metadata events so worker threads read as ``dmtrl-worker-3`` in
        the viewer, not bare integers."""
        with self._lock:
            events = list(self._events)
            names = dict(self._tid_names)
        meta = [
            {
                "name": "thread_name",
                "ph": "M",
                "pid": self._pid,
                "tid": tid,
                "args": {"name": tname},
            }
            for tid, tname in sorted(names.items())
        ]
        doc = {
            "traceEvents": meta + events,
            "displayTimeUnit": "ms",
        }
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(events)

    def phase_breakdown(self, cat: Optional[str] = None) -> Dict[str, dict]:
        """Wall-clock totals by span name: ``{name: {count, total_s,
        mean_s, max_s}}``.  Nested spans each count their own full
        duration (this is an inclusive-time breakdown: compare siblings,
        not a parent against its children)."""
        out: Dict[str, dict] = {}
        for ev in self.events():
            if cat is not None and ev.get("cat") != cat:
                continue
            row = out.setdefault(
                ev["name"], {"count": 0, "total_s": 0.0, "max_s": 0.0}
            )
            d = ev["dur"] / 1e6
            row["count"] += 1
            row["total_s"] += d
            row["max_s"] = max(row["max_s"], d)
        for row in out.values():
            row["mean_s"] = row["total_s"] / row["count"]
        return out


class _Span:
    """One live span: clock at enter, record at exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer: Tracer, name: str, cat: str, args: dict):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer.clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = self._tracer.clock()
        self._tracer.record(
            self._name, self._cat, self._t0, t1 - self._t0, self._args
        )
        return False


class _NullSpan:
    """The disabled path: a shared, allocation-free no-op context manager."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NULL_SPAN = _NullSpan()
_TRACER = Tracer()
_ENABLED = False


def span(name: str, cat: str = "repro", **args):
    """Context manager timing one phase; a no-op unless ``obs.enable()``
    ran.  Keyword labels land in the Chrome-trace ``args`` pane."""
    if not _ENABLED:
        return _NULL_SPAN
    return _Span(_TRACER, name, cat, args)


def enable(
    *,
    capacity: Optional[int] = None,
    clock: Optional[Callable[[], float]] = None,
    clear: bool = False,
) -> Tracer:
    """Turn span recording on (idempotent); optionally resize the ring
    buffer, swap the clock, or clear prior history.  Returns the tracer."""
    global _ENABLED, _TRACER
    if capacity is not None and capacity != _TRACER.capacity:
        _TRACER = Tracer(capacity=capacity, clock=clock or _TRACER.clock)
    elif clock is not None:
        _TRACER.set_clock(clock)
    if clear:
        _TRACER.clear()
    _ENABLED = True
    return _TRACER


def disable() -> None:
    """Turn span recording off: every ``span()`` call collapses to the
    shared no-op (the nearly-free path ``bench_obs`` measures).  Buffered
    spans stay exportable."""
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


def set_clock(clock: Callable[[], float]) -> None:
    _TRACER.set_clock(clock)


def get_tracer() -> Tracer:
    return _TRACER


def export_chrome(path: str) -> int:
    return _TRACER.export_chrome(path)


def phase_breakdown(cat: Optional[str] = None) -> Dict[str, dict]:
    return _TRACER.phase_breakdown(cat)
