from .analysis import RooflineTerms, analyze_compiled, collective_bytes_from_hlo

__all__ = ["RooflineTerms", "analyze_compiled", "collective_bytes_from_hlo"]
