"""Roofline-term extraction from compiled dry-run artifacts.

Terms (per docs/DESIGN.md §Roofline; cost_analysis operates on the
post-SPMD per-device module, so "per device / per-chip bandwidth" equals the
spec's "global / (chips x bandwidth)"):

    compute   = flops_per_device / PEAK_FLOPS_BF16
    memory    = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

collective bytes are parsed from the optimized HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op contributes the LARGEST shape literal on its line (≈ the full tensor
moved; documented upper-bound proxy). Ops inside while bodies (layer scans,
attention chunk scans) are multiplied by the loop trip count, inferred from
the largest integer constant in the while condition computation — the
standard XLA scan lowering puts the trip count there.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "bf16": 2,
    "f16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_max_bytes(line: str) -> int:
    return max(
        (_shape_bytes(m.group(1), m.group(2)) for m in _SHAPE_RE.finditer(line)),
        default=0,
    )


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> list of body lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$", s)
        if m and ("{" in s) and ("=" not in s.split("{")[0]):
            cur = m.group(1)
            comps[cur] = []
            continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps


def _find_entry(comps: Dict[str, List[str]], hlo: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.MULTILINE)
    return m.group(1) if m else None


def _trip_count(cond_lines: List[str]) -> int:
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def collective_bytes_from_hlo(hlo: str) -> Tuple[Dict[str, int], Dict[str, int]]:
    """Returns (bytes_by_kind_weighted, raw_counts_by_kind).

    Weighted = multiplied by inferred while-loop trip counts along the call
    chain from ENTRY.
    """
    comps = _split_computations(hlo)
    entry = _find_entry(comps, hlo)

    # per-computation: direct collective bytes and (callee, multiplier) edges
    direct: Dict[str, Dict[str, int]] = {}
    edges: Dict[str, List[Tuple[str, int]]] = {}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}

    call_re = re.compile(
        r"(?:body|condition|to_apply|called_computations=\{[^}]*\})=%?([\w\.\-]+)"
    )
    while_re = re.compile(r"=\s*\S+\s+while\(")
    body_re = re.compile(r"body=%?([\w\.\-]+)")
    cond_re = re.compile(r"condition=%?([\w\.\-]+)")
    callop_re = re.compile(r"=\s*\S+\s+(?:call|fusion|conditional)\(")

    for name, lines in comps.items():
        d: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
        e: List[Tuple[str, int]] = []
        for line in lines:
            for kind in _COLLECTIVES:
                if re.search(rf"\b{kind}(?:-start|-done)?\(", line):
                    d[kind] += _line_max_bytes(line)
                    counts[kind] += 1
                    break
            if while_re.search(line):
                bm, cm = body_re.search(line), cond_re.search(line)
                if bm:
                    trips = _trip_count(comps.get(cm.group(1), [])) if cm else 1
                    e.append((bm.group(1), max(trips, 1)))
            elif callop_re.search(line):
                for cm2 in call_re.finditer(line):
                    e.append((cm2.group(1), 1))
                m2 = re.search(r"(?:to_apply|calls)=%?([\w\.\-]+)", line)
                if m2:
                    e.append((m2.group(1), 1))
        direct[name] = d
        edges[name] = e

    memo: Dict[str, Dict[str, int]] = {}

    def total(name: str, stack=()) -> Dict[str, int]:
        if name in memo:
            return memo[name]
        if name in stack or name not in direct:
            return {k: 0 for k in _COLLECTIVES}
        acc = dict(direct[name])
        for callee, mult in edges[name]:
            sub = total(callee, stack + (name,))
            for k, v in sub.items():
                acc[k] += mult * v
        memo[name] = acc
        return acc

    if entry is None:
        # fall back: unweighted sum over all computations
        acc = {k: 0 for k in _COLLECTIVES}
        for d in direct.values():
            for k, v in d.items():
                acc[k] += v
        return acc, counts
    return total(entry), counts


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, int]
    collective_counts: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float  # 6*N*D (or 6*N_active*D) global
    useful_flops_ratio: float
    memory_analysis: Optional[str] = None
    # raw cost_analysis numbers (while bodies counted ONCE — kept for
    # reference; the roofline uses the trip-count-weighted parser values)
    xla_flops_raw: float = 0.0
    xla_bytes_raw: float = 0.0

    def to_row(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def analyze_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_chips: int,
    model_flops: float,
    peak_flops: float,
    hbm_bw: float,
    ici_bw: float,
) -> RooflineTerms:
    from .hlo_parse import analyze_hlo

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    costs = analyze_hlo(hlo)
    # the parser counts only dot flops (matmuls dominate); take the max of
    # parser (loop-weighted) and XLA (loop-unaware) as the best estimate
    flops = max(costs.dot_flops, xla_flops)
    byts = max(costs.bytes, xla_bytes)
    coll = {k: int(v) for k, v in costs.coll.items()}
    counts = dict(costs.coll_count)
    coll_total = float(sum(coll.values()))

    compute_s = flops / peak_flops
    memory_s = byts / hbm_bw
    collective_s = coll_total / ici_bw
    dom = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", collective_s)],
        key=lambda kv: kv[1],
    )[0]
    global_flops = flops * n_chips
    ratio = model_flops / global_flops if global_flops > 0 else 0.0

    mem_txt = None
    try:
        ma = compiled.memory_analysis()
        mem_txt = str(ma)
    except Exception:
        pass

    return RooflineTerms(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=coll_total,
        collective_breakdown=coll,
        collective_counts=counts,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dom,
        model_flops=model_flops,
        useful_flops_ratio=ratio,
        memory_analysis=mem_txt,
        xla_flops_raw=xla_flops,
        xla_bytes_raw=xla_bytes,
    )
