"""Trip-count-aware HLO text analyzer.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, which makes
scan-over-layers programs look ~n_layers x cheaper than they are (verified
in tests/test_roofline.py). This module re-derives the roofline inputs from
the scheduled post-SPMD HLO text with loop weighting:

  * computations are split robustly (headers may contain /*index=k*/
    comments and tuple types);
  * a per-computation symbol table (header params + instruction defs) gives
    operand shapes;
  * dot flops  = 2 * prod(output shape) * prod(contracting dims of lhs);
  * bytes      = sum over scheduled instructions of output + operand bytes
    (fusions count once at their call site, matching buffer semantics;
    parameter/constant/tuple/GTE/bitcast are free);
  * collective bytes per kind, from the op's shapes;
  * while bodies multiply their interior by the trip count inferred from
    the largest integer constant in the condition computation (the standard
    scan lowering compares the induction variable against that constant);
    conditional branches count both sides (documented upper bound).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b(pred|s4|u4|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([\d,]*)\](?:\{[^}]*\})?")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w\.\-]+)")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^()]*\)|[\w\[\]\{\},\/\*=\s])+?)(?=,\s*%?[\w\.\-]+:|\)\s*->|\)$)")

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_FREE_OPS = (
    "parameter(", "constant(", "tuple(", "get-tuple-element(", "bitcast(",
    "after-all(", "iota(",
)


def _shapes_bytes(text: str) -> int:
    """Total bytes of all shape literals in a type string."""
    return sum(
        _DTYPE_BYTES[m.group(1)]
        * (eval("*".join(m.group(2).split(",")) or "1") if m.group(2).strip() else 1)
        for m in _SHAPE_RE.finditer(text)
    )


def _first_shape_bytes(text: str) -> int:
    m = _SHAPE_RE.search(text)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[m.group(1)]


def _shape_dims(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2).strip() else []
    return m.group(1), dims


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    symbols: Dict[str, str]  # instr/param name -> type text


def split_computations(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        s = raw.strip()
        if not s or s.startswith("//"):
            continue
        if s.endswith("{") and " = " not in s.split("(")[0] and not s.startswith(
            "HloModule"
        ):
            toks = s.split()
            is_entry = toks[0] == "ENTRY"
            name_tok = toks[1] if is_entry else toks[0]
            name = name_tok.lstrip("%").split("(")[0]
            cur = Computation(name, [], {})
            comps[name] = cur
            if is_entry:
                entry = name
            # header params: "%p: f32[2,3]" pairs
            header = s[s.find("(") + 1 :]
            for pm in re.finditer(r"%?([\w\.\-]+):\s*([^,()]+(?:\([^)]*\))?[^,()]*)", header):
                cur.symbols[pm.group(1)] = pm.group(2)
            continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        cur.lines.append(s)
        dm = _DEF_RE.match(s)
        if dm:
            cur.symbols[dm.group(1)] = dm.group(2)
    return comps, entry


def _trip_count(cond: Optional[Computation]) -> int:
    if cond is None:
        return 1
    best = 1
    for line in cond.lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


_WHILE_RE = re.compile(r"\bwhile\(")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"(?:true_computation|false_computation|branch_computations=\{[^}]*\})")
_BRANCH_NAMES = re.compile(r"%([\w\.\-]+)")


@dataclasses.dataclass
class Costs:
    dot_flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS}
    )
    coll_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {k: 0 for k in COLLECTIVE_KINDS}
    )

    def add(self, other: "Costs", mult: float = 1.0, bytes_too: bool = True):
        self.dot_flops += mult * other.dot_flops
        if bytes_too:
            self.bytes += mult * other.bytes
        for k in COLLECTIVE_KINDS:
            self.coll[k] += mult * other.coll[k]
            self.coll_count[k] += int(mult * other.coll_count[k])


def _dot_flops(line: str, comp: Computation) -> float:
    """2 * prod(out) * prod(lhs contracting dims)."""
    out = _shape_dims(line.split("dot(")[0])
    if out is None:
        return 0.0
    _, out_dims = out
    opnds = _OPND_RE.findall(line.split("dot(", 1)[1])
    if not opnds:
        return 0.0
    lhs_type = comp.symbols.get(opnds[0], "")
    lhs = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if lhs is None or m is None:
        # fall back: assume contraction over last lhs dim unknown -> use out only
        k = 1
    else:
        dims = [int(d) for d in m.group(1).split(",") if d]
        k = 1
        for d in dims:
            if d < len(lhs[1]):
                k *= lhs[1][d]
    n = 1
    for d in out_dims:
        n *= d
    return 2.0 * n * k


def _line_bytes(line: str, comp: Computation) -> float:
    """output bytes + operand bytes (shapes via the symbol table).

    dynamic-slice / dynamic-update-slice touch only the slice, not the whole
    buffer (XLA updates in place) — counted as 2x the slice size; without
    this, buffers updated inside scans would be charged fully per trip.
    """
    head, _, tail = line.partition("(")
    out_b = _shapes_bytes(head.split("=", 1)[1] if "=" in head else head)
    if "dynamic-update-slice(" in line:
        # update operand = second arg; approximate via smallest shape on line
        args = tail.split(")", 1)[0]
        opnds = _OPND_RE.findall(args)
        upd = (
            _shapes_bytes(comp.symbols.get(opnds[1], "").split("=")[0])
            if len(opnds) >= 2
            else out_b
        )
        return float(2 * upd)
    if "dynamic-slice(" in line:
        return float(2 * out_b)
    opnd_b = 0
    args = tail.split(")", 1)[0] if ")" in tail else tail
    for nm in _OPND_RE.findall(args):
        t = comp.symbols.get(nm)
        if t:
            opnd_b += _shapes_bytes(t.split("(")[0].split("=")[0] if "=" in t else t)
    return float(out_b + opnd_b)


def analyze_hlo(hlo: str) -> Costs:
    comps, entry = split_computations(hlo)
    memo: Dict[str, Costs] = {}

    def visit(name: str, stack=()) -> Costs:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        out = Costs()
        if comp is None or name in stack:
            return out
        for line in comp.lines:
            # collectives
            matched_coll = False
            for kind in COLLECTIVE_KINDS:
                if re.search(rf"\b{kind}(?:-start)?\(", line):
                    out.coll[kind] += _line_max_bytes(line)
                    out.coll_count[kind] += 1
                    matched_coll = True
                    break
            if matched_coll:
                out.bytes += _line_bytes(line, comp)
                continue
            if _WHILE_RE.search(line):
                bm, cm = _BODY_RE.search(line), _COND_RE.search(line)
                if bm:
                    trips = _trip_count(comps.get(cm.group(1))) if cm else 1
                    out.add(visit(bm.group(1), stack + (name,)), mult=max(trips, 1))
                continue
            if _BRANCH_RE.search(line):
                seg = line[line.find("conditional") :]
                for nm in set(_BRANCH_NAMES.findall(seg)):
                    if nm in comps:
                        out.add(visit(nm, stack + (name,)), mult=1.0)
                continue
            if " fusion(" in line or re.search(r"=\s*\S+\s+call\(", line):
                cm2 = _CALLS_RE.search(line)
                sliced = False
                if cm2:
                    sub = visit(cm2.group(1), stack + (name,))
                    # fusion interior: count its dots/collectives, but bytes
                    # are the call-site operands+output (fusion semantics)
                    out.add(sub, mult=1.0, bytes_too=False)
                    callee = comps.get(cm2.group(1))
                    sliced = callee is not None and any(
                        "dynamic-slice(" in l or "dynamic-update-slice(" in l
                        for l in callee.lines
                    )
                if sliced:
                    # the fusion slices its big operand(s): charge output +
                    # operands no larger than 100x the output (the sliced
                    # mega-operand is read O(slice), not in full, per trip)
                    head = line.partition("(")[0]
                    out_b = _shapes_bytes(
                        head.split("=", 1)[1] if "=" in head else head
                    )
                    opnd_b = 0
                    args = line.partition("(")[2].split(")", 1)[0]
                    for nm2 in _OPND_RE.findall(args):
                        t = comp.symbols.get(nm2)
                        if t:
                            b = _shapes_bytes(t.split("=")[0])
                            opnd_b += b if b <= 100 * max(out_b, 1) else 2 * out_b
                    out.bytes += float(out_b + opnd_b)
                else:
                    out.bytes += _line_bytes(line, comp)
                continue
            if " dot(" in line:
                out.dot_flops += _dot_flops(line, comp)
                out.bytes += _line_bytes(line, comp)
                continue
            if any(op in line for op in _FREE_OPS):
                continue
            if "=" in line:
                out.bytes += _line_bytes(line, comp)
        memo[name] = out
        return out

    if entry is None:
        total = Costs()
        for nm in comps:
            total.add(visit(nm))
        return total
    return visit(entry)


def _line_max_bytes(line: str) -> int:
    return max(
        (
            _DTYPE_BYTES[m.group(1)]
            * (
                eval("*".join(m.group(2).split(",")))
                if m.group(2).strip()
                else 1
            )
            for m in _SHAPE_RE.finditer(line)
        ),
        default=0,
    )
