"""Serving: continuous-batching scheduler over batched LM and MTL engines.

Submodules load lazily (PEP 562): the MTL scoring surface must not pull
in the LM model stack that ``engine`` imports (transformers, flash
kernels), and vice versa. ``scheduler``/``metrics`` are engine-agnostic
(no model imports at all).
"""
_LM = {"Request", "ServeConfig", "ServingEngine", "make_serve_step"}
_MTL = {"MTLScoringEngine", "ScoreRequest", "make_score_step"}
_SCHED = {
    "ContinuousBatchingScheduler",
    "ModelSnapshot",
    "QueueFull",
    "ServeRequest",
    "SubmitOutcome",
    "VirtualClock",
}
_METRICS = {"LatencyHistogram", "ServingMetrics"}
_FLEET = {"ClientToken", "FleetRouter", "ReplicaHandle"}

__all__ = sorted(_LM | _MTL | _SCHED | _METRICS | _FLEET)


def __getattr__(name):
    if name in _LM:
        from . import engine

        return getattr(engine, name)
    if name in _MTL:
        from . import mtl

        return getattr(mtl, name)
    if name in _SCHED:
        from . import scheduler

        return getattr(scheduler, name)
    if name in _METRICS:
        from . import metrics

        return getattr(metrics, name)
    if name in _FLEET:
        from . import fleet

        return getattr(fleet, name)
    raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")
