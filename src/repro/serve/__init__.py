"""Serving: batched prefill + decode engine with KV/SSM-state caches."""
from .engine import Request, ServeConfig, ServingEngine, make_serve_step

__all__ = ["Request", "ServeConfig", "ServingEngine", "make_serve_step"]
