"""Serving: batched LM prefill+decode engine and batched MTL scoring.

Submodules load lazily (PEP 562): the MTL scoring surface must not pull
in the LM model stack that ``engine`` imports (transformers, flash
kernels), and vice versa.
"""
_LM = {"Request", "ServeConfig", "ServingEngine", "make_serve_step"}
_MTL = {"MTLScoringEngine", "ScoreRequest", "make_score_step"}

__all__ = sorted(_LM | _MTL)


def __getattr__(name):
    if name in _LM:
        from . import engine

        return getattr(engine, name)
    if name in _MTL:
        from . import mtl

        return getattr(mtl, name)
    raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")
