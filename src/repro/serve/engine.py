"""Batched LM serving engine: request queue -> batched prefill -> decode loop.

The jitted ``serve_step`` (one token for the whole batch, cache in/out) is
the unit the dry-run lowers for the decode_32k / long_500k shapes.

``Request`` shares the ``ServeRequest`` queue fields with the MTL scorer
(arrival/deadline/status/snapshot_version), and the engine implements the
same scheduler adapter surface (``admit`` / ``run_tile`` /
``model_snapshot`` — LM params are fixed for the engine's lifetime, so
its snapshots never change version), so both engines run behind ONE
``ContinuousBatchingScheduler``. The LM tile unit is a full
prefill+decode generation for <= batch requests; decode-step-level
continuous batching (injecting requests mid-decode) is future work
(docs/DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, prefill
from .scheduler import ModelSnapshot, ServeRequest

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int = 8
    max_len: int = 2048
    temperature: float = 0.0  # 0 => greedy
    eos_id: int = 1
    seed: int = 0


def make_serve_step(cfg: ModelConfig) -> Callable:
    """serve_step(params, token (B,), cache) -> (next_logits (B, Vp), cache)."""

    def serve_step(params, token, cache):
        return decode_step(cfg, params, token, cache)

    return serve_step


def _sample(logits: Array, key: Array, temperature: float) -> Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class Request(ServeRequest):
    prompt: np.ndarray = None  # (S,) int32
    max_new_tokens: int = 32
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None  # "eos" | "length"


class ServingEngine:
    """Batched generate engine: right-pad a tile of <= batch prompts to a
    common length, batched prefill, then decode until every request
    finishes (EOS or token budget).

    The decode loop is ``_decode`` so its stopping semantics (EOS vs
    budget) are testable against a scripted step function without a real
    model.
    """

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self._step = jax.jit(make_serve_step(cfg))
        self._key = jax.random.PRNGKey(scfg.seed)
        # one stable snapshot object: the scheduler detects engine-side
        # swaps by identity, and LM params never change
        self._snapshot = ModelSnapshot(version=0)

    # -- scheduler adapter surface -----------------------------------------
    @property
    def batch(self) -> int:
        return self.scfg.batch

    def model_snapshot(self) -> ModelSnapshot:
        return self._snapshot

    def admit(self, r: Request) -> None:
        prompt = np.asarray(r.prompt)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError(
                f"prompt must be a non-empty 1-D token array, got shape "
                f"{prompt.shape}"
            )
        if not np.issubdtype(prompt.dtype, np.integer):
            raise ValueError(
                f"prompt must hold integer token ids, got dtype {prompt.dtype}"
            )
        # canonicalize in place: a list/other-int-dtype prompt admitted
        # here must also be servable by run() (which reads .shape)
        r.prompt = prompt.astype(np.int32, copy=False)
        if r.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {r.max_new_tokens}"
            )

    def run_tile(self, requests: Sequence[Request], snapshot: ModelSnapshot) -> None:
        """LM tiles ignore the snapshot weights: params are fixed for the
        engine's lifetime (hot-swap is the MTL scorer's feature)."""
        self.run(list(requests))

    # -- blocking surface ---------------------------------------------------
    def run(
        self, requests: List[Request], side: Optional[Array] = None
    ) -> List[Request]:
        cfg, scfg = self.cfg, self.scfg
        if len(requests) > scfg.batch:
            raise ValueError(
                f"{len(requests)} requests exceed the engine batch "
                f"{scfg.batch}; run in tiles (or use the scheduler)"
            )
        # pad the TILE with dummy requests, not the caller's list
        tile = list(requests)
        while len(tile) < scfg.batch:
            tile.append(Request(prompt=np.array([0], np.int32), max_new_tokens=1))
        S = max(int(r.prompt.shape[0]) for r in tile)
        toks = np.zeros((scfg.batch, S), np.int32)
        for i, r in enumerate(tile):
            toks[i, S - r.prompt.shape[0] :] = r.prompt  # left-pad
        last_logits, cache = prefill(
            cfg, self.params, jnp.asarray(toks), side, extra_len=scfg.max_len
        )
        self._decode(tile, last_logits, cache)
        return requests

    def _decode(self, requests: List[Request], logits: Array, cache) -> None:
        """Greedy/sampled decode until every request is done.

        A request stops on EOS (``finish_reason="eos"``, the EOS token is
        kept in the output) or on exhausting its ``max_new_tokens`` budget
        (``finish_reason="length"``); the loop ends when all requests
        stopped, never beyond the largest budget.
        """
        scfg = self.scfg
        budget = max(r.max_new_tokens for r in requests)
        for t in range(budget):
            self._key, sub = jax.random.split(self._key)
            nxt = _sample(logits, sub, scfg.temperature)
            nxt_np = np.asarray(nxt)
            for i, r in enumerate(requests):
                if not r.done and t < r.max_new_tokens:
                    tok = int(nxt_np[i])
                    r.output.append(tok)
                    if tok == scfg.eos_id:
                        r.done = True
                        r.finish_reason = "eos"
                    elif len(r.output) >= r.max_new_tokens:
                        r.done = True
                        r.finish_reason = "length"
            if all(r.done for r in requests):
                break
            logits, cache = self._step(self.params, nxt, cache)
