"""LM serving engine: per-slot continuous batching over one decode batch.

The engine owns a slot table over a batch-wide KV cache: each of the
``batch`` rows (slots) is free or holds exactly one in-flight request.
New requests are PREFILLED INDIVIDUALLY (B=1) through length-bucketed,
AOT-compiled prefill executables — the prompt is right-padded to the
next power-of-two bucket and the pad is carried as an explicit mask
(``prefill(..., true_len=)``), so one compiled executable serves every
prompt length in its bucket and a padded prefill is bit-equal to a solo
unpadded one — then inserted into a free slot at a decode-step boundary.
The whole batch then advances ONE token per ``decode_tick``; a request
that hits EOS or its token budget frees its slot for the next waiting
request. That is the head-of-line-blocking fix: a long generation only
ever occupies its own slot, it never gates the other ``batch - 1`` rows.

Sampled tokens stay on device in a detokenize backlog (one entry per
decode step) and are only transferred/finalized when the backlog drains
(every ``drain_every`` steps, when slots are needed, or at idle), so the
hot loop never blocks on host syncs per token.

``Request`` shares the ``ServeRequest`` queue fields with the MTL scorer
and the engine keeps the classic scheduler adapter surface (``admit`` /
``run_tile`` / ``model_snapshot``) PLUS the streaming surface the
scheduler prefers when present (``free_slots`` / ``active`` / ``inject``
/ ``decode_tick`` / ``drain`` / ``evict_active``), so both engines run
behind ONE ``ContinuousBatchingScheduler`` — the LM tile unit is one
decode STEP, not a whole generation (docs/DESIGN.md §10).

SSM / hybrid / encoder-decoder architectures cannot mask pad steps out
of a state scan, so they prefill at EXACT prompt length (one executable
per distinct length) but share the same slot table and per-row decode.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, prefill
from repro.models.transformer import DecodeCache
from .scheduler import ModelSnapshot, ServeRequest

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int = 8        # decode slots
    max_len: int = 2048   # KV slots per sequence: prompt + generated tokens
    temperature: float = 0.0  # 0 => greedy
    eos_id: int = 1
    seed: int = 0
    bucket_min: int = 16  # smallest prefill bucket (buckets are powers of 2)
    drain_every: int = 4  # decode steps between detokenize-backlog drains


def make_serve_step(cfg: ModelConfig) -> Callable:
    """serve_step(params, token (B,), cache) -> (next_logits (B, Vp), cache)."""

    def serve_step(params, token, cache):
        return decode_step(cfg, params, token, cache)

    return serve_step


def _sample(logits: Array, key: Array, temperature: float) -> Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class Request(ServeRequest):
    prompt: np.ndarray = None  # (S,) int32
    max_new_tokens: int = 32
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None  # "eos" | "length"
    side: Optional[np.ndarray] = None  # (F, d) audio frames for enc-dec cfgs


def _next_bucket(n: int, lo: int, hi: int) -> int:
    """Smallest power-of-two >= max(n, lo), capped at hi (hi >= n always
    holds because admission bounds prompt lengths)."""
    b = max(lo, 1)
    while b < n:
        b *= 2
    return min(b, hi)


class ServingEngine:
    """Slot-table LM engine: bucketed B=1 prefill into free slots, one
    shared decode batch stepping all occupied slots together.

    Two surfaces over the same slot machinery:

      * streaming (the scheduler's preferred path): ``inject`` new
        requests at a decode-step boundary, ``decode_tick`` one step,
        finished requests surface from the drain backlog;
      * blocking ``run(requests)``: inject all, tick until every request
        finishes — kept for one-shot batches and the legacy
        ``run_tile`` adapter.

    ``warmup()`` AOT-compiles every fixed tile shape (each prefill
    bucket + the decode step + the slot insert) so the first real
    request never pays a retrace.
    """

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        if scfg.batch < 1:
            raise ValueError(f"batch must be >= 1, got {scfg.batch}")
        if scfg.drain_every < 1:
            raise ValueError(f"drain_every must be >= 1, got {scfg.drain_every}")
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self._key = jax.random.PRNGKey(scfg.seed)
        # one stable snapshot object: the scheduler detects engine-side
        # swaps by identity, and LM params never change
        self._snapshot = ModelSnapshot(version=0)
        # pad-masked bucketed prefill needs attention-only archs; state
        # scans (ssm/hybrid) and the enc-dec decoder prefill exactly
        self._maskable = not (
            cfg.arch_type in ("ssm", "hybrid") or cfg.is_encoder_decoder
        )
        # slot table
        B = scfg.batch
        self._slots: List[Optional[Request]] = [None] * B
        self._free: List[int] = list(range(B - 1, -1, -1))  # pop() -> slot 0 first
        self._emitted = [0] * B   # tokens sampled for the CURRENT attempt
        self._budget = [0] * B
        # device state (allocated on first inject; shapes fixed after that)
        self._cache: Optional[DecodeCache] = None
        self._token: Optional[Array] = None  # (B,) next input token per row
        self._one_sds = None  # B=1 cache shape template (set at alloc)
        # detokenize/finalize backlog: [(device tokens, [(row, request)])]
        self._backlog: List[Tuple[Array, List[Tuple[int, Request]]]] = []
        self._finished: List[Request] = []
        # compiled executables (AOT via jit(...).lower(...).compile())
        self._prefill_exe: Dict[int, Callable] = {}
        self._decode_exe: Optional[Callable] = None
        self._insert_exe: Optional[Callable] = None

    # -- scheduler adapter surface -----------------------------------------
    @property
    def batch(self) -> int:
        return self.scfg.batch

    def model_snapshot(self) -> ModelSnapshot:
        return self._snapshot

    def admit(self, r: Request) -> None:
        prompt = np.asarray(r.prompt)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError(
                f"prompt must be a non-empty 1-D token array, got shape "
                f"{prompt.shape}"
            )
        if not np.issubdtype(prompt.dtype, np.integer):
            raise ValueError(
                f"prompt must hold integer token ids, got dtype {prompt.dtype}"
            )
        # canonicalize in place: a list/other-int-dtype prompt admitted
        # here must also be servable by the packers (which read .shape)
        r.prompt = prompt.astype(np.int32, copy=False)
        if r.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {r.max_new_tokens}"
            )
        total = int(prompt.shape[0]) + int(r.max_new_tokens)
        if total > self.scfg.max_len:
            raise ValueError(
                f"prompt ({prompt.shape[0]}) + max_new_tokens "
                f"({r.max_new_tokens}) = {total} exceeds max_len="
                f"{self.scfg.max_len} KV slots"
            )
        if self.cfg.is_encoder_decoder and r.side is None:
            raise ValueError(
                "encoder-decoder configs need per-request side frames "
                "(Request.side)"
            )

    def run_tile(self, requests: Sequence[Request], snapshot: ModelSnapshot) -> None:
        """Legacy whole-generation tile hook (non-streaming schedulers).
        LM tiles ignore the snapshot weights: params are fixed for the
        engine's lifetime (hot-swap is the MTL scorer's feature)."""
        self.run(list(requests))

    # -- streaming surface --------------------------------------------------
    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def active(self) -> int:
        """Occupied slots (requests injected and not yet drained-finished)."""
        return self.scfg.batch - len(self._free)

    def inject(
        self, requests: Sequence[Request], snapshot: Optional[ModelSnapshot] = None
    ) -> None:
        """Admit <= free_slots requests into the running batch at a
        decode-step boundary: per-request bucketed prefill, slot assign,
        first token sampled from the prefill logits (time-to-first-token
        is paid here, not after the whole batch finishes).

        Per-attempt decode state (``output``/``done``/``finish_reason``)
        is RESET on entry, so a request re-queued after a failed tile
        never double-appends its previous partial output.
        """
        if len(requests) > len(self._free):
            raise RuntimeError(
                f"{len(requests)} requests for {len(self._free)} free slots; "
                "drain() first or inject fewer"
            )
        for r in requests:
            # per-attempt reset (retry double-append fix)
            r.output = []
            r.done = False
            r.finish_reason = None
            if snapshot is not None:
                r.snapshot_version = snapshot.version
            last_logits, one = self._prefill_one(r)
            if self._cache is None:
                self._alloc_batch_state(one)
            self._key, sub = jax.random.split(self._key)
            tok0 = _sample(last_logits, sub, self.scfg.temperature)  # (1,)
            i = self._free.pop()  # slot assigned only after prefill succeeded
            self._slots[i] = r
            self._emitted[i] = 1
            self._budget[i] = int(r.max_new_tokens)
            self._cache, self._token = self._insert(one, i, tok0)
            self._backlog.append((tok0, [(0, r)]))

    def decode_tick(self) -> List[Request]:
        """Advance every occupied slot one token; returns requests that
        FINISHED (possibly injected many ticks ago). Token transfer and
        finalize bookkeeping run off the hot loop: device tokens pile
        into the backlog and drain every ``drain_every`` steps (or when
        no slot can take another token), costing at most ``drain_every``
        wasted decode rows after an undetected EOS."""
        active = [
            i
            for i, r in enumerate(self._slots)
            if r is not None and self._emitted[i] < self._budget[i]
        ]
        if not active:
            self._drain_backlog()
            return self._pop_finished()
        logits, cache = self._step_call(self._token, self._cache)
        self._cache = cache
        self._key, sub = jax.random.split(self._key)
        nxt = _sample(logits, sub, self.scfg.temperature)  # (B,)
        self._token = nxt
        self._backlog.append((nxt, [(i, self._slots[i]) for i in active]))
        for i in active:
            self._emitted[i] += 1
        at_budget = all(
            self._emitted[i] >= self._budget[i]
            for i, r in enumerate(self._slots)
            if r is not None
        )
        if len(self._backlog) >= self.scfg.drain_every or at_budget:
            self._drain_backlog()
        return self._pop_finished()

    def drain(self) -> List[Request]:
        """Force a backlog drain (the scheduler calls this when it needs
        slots freed before packing); returns newly finished requests."""
        self._drain_backlog()
        return self._pop_finished()

    def evict_active(self) -> List[Request]:
        """Pull every in-flight (not yet finished) request out of the slot
        table — the failed-tile path: the scheduler re-queues them and the
        next ``inject`` resets their per-attempt state. Finished requests
        already drained stay in the finished backlog."""
        self._backlog.clear()
        evicted = [r for r in self._slots if r is not None]
        self._slots = [None] * self.scfg.batch
        self._free = list(range(self.scfg.batch - 1, -1, -1))
        self._emitted = [0] * self.scfg.batch
        self._budget = [0] * self.scfg.batch
        return evicted

    # -- blocking surface ---------------------------------------------------
    def run(
        self, requests: List[Request], side: Optional[Array] = None
    ) -> List[Request]:
        """One-shot batch: inject every request, tick until all finish.
        ``side`` optionally carries stacked (B, F, d) enc-dec frames,
        distributed to the requests row-by-row."""
        scfg = self.scfg
        if len(requests) > scfg.batch:
            raise ValueError(
                f"{len(requests)} requests exceed the engine batch "
                f"{scfg.batch}; run in tiles (or use the scheduler)"
            )
        if side is not None:
            for i, r in enumerate(requests):
                r.side = np.asarray(side[i])
        for r in requests:
            self.admit(r)
        if len(requests) > len(self._free):
            raise RuntimeError(
                "blocking run() needs exclusive slots; engine has "
                f"{self.active} in-flight streaming requests"
            )
        self.inject(requests, self._snapshot)
        # bounded: every slot stops at its budget, drain then frees it
        while not all(r.done for r in requests):
            self.decode_tick()
        self._pop_finished()
        return requests

    # -- AOT warmup ---------------------------------------------------------
    def warmup(
        self, buckets: Optional[Sequence[int]] = None
    ) -> List[int]:
        """AOT-compile every fixed tile shape ahead of traffic: each
        prefill bucket, the decode step, and the slot insert. Returns the
        bucket lengths compiled. With no argument, compiles the full
        power-of-two ladder ``bucket_min .. max_len/2`` (exact-length
        archs compile the same list as literal lengths)."""
        scfg = self.scfg
        if buckets is None:
            buckets, b = [], scfg.bucket_min
            while b <= scfg.max_len // 2:
                buckets.append(b)
                b *= 2
        done = []
        for b in buckets:
            if b >= scfg.max_len:
                raise ValueError(
                    f"bucket {b} leaves no decode room in max_len={scfg.max_len}"
                )
            self._get_prefill_exe(int(b))
            done.append(int(b))
        if self._cache is None and not self.cfg.is_encoder_decoder:
            # materialize batch state from an abstract prefill so the
            # decode/insert executables compile now, not at first inject
            one = jax.eval_shape(
                lambda: self._run_prefill(
                    int(buckets[0]) if buckets else scfg.bucket_min,
                    jnp.zeros((1, int(buckets[0]) if buckets else scfg.bucket_min), jnp.int32),
                    jnp.asarray(1, jnp.int32),
                    None,
                )
            )[1]
            self._alloc_batch_state(one)
        if self._cache is not None:
            self._ensure_decode_exe()
            self._ensure_insert_exe()
        return done

    # -- internals: prefill/bucket machinery --------------------------------
    def _bucket_for(self, L: int) -> int:
        if not self._maskable:
            return L  # exact-length prefill (state scans can't mask pads)
        return _next_bucket(L, self.scfg.bucket_min, self.scfg.max_len - 1)

    def _run_prefill(self, S: int, toks: Array, true_len: Array, side):
        extra = self.scfg.max_len - S
        tl = true_len if self._maskable else None
        return prefill(self.cfg, self.params, toks, side, extra_len=extra, true_len=tl)

    def _get_prefill_exe(self, S: int) -> Callable:
        exe = self._prefill_exe.get(S)
        if exe is None:
            i32 = jnp.int32
            if self.cfg.is_encoder_decoder:
                fn = jax.jit(
                    lambda toks, true_len, side: self._run_prefill(
                        S, toks, true_len, side
                    )
                )
                side_s = jax.ShapeDtypeStruct(
                    (1, self.cfg.enc_frames, self.cfg.d_model), jnp.float32
                )
                exe = fn.lower(
                    jax.ShapeDtypeStruct((1, S), i32),
                    jax.ShapeDtypeStruct((), i32),
                    side_s,
                ).compile()
            else:
                fn = jax.jit(
                    lambda toks, true_len: self._run_prefill(S, toks, true_len, None)
                )
                exe = fn.lower(
                    jax.ShapeDtypeStruct((1, S), i32),
                    jax.ShapeDtypeStruct((), i32),
                ).compile()
            self._prefill_exe[S] = exe
        return exe

    def _prefill_one(self, r: Request) -> Tuple[Array, DecodeCache]:
        """Bucketed B=1 prefill of one request -> (logits (1, Vp), cache).
        Tests stub THIS method to script token streams without a model."""
        L = int(r.prompt.shape[0])
        S = self._bucket_for(L)
        toks = np.zeros((1, S), np.int32)
        toks[0, :L] = r.prompt  # right-pad; the mask rides true_len
        exe = self._get_prefill_exe(S)
        args = [jnp.asarray(toks), jnp.asarray(L, jnp.int32)]
        if self.cfg.is_encoder_decoder:
            args.append(jnp.asarray(r.side, jnp.float32)[None])
        logits, cache = exe(*args)
        return logits, cache

    # -- internals: batch state / insert / decode ---------------------------
    @staticmethod
    def _map_cache(fn, *caches):
        """Apply ``fn(batch_axis, *leaves)`` over matching leaves of one or
        more DecodeCaches. The batch axis is NOT uniform: uniform archs
        stack layer caches as (n_layers, B, ...) dicts (batch at axis 1),
        heterogeneous archs keep per-layer lists of (B, ...) leaves, and
        the position is a scalar (B=1 prefill) or (B,) vector (batch)."""
        c0 = caches[0]
        ax = 1 if isinstance(c0.layers, dict) else 0
        layers = jax.tree.map(lambda *ls: fn(ax, *ls), *(c.layers for c in caches))
        shared = (
            jax.tree.map(lambda *ls: fn(0, *ls), *(c.shared for c in caches))
            if c0.shared is not None
            else None
        )
        cross = (
            jax.tree.map(lambda *ls: fn(0, *ls), *(c.cross for c in caches))
            if c0.cross is not None
            else None
        )
        pos = fn(0, *(c.position for c in caches))
        return DecodeCache(layers, pos, shared, cross)

    def _alloc_batch_state(self, one: DecodeCache) -> None:
        """Allocate the batch-wide cache from the structure of one B=1
        prefill cache: every leaf grows its batch axis to ``batch``; the
        scalar position becomes a per-row (B,) vector."""
        B = self.scfg.batch

        def rep(ax, a):
            if a.ndim == 0:  # position scalar -> per-row vector
                return jnp.zeros((B,), a.dtype)
            shape = list(a.shape)
            shape[ax] = B
            return jnp.zeros(tuple(shape), a.dtype)

        if not isinstance(one, DecodeCache):  # scripted-test stub caches
            self._cache = jax.tree.map(lambda a: rep(0, a), one)
        else:
            self._cache = self._map_cache(rep, one)
        self._token = jnp.zeros((B,), jnp.int32)
        # B=1 shape template for the insert executable: NOT recoverable
        # from the batch cache (a scalar position leaf and a size-1 batch
        # leaf both lose their identity there)
        self._one_sds = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), one
        )

    def _ensure_insert_exe(self) -> None:
        if self._insert_exe is not None:
            return

        def insert(full, one, i, token_vec, tok0):
            # dynamic_update_index_in_dim takes an update of equal rank
            # with a size-1 batch axis (layer/shared/cross leaves) OR of
            # rank-1 (the scalar position into the (B,) vector): the B=1
            # prefill cache leaves are exactly one or the other
            def put(ax, f, o):
                return jax.lax.dynamic_update_index_in_dim(f, o, i, ax)

            if not isinstance(full, DecodeCache):  # scripted-test stubs
                new_cache = jax.tree.map(lambda f, o: put(0, f, o), full, one)
            else:
                new_cache = self._map_cache(put, full, one)
            return new_cache, jax.lax.dynamic_update_index_in_dim(
                token_vec, tok0[0], i, 0
            )

        sds = lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
        self._insert_exe = (
            jax.jit(insert)
            .lower(
                jax.tree.map(sds, self._cache),
                self._one_sds,
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((self.scfg.batch,), jnp.int32),
                jax.ShapeDtypeStruct((1,), jnp.int32),
            )
            .compile()
        )

    def _insert(self, one: DecodeCache, i: int, tok0: Array):
        self._ensure_insert_exe()
        return self._insert_exe(
            self._cache, one, jnp.asarray(i, jnp.int32), self._token,
            tok0.astype(jnp.int32),
        )

    def _ensure_decode_exe(self) -> None:
        if self._decode_exe is not None:
            return
        sds = lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
        self._decode_exe = (
            jax.jit(lambda token, cache: decode_step(self.cfg, self.params, token, cache))
            .lower(
                jax.ShapeDtypeStruct((self.scfg.batch,), jnp.int32),
                jax.tree.map(sds, self._cache),
            )
            .compile()
        )

    def _step_call(self, token: Array, cache: DecodeCache):
        self._ensure_decode_exe()
        return self._decode_exe(token, cache)

    # -- internals: detokenize/finalize backlog -----------------------------
    def _drain_backlog(self) -> None:
        """Transfer backlogged device tokens to host, append to request
        outputs in decode order, finalize EOS/budget stops, recycle their
        slots. The ONLY host-sync point of the decode loop."""
        if not self._backlog:
            return
        events = self._backlog
        self._backlog = []
        for dev, rows in events:
            arr = np.asarray(dev)
            for row, r in rows:
                if r.done:
                    continue  # post-EOS rows sampled before the drain
                tok = int(arr[row])
                r.output.append(tok)
                if tok == self.scfg.eos_id:
                    r.done = True
                    r.finish_reason = "eos"
                elif len(r.output) >= r.max_new_tokens:
                    r.done = True
                    r.finish_reason = "length"
        for j, r in enumerate(self._slots):
            if r is not None and r.done:
                self._slots[j] = None
                self._free.append(j)
                self._finished.append(r)

    def _pop_finished(self) -> List[Request]:
        out, self._finished = self._finished, []
        return out
