"""Batched serving engine: request queue -> batched prefill -> decode loop.

The jitted ``serve_step`` (one token for the whole batch, cache in/out) is
the unit the dry-run lowers for the decode_32k / long_500k shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, prefill

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int = 8
    max_len: int = 2048
    temperature: float = 0.0  # 0 => greedy
    eos_id: int = 1
    seed: int = 0


def make_serve_step(cfg: ModelConfig) -> Callable:
    """serve_step(params, token (B,), cache) -> (next_logits (B, Vp), cache)."""

    def serve_step(params, token, cache):
        return decode_step(cfg, params, token, cache)

    return serve_step


def _sample(logits: Array, key: Array, temperature: float) -> Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Minimal continuous-batching-free engine: collect a batch of requests,
    right-pad prompts to a common length, batched prefill, then decode until
    all requests finish (EOS or budget)."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self._step = jax.jit(make_serve_step(cfg))
        self._key = jax.random.PRNGKey(scfg.seed)

    def run(self, requests: List[Request], side: Optional[Array] = None) -> List[Request]:
        cfg, scfg = self.cfg, self.scfg
        assert len(requests) <= scfg.batch
        while len(requests) < scfg.batch:  # pad batch with dummies
            requests.append(Request(prompt=np.array([0], np.int32), max_new_tokens=1))
        S = max(int(r.prompt.shape[0]) for r in requests)
        toks = np.zeros((scfg.batch, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - r.prompt.shape[0] :] = r.prompt  # left-pad
        last_logits, cache = prefill(
            cfg, self.params, jnp.asarray(toks), side, extra_len=scfg.max_len
        )
        budget = max(r.max_new_tokens for r in requests)
        logits = last_logits
        for t in range(budget):
            self._key, sub = jax.random.split(self._key)
            nxt = _sample(logits, sub, scfg.temperature)
            nxt_np = np.asarray(nxt)
            for i, r in enumerate(requests):
                if not r.done and t < r.max_new_tokens:
                    tok = int(nxt_np[i])
                    r.output.append(tok)
                    if tok == scfg.eos_id:
                        r.done = True
            if all(r.done or len(r.output) >= r.max_new_tokens for r in requests):
                break
            logits, cache = self._step(self.params, nxt, cache)
        return requests
