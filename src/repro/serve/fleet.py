"""Multi-replica serving fleet: task-affinity router over N schedulers.

One ``ContinuousBatchingScheduler`` is one host.  The ``FleetRouter``
fronts N of them — each replica a scheduler over its own engine holding
its own copy of the model — and adds the four things a fleet needs that a
single queue cannot provide:

  * **per-task affinity dispatch** — tasks are pinned to replicas by
    consistent hashing (a 64-bit ring with virtual nodes), so a task's
    requests keep landing where its hot per-task state (compiled tiles,
    cached Sigma rows) already lives; when the home replica's backlog runs
    ahead of the fleet, the request **spills to the least-loaded replica**
    instead of queueing behind the hot spot,
  * **deadline-aware load shedding** — the router estimates each
    candidate's queue delay (``ceil(backlog / batch) * tile_cost_s``) and,
    when EVERY candidate's estimate exceeds the request's budget (its
    relative deadline, else the router ``slo_s``), rejects at the door
    with an explicit ``SubmitOutcome(reason="shed")`` instead of admitting
    a guaranteed SLO violation.  Shed is **not** an SLO violation: the
    client got synchronous back-pressure and can retry; ``expired`` means
    the fleet accepted work it then failed — that one always counts,
  * **replica health** — a replica whose ``step()`` raises (or that an
    operator fails explicitly) is marked down; its backlog — including the
    tile the scheduler re-queued on the failure — is drained and re-pinned
    onto the survivors with original arrival stamps intact, and the hash
    ring routes around it until ``restore_replica`` brings it back
    (catching its model up to the fleet version first),
  * **rolling snapshot hot-swap with a monotonic-read guarantee** —
    ``publish_weights(W, sigma, version)`` has exactly the transport
    subscription signature, so ``transport.subscribe(router.publish_weights)``
    makes the router a second subscriber tier over the whole fleet.  A
    publish installs on ONE replica immediately and on one more per
    ``step()`` (the rolling swap: most of the fleet keeps serving the old
    snapshot while the new one warms through), and a per-client
    ``ClientToken`` carries ``min_version`` so a client is only ever
    routed to replicas at or past the newest version it has observed —
    ``ModelSnapshot.version`` never regresses for a client even mid-roll.
    If no live replica satisfies the token (its home died mid-roll), the
    router pulls the roll forward: it installs the latest snapshot on a
    survivor right then instead of rejecting.

The guarantee is the session kind: monotonic reads for SEQUENTIAL
requests per token (submit after observing the previous completion).
Publishes must flow through the router — it owns the fleet's version
space and restamps external counters into it, exactly like a single
scheduler's ``publish_weights`` — so every replica serves the same
strictly-increasing version sequence.

The router is time-agnostic: replicas and router share one injectable
clock (``VirtualClock`` for deterministic fleet sims — crash/restart,
rolling swap under load, Zipf-skewed traffic in
``benchmarks/bench_fleet.py``), and ``step()`` steps every live replica
once, which models replicas running in parallel when the driver advances
the shared clock once per round.
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
import logging
import threading
from typing import Dict, List, Optional, Sequence

from .metrics import ServingMetrics
from ..obs.metrics import publish_serving_metrics
from ..obs.trace import span

logger = logging.getLogger(__name__)
from .scheduler import (
    ContinuousBatchingScheduler,
    ModelSnapshot,
    QueueFull,
    ServeRequest,
    SubmitOutcome,
)


def _hash64(key: str) -> int:
    """Deterministic 64-bit point on the ring (blake2b; NOT Python's
    salted ``hash``, so placements are stable across processes/runs)."""
    return int.from_bytes(
        hashlib.blake2b(key.encode(), digest_size=8).digest(), "big"
    )


class ClientToken:
    """Per-client monotonic-read session token.

    ``min_version`` is the newest ``ModelSnapshot.version`` this client
    has observed on a completion; the router only admits the client's next
    request to replicas at or past it.  ``observe`` is called by
    ``FleetRouter.step`` for every completion carrying the token — clients
    never need to touch it, only hand the same token to every ``submit``
    of one logical session.
    """

    __slots__ = ("min_version", "_lock")

    def __init__(self, min_version: int = 0):
        self.min_version = int(min_version)
        self._lock = threading.Lock()

    def observe(self, version: Optional[int]) -> None:
        if version is None:
            return
        with self._lock:
            if version > self.min_version:
                self.min_version = int(version)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"ClientToken(min_version={self.min_version})"


@dataclasses.dataclass
class ReplicaHandle:
    """One fleet member: a scheduler plus its health bookkeeping."""

    id: int
    scheduler: ContinuousBatchingScheduler
    up: bool = True
    restarts: int = 0
    last_error: Optional[str] = None


class FleetRouter:
    """Task-affinity router over N ``ContinuousBatchingScheduler`` replicas.

    Parameters
    ----------
    replicas : the fleet members, homogeneous engines (same W shape, same
        ``batch``); replica i's id is its index.
    slo_s : default shed budget for requests submitted WITHOUT a deadline
        (a request's own relative deadline wins).  None + no deadline =
        that request is never shed.
    tile_cost_s : estimated service time of one tile, the unit of the
        router's queue-delay estimate.  None disables estimate-based
        shedding (bounded queues still reject).  When the router observes
        real (clock-visible) step durations it refines this with an EWMA.
    spill_depth : home-replica backlog (pending requests) beyond which a
        request may spill to the least-loaded candidate; default
        ``2 * batch``.
    vnodes : virtual nodes per replica on the hash ring (placement
        smoothness; 64 keeps the max/mean task load ratio low).
    """

    def __init__(
        self,
        replicas: Sequence[ContinuousBatchingScheduler],
        *,
        slo_s: Optional[float] = None,
        tile_cost_s: Optional[float] = None,
        spill_depth: Optional[int] = None,
        vnodes: int = 64,
    ):
        if not replicas:
            raise ValueError("a fleet needs at least one replica")
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        self._handles = [
            ReplicaHandle(id=i, scheduler=s) for i, s in enumerate(replicas)
        ]
        self.slo_s = slo_s
        self.tile_cost_s = tile_cost_s
        batch = int(replicas[0].engine.batch)
        self.spill_depth = (
            int(spill_depth) if spill_depth is not None else 2 * batch
        )
        if self.spill_depth < 1:
            raise ValueError(f"spill_depth must be >= 1, got {self.spill_depth}")
        self._task_key = getattr(
            replicas[0].engine, "task_key", lambda r: None
        )
        self.clock = replicas[0].clock
        # consistent-hash ring: vnodes points per replica, sorted once
        self._ring = sorted(
            (_hash64(f"replica:{h.id}:vnode:{v}"), h.id)
            for h in self._handles
            for v in range(vnodes)
        )
        self._ring_points = [p for p, _ in self._ring]
        # the fleet's version space: _latest is the newest snapshot any
        # replica may serve; rolling swaps converge every UP replica to it
        self._latest: ModelSnapshot = max(
            (h.scheduler.snapshot for h in self._handles),
            key=lambda s: s.version,
        )
        self._version = self._latest.version
        self._lock = threading.RLock()
        self.counters: Dict[str, int] = {
            "submitted": 0,
            "admitted": 0,
            "spills": 0,
            "shed": 0,
            "queue_full": 0,
            "no_replica": 0,
            "expired_at_door": 0,
            "publishes": 0,
            "rolled_installs": 0,
            "pull_forwards": 0,
            "failovers": 0,
            "requeued": 0,
            "requeue_shed": 0,
            "restarts": 0,
        }

    # -- introspection ------------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return len(self._handles)

    @property
    def n_up(self) -> int:
        return sum(1 for h in self._handles if h.up)

    @property
    def version(self) -> int:
        """The fleet's target version (the roll converges every up replica
        to it; individual replicas may still be behind mid-roll)."""
        with self._lock:
            return self._version

    @property
    def pending(self) -> int:
        return sum(h.scheduler.pending for h in self._handles)

    @property
    def in_flight(self) -> int:
        return sum(h.scheduler.in_flight for h in self._handles)

    def replica(self, rid: int) -> ReplicaHandle:
        return self._handles[rid]

    def session(self, min_version: int = 0) -> ClientToken:
        """A fresh monotonic-read token for one client session."""
        return ClientToken(min_version)

    def home_of(self, task: int) -> int:
        """Ring lookup only (ignores health/load): the replica id task
        traffic is pinned to while the fleet is healthy and balanced."""
        return self._chain(task)[0]

    # -- ring ---------------------------------------------------------------
    def _chain(self, task) -> List[int]:
        """Replica ids in ring order starting at ``task``'s successor:
        element 0 is the home, the rest the failover order."""
        h = _hash64(f"task:{task}")
        start = bisect.bisect_right(self._ring_points, h) % len(self._ring)
        chain: List[int] = []
        for i in range(len(self._ring)):
            rid = self._ring[(start + i) % len(self._ring)][1]
            if rid not in chain:
                chain.append(rid)
                if len(chain) == len(self._handles):
                    break
        return chain

    def _est_wait_s(self, h: ReplicaHandle) -> float:
        """Queue-delay estimate if one more request joined ``h``'s queue."""
        if not self.tile_cost_s:
            return 0.0
        batch = int(h.scheduler.engine.batch)
        tiles_ahead = h.scheduler.pending // batch + 1
        return tiles_ahead * self.tile_cost_s

    def _pick(
        self, task, candidates: List[ReplicaHandle], *, count_spill: bool
    ) -> ReplicaHandle:
        """Affinity target among ``candidates``: the first chain member
        present, unless its backlog warrants a spill to the least loaded."""
        least = min(candidates, key=lambda h: (h.scheduler.pending, h.id))
        if task is None:
            return least
        by_id = {h.id: h for h in candidates}
        home = next(
            (by_id[rid] for rid in self._chain(task) if rid in by_id), least
        )
        if (
            home.scheduler.pending >= self.spill_depth
            and least.scheduler.pending < home.scheduler.pending
        ):
            if count_spill:
                self.counters["spills"] += 1
            return least
        return home

    # -- ingress ------------------------------------------------------------
    def submit(
        self,
        req: ServeRequest,
        *,
        deadline_s: Optional[float] = None,
        client: Optional[ClientToken] = None,
    ) -> SubmitOutcome:
        """Route one request: affinity + spill + monotonic-read filter +
        shed.  Never raises for capacity — rejects come back as explicit
        ``SubmitOutcome``s (``shed`` / ``queue_full`` / ``no_replica`` /
        ``expired``), unlike a bare scheduler's ``QueueFull``."""
        with self._lock:
            return self._submit_locked(req, deadline_s, client)

    def submit_many(
        self,
        reqs: Sequence[ServeRequest],
        *,
        deadline_s: Optional[float] = None,
        client: Optional[ClientToken] = None,
    ) -> List[SubmitOutcome]:
        return [
            self.submit(r, deadline_s=deadline_s, client=client) for r in reqs
        ]

    def _submit_locked(self, req, deadline_s, client) -> SubmitOutcome:
        self.counters["submitted"] += 1
        up = [h for h in self._handles if h.up]
        if not up:
            req.status = "shed"
            self.counters["no_replica"] += 1
            return SubmitOutcome(request=req, admitted=False, reason="no_replica")
        minv = client.min_version if client is not None else 0
        candidates = [h for h in up if h.scheduler.version >= minv]
        if not candidates:
            # monotonic-read pull-forward: every replica at this client's
            # version died mid-roll; install the latest snapshot (whose
            # version is >= anything any client ever observed) on a
            # survivor NOW instead of rejecting
            h = min(up, key=lambda h: (h.scheduler.pending, h.id))
            self._install_locked(h, self._latest)
            self.counters["pull_forwards"] += 1
            candidates = [h]
        budget = deadline_s if deadline_s is not None else self.slo_s
        if budget is not None and self.tile_cost_s:
            if min(self._est_wait_s(h) for h in candidates) > budget:
                req.status = "shed"
                self.counters["shed"] += 1
                return SubmitOutcome(request=req, admitted=False, reason="shed")
        task = self._task_key(req)
        target = self._pick(task, candidates, count_spill=True)
        order = [target] + sorted(
            (h for h in candidates if h is not target),
            key=lambda h: (h.scheduler.pending, h.id),
        )
        for h in order:
            try:
                r = h.scheduler.submit(req, deadline_s=deadline_s)
            except QueueFull:
                continue
            if r.status == "expired":
                self.counters["expired_at_door"] += 1
                return SubmitOutcome(
                    request=req, admitted=False, reason="expired", replica=h.id
                )
            if client is not None:
                req._fleet_client = client
            self.counters["admitted"] += 1
            return SubmitOutcome(request=req, admitted=True, replica=h.id)
        # every candidate's bounded queue rejected: scheduler-level shed
        req.status = "shed"
        self.counters["queue_full"] += 1
        return SubmitOutcome(request=req, admitted=False, reason="queue_full")

    # -- model publish (rolling hot-swap) -----------------------------------
    def publish_weights(
        self, W, sigma=None, version: Optional[int] = None
    ) -> int:
        """Install a new model FLEET-wide as a rolling swap.

        Exactly the ``core.transport`` subscription signature
        (``callback(W, sigma, version)``), so the router is a drop-in
        second subscriber tier: ``transport.subscribe(router.publish_weights)``
        rolls every training install across the fleet; so is an estimator
        push (``est.serving_fleet`` registers the router the same way it
        registers single schedulers).  External version counters are
        restamped into the fleet's monotone version space when not ahead
        of it.  The snapshot lands on ONE replica immediately; each
        subsequent ``step()`` converges one more replica, so the fleet
        keeps serving throughout.  Returns the fleet version installed.
        """
        # shape-check eagerly so a bad publish fails the publisher, not a
        # later roll step
        validate = getattr(
            self._handles[0].scheduler.engine, "validate_snapshot", None
        )
        if validate is not None:
            validate(ModelSnapshot(version=0, W=W, sigma=sigma))
        with self._lock:
            cur = max(
                [self._version]
                + [h.scheduler.version for h in self._handles]
            )
            v = int(version) if version is not None else cur + 1
            if v <= cur:
                v = cur + 1
            self._version = v
            self._latest = ModelSnapshot(version=v, W=W, sigma=sigma)
            self.counters["publishes"] += 1
            self._advance_roll_locked()
        return v

    def publish(self, snapshot: ModelSnapshot) -> int:
        """Snapshot-level publish convenience (delegates to the rolling
        ``publish_weights``; the version is restamped if not ahead)."""
        if not isinstance(snapshot, ModelSnapshot):
            raise TypeError(
                f"publish takes a ModelSnapshot, got {type(snapshot).__name__}"
            )
        return self.publish_weights(
            snapshot.W, snapshot.sigma, version=snapshot.version
        )

    def _install_locked(self, h: ReplicaHandle, snap: ModelSnapshot) -> None:
        if h.scheduler.version < snap.version:
            h.scheduler.publish(snap)
            self.counters["rolled_installs"] += 1

    def _advance_roll_locked(self) -> bool:
        """Converge ONE lagging up replica to the latest snapshot."""
        for h in self._handles:
            if h.up and h.scheduler.version < self._latest.version:
                self._install_locked(h, self._latest)
                return True
        return False

    @property
    def roll_pending(self) -> int:
        """Up replicas still behind the fleet version (0 = roll complete)."""
        with self._lock:
            return sum(
                1
                for h in self._handles
                if h.up and h.scheduler.version < self._latest.version
            )

    # -- health -------------------------------------------------------------
    def fail_replica(self, rid: int, error: Optional[str] = None) -> int:
        """Mark a replica dead and fail its backlog over to the survivors
        (the same path ``step()`` takes when a replica raises).  Returns
        the number of requests re-pinned."""
        with self._lock:
            return self._fail_locked(self._handles[rid], error or "failed by operator")

    def _fail_locked(self, h: ReplicaHandle, error: str) -> int:
        with span("failover", cat="serve", replica=h.id):
            return self._fail_over(h, error)

    def _fail_over(self, h: ReplicaHandle, error: str) -> int:
        if not h.up:
            return 0
        h.up = False
        h.last_error = error
        self.counters["failovers"] += 1
        stranded = h.scheduler.drain_queue()
        logger.warning(
            "replica %d failed at snapshot version %d (%s); failing over "
            "%d stranded request(s)",
            h.id,
            h.scheduler.version,
            error,
            len(stranded),
        )
        moved = 0
        for req in stranded:
            client = getattr(req, "_fleet_client", None)
            minv = client.min_version if client is not None else 0
            up = [x for x in self._handles if x.up]
            candidates = [x for x in up if x.scheduler.version >= minv]
            if not candidates and up:
                x = min(up, key=lambda h: (h.scheduler.pending, h.id))
                self._install_locked(x, self._latest)
                self.counters["pull_forwards"] += 1
                candidates = [x]
            placed = False
            if candidates:
                target = self._pick(
                    self._task_key(req), candidates, count_spill=False
                )
                order = [target] + sorted(
                    (x for x in candidates if x is not target),
                    key=lambda x: (x.scheduler.pending, x.id),
                )
                for x in order:
                    try:
                        if x.scheduler.requeue([req]):
                            moved += 1
                        # an empty requeue result = expired in transit:
                        # accounted by the receiving queue, not shed
                        placed = True
                        break
                    except QueueFull:
                        continue
            if not placed:
                req.status = "shed"
                self.counters["requeue_shed"] += 1
        self.counters["requeued"] += moved
        return moved

    def restore_replica(self, rid: int) -> None:
        """Bring a dead replica back: catch its model up to the fleet
        version FIRST (a revived replica must never serve a snapshot a
        client could have moved past), then rejoin the ring."""
        with self._lock:
            h = self._handles[rid]
            if h.up:
                return
            self._install_locked(h, self._latest)
            h.up = True
            h.last_error = None
            h.restarts += 1
            self.counters["restarts"] += 1
            logger.info(
                "replica %d restored at snapshot version %d (restart #%d)",
                h.id,
                h.scheduler.version,
                h.restarts,
            )

    # -- serving ------------------------------------------------------------
    def step(self) -> List[ServeRequest]:
        """One fleet round: advance the rolling swap by one replica, step
        every live replica once (replicas run in parallel — a driver on a
        virtual clock advances time once per round, not per replica), fail
        over any replica whose engine raised, and return everything that
        completed.  Completions update their clients' monotonic-read
        tokens before the requests are handed back."""
        with span("fleet_step", cat="serve", replicas=self.n_up):
            with self._lock:
                self._advance_roll_locked()
                handles = [h for h in self._handles if h.up]
            done: List[ServeRequest] = []
            for h in handles:
                try:
                    done.extend(h.scheduler.step())
                except Exception as exc:  # replica crash: fail over, keep serving
                    with self._lock:
                        self._fail_locked(h, repr(exc))
            for r in done:
                client = getattr(r, "_fleet_client", None)
                if client is not None:
                    client.observe(r.snapshot_version)
            return done

    def run_until_idle(self, max_steps: int = 1_000_000) -> int:
        """Step until every queue drains; returns requests completed."""
        total = 0
        for _ in range(max_steps):
            n = len(self.step())
            total += n
            if not n and not self.pending and not self.in_flight:
                break
        return total

    def warmup(self) -> None:
        """AOT-warm every replica engine ahead of traffic.  Homogeneous
        MTL replicas compile ONCE: the first engine pays the compile, the
        rest adopt its executable (``MTLScoringEngine.adopt_warmup``)."""
        donor = None
        for h in self._handles:
            eng = h.scheduler.engine
            adopt = getattr(eng, "adopt_warmup", None)
            if donor is not None and adopt is not None and adopt(donor):
                continue
            warm = getattr(eng, "warmup", None)
            if warm is not None:
                warm()
                if donor is None:
                    donor = eng

    # -- rollup -------------------------------------------------------------
    def metrics(self) -> ServingMetrics:
        """Fleet-level metrics: every replica's counters/histograms merged
        (``ServingMetrics.merge``) into one point-in-time rollup."""
        per = [h.scheduler.metrics for h in self._handles]
        return per[0].merge(*per[1:]) if len(per) > 1 else per[0]

    def publish_metrics(self, registry=None) -> None:
        """Bridge the fleet's ServingMetrics into the obs registry:
        the merged rollup as ``replica="all"`` plus one labeled series
        per replica — the machine-readable autoscaling signals."""
        publish_serving_metrics(self.metrics(), replica="all", registry=registry)
        for h in self._handles:
            publish_serving_metrics(
                h.scheduler.metrics, replica=str(h.id), registry=registry
            )

    def summary(self) -> Dict[str, object]:
        """JSON-ready fleet record: router counters + merged replica
        metrics + per-replica health (the ``BENCH_fleet.json`` row shape)."""
        with self._lock:
            return {
                "replicas": self.n_replicas,
                "up": self.n_up,
                "version": self._version,
                "roll_pending": sum(
                    1
                    for h in self._handles
                    if h.up and h.scheduler.version < self._latest.version
                ),
                "router": dict(self.counters),
                "fleet": self.metrics().summary(),
                "per_replica": [
                    {
                        "id": h.id,
                        "up": h.up,
                        "restarts": h.restarts,
                        "version": h.scheduler.version,
                        "pending": h.scheduler.pending,
                        "completed": h.scheduler.metrics.completed,
                        "expired": h.scheduler.metrics.expired,
                    }
                    for h in self._handles
                ],
            }
