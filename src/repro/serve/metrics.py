"""Serving metrics: latency histograms, throughput, queue depth, SLO accounting.

One ``ServingMetrics`` object aggregates everything a scheduler run emits:

  * end-to-end latency (arrival -> completion) as a ``LatencyHistogram``
    with sample-based p50/p95/p99 percentiles (exact up to
    ``max_samples`` observations, deterministically subsampled beyond)
    plus log-spaced bucket counts,
  * request counters (submitted / completed / rejected / expired) overall
    and per task,
  * queue depth (last observed + high-water mark),
  * tile packing utilisation (filled slots / total slots of every packed
    tile — the cost of serving partial tiles through a fixed-shape step),
  * SLO-violation accounting: a completed request violates when its
    latency exceeds ``slo_s`` or it finished past its deadline; a request
    expired at admission or packing (deadline already passed) always
    counts as a violation,
  * model hot-swaps observed.

The object is passive — the scheduler computes timestamps/latencies with
ITS clock and calls the ``on_*`` observers, so a virtual clock drives the
metrics exactly like a wall clock (deterministic tests, simulated-time
load benchmarks). ``summary()`` returns a JSON-ready dict; that is the
record ``benchmarks/bench_serving.py`` writes to ``BENCH_serving.json``.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np

# log-spaced latency bucket upper bounds: 1us .. 100s, 4 per decade
BUCKET_BOUNDS = 10.0 ** np.linspace(-6.0, 2.0, 33)


class LatencyHistogram:
    """Latency distribution: sample-based percentiles + log bucket counts.

    Samples are retained for ``np.percentile`` quantiles — exact while
    the observation count stays within ``max_samples``; past that the
    reservoir decimates deterministically (keep every 2nd sample, double
    the retention stride), so percentiles become a uniform-stride
    approximation while memory stays bounded and bucket counts, count,
    mean and max remain exact.
    """

    def __init__(self, max_samples: int = 100_000):
        if max_samples < 2:
            raise ValueError(f"max_samples must be >= 2, got {max_samples}")
        self.max_samples = int(max_samples)
        self._samples: List[float] = []
        self._stride = 1
        self._seen = 0
        self._sum = 0.0
        self._max = 0.0
        self.counts = np.zeros(len(BUCKET_BOUNDS) + 1, np.int64)

    @property
    def count(self) -> int:
        return self._seen

    def observe(self, value_s: float) -> None:
        v = float(value_s)
        self._seen += 1
        self._sum += v
        self._max = max(self._max, v)
        self.counts[int(np.searchsorted(BUCKET_BOUNDS, v, side="left"))] += 1
        if (self._seen - 1) % self._stride == 0:
            self._samples.append(v)
            if len(self._samples) > self.max_samples:
                self._samples = self._samples[::2]
                self._stride *= 2

    def percentile(self, q: float) -> float:
        if not self._samples:
            return 0.0
        return float(np.percentile(np.asarray(self._samples), q))

    def merge(self, *others: "LatencyHistogram") -> "LatencyHistogram":
        """Combine this histogram with ``others`` into a NEW histogram
        (the inputs are untouched) — per-replica latency distributions
        roll up into one fleet-level view, and multi-run bench records
        aggregate the same way.

        Counts, sums, maxima and bucket rows merge exactly.  Percentiles
        merge from the retained samples: every input is first decimated to
        the coarsest stride among the inputs (strides are powers of two,
        so the decimation is exact), keeping each input's samples a
        uniform-stride subsample of its observations — the same guarantee
        a single over-full histogram gives — then the merged reservoir
        decimates again if it exceeds ``max_samples``.
        """
        hists = (self,) + tuple(others)
        out = LatencyHistogram(max_samples=self.max_samples)
        out._seen = sum(h._seen for h in hists)
        out._sum = sum(h._sum for h in hists)
        out._max = max(h._max for h in hists)
        out.counts = np.sum([h.counts for h in hists], axis=0)
        stride = max(h._stride for h in hists)
        samples: List[float] = []
        for h in hists:
            samples.extend(h._samples[:: stride // h._stride])
        out._stride = stride
        out._samples = samples
        while len(out._samples) > out.max_samples:
            out._samples = out._samples[::2]
            out._stride *= 2
        return out

    def buckets(self) -> List[Dict[str, float]]:
        """Non-cumulative ``{"le": bound, "count": n}`` rows (last row has
        ``le=inf``); only non-empty buckets are emitted."""
        rows = []
        for i, c in enumerate(self.counts):
            if c:
                le = (
                    float(BUCKET_BOUNDS[i])
                    if i < len(BUCKET_BOUNDS)
                    else float("inf")
                )
                rows.append({"le": le, "count": int(c)})
        return rows

    def summary(self) -> Dict[str, float]:
        n = self._seen
        return {
            "count": n,
            "mean_s": self._sum / n if n else 0.0,
            "max_s": self._max,
            "p50_s": self.percentile(50.0),
            "p95_s": self.percentile(95.0),
            "p99_s": self.percentile(99.0),
        }


def _task_row() -> Dict[str, int]:
    return {"submitted": 0, "completed": 0, "expired": 0, "slo_violations": 0}


class ServingMetrics:
    """Aggregate serving counters + SLO accounting for one scheduler."""

    def __init__(
        self,
        slo_s: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if slo_s is not None and slo_s <= 0:
            raise ValueError(f"slo_s must be positive, got {slo_s}")
        self.slo_s = slo_s
        self._clock = clock
        self._t0 = clock()
        self.latency = LatencyHistogram()
        self.ttft = LatencyHistogram()  # arrival -> first sampled token
        self.decode_steps = 0
        self.decode_occupied = 0
        self.decode_slots = 0
        self.submitted = 0
        self.completed = 0
        self.rejected = 0
        self.expired = 0
        self.slo_violations = 0
        self.swaps = 0
        self.last_version: Optional[int] = None
        self.queue_depth = 0
        self.queue_depth_max = 0
        self.tiles = 0
        self.tile_slots = 0
        self.tile_filled = 0
        self.per_task: Dict[int, Dict[str, int]] = {}

    # -- observers (called by the scheduler with ITS clock/latencies) -------
    def _task(self, task: Optional[int]) -> Optional[Dict[str, int]]:
        if task is None:
            return None
        return self.per_task.setdefault(int(task), _task_row())

    def on_submit(self, task: Optional[int] = None) -> None:
        self.submitted += 1
        row = self._task(task)
        if row is not None:
            row["submitted"] += 1

    def on_reject(self, task: Optional[int] = None) -> None:
        self.rejected += 1

    def on_expired(self, task: Optional[int] = None) -> None:
        """A request dropped because its deadline passed before it could be
        packed: always an SLO violation."""
        self.expired += 1
        self.slo_violations += 1
        row = self._task(task)
        if row is not None:
            row["expired"] += 1
            row["slo_violations"] += 1

    def on_complete(
        self, task: Optional[int], latency_s: float, violated: bool
    ) -> None:
        self.completed += 1
        self.latency.observe(latency_s)
        row = self._task(task)
        if row is not None:
            row["completed"] += 1
        if violated:
            self.slo_violations += 1
            if row is not None:
                row["slo_violations"] += 1

    def on_tile(self, filled: int, slots: int) -> None:
        self.tiles += 1
        self.tile_filled += int(filled)
        self.tile_slots += int(slots)

    def on_first_token(self, ttft_s: float) -> None:
        """A streaming engine sampled a request's FIRST token (at inject:
        the prefill logits); latency so far is the time-to-first-token."""
        self.ttft.observe(ttft_s)

    def on_decode_step(self, occupied: int, slots: int) -> None:
        """One decode step advanced ``occupied`` of ``slots`` batch rows:
        the continuous-batching utilisation signal (a head-of-line-blocked
        engine shows long tails of near-empty steps; per-slot recycling
        keeps occupancy near 1 under load)."""
        self.decode_steps += 1
        self.decode_occupied += int(occupied)
        self.decode_slots += int(slots)

    def on_swap(self, version: int) -> None:
        self.swaps += 1
        self.last_version = int(version)

    def observe_queue_depth(self, depth: int) -> None:
        self.queue_depth = int(depth)
        self.queue_depth_max = max(self.queue_depth_max, int(depth))

    # -- fleet rollup -------------------------------------------------------
    def merge(self, *others: "ServingMetrics") -> "ServingMetrics":
        """Roll this object and ``others`` up into ONE new ServingMetrics
        (inputs untouched): counters and per-task rows sum, latency/ttft
        histograms merge from retained samples (``LatencyHistogram.merge``),
        queue-depth high-water is the max across queues, ``last_version``
        the newest.  The merged elapsed time is the MAX of the inputs'
        elapsed times frozen at merge time — replicas serve the same
        wall/virtual window in parallel, so fleet throughput is total
        completions over that shared window, not over the sum.
        """
        all_m = (self,) + tuple(others)
        out = ServingMetrics(slo_s=self.slo_s, clock=self._clock)
        # freeze elapsed at merge time: rollups are point-in-time records
        elapsed = max(m.elapsed_s() for m in all_m)
        out._t0 = self._clock() - elapsed
        out.latency = self.latency.merge(*(m.latency for m in others))
        out.ttft = self.ttft.merge(*(m.ttft for m in others))
        for field in (
            "decode_steps", "decode_occupied", "decode_slots", "submitted",
            "completed", "rejected", "expired", "slo_violations", "swaps",
            "queue_depth", "tiles", "tile_slots", "tile_filled",
        ):
            setattr(out, field, sum(getattr(m, field) for m in all_m))
        out.queue_depth_max = max(m.queue_depth_max for m in all_m)
        versions = [m.last_version for m in all_m if m.last_version is not None]
        out.last_version = max(versions) if versions else None
        for m in all_m:
            for task, row in m.per_task.items():
                dst = out.per_task.setdefault(task, _task_row())
                for k, v in row.items():
                    dst[k] += v
        return out

    # -- derived ------------------------------------------------------------
    def elapsed_s(self) -> float:
        return self._clock() - self._t0

    def throughput(self) -> float:
        """Completed requests per (scheduler-clock) second."""
        dt = self.elapsed_s()
        return self.completed / dt if dt > 0 else 0.0

    def tile_fill(self) -> float:
        """Mean fraction of tile slots carrying real requests."""
        return self.tile_filled / self.tile_slots if self.tile_slots else 0.0

    def slot_occupancy(self) -> float:
        """Mean fraction of decode-batch rows advancing a live request
        per decode step (streaming engines only)."""
        return (
            self.decode_occupied / self.decode_slots if self.decode_slots else 0.0
        )

    def summary(self) -> Dict[str, object]:
        """JSON-ready snapshot (the ``BENCH_serving.json`` row shape)."""
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "expired": self.expired,
            "slo_s": self.slo_s,
            "slo_violations": self.slo_violations,
            "swaps": self.swaps,
            "last_version": self.last_version,
            "elapsed_s": self.elapsed_s(),
            "throughput_rps": self.throughput(),
            "queue_depth_max": self.queue_depth_max,
            "tiles": self.tiles,
            "tile_fill": self.tile_fill(),
            "decode_steps": self.decode_steps,
            "slot_occupancy": self.slot_occupancy(),
            "ttft": self.ttft.summary(),
            "latency": self.latency.summary(),
            "latency_buckets": self.latency.buckets(),
            "per_task": {str(k): dict(v) for k, v in sorted(self.per_task.items())},
        }
