"""Batched MTL scoring: request queue -> fixed-shape jitted score step.

The MTL analogue of ``serve/engine.py``: requests carry (task_id, feature
vector), the engine packs them into fixed (batch, d) tiles so ONE jitted
computation serves every batch (no per-request recompilation), gathers the
per-task weight rows, and returns raw scores plus +-1 labels for
classification models.

    est = DMTRLEstimator(...).fit(train)
    eng = est.scoring_engine(batch=64)          # or MTLScoringEngine(W)
    done = eng.run([ScoreRequest(task=3, x=phi), ...])
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class ScoreRequest:
    """One scoring request: task id + feature vector (phi already applied).

    The engine fills ``score`` (raw margin w_task^T x) and, for
    classification models, ``label`` (+-1).
    """

    task: int
    x: np.ndarray  # (d,)
    score: Optional[float] = None
    label: Optional[float] = None


def make_score_step(W: Array):
    """score_step(X (B, d), tasks (B,)) -> (B,) margins; jit-able, fixed
    batch shape so all batches share one executable. Same kernel as the
    estimator's predict path (core/dual.py:task_scores)."""
    from repro.core.dual import task_scores

    def score_step(X, tasks):
        return task_scores(W, X, tasks)

    return score_step


class MTLScoringEngine:
    """Minimal batched scorer over a fitted task-weight matrix W (m, d).

    Requests are packed into fixed-size (batch, d) tiles (the last tile is
    padded with task-0 zero rows) so the jitted step never retraces; the
    padding rows are dropped before results are written back.
    """

    def __init__(self, W, batch: int = 32, classify: bool = True):
        self.W = jnp.asarray(W)
        if self.W.ndim != 2:
            raise ValueError(f"W must be (m, d), got {self.W.shape}")
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.batch = int(batch)
        self.classify = bool(classify)
        self._step = jax.jit(make_score_step(self.W))

    @property
    def m(self) -> int:
        return int(self.W.shape[0])

    @property
    def d(self) -> int:
        return int(self.W.shape[1])

    def _validate(self, r: ScoreRequest) -> None:
        if not 0 <= int(r.task) < self.m:
            raise ValueError(
                f"task id {r.task} out of range [0, {self.m})"
            )
        x = np.asarray(r.x)
        if x.shape != (self.d,):
            raise ValueError(
                f"request feature shape {x.shape} != ({self.d},)"
            )

    def run(self, requests: List[ScoreRequest]) -> List[ScoreRequest]:
        """Score all requests in fixed-shape batches; fills score/label
        in place and returns the same list. Delegates the pad/tile/score
        loop to ``score_batch`` so there is exactly one scoring path."""
        for r in requests:
            self._validate(r)
        if not requests:
            return requests
        X = np.stack([np.asarray(r.x, np.float32) for r in requests])
        t = np.asarray([int(r.task) for r in requests], np.int32)
        z = self.score_batch(X, t)
        for r, zi in zip(requests, z):
            r.score = float(zi)
            if self.classify:
                r.label = 1.0 if zi >= 0.0 else -1.0
        return requests

    def score_batch(self, X, tasks) -> np.ndarray:
        """Array-in/array-out fast path: (n, d) features + (n,) task ids ->
        (n,) margins through the same fixed-shape jitted step, with no
        per-row request objects (pad with numpy, slice tiles)."""
        X = np.asarray(X, np.float32)
        if X.ndim != 2 or X.shape[1] != self.d:
            raise ValueError(f"X must be (n, {self.d}), got {X.shape}")
        t = np.ascontiguousarray(
            np.broadcast_to(np.asarray(tasks, np.int32), (X.shape[0],))
        )
        if t.size and (t.min() < 0 or t.max() >= self.m):
            raise ValueError(
                f"task id out of range [0, {self.m}): [{t.min()}, {t.max()}]"
            )
        n, B = X.shape[0], self.batch
        pad = (-n) % B
        if pad:
            X = np.concatenate([X, np.zeros((pad, self.d), np.float32)])
            t = np.concatenate([t, np.zeros((pad,), np.int32)])
        out = np.empty((X.shape[0],), np.float32)
        for lo in range(0, X.shape[0], B):
            out[lo : lo + B] = np.asarray(
                self._step(jnp.asarray(X[lo : lo + B]), jnp.asarray(t[lo : lo + B]))
            )
        return out[:n]
