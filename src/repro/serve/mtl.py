"""Batched MTL scoring: fixed-shape jitted tiles over a hot-swappable W.

The MTL analogue of ``serve/engine.py``: requests carry (task_id, feature
vector), the engine packs them into fixed (batch, d) tiles so ONE jitted
computation serves every batch (no per-request recompilation), gathers the
per-task weight rows, and returns raw scores plus +-1 labels for
classification models.

The engine serves a versioned ``ModelSnapshot`` (W, sigma, version) and
swaps it live: ``publish``/``swap`` install a new same-shape W without
retracing (W is an ARGUMENT of the jitted step, not a closure), and
``refresh()`` pulls the newest snapshot from the estimator that built the
engine — the fix for the stale-weights footgun where an engine created
before ``partial_fit`` silently kept serving the old weights.

Two call surfaces, one scoring/validation path:

    eng = est.scoring_engine(batch=64)           # or MTLScoringEngine(W)
    done = eng.run([ScoreRequest(task=3, x=phi), ...])   # blocking batch
    sched = est.serving_scheduler(batch=64)      # continuous batching
    sched.submit(ScoreRequest(task=3, x=phi)); sched.step()

``run`` / ``run_tile`` / ``score_batch`` all validate through
``_validate_batch`` (task range + feature width) exactly once, and all
score through the same pad/tile loop.
"""
from __future__ import annotations

import dataclasses
import threading
import weakref
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .scheduler import ModelSnapshot, ServeRequest

Array = jax.Array


@dataclasses.dataclass
class ScoreRequest(ServeRequest):
    """One scoring request: task id + feature vector (phi already applied).

    The engine fills ``score`` (raw margin w_task^T x) and, for
    classification models, ``label`` (+-1); the scheduler additionally
    stamps the queue fields inherited from ``ServeRequest`` (arrival,
    deadline, status, ``snapshot_version``).
    """

    task: int
    x: np.ndarray  # (d,)
    score: Optional[float] = None
    label: Optional[float] = None
    # filled by ``run_tile`` when the engine was built with
    # ``gather_sigma_rows=True`` and the packed snapshot carries a Sigma:
    # this request's task-relatedness row Sigma[task] (m,) — gathered
    # sparsely from the structured factors, never via a dense (m, m)
    sigma_row: Optional[np.ndarray] = None


def make_score_step():
    """score_step(W (m, d), X (B, d), tasks (B,)) -> (B,) margins.

    W is a runtime argument, not a closure: a hot-swapped W of the same
    shape reuses the compiled executable (no retrace on ``publish``).
    Same kernel as the estimator's predict path (core/dual.py:task_scores).
    """
    from repro.core.dual import task_scores

    def score_step(W, X, tasks):
        return task_scores(W, X, tasks)

    return score_step


def make_sigma_gather():
    """gather(sigma, tasks (B,)) -> (B, m) Sigma rows of a tile's tasks.

    ``sigma`` is a jit ARGUMENT (dense array or SigmaView pytree), keyed by
    the tile's task ids at the fixed batch shape — so one compiled gather
    serves every tile and a hot-swapped same-shape snapshot never retraces.
    A SigmaView gathers from its factors (O(B * m) work / output, no dense
    (m, m) ever); a dense Sigma is a plain row take.
    """
    from repro.core.sigma_view import SigmaView

    def gather(sigma, tasks):
        if isinstance(sigma, SigmaView):
            return sigma.rows(tasks)
        return jnp.asarray(sigma)[tasks]

    return gather


class MTLScoringEngine:
    """Batched scorer over a versioned task-weight matrix W (m, d).

    Requests are packed into fixed-size (batch, d) tiles (the last tile is
    padded with task-0 zero rows) so the jitted step never retraces; the
    padding rows are dropped before results are written back. Implements
    the scheduler adapter surface (``admit`` / ``run_tile`` /
    ``model_snapshot`` / ``task_key``) so it can sit behind a
    ``ContinuousBatchingScheduler``.
    """

    def __init__(
        self,
        W,
        batch: int = 32,
        classify: bool = True,
        *,
        version: int = 0,
        source=None,
        sigma=None,
        gather_sigma_rows: bool = False,
    ):
        W = jnp.asarray(W)
        if W.ndim != 2:
            raise ValueError(f"W must be (m, d), got {W.shape}")
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.batch = int(batch)
        self.classify = bool(classify)
        self.gather_sigma_rows = bool(gather_sigma_rows)
        self._snapshot = ModelSnapshot(version=int(version), W=W, sigma=sigma)
        self._step = jax.jit(make_score_step())
        self._gather = jax.jit(make_sigma_gather())
        self._step_exe = None  # AOT executable installed by warmup()
        self._source = weakref.ref(source) if source is not None else None
        # serializes the swap surface (publish/swap/publish_weights/refresh)
        # against concurrent publishers; scoring reads one snapshot ref and
        # needs no lock
        self._swap_lock = threading.RLock()

    # -- model surface ------------------------------------------------------
    @property
    def W(self) -> Array:
        return self._snapshot.W

    @property
    def version(self) -> int:
        return self._snapshot.version

    @property
    def m(self) -> int:
        return int(self.W.shape[0])

    @property
    def d(self) -> int:
        return int(self.W.shape[1])

    def model_snapshot(self) -> ModelSnapshot:
        return self._snapshot

    def validate_snapshot(self, snapshot: ModelSnapshot) -> None:
        """Hot-swap admission: W must keep the serving shape so the
        compiled step is reused and task ids stay valid. The scheduler
        calls this before installing any published snapshot."""
        W = jnp.asarray(snapshot.W)
        if W.shape != self.W.shape:
            raise ValueError(
                f"hot-swap W shape {W.shape} != serving shape {self.W.shape}"
            )

    def publish(self, snapshot: ModelSnapshot) -> int:
        """Install a newer (W, sigma, version); shape must match so the
        compiled step is reused and task ids stay valid. Re-delivering the
        current version is an idempotent no-op; an older version raises."""
        self.validate_snapshot(snapshot)
        W = jnp.asarray(snapshot.W)
        with self._swap_lock:
            if snapshot.version == self._snapshot.version:
                return self._snapshot.version
            if snapshot.version < self._snapshot.version:
                raise ValueError(
                    f"snapshot version {snapshot.version} is not newer than "
                    f"the installed version {self._snapshot.version}"
                )
            self._snapshot = dataclasses.replace(snapshot, W=W)
            return self._snapshot.version

    def swap(self, W, sigma=None, version: Optional[int] = None) -> int:
        """Array-level hot-swap (auto-increments the version)."""
        with self._swap_lock:
            if version is None:
                version = self._snapshot.version + 1
            return self.publish(
                ModelSnapshot(version=int(version), W=W, sigma=sigma)
            )

    def publish_weights(self, W, sigma=None, version: Optional[int] = None) -> int:
        """Restamping array-level publish: an external producer's version
        counter (estimator model version, transport install counter) that
        is not ahead of this engine's is re-stamped into the engine's own
        monotone space, so a push from an independent producer ALWAYS
        installs its weights instead of colliding (same atomic
        compute-and-install contract as
        ``ContinuousBatchingScheduler.publish_weights``)."""
        with self._swap_lock:
            cur = self._snapshot.version
            v = int(version) if version is not None else cur + 1
            if v <= cur:
                v = cur + 1
            return self.publish(ModelSnapshot(version=v, W=W, sigma=sigma))

    def refresh(self) -> int:
        """Pull the newest snapshot from the estimator that built this
        engine (``DMTRLEstimator.scoring_engine``); no-op when already
        current. Returns the serving version."""
        est = self._source() if self._source is not None else None
        if est is None:
            raise RuntimeError(
                "refresh() needs an engine built by "
                "DMTRLEstimator.scoring_engine (no live source estimator)"
            )
        snap = est.model_snapshot()
        with self._swap_lock:
            if snap.version > self._snapshot.version:
                self.publish(snap)
            return self._snapshot.version

    def warmup(self) -> None:
        """AOT-compile the fixed (batch, d) scoring tile ahead of traffic
        (``jit(...).lower(...).compile()``), so the first real request
        never pays the trace+compile and warm-start p99 carries no
        retrace spike. Hot-swapped W of the same shape/dtype reuses the
        executable (W is an argument, exactly like the jitted path)."""
        sds = jax.ShapeDtypeStruct
        W = self.W
        self._step_exe_dtype = W.dtype
        self._step_exe = (
            jax.jit(make_score_step())
            .lower(
                sds(W.shape, W.dtype),
                sds((self.batch, self.d), jnp.float32),
                sds((self.batch,), jnp.int32),
            )
            .compile()
        )

    def adopt_warmup(self, other: "MTLScoringEngine") -> bool:
        """Share a sibling engine's warm AOT executable instead of
        recompiling: homogeneous fleet replicas (same batch, same W
        shape/dtype) serve the identical fixed-shape step, so ONE compile
        warms the whole fleet (``FleetRouter.warmup``). Returns False —
        and leaves this engine untouched — when the donor is cold or the
        shapes differ (caller falls back to ``warmup()``)."""
        if (
            other._step_exe is None
            or other.batch != self.batch
            or other.W.shape != self.W.shape
            or other._step_exe_dtype != self.W.dtype
        ):
            return False
        self._step_exe = other._step_exe
        self._step_exe_dtype = other._step_exe_dtype
        return True

    # -- validation (THE single point: every entry path lands here) ---------
    def _validate_batch(
        self, X, tasks
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Normalize + validate (X, tasks) once for run/run_tile/score_batch:
        feature width must be d, task ids in [0, m)."""
        X = np.asarray(X, np.float32)
        if X.ndim != 2 or X.shape[1] != self.d:
            raise ValueError(
                f"request feature shape {X.shape} does not pack to "
                f"(n, {self.d})"
            )
        t = np.ascontiguousarray(
            np.broadcast_to(np.asarray(tasks, np.int32), (X.shape[0],))
        )
        if t.size and (t.min() < 0 or t.max() >= self.m):
            raise ValueError(
                f"task id out of range [0, {self.m}): [{t.min()}, {t.max()}]"
            )
        return X, t

    def admit(self, r: ScoreRequest) -> None:
        """Scheduler admission hook: validate ONE request through the same
        batch validator (a 1-row pack)."""
        x = np.asarray(r.x, np.float32)
        if x.ndim != 1:
            raise ValueError(
                f"request feature shape {x.shape} != ({self.d},)"
            )
        self._validate_batch(x[None], np.asarray([int(r.task)]))

    def task_key(self, r: ScoreRequest) -> int:
        return int(r.task)

    # -- scoring (one pad/tile loop shared by every surface) ----------------
    def _score_tiles(self, X: np.ndarray, t: np.ndarray, W: Array) -> np.ndarray:
        n, B = X.shape[0], self.batch
        pad = (-n) % B
        if pad:
            X = np.concatenate([X, np.zeros((pad, self.d), np.float32)])
            t = np.concatenate([t, np.zeros((pad,), np.int32)])
        W = jnp.asarray(W)
        # the warm AOT executable is shape/dtype-exact; anything else
        # (e.g. a differently-typed W) falls back to the jitted step
        step = self._step
        if self._step_exe is not None and W.dtype == self._step_exe_dtype:
            step = self._step_exe
        out = np.empty((X.shape[0],), np.float32)
        for lo in range(0, X.shape[0], B):
            out[lo : lo + B] = np.asarray(
                step(W, jnp.asarray(X[lo : lo + B]), jnp.asarray(t[lo : lo + B]))
            )
        return out[:n]

    def _stack(self, requests: Sequence[ScoreRequest]) -> Tuple[np.ndarray, np.ndarray]:
        xs = [np.asarray(r.x, np.float32) for r in requests]
        try:
            X = np.stack(xs)
        except ValueError as e:
            raise ValueError(
                f"request feature shapes do not stack: "
                f"{sorted({x.shape for x in xs})}"
            ) from e
        t = np.asarray([int(r.task) for r in requests], np.int32)
        return X, t

    def _write_back(self, requests: Sequence[ScoreRequest], z: np.ndarray) -> None:
        for r, zi in zip(requests, z):
            r.score = float(zi)
            if self.classify:
                r.label = 1.0 if zi >= 0.0 else -1.0

    def score_batch(self, X, tasks) -> np.ndarray:
        """Array-in/array-out fast path: (n, d) features + (n,) task ids ->
        (n,) margins against the CURRENT snapshot."""
        X, t = self._validate_batch(X, tasks)
        return self._score_tiles(X, t, self.W)

    def run(self, requests: List[ScoreRequest]) -> List[ScoreRequest]:
        """Blocking batch surface: score all requests in fixed-shape tiles
        against the current snapshot; fills score/label in place and
        returns the same list (validation + scoring both delegate to the
        single ``score_batch`` path). Honors ``gather_sigma_rows`` the same
        way the scheduler tile hook does."""
        if not requests:
            return requests
        X, t = self._stack(requests)
        self._write_back(requests, self.score_batch(X, t))
        if self.gather_sigma_rows and self._snapshot.sigma is not None:
            for r, row in zip(requests, self.sigma_rows_for(t)):
                r.sigma_row = row
        return requests

    def sigma_rows_for(self, tasks, sigma=None) -> np.ndarray:
        """Sparse serve-path gather: the (n, m) Sigma rows of ``tasks``
        against ``sigma`` (default: the current snapshot's), padded to the
        fixed tile shape internally so the jitted gather never retraces.
        Structured snapshots gather straight from the factors — the dense
        (m, m) is never materialized on the serving host."""
        if sigma is None:
            sigma = self._snapshot.sigma
        if sigma is None:
            raise ValueError(
                "no Sigma on the serving snapshot: build the engine with "
                "sigma=... or publish a snapshot that carries one"
            )
        t = np.ascontiguousarray(np.asarray(tasks, np.int32).reshape(-1))
        if t.size and (t.min() < 0 or t.max() >= self.m):
            raise ValueError(
                f"task id out of range [0, {self.m}): [{t.min()}, {t.max()}]"
            )
        n, B = t.shape[0], self.batch
        pad = (-n) % B
        if pad:
            t = np.concatenate([t, np.zeros((pad,), np.int32)])
        out = np.empty((t.shape[0], self.m), np.float32)
        for lo in range(0, t.shape[0], B):
            out[lo : lo + B] = np.asarray(
                self._gather(sigma, jnp.asarray(t[lo : lo + B]))
            )
        return out[:n]

    def run_tile(
        self, requests: Sequence[ScoreRequest], snapshot: ModelSnapshot
    ) -> None:
        """Scheduler tile hook: score <= batch requests against the PACKED
        snapshot (not the engine's current one) so in-flight tiles complete
        on the model they were packed with. Requests were already validated
        at admission (``admit``), so the hot path goes straight to the
        shared tile loop. With ``gather_sigma_rows`` on and a Sigma-bearing
        snapshot, each request also gets its task's Sigma row, gathered
        only for the tasks this tile touches."""
        X, t = self._stack(requests)
        self._write_back(requests, self._score_tiles(X, t, jnp.asarray(snapshot.W)))
        if self.gather_sigma_rows and snapshot.sigma is not None:
            rows = self.sigma_rows_for(t, snapshot.sigma)
            for r, row in zip(requests, rows):
                r.sigma_row = row
