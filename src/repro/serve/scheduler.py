"""Continuous-batching scheduler: one request queue for every serving engine.

``MTLScoringEngine.run`` (and the LM ``ServingEngine.run``) are blocking
all-at-once surfaces: the caller hands over a full request list and waits
for every tile. Production traffic does not arrive as lists — it arrives
as a *stream*, and the scheduler is the piece in between:

  * a shared request queue with arrival timestamps and optional absolute
    deadlines (``ServeRequest`` base fields every engine's request type
    inherits),
  * deadline-aware admission: a request whose deadline already passed is
    dropped at the door (and again at packing time) instead of wasting a
    tile slot — each drop is an SLO violation in the metrics,
  * dynamic tile packing: every ``step()`` fills ONE fixed-shape jitted
    tile (``engine.batch`` slots) from whatever is queued right now —
    EDF (earliest deadline first) or FIFO order — so late arrivals ride
    the next tile instead of waiting for a full batch to assemble,
  * versioned model hot-swap: ``publish(ModelSnapshot)`` switches the
    weights between tiles without draining the queue. A tile is packed
    against the snapshot current at pack time and COMPLETES on it even if
    a publish lands mid-tile, so every request is scored against exactly
    one well-defined model version (recorded in ``snapshot_version``).

The scheduler is engine-agnostic: anything with ``batch``,
``admit(req)``, ``model_snapshot()`` and ``run_tile(reqs, snapshot)``
(plus optional ``task_key(req)`` for per-task metrics) can sit behind it
— ``serve/mtl.py`` (MTL scoring) and ``serve/engine.py`` (LM decode)
both do. Time is injectable (``clock=``), so tests and the load bench
drive it with a virtual clock; ``submit``/``publish`` are thread-safe so
a training loop (``DMTRLEstimator.partial_fit`` or a transport
subscription) can push snapshots while another thread serves.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from typing import Any, Callable, List, Optional, Sequence

from .metrics import ServingMetrics
from ..obs.trace import span

logger = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class ModelSnapshot:
    """An immutable versioned model: what one tile is scored against.

    For the MTL scorer ``W`` (m, d) is the task-weight matrix and
    ``sigma`` the task covariance that produced it — either a dense
    (m, m) array or, under a structured regularizer, a
    ``core.sigma_view.SigmaView`` carrying only the factors (a few KB at
    any m); consumers that need relatedness rows gather them sparsely
    (``MTLScoringEngine.sigma_rows_for``), scoring itself only reads W.
    Versions are strictly increasing — publishers (``DMTRLEstimator``
    installs, transport subscriptions) stamp them, consumers refuse to go
    backwards.
    """

    version: int
    W: Optional[Any] = None
    sigma: Optional[Any] = None


@dataclasses.dataclass(kw_only=True)
class ServeRequest:
    """Queue fields shared by every engine's request type.

    ``arrival_s``/``deadline_s``/``finish_s`` are absolute times on the
    scheduler's clock; ``deadline_s`` is optional (None = best effort).
    ``status`` walks new -> queued -> done | expired (| shed, when a
    ``serve.fleet.FleetRouter`` rejects at the door); ``snapshot_version``
    records the model version the request was scored against.
    """

    arrival_s: Optional[float] = None
    deadline_s: Optional[float] = None
    finish_s: Optional[float] = None
    first_token_s: Optional[float] = None
    status: str = "new"
    snapshot_version: Optional[int] = None

    @property
    def latency_s(self) -> Optional[float]:
        if self.finish_s is None or self.arrival_s is None:
            return None
        return self.finish_s - self.arrival_s

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (arrival -> first sampled token); only
        streaming engines stamp ``first_token_s``."""
        if self.first_token_s is None or self.arrival_s is None:
            return None
        return self.first_token_s - self.arrival_s


class QueueFull(RuntimeError):
    """Raised by ``submit`` when the scheduler's bounded queue is full."""


@dataclasses.dataclass(frozen=True)
class SubmitOutcome:
    """Per-request admission result of a batch submit or a fleet routing
    decision.  ``admitted`` requests are queued somewhere; rejects carry a
    ``reason`` (``"queue_full"`` | ``"expired"`` | ``"shed"`` |
    ``"no_replica"``).  Behind a ``serve.fleet.FleetRouter``, ``replica``
    names the replica whose queue admitted the request."""

    request: ServeRequest
    admitted: bool
    reason: Optional[str] = None
    replica: Optional[int] = None


class VirtualClock:
    """Deterministic injectable scheduler clock (``clock=VirtualClock()``).

    Tests, the load bench and simulated-time demos advance it explicitly;
    latency/throughput metrics then measure virtual seconds exactly the
    way they measure wall seconds.  Thread-safe: fleet simulations share
    ONE clock between a router, N replica schedulers and trainer threads,
    so reads and advances are serialized under a lock.  Time never runs
    backwards — ``advance`` rejects negative steps and ``advance_to``
    rejects targets earlier than the current time.
    """

    def __init__(self, t: float = 0.0):
        self._t = float(t)
        self._lock = threading.Lock()

    def __call__(self) -> float:
        with self._lock:
            return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"dt must be >= 0, got {dt}")
        with self._lock:
            self._t += float(dt)

    def advance_to(self, t: float) -> None:
        t = float(t)
        with self._lock:
            if t < self._t:
                raise ValueError(
                    f"advance_to target {t} is earlier than the current "
                    f"time {self._t}; virtual time never runs backwards"
                )
            self._t = t


_POLICIES = ("edf", "fifo")


class ContinuousBatchingScheduler:
    """Deadline-aware continuous-batching scheduler over one engine.

    Parameters
    ----------
    engine : the batch runner (``MTLScoringEngine`` / ``ServingEngine`` /
        anything with the adapter surface described in the module doc).
        Request validation happens ONCE, at admission (``engine.admit``).
    slo_s : latency SLO; a completed request with latency above it counts
        as an SLO violation (deadline misses always count).
    policy : ``"edf"`` packs earliest-deadline-first (deadline-less
        requests last, FIFO within ties); ``"fifo"`` packs in arrival
        order.
    max_queue : bounded queue; ``submit`` raises ``QueueFull`` beyond it
        (load shedding is the caller's policy, the drop is counted).
    clock : injectable time source (virtual clocks for tests/benches).
    """

    def __init__(
        self,
        engine,
        *,
        slo_s: Optional[float] = None,
        policy: str = "edf",
        max_queue: Optional[int] = None,
        metrics: Optional[ServingMetrics] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if policy not in _POLICIES:
            raise ValueError(f"policy must be one of {_POLICIES}, got {policy!r}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.engine = engine
        self.policy = policy
        self.max_queue = max_queue
        self.clock = clock
        self.metrics = metrics if metrics is not None else ServingMetrics(
            slo_s=slo_s, clock=clock
        )
        self._task_key = getattr(engine, "task_key", lambda r: None)
        # engines that care about snapshot shape expose validate_snapshot
        # (the MTL scorer rejects W-shape changes); LM engines don't
        self._validate_snapshot = getattr(
            engine, "validate_snapshot", lambda snap: None
        )
        self._snapshot: ModelSnapshot = engine.model_snapshot()
        self._engine_snap: ModelSnapshot = self._snapshot
        self._queue: List[ServeRequest] = []
        self._lock = threading.Lock()
        # streaming engines (serve/engine.py) expose a per-decode-step
        # surface; for them one step() = one decode STEP over the running
        # batch, not one whole-generation tile
        self._streaming = hasattr(engine, "decode_tick")

    # -- introspection ------------------------------------------------------
    @property
    def version(self) -> int:
        """Version of the snapshot the NEXT tile will be packed against."""
        with self._lock:
            return self._snapshot.version

    @property
    def snapshot(self) -> ModelSnapshot:
        with self._lock:
            return self._snapshot

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    @property
    def in_flight(self) -> int:
        """Requests injected into a streaming engine's slot table and not
        yet finished (always 0 for whole-tile engines)."""
        return int(getattr(self.engine, "active", 0))

    # -- ingress ------------------------------------------------------------
    def submit(
        self, req: ServeRequest, *, deadline_s: Optional[float] = None
    ) -> ServeRequest:
        """Admit one request: validate, stamp arrival, enqueue.

        ``deadline_s`` is RELATIVE (seconds from now) and is written into
        ``req.deadline_s`` as an absolute time; a request arriving with
        its deadline already in the past is dropped as ``expired``.
        """
        self.engine.admit(req)  # the single validation point
        task = self._task_key(req)
        with self._lock:
            now = self.clock()
            req.arrival_s = now
            if deadline_s is not None:
                if deadline_s <= 0:
                    raise ValueError(
                        f"deadline_s must be positive, got {deadline_s}"
                    )
                req.deadline_s = now + deadline_s
            if req.deadline_s is not None and req.deadline_s < now:
                req.status = "expired"
                self.metrics.on_submit(task)
                self.metrics.on_expired(task)
                return req
            if self.max_queue is not None and len(self._queue) >= self.max_queue:
                self.metrics.on_reject(task)
                raise QueueFull(
                    f"queue is at max_queue={self.max_queue}; request rejected"
                )
            req.status = "queued"
            self._queue.append(req)
            self.metrics.on_submit(task)
            self.metrics.observe_queue_depth(len(self._queue))
        return req

    def submit_many(
        self, reqs: Sequence[ServeRequest], *, deadline_s: Optional[float] = None
    ) -> List[SubmitOutcome]:
        """Admit a batch: one ``SubmitOutcome`` per request, in order.

        Unlike ``submit``, a full queue does NOT raise — the offending
        request is reported as ``admitted=False, reason="queue_full"`` and
        the REST of the batch is still attempted (a mid-batch ``QueueFull``
        used to silently drop the remainder), so callers — and the fleet
        router — can retry or shed each reject deterministically.
        """
        out: List[SubmitOutcome] = []
        for r in reqs:
            try:
                r = self.submit(r, deadline_s=deadline_s)
            except QueueFull:
                out.append(
                    SubmitOutcome(request=r, admitted=False, reason="queue_full")
                )
                continue
            if r.status == "expired":
                out.append(
                    SubmitOutcome(request=r, admitted=False, reason="expired")
                )
            else:
                out.append(SubmitOutcome(request=r, admitted=True))
        return out

    # -- fleet failover hooks (serve/fleet.py) ------------------------------
    def drain_queue(self) -> List[ServeRequest]:
        """Remove and return every queued request, stamps intact.

        The fleet router calls this on a replica it just marked dead: the
        backlog (including any tile ``step`` re-queued on the engine
        failure) is re-pinned onto surviving replicas via ``requeue``.
        """
        with self._lock:
            drained, self._queue = self._queue, []
            self.metrics.observe_queue_depth(0)
            return drained

    def requeue(self, reqs: Sequence[ServeRequest]) -> List[ServeRequest]:
        """Re-admit requests ALREADY admitted once (fleet failover path).

        Arrival/deadline stamps survive (latency keeps counting from the
        ORIGINAL arrival), there is no re-validation and no second
        ``on_submit`` count — the request was counted at the replica that
        first admitted it.  Requests whose deadline passed in the meantime
        expire here (counted against THIS queue); the bounded queue still
        applies (``QueueFull`` admits none of the batch).  Returns the
        requests actually queued.
        """
        reqs = list(reqs)
        if not reqs:
            return []
        with self._lock:
            now = self.clock()
            live: List[ServeRequest] = []
            for r in reqs:
                if r.deadline_s is not None and r.deadline_s < now:
                    r.status = "expired"
                    self.metrics.on_expired(self._task_key(r))
                else:
                    live.append(r)
            if (
                self.max_queue is not None
                and len(self._queue) + len(live) > self.max_queue
            ):
                raise QueueFull(
                    f"requeue of {len(live)} requests would exceed "
                    f"max_queue={self.max_queue}"
                )
            for r in live:
                r.status = "queued"
            self._queue.extend(live)
            self.metrics.observe_queue_depth(len(self._queue))
        return live

    # -- model hot-swap -----------------------------------------------------
    def publish(self, snapshot: ModelSnapshot) -> int:
        """Install a new model snapshot for all FUTURE tiles.

        Tiles already packed complete on the snapshot they were packed
        against (no drain, no drop, no double-score). Versions are
        strictly increasing: re-delivering the CURRENT version is an
        idempotent no-op (at-least-once publishers are fine), an OLDER
        version raises. Returns the installed version.
        """
        if not isinstance(snapshot, ModelSnapshot):
            raise TypeError(
                f"publish takes a ModelSnapshot, got {type(snapshot).__name__}"
            )
        self._validate_snapshot(snapshot)
        with self._lock:
            if snapshot.version == self._snapshot.version:
                return snapshot.version
            if snapshot.version < self._snapshot.version:
                raise ValueError(
                    f"snapshot version {snapshot.version} is not newer than "
                    f"the installed version {self._snapshot.version}"
                )
            self._snapshot = snapshot
            self.metrics.on_swap(snapshot.version)
        return snapshot.version

    def publish_weights(self, W, sigma=None, version: Optional[int] = None) -> int:
        """Array-level publish — the shape a ``core.transport`` model
        subscription emits (``callback(W, sigma, version)``), so
        ``transport.subscribe(scheduler.publish_weights)`` wires live
        training commits straight into serving.

        Unlike the strict ``publish``, external version counters are
        RE-STAMPED into this scheduler's monotone version space when they
        are not ahead of it (a transport's install counter and an
        estimator's model version are independent sequences); the
        compute-and-install is one atomic lock acquisition, so concurrent
        publishers can never drop each other's weights. Returns the
        installed version."""
        self._validate_snapshot(ModelSnapshot(version=0, W=W, sigma=sigma))
        with self._lock:
            cur = self._snapshot.version
            v = int(version) if version is not None else cur + 1
            if v <= cur:
                v = cur + 1
            self._snapshot = ModelSnapshot(version=v, W=W, sigma=sigma)
            self.metrics.on_swap(v)
        return v

    # -- scheduling ---------------------------------------------------------
    def _expire_locked(self, now: float) -> None:
        keep: List[ServeRequest] = []
        for r in self._queue:
            if r.deadline_s is not None and r.deadline_s < now:
                r.status = "expired"
                self.metrics.on_expired(self._task_key(r))
            else:
                keep.append(r)
        self._queue = keep

    def _pickup_engine_snapshot_locked(self) -> None:
        # pick up snapshots pushed INTO the engine directly (e.g. an
        # estimator push to an engine this scheduler was composed
        # over). Detected by IDENTITY, not version: producer counters
        # are independent spaces, so an engine push can carry a lower
        # number than a scheduler counter that transport pushes ran
        # ahead — restamp it instead of ignoring it.
        eng_snap = self.engine.model_snapshot()
        if eng_snap is not self._engine_snap:
            self._engine_snap = eng_snap
            cur = self._snapshot.version
            # equal version = the same model delivered down both paths
            # (estimator pushes to engine AND scheduler): no-op
            if eng_snap.version != cur:
                v = eng_snap.version if eng_snap.version > cur else cur + 1
                self._snapshot = (
                    eng_snap
                    if v == eng_snap.version
                    else dataclasses.replace(eng_snap, version=v)
                )
                self.metrics.on_swap(v)

    def _sort_queue_locked(self) -> None:
        if self.policy == "edf":
            # stable sort: FIFO within equal (or absent) deadlines
            self._queue.sort(
                key=lambda r: (
                    r.deadline_s if r.deadline_s is not None else float("inf")
                )
            )

    def step(self) -> List[ServeRequest]:
        """Pack and run ONE tile; returns the completed requests.

        Whole-tile engines: packing (under the lock) drops expired
        requests, orders the queue by policy, takes up to
        ``engine.batch``, captures the current snapshot; execution
        (outside the lock) is ``engine.run_tile`` on the captured
        snapshot — concurrent ``publish``/``submit`` calls only affect
        later tiles. An empty queue returns [].

        Streaming engines (``decode_tick`` present): the tile unit is one
        decode STEP. Each step drains finished requests out of the slot
        table, injects up to ``engine.free_slots`` queued requests into
        the RUNNING batch (stamping time-to-first-token and the snapshot
        version they were admitted under — a request completes on that
        version even if a publish lands mid-generation), then advances
        every occupied slot one token. Returns whatever finished this
        step, possibly requests injected many steps ago.
        """
        if self._streaming:
            return self._step_streaming()
        with span("pack", cat="serve"), self._lock:
            now = self.clock()
            self._expire_locked(now)
            self._pickup_engine_snapshot_locked()
            if not self._queue:
                self.metrics.observe_queue_depth(0)
                return []
            self._sort_queue_locked()
            tile = self._queue[: self.engine.batch]
            del self._queue[: self.engine.batch]
            snap = self._snapshot
            self.metrics.observe_queue_depth(len(self._queue))
        try:
            with span("run_tile", cat="serve", tile=len(tile)):
                self.engine.run_tile(tile, snap)
        except BaseException:
            # never lose a packed tile: put the requests back at the head
            # of the queue (still "queued", timestamps intact) and let the
            # caller see the engine failure
            logger.warning(
                "run_tile failed on snapshot version %d; re-queuing %d "
                "packed request(s) at the head",
                snap.version,
                len(tile),
                exc_info=True,
            )
            with self._lock:
                self._queue[:0] = tile
            raise
        done_s = self.clock()
        # completion bookkeeping under the lock: metrics are also mutated
        # by concurrent submit()/publish() callers
        with self._lock:
            slo = self.metrics.slo_s
            for r in tile:
                r.status = "done"
                r.finish_s = done_s
                r.snapshot_version = snap.version
                lat = done_s - r.arrival_s
                violated = (slo is not None and lat > slo) or (
                    r.deadline_s is not None and done_s > r.deadline_s
                )
                self.metrics.on_complete(self._task_key(r), lat, violated)
            self.metrics.on_tile(len(tile), self.engine.batch)
        return tile

    def _step_streaming(self) -> List[ServeRequest]:
        # surface generations finished on earlier ticks and free their
        # slots BEFORE packing, so this step's injection sees them
        finished: List[ServeRequest] = list(self.engine.drain())
        with self._lock:
            now = self.clock()
            self._expire_locked(now)
            self._pickup_engine_snapshot_locked()
            take: List[ServeRequest] = []
            free = self.engine.free_slots
            if free and self._queue:
                self._sort_queue_locked()
                take = self._queue[:free]
                del self._queue[:free]
            snap = self._snapshot
            self.metrics.observe_queue_depth(len(self._queue))
        try:
            if take:
                # inject = per-request prefill + first sampled token:
                # time-to-first-token is paid here, and the request is
                # stamped with the snapshot it was ADMITTED under
                with span("inject", cat="serve", n=len(take)):
                    self.engine.inject(take, snap)
                t1 = self.clock()
                with self._lock:
                    for r in take:
                        r.status = "running"
                        r.first_token_s = t1
                        self.metrics.on_first_token(t1 - r.arrival_s)
                    self.metrics.on_tile(len(take), self.engine.batch)
            occupied = self.engine.active
            if occupied:
                with span("decode_step", cat="serve", occupied=occupied):
                    finished.extend(self.engine.decode_tick())
            with self._lock:
                self.metrics.on_decode_step(occupied, self.engine.batch)
        except BaseException:
            # never lose a request: evict everything in-flight (the next
            # inject resets per-attempt decode state) and requeue at the
            # head, then let the caller see the engine failure
            evicted = self.engine.evict_active()
            ids = {id(r) for r in evicted}
            back = evicted + [r for r in take if id(r) not in ids]
            logger.warning(
                "streaming step failed on snapshot version %d; evicted %d "
                "in-flight and re-queued %d request(s)",
                snap.version,
                len(evicted),
                len(back),
                exc_info=True,
            )
            with self._lock:
                for r in back:
                    r.status = "queued"
                self._queue[:0] = back
            raise
        done_s = self.clock()
        with self._lock:
            slo = self.metrics.slo_s
            for r in finished:
                r.status = "done"
                r.finish_s = done_s
                # snapshot_version was stamped at INJECT (admission), not
                # here: mid-generation publishes must not relabel it
                lat = done_s - r.arrival_s
                violated = (slo is not None and lat > slo) or (
                    r.deadline_s is not None and done_s > r.deadline_s
                )
                self.metrics.on_complete(self._task_key(r), lat, violated)
        return finished

    def run_until_idle(self, max_steps: int = 1_000_000) -> int:
        """Step until the queue AND any streaming slot table drain;
        returns requests completed."""
        total = 0
        for _ in range(max_steps):
            done = self.step()
            if not done and not self.pending and not self.in_flight:
                break
            total += len(done)
        return total
