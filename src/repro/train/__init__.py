"""Training substrate: optimizer, loop, checkpointing, DMTRL head bridge."""
from . import checkpoint, loop, mtl_head, optimizer
from .loop import TrainLogger, make_sharded_train_step, make_train_step, train
from .optimizer import AdamW, AdamWState

__all__ = [
    "checkpoint",
    "loop",
    "mtl_head",
    "optimizer",
    "TrainLogger",
    "make_sharded_train_step",
    "make_train_step",
    "train",
    "AdamW",
    "AdamWState",
]
