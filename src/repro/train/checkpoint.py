"""Checkpointing: pytree <-> directory of .npz shards + msgpack manifest.

Production notes: on a real pod each host writes its addressable shards and
the manifest records the global sharding; here (single host) we save the
full arrays. Restore validates structure and shapes against the target tree.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree: Any, step: int = 0, meta: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "meta": meta or {},
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))


def load(path: str, target_tree: Any) -> Any:
    """Restore into the structure of ``target_tree`` (shape-checked)."""
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    arrays = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves = []
    for pth, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in pth)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs target {leaf.shape}"
            )
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target_tree), leaves
    )


def latest_step(path: str) -> int:
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read())["step"]
