"""Generic LM training loop: jitted train_step with explicit shardings,
metric logging, checkpointing hooks."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import loss_fn, init_params
from repro.models.sharding import param_shardings, train_batch_pspec
from .optimizer import AdamW, AdamWState

Array = jax.Array


def make_train_step(
    cfg: ModelConfig,
    opt: AdamW,
    microbatches: int = 1,
    inner_param_specs=None,
    grad_specs=None,
) -> Callable:
    """train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    microbatches > 1: gradient accumulation over batch splits (bounds the
    L x B x S x d residual saves that dominate training memory).

    inner_param_specs (ZeRO-2 style, §Perf): constrain params to these specs
    (typically model-only / un-FSDP'd) for the forward/backward so the FSDP
    all-gathers happen ONCE per step instead of once per microbatch;
    grad_specs keeps the accumulated grads FSDP-sharded (the reduce-scatter
    side)."""

    def grads_of(params, batch):
        if inner_param_specs is not None:
            params = jax.lax.with_sharding_constraint(params, inner_param_specs)
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True
        )(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            def split(v):
                b = v.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return v.reshape((microbatches, b // microbatches) + v.shape[1:])

            mb = {k: split(v) for k, v in batch.items()}

            def acc_fn(carry, mb_i):
                g_acc, l_acc, a_acc = carry
                (l, met), g = grads_of(params, mb_i)
                if grad_specs is not None:
                    g = jax.lax.with_sharding_constraint(g, grad_specs)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l, a_acc + met["aux_loss"]), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            if grad_specs is not None:
                g0 = jax.lax.with_sharding_constraint(g0, grad_specs)
            (grads, loss_sum, aux_sum), _ = jax.lax.scan(
                acc_fn, (g0, jnp.float32(0.0), jnp.float32(0.0)), mb
            )
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss_sum * inv
            metrics = {"ce": loss, "aux_loss": aux_sum * inv}
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_sharded_train_step(
    cfg: ModelConfig, opt: AdamW, mesh: Mesh, global_batch: int, seq_len: int
):
    """jit the train step with in/out shardings for the production mesh.
    Used by the launcher and the dry-run (via .lower on ShapeDtypeStructs)."""
    import repro.models.transformer as tf

    pshapes = tf.param_shapes(cfg)
    pshard = param_shardings(cfg, pshapes, mesh)
    opt_shard = AdamWState(
        step=NamedSharding(mesh, P()),
        mu=pshard,
        nu=pshard,
    )
    bspec = train_batch_pspec(mesh, global_batch)
    batch_shard: Dict[str, Any] = {
        "tokens": NamedSharding(mesh, bspec),
        "labels": NamedSharding(mesh, bspec),
        "mask": NamedSharding(mesh, bspec),
    }
    if cfg.is_encoder_decoder:
        batch_shard["frames"] = NamedSharding(mesh, P(bspec[0], None, None))
    metric_shard = NamedSharding(mesh, P())

    step = make_train_step(cfg, opt)
    jitted = jax.jit(
        step,
        in_shardings=(pshard, opt_shard, batch_shard),
        out_shardings=(
            pshard,
            opt_shard,
            {k: metric_shard for k in ("ce", "aux_loss", "grad_norm", "lr", "loss")},
        ),
        donate_argnums=(0, 1),
    )
    return jitted, pshard, opt_shard, batch_shard


@dataclasses.dataclass
class TrainLogger:
    every: int = 10
    history: list = dataclasses.field(default_factory=list)

    def log(self, step: int, metrics: Dict[str, Array], t0: float):
        if step % self.every == 0:
            row = {k: float(v) for k, v in metrics.items()}
            row["step"] = step
            row["elapsed_s"] = time.time() - t0
            self.history.append(row)
            print(
                f"step {step:5d}  loss {row['loss']:.4f}  ce {row['ce']:.4f}  "
                f"gnorm {row['grad_norm']:.3f}  lr {row['lr']:.2e}  "
                f"t {row['elapsed_s']:.1f}s",
                flush=True,
            )


def train(
    cfg: ModelConfig,
    opt: AdamW,
    data_iter,
    steps: int,
    seed: int = 0,
    logger: Optional[TrainLogger] = None,
    checkpoint_fn: Optional[Callable[[int, Any, Any], None]] = None,
    checkpoint_every: int = 0,
) -> Tuple[Any, AdamWState, list]:
    """Single-host training driver (CPU smoke / examples)."""
    logger = logger or TrainLogger()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
    t0 = time.time()
    for step in range(steps):
        batch = next(data_iter)
        batch = {k: jnp.asarray(v) for k, v in batch.items() if v is not None}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        logger.log(step, metrics, t0)
        if checkpoint_fn and checkpoint_every and (step + 1) % checkpoint_every == 0:
            checkpoint_fn(step + 1, params, opt_state)
    return params, opt_state, logger.history
