"""Backbone <-> DMTRL bridge: per-task heads over backbone features.

This is where the paper's technique plugs into the model substrate: the
backbone's pooled final hidden state is the paper's explicit feature map
phi(.), and the per-task linear heads are trained with DMTRL's distributed
primal-dual W-step — the task data (e.g. per-tenant classification sets)
never leaves its worker; only the d-dimensional delta_b vectors move.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import DMTRLConfig, MTLData, from_task_list
from repro.core.dmtrl import fit as dmtrl_fit
from repro.core.dmtrl import DMTRLResult

Array = jax.Array


def pooled_features(
    cfg: ModelConfig, params, tokens: Array, side: Optional[Array] = None
) -> Array:
    """Mean-pooled final hidden state (B, d_model) == phi(x)."""
    # forward_train returns logits; reuse the trunk by re-running up to the
    # final norm. Cheap trick: logits @ pinv(lm_head) is wrong; instead we
    # expose the trunk here.
    import repro.models.transformer as tf

    h = params["embed"][tokens]
    positions = jnp.arange(tokens.shape[1])
    if cfg.is_encoder_decoder:
        enc = tf.encode_audio(cfg, params, side)
        from repro.models.common import rms_norm
        from repro.models import attention as attn_mod
        from repro.models.transformer import _dense_block

        def body(hh, xs):
            lp, cp = xs
            hh, _ = _dense_block(cfg, lp, hh, positions, False)
            hh = hh + attn_mod.cross_attention_train(
                rms_norm(hh, cp["ln"], cfg.norm_eps), enc, cp["attn"], cfg
            )
            return hh, None

        h, _ = jax.lax.scan(body, h, (params["layers"], params["cross_layers"]))
    else:
        h, _, _ = tf._scan_layers(cfg, params, h, positions)
    from repro.models.common import rms_norm

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return jnp.mean(h, axis=1).astype(jnp.float32)


def build_mtl_data_from_backbone(
    cfg: ModelConfig,
    params,
    task_tokens: Sequence[np.ndarray],  # per task: (n_i, S) int32
    task_labels: Sequence[np.ndarray],  # per task: (n_i,) +-1
    batch: int = 32,
) -> MTLData:
    """Encode every task's examples with the backbone into phi features.

    In the geo-distributed deployment each worker runs this locally on its
    own task shard with the SAME backbone checkpoint (broadcast once); the
    raw tokens never leave the worker.
    """
    feat_fn = jax.jit(lambda t: pooled_features(cfg, params, t))
    xs: List[np.ndarray] = []
    for toks in task_tokens:
        outs = []
        for i in range(0, toks.shape[0], batch):
            outs.append(np.asarray(feat_fn(jnp.asarray(toks[i : i + batch]))))
        feats = np.concatenate(outs, axis=0)
        feats /= np.maximum(np.linalg.norm(feats, axis=1, keepdims=True), 1e-9)
        xs.append(feats.astype(np.float32))
    return from_task_list(xs, list(task_labels))


@dataclasses.dataclass
class MTLHeadResult:
    dmtrl: DMTRLResult
    features_dim: int

    def predict(self, feats: np.ndarray, task: int) -> np.ndarray:
        return feats @ np.asarray(self.dmtrl.W[task])


def fit_mtl_heads(
    cfg: ModelConfig,
    params,
    task_tokens: Sequence[np.ndarray],
    task_labels: Sequence[np.ndarray],
    dmtrl_cfg: Optional[DMTRLConfig] = None,
) -> MTLHeadResult:
    data = build_mtl_data_from_backbone(cfg, params, task_tokens, task_labels)
    dcfg = dmtrl_cfg or DMTRLConfig(
        loss="hinge", lam=1e-4, outer_iters=3, rounds=10, local_iters=256
    )
    res = dmtrl_fit(dcfg, data)
    return MTLHeadResult(dmtrl=res, features_dim=data.d)
