"""AdamW with fp32 moments over (possibly bf16) params; state shards like
the params (same PartitionSpecs, moments inherit the param sharding)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1

    def init(self, params) -> AdamWState:
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(f32, params),
            nu=jax.tree.map(f32, params),
        )

    def schedule(self, step: Array) -> Array:
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(self.warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (s - self.warmup_steps) / max(self.total_steps - self.warmup_steps, 1),
            0.0,
            1.0,
        )
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        frac = self.min_lr_frac + (1.0 - self.min_lr_frac) * cos
        return self.lr * warm * frac

    def update(
        self, grads, state: AdamWState, params
    ) -> Tuple[Any, AdamWState, dict]:
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)
            )
        )
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-12))
        step = state.step + 1
        lr = self.schedule(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1.0 - self.b1) * g
            v = self.b2 * v + (1.0 - self.b2) * g * g
            mhat = m / b1c
            vhat = v / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return (
            new_params,
            AdamWState(step=step, mu=new_mu, nu=new_nu),
            {"grad_norm": gnorm, "lr": lr},
        )
