import os

import jax
import pytest

# persistent XLA compilation cache: the suite is compile-dominated on CPU,
# so re-runs (local dev, cached CI) skip most of the wall clock. Opt out
# with JAX_COMPILATION_CACHE_DIR="" in the environment.
_cache_dir = os.environ.get(
    "JAX_COMPILATION_CACHE_DIR", "/tmp/jax-cache-dmtrl-repro"
)
if _cache_dir:
    jax.config.update("jax_compilation_cache_dir", _cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
    # subprocess-based mesh tests pick the cache up from the environment
    os.environ["JAX_COMPILATION_CACHE_DIR"] = _cache_dir
    os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0.5")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (subprocess/convergence)"
    )
    config.addinivalue_line(
        "markers", "load: serving load-generator test (scheduler under "
        "queued traffic)"
    )


def fast_arch_params(fast):
    """Parametrize over all arch ids, marking everything outside ``fast``
    as slow. Asserts the fast ids actually exist so a rename in
    configs/base.py fails loudly instead of silently demoting archs."""
    from repro.configs import ARCH_IDS

    unknown = set(fast) - set(ARCH_IDS)
    assert not unknown, f"fast arch ids not in ARCH_IDS: {sorted(unknown)}"
    return [
        a if a in fast else pytest.param(a, marks=pytest.mark.slow)
        for a in ARCH_IDS
    ]


# Small shared problems: fast tests should reuse these instead of building
# their own larger instances (keeps the default tier-1 run under ~2 min).
@pytest.fixture(scope="session")
def small_problem():
    from repro.data.synthetic import synthetic

    return synthetic(1, m=4, d=16, n_train_avg=40, n_test_avg=10, seed=1)


@pytest.fixture(scope="session")
def small_cfg():
    from repro.core import DMTRLConfig

    return DMTRLConfig(
        loss="hinge",
        lam=1e-3,
        outer_iters=2,
        rounds=3,
        local_iters=32,
        solver="block_gram",
        block_size=32,
        seed=0,
    )


@pytest.fixture(scope="session")
def one_device_mesh():
    return jax.make_mesh((1,), ("data",))
