"""Regenerate the golden async event histories (tests/golden/async_histories.json).

The goldens pin the *integer* event bookkeeping of the bounded-staleness
protocol — per-commit (worker, round, staleness, lag, tick) sequences plus
the tau trace and objective-sample indices — for a fixed set of configs.
Integers are platform-independent (unlike float iterates), so the fixture
can be committed and replayed on any host: the ``simulated`` transport must
reproduce every sequence bit-exactly after any refactor of the engine.

Recorded from the pre-transport-refactor engine (PR 3 tree). Regenerate
only if the *protocol semantics* deliberately change:

    PYTHONPATH=src python tests/golden/gen_async_golden.py
"""
import json
import os
import subprocess
import sys
import textwrap

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(os.path.dirname(HERE))

# keys whose values are integral and platform-stable
INT_KEYS = (
    "round", "tick", "min_round",
    "w_worker", "w_round", "w_staleness", "w_lag", "w_tick",
    "tau_trace",
)

# name -> (devices, problem kwargs, config kwargs)
CASES = {
    "g1_tau2_omega1": (
        1,
        dict(m=4, d=16, n_train_avg=40, n_test_avg=10, seed=1),
        dict(loss="hinge", lam=1e-3, outer_iters=2, rounds=3, local_iters=32,
             solver="block_gram", block_size=32, seed=0, tau=2,
             omega_delay=1, async_delays=(2,)),
    ),
    "g4_straggler_tau1": (
        4,
        dict(m=4, d=16, n_train_avg=40, n_test_avg=10, seed=3),
        dict(loss="hinge", lam=1e-3, outer_iters=1, rounds=4, local_iters=32,
             solver="block_gram", block_size=32, seed=0, tau=1,
             async_delays=(1, 1, 1, 3)),
    ),
    "g4_straggler_tau4_omega2": (
        4,
        dict(m=4, d=16, n_train_avg=40, n_test_avg=10, seed=3),
        dict(loss="hinge", lam=1e-3, outer_iters=2, rounds=4, local_iters=32,
             solver="block_gram", block_size=32, seed=0, tau=4,
             omega_delay=2, async_delays=(1, 1, 1, 3)),
    ),
    "g4_straggler_tau_auto": (
        4,
        dict(m=4, d=16, n_train_avg=40, n_test_avg=10, seed=3),
        dict(loss="hinge", lam=1e-3, outer_iters=2, rounds=4, local_iters=32,
             solver="block_gram", block_size=32, seed=0, tau="auto",
             async_delays=(1, 1, 1, 3)),
    ),
}

_RUNNER = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    import json, sys
    import jax, numpy as np
    sys.path.insert(0, {repo!r} + "/src")
    from repro.core import DMTRLConfig, MeshAxes
    from repro.core.async_dmtrl import fit_async
    from repro.data.synthetic import synthetic

    prob = {prob!r}
    cfg_kw = {cfg!r}
    cfg_kw["async_delays"] = tuple(cfg_kw["async_delays"])
    sp = synthetic(1, **prob)
    mesh = jax.make_mesh(({devices},), ("data",))
    _, _, _, hist = fit_async(
        DMTRLConfig(**cfg_kw), sp.train, mesh, MeshAxes(data="data")
    )
    out = {{k: np.asarray(hist[k]).astype(int).tolist() for k in {keys!r}}}
    print("GOLDEN" + json.dumps(out))
    """
)


def run_case(devices, prob, cfg):
    code = _RUNNER.format(
        devices=devices, repo=REPO, prob=prob, cfg=cfg, keys=INT_KEYS
    )
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=900,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("GOLDEN")][-1]
    return json.loads(line[len("GOLDEN"):])


def main():
    golden = {}
    for name, (devices, prob, cfg) in CASES.items():
        print(f"recording {name} (devices={devices}) ...", flush=True)
        golden[name] = {
            "devices": devices,
            "problem": prob,
            "config": {k: list(v) if isinstance(v, tuple) else v
                       for k, v in cfg.items()},
            "history": run_case(devices, prob, cfg),
        }
    path = os.path.join(HERE, "async_histories.json")
    with open(path, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
