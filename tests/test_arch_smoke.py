"""Per-architecture smoke tests (spec requirement f): reduced variant of
each family — one forward + one train step on CPU, asserting output shapes
and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import forward_train, init_params, loss_fn
from repro.train import AdamW
from repro.train.loop import make_train_step

from conftest import fast_arch_params

# one attention + one SSM representative stay in the fast tier-1 run; the
# full matrix (MoE giants, hybrid, enc-dec, deep attn) runs under -m slow.
# whisper/gemma forward paths keep fast coverage via test_serve's prefill
# and engine tests.
ARCH_PARAMS = fast_arch_params(("qwen1_5-4b", "mamba2-780m"))


def _batch(cfg, key, B=2, S=32):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks, "mask": jnp.ones((B, S))}
    if cfg.is_encoder_decoder:
        batch["frames"] = (
            jax.random.normal(key, (B, cfg.enc_frames, cfg.d_model)) * 0.02
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_limits(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 4
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


# the train step below compiles the same forward inside its grad, so the
# standalone forward sweep is slow-tier only (full matrix in CI's slow job)
@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = forward_train(cfg, params, batch["tokens"], batch.get("frames"))
    assert logits.shape == (2, 32, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())
    loss, metrics = loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    batch = _batch(cfg, key)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved


def test_full_configs_match_assignment():
    """Exact published shapes from the assignment table."""
    spec = {
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "qwen1_5-32b": (64, 5120, 40, 40, 27392, 152064),
        "zamba2-2_7b": (54, 2560, 32, 32, 10240, 32000),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "qwen1_5-4b": (40, 2560, 20, 20, 6912, 151936),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == h, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    # MoE / SSM structure
    assert get_config("qwen3-moe-30b-a3b").n_experts == 128
    assert get_config("qwen3-moe-30b-a3b").top_k == 8
    assert get_config("kimi-k2-1t-a32b").n_experts == 384
    assert get_config("mamba2-780m").ssm_state == 128
    assert get_config("zamba2-2_7b").ssm_state == 64
    assert get_config("gemma3-1b").local_ratio == 5


def test_param_counts_in_expected_range():
    """Sanity: param_count should land near the published sizes."""
    expect = {
        "nemotron-4-15b": (12e9, 19e9),
        "qwen1_5-32b": (28e9, 38e9),
        "zamba2-2_7b": (2.0e9, 3.6e9),
        "gemma3-1b": (0.7e9, 1.6e9),
        "mamba2-780m": (0.55e9, 1.0e9),
        "qwen3-moe-30b-a3b": (26e9, 34e9),
        "chameleon-34b": (28e9, 40e9),
        "kimi-k2-1t-a32b": (0.75e12, 1.25e12),
        "qwen1_5-4b": (3e9, 5e9),
        "whisper-tiny": (2.5e7, 9e7),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
    # active params for the MoE giants
    assert get_config("kimi-k2-1t-a32b").active_param_count() < 6e10
    assert get_config("qwen3-moe-30b-a3b").active_param_count() < 6e9
