"""Bounded-staleness async engine (core/async_dmtrl.py).

Anchors:
  * tau=0 must be BIT-identical to fit_distributed — the sync path and the
    async tick share the same factored local-solve/server-reduce pieces, so
    any refactor drift shows up here first. 1-device runs in-process; the
    8-device mesh runs in a subprocess (device count must be set before jax
    initializes) and is marked slow.
  * tau in {1, 4} under a deterministic straggler schedule must still
    converge (gap within 2x of the synchronous gap for the same number of
    per-worker rounds).
  * stale snapshot reads must never mix coordinates across tasks.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import DMTRLConfig, MeshAxes, fit_async, fit_distributed
from repro.core import convergence as cv
from repro.data.synthetic import synthetic

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def tau0_async(small_problem, small_cfg, one_device_mesh):
    return fit_async(
        small_cfg, small_problem.train, one_device_mesh, MeshAxes(data="data")
    )


def test_tau0_bit_parity_one_device(
    small_problem, small_cfg, one_device_mesh, tau0_async
):
    W1, s1, st1, h1 = fit_distributed(
        small_cfg, small_problem.train, one_device_mesh, MeshAxes(data="data")
    )
    W2, s2, st2, h2 = tau0_async
    assert np.array_equal(W1, W2), np.max(np.abs(W1 - W2))
    assert np.array_equal(s1, s2)
    assert np.array_equal(np.asarray(st1.alpha), np.asarray(st2.alpha))
    # the anchor also pins the bookkeeping: no staleness at tau=0
    assert h2["w_staleness"].max() == 0
    assert h2["w_lag"].max() == 0


def test_tau0_homogeneous_clock_matches_round_count(small_cfg, tau0_async):
    _, _, _, hist = tau0_async
    total = small_cfg.outer_iters * small_cfg.rounds
    assert len(hist["gap"]) == total
    # homogeneous delay-1 workers: one commit per tick, clock == round count
    np.testing.assert_array_equal(hist["tick"], np.arange(1, total + 1))


_STRAGGLER_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json, sys
    import jax, numpy as np
    sys.path.insert(0, {repo!r} + "/src")
    from repro.core import DMTRLConfig, MeshAxes, fit_async, fit_distributed
    from repro.data.synthetic import synthetic

    sp = synthetic(1, m=4, d=16, n_train_avg=40, n_test_avg=10, seed=3)
    base = dict(loss="hinge", lam=1e-3, outer_iters=1, rounds=4,
                local_iters=32, solver="block_gram", block_size=32, seed=0)
    mesh = jax.make_mesh((4,), ("data",))
    ax = MeshAxes(data="data")
    _, _, _, h_sync = fit_distributed(DMTRLConfig(**base), sp.train, mesh, ax)
    out = dict(sync_gap=float(h_sync["gap"][-1]))
    mask = np.asarray(sp.train.mask)
    for tau in (1, 4):
        cfg = DMTRLConfig(**base, tau=tau, async_delays=(1, 1, 1, 3))
        _, _, st, h = fit_async(cfg, sp.train, mesh, ax)
        out[f"tau{{tau}}_gap"] = float(h["gap"][-1])
        out[f"tau{{tau}}_stal"] = int(h["w_staleness"].max())
        out[f"tau{{tau}}_lag"] = int(h["w_lag"].max())
        # stale-snapshot reads must never mix coordinates across tasks:
        # padded coords stay exactly zero, every real task's block moves
        alpha = np.asarray(st.alpha)[: sp.train.m]
        out[f"tau{{tau}}_pad_leak"] = bool(np.any(alpha[mask == 0.0] != 0.0))
        out[f"tau{{tau}}_all_tasks_moved"] = bool(
            all(np.any(alpha[i][mask[i] == 1.0] != 0.0)
                for i in range(sp.train.m))
        )
    cfg_auto = DMTRLConfig(**dict(base, outer_iters=2), tau="auto",
                           async_delays=(1, 1, 1, 3))
    _, _, _, h_auto = fit_async(cfg_auto, sp.train, mesh, ax)
    out["auto_gap"] = float(h_auto["gap"][-1])
    out["auto_tau_max"] = int(h_auto["tau_trace"].max())
    out["auto_tau_start"] = int(h_auto["tau_trace"][0])
    print(json.dumps(out))
    """
)


@pytest.mark.slow
def test_straggler_converges_within_2x_sync_gap():
    """Deterministic 3x straggler on a 4-worker mesh, tau in {1, 4}: the
    async gap after the same per-worker round budget stays within 2x of
    sync, and the schedule really produced stale commits."""
    code = _STRAGGLER_SUBPROC.format(repo=REPO)
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=600,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert out.returncode == 0, out.stderr[-3000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    for tau in (1, 4):
        assert r[f"tau{tau}_gap"] <= 2.0 * abs(r["sync_gap"]) + 1e-9, r
        assert r[f"tau{tau}_stal"] >= 1, r
        # genuinely-stale snapshot reads never mixed task coordinates
        assert not r[f"tau{tau}_pad_leak"], r
        assert r[f"tau{tau}_all_tasks_moved"], r
    # a larger staleness bound must actually allow more lag
    assert r["tau4_lag"] >= r["tau1_lag"], r
    # tau="auto": starts bulk-synchronous, the straggler's gate refusals
    # must widen the bound, and the run still converges within 2x of sync
    assert r["auto_tau_start"] == 0, r
    assert r["auto_tau_max"] >= 1, r
    assert r["auto_gap"] <= 2.0 * abs(r["sync_gap"]) + 1e-9, r


def test_stale_snapshots_never_mix_tasks(one_device_mesh):
    """Property: per-task dual blocks only move where that task has real
    samples. On a 1-device mesh (G=1) snapshots are always fresh, so this
    covers the padding invariance of the engine plumbing; the genuinely-
    stale multi-worker case is asserted inside the straggler subprocess
    test above (pad_leak / all_tasks_moved outputs)."""
    sp = synthetic(1, m=4, d=12, n_train_avg=24, n_test_avg=6, seed=5)
    data = sp.train
    for tau in (0, 2):
        cfg = DMTRLConfig(
            loss="squared", lam=1e-3, outer_iters=1, rounds=5, local_iters=32,
            solver="block_gram", block_size=32, seed=7, tau=tau,
        )
        _, _, state, _ = fit_async(
            cfg, data, one_device_mesh, MeshAxes(data="data")
        )
        alpha = np.asarray(state.alpha)[: data.m]
        mask = np.asarray(data.mask)
        # padded coordinates (mask==0) must be exactly zero: SDCA only draws
        # indices in [0, n_i) so cross-task/padding leakage would land here
        assert np.all(alpha[mask == 0.0] == 0.0)
        # each real task must have moved its own block
        for i in range(data.m):
            assert np.any(alpha[i][mask[i] == 1.0] != 0.0)


def test_adapt_tau_controller():
    """tau="auto" decision rule: widen on gate refusals, narrow on unused
    slack, clamp to [0, tau_max]."""
    from repro.core.async_dmtrl import _adapt_tau

    slack = {"max_lag": 0.0}
    tight = {"max_lag": 3.0}
    # gate refused starts -> widen (regardless of the window summary)
    assert _adapt_tau(0, 2, slack, 8) == 1
    assert _adapt_tau(3, 1, tight, 8) == 4
    # cap
    assert _adapt_tau(8, 5, slack, 8) == 8
    # no refusals and lag strictly under the bound -> narrow
    assert _adapt_tau(3, 0, slack, 8) == 2
    # floor
    assert _adapt_tau(0, 0, slack, 8) == 0
    # no refusals but the slack was fully used -> hold
    assert _adapt_tau(3, 0, tight, 8) == 3


def test_tau_auto_one_device_matches_sync(
    small_problem, small_cfg, one_device_mesh
):
    """A single worker can never be gated, so tau="auto" must stay at 0 and
    reproduce the synchronous engine bit-exactly."""
    import dataclasses

    cfg = dataclasses.replace(small_cfg, tau="auto")
    W1, s1, st1, _ = fit_distributed(
        small_cfg, small_problem.train, one_device_mesh, MeshAxes(data="data")
    )
    W2, s2, st2, h2 = fit_async(
        cfg, small_problem.train, one_device_mesh, MeshAxes(data="data")
    )
    assert np.array_equal(W1, W2)
    assert np.array_equal(np.asarray(st1.alpha), np.asarray(st2.alpha))
    assert h2["tau_trace"].max() == 0


def test_omega_overlap_converges(small_problem, one_device_mesh):
    """omega_delay > 0: the Sigma install lands mid-W-step; the run must
    still reduce the duality gap and end with a valid trace-1 Sigma."""
    cfg = DMTRLConfig(
        loss="hinge", lam=1e-3, outer_iters=3, rounds=4, local_iters=32,
        solver="block_gram", block_size=32, seed=0, tau=1, omega_delay=2,
    )
    W, sigma, _, hist = fit_async(
        cfg, small_problem.train, one_device_mesh, MeshAxes(data="data")
    )
    assert np.trace(sigma) == pytest.approx(1.0, abs=1e-4)
    assert hist["gap"][-1] < hist["gap"][0]


def test_staleness_summary_and_effective_curve(small_cfg, tau0_async):
    _, _, _, hist = tau0_async
    s = cv.staleness_summary(hist)
    assert s["n_commits"] == small_cfg.outer_iters * small_cfg.rounds
    assert s["max_staleness"] == 0.0
    ticks, gaps = cv.effective_gap_curve(hist)
    assert ticks.shape == gaps.shape
    assert cv.ticks_to_gap(ticks, gaps, target=gaps[-1]) <= ticks[-1]


def test_omega_delay_exceeding_round_budget_still_installs(
    small_problem, one_device_mesh
):
    """omega_delay larger than a W-step's commit count: the pending Sigma
    must land at the next barrier, never be silently dropped."""
    cfg = DMTRLConfig(
        loss="hinge", lam=1e-3, outer_iters=2, rounds=3, local_iters=32,
        solver="block_gram", block_size=32, seed=0, omega_delay=50,
    )
    _, sigma, _, _ = fit_async(
        cfg, small_problem.train, one_device_mesh, MeshAxes(data="data")
    )
    m = small_problem.train.m
    # still learned: not the I/m init the run started from
    assert not np.allclose(sigma, np.eye(m) / m, atol=1e-3)
    assert np.trace(sigma) == pytest.approx(1.0, abs=1e-4)


def test_bad_config_rejected(small_problem, one_device_mesh):
    ax = MeshAxes(data="data")
    with pytest.raises(ValueError, match="tau"):
        fit_async(
            DMTRLConfig(tau=-1), small_problem.train, one_device_mesh, ax
        )
    # only "auto" is a valid non-int staleness bound
    for bad in ("adaptive", None, 1.5):
        with pytest.raises(ValueError, match="tau"):
            fit_async(
                DMTRLConfig(tau=bad), small_problem.train,
                one_device_mesh, ax,
            )
    with pytest.raises(ValueError, match="async_delays"):
        fit_async(
            DMTRLConfig(async_delays=(1, 2)), small_problem.train,
            one_device_mesh, ax,
        )
    # empty tuple must hit the length check, not fall back to all-ones
    with pytest.raises(ValueError, match="async_delays"):
        fit_async(
            DMTRLConfig(async_delays=()), small_problem.train,
            one_device_mesh, ax,
        )
    with pytest.raises(ValueError, match="omega_delay"):
        fit_async(
            DMTRLConfig(omega_delay=-2), small_problem.train,
            one_device_mesh, ax,
        )


_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax, numpy as np
    sys.path.insert(0, {repo!r} + "/src")
    from repro.core import DMTRLConfig, MeshAxes, fit_async, fit_distributed
    from repro.data.synthetic import synthetic

    sp = synthetic(1, m=8, d=24, n_train_avg=50, n_test_avg=10, seed=2)
    base = dict(loss="hinge", lam=1e-3, outer_iters=2, rounds=4,
                local_iters=32, solver="block_gram", block_size=32, seed=0)
    mesh = jax.make_mesh((8,), ("data",))
    ax = MeshAxes(data="data")
    cfg = DMTRLConfig(**base)
    W1, s1, st1, h1 = fit_distributed(cfg, sp.train, mesh, ax)
    W2, s2, st2, h2 = fit_async(cfg, sp.train, mesh, ax)
    out = dict(
        w_bit_equal=bool(np.array_equal(W1, W2)),
        alpha_bit_equal=bool(np.array_equal(np.asarray(st1.alpha),
                                            np.asarray(st2.alpha))),
        sync_gap=float(h1["gap"][-1]),
    )
    cfg4 = DMTRLConfig(**base, tau=4, async_delays=(1, 1, 1, 1, 1, 1, 1, 3))
    W4, s4, st4, h4 = fit_async(cfg4, sp.train, mesh, ax)
    out["tau4_gap"] = float(h4["gap"][-1])
    out["tau4_max_staleness"] = int(h4["w_staleness"].max())
    print(json.dumps(out))
    """
)


@pytest.mark.slow
def test_eight_device_parity_and_straggler_convergence():
    """Acceptance anchor on a real 8-device CPU mesh: bit parity at tau=0
    and gap <= 2x sync in the same per-worker round budget at tau=4."""
    code = _SUBPROC.format(repo=REPO)
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=900,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert out.returncode == 0, out.stderr[-3000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["w_bit_equal"], r
    assert r["alpha_bit_equal"], r
    assert r["tau4_gap"] <= 2.0 * abs(r["sync_gap"]) + 1e-9, r
    assert r["tau4_max_staleness"] >= 1, r  # the straggler really was stale
