"""Distributed (shard_map) DMTRL == single-process reference.

The 1-device mesh case runs in-process; the real multi-device cases run in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 (device
count must be set before jax initializes).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import MeshAxes, fit, fit_distributed

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_one_device_mesh_equals_reference(
    small_problem, small_cfg, one_device_mesh
):
    res = fit(small_cfg, small_problem.train)
    W, sigma, _, hist = fit_distributed(
        small_cfg, small_problem.train, one_device_mesh, MeshAxes(data="data")
    )
    np.testing.assert_allclose(W, np.asarray(res.W), atol=2e-4)
    np.testing.assert_allclose(sigma, np.asarray(res.sigma), atol=1e-5)


_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax, numpy as np
    sys.path.insert(0, {repo!r} + "/src")
    from repro.core import DMTRLConfig, MeshAxes, fit, fit_distributed
    from repro.data.synthetic import synthetic

    sp = synthetic(1, m=8, d=32, n_train_avg=70, n_test_avg=20, seed=2)
    cfg = DMTRLConfig(loss={loss!r}, lam=1e-3, outer_iters=2, rounds=3,
                      local_iters=64, solver="block_gram", block_size=32, seed=0,
                      **{extra})
    res = fit(cfg, sp.train)
    mesh = jax.make_mesh({mesh_shape}, {mesh_axes})
    W, sigma, _, hist = fit_distributed(cfg, sp.train, mesh, MeshAxes(**{axes_kw}))
    werr = float(np.max(np.abs(W - np.asarray(res.W))))
    serr = float(np.max(np.abs(sigma - np.asarray(res.sigma))))
    gap_last = float(hist["gap"][-1]); gap_first = float(hist["gap"][0])
    print(json.dumps({{"werr": werr, "serr": serr,
                       "gap_first": gap_first, "gap_last": gap_last}}))
    """
)


def _run_subproc(loss, mesh_shape, mesh_axes, axes_kw, extra="dict()"):
    code = _SUBPROC.format(
        repo=REPO, loss=loss, mesh_shape=mesh_shape, mesh_axes=mesh_axes,
        axes_kw=axes_kw, extra=extra,
    )
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=900,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_eight_workers_data_parallel_exact():
    """8 tasks over 8 workers — the paper's one-task-per-worker setting."""
    r = _run_subproc("hinge", "(8,)", '("data",)', 'dict(data="data")')
    assert r["werr"] < 5e-4, r
    assert r["serr"] < 5e-5, r


@pytest.mark.slow
def test_data_plus_model_axes_exact():
    """tasks over 'data', feature dim over 'model' (block-Gram psums)."""
    r = _run_subproc(
        "squared", "(4, 2)", '("data", "model")',
        'dict(data="data", model="model")',
    )
    assert r["werr"] < 5e-4, r
    assert r["serr"] < 5e-5, r


@pytest.mark.slow
def test_model_axis_hoisted_block_gram_exact():
    """the hoisted block-Gram distributed round (dist_block_hoisted) must
    produce the same iterates as the reference — guards the refactor of the
    round body into local-solve/server-reduce pieces."""
    r = _run_subproc(
        "squared", "(4, 2)", '("data", "model")',
        'dict(data="data", model="model")',
        extra='dict(dist_block_hoisted=True)',
    )
    assert r["werr"] < 5e-4, r
    assert r["serr"] < 5e-5, r


@pytest.mark.slow
def test_pod_axis_converges():
    """intra-task sample partitioning over 'pod': iterates differ from the
    single-process reference (finer CoCoA blocks) but the gap must shrink."""
    r = _run_subproc(
        "hinge", "(2, 4)", '("pod", "data")', 'dict(data="data", pod="pod")'
    )
    assert r["gap_last"] < r["gap_first"] * 0.8, r
