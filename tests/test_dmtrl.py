"""End-to-end DMTRL (Algorithm 1) behaviour + paper-claim spot checks."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DMTRLConfig, fit
from repro.core import dual as dm
from repro.core import omega as om
from repro.core.baselines import fit_centralized_mtrl, fit_ssdca, fit_stl
from repro.data.synthetic import synthetic


@pytest.fixture(scope="module")
def splits():
    return synthetic(1, m=8, d=40, n_train_avg=100, n_test_avg=60, seed=0)


@pytest.fixture(scope="module")
def fitted(splits):
    cfg = DMTRLConfig(
        loss="hinge", lam=1e-3, outer_iters=4, rounds=8, local_iters=128,
        solver="block_gram", block_size=64, seed=0,
    )
    return cfg, fit(cfg, splits.train)


def test_gap_decreases(fitted):
    _, res = fitted
    gaps = res.history["gap"]
    # within each outer iteration the gap is non-increasing up to noise
    assert gaps[-1] < gaps[0] * 0.1
    assert gaps[-1] < 0.1


def test_w_alpha_invariant(fitted, splits):
    cfg, res = fitted
    W2 = dm.weights_from_alpha(splits.train, res.alpha, res.sigma, cfg.lam)
    np.testing.assert_allclose(np.asarray(res.W), np.asarray(W2), atol=1e-4)


def test_sigma_constraints(fitted):
    _, res = fitted
    s = np.asarray(res.sigma)
    assert float(np.trace(s)) == pytest.approx(1.0, abs=1e-3)
    assert np.linalg.eigvalsh(s).min() > 0


def test_task_correlation_recovery(fitted, splits):
    """Paper Fig. 2: learned task correlations match the ground truth."""
    _, res = fitted
    learned = np.asarray(om.correlation_from_sigma(res.sigma))
    truth = splits.corr_true
    iu = np.triu_indices(truth.shape[0], k=1)
    align = np.corrcoef(learned[iu], truth[iu])[0, 1]
    assert align > 0.7, align


def test_dmtrl_beats_stl_on_correlated_tasks(splits):
    """Paper Tables 2/3 qualitative claim: exploiting task relations helps
    when tasks are related and data per task is limited."""
    small = synthetic(1, m=8, d=40, n_train_avg=40, n_test_avg=120, seed=2)
    cfg = DMTRLConfig(
        loss="hinge", lam=1e-3, outer_iters=3, rounds=6, local_iters=96, seed=0
    )
    res = fit(cfg, small.train)
    stl = fit_stl(cfg, small.train)
    err_mtl = float(dm.error_rate(small.test, jnp.asarray(res.W)))
    err_stl = float(dm.error_rate(small.test, jnp.asarray(stl.W)))
    assert err_mtl <= err_stl + 0.01, (err_mtl, err_stl)


def test_ssdca_converges_to_same_dual(splits):
    """SSDCA (single machine, exact updates) and DMTRL optimize the same
    objective; with Omega fixed both must approach the same dual value."""
    from repro.core import dual
    from repro.core.losses import get_loss

    data = synthetic(1, m=4, d=24, n_train_avg=60, n_test_avg=20, seed=3).train
    cfg = DMTRLConfig(
        loss="hinge", lam=1e-2, outer_iters=1, rounds=25, local_iters=128,
        learn_omega=False, seed=0,
    )
    res = fit(cfg, data)
    _, _, hist = fit_ssdca(cfg, data, passes=25)
    loss = get_loss("hinge")
    sigma, _ = om.init_sigma(data.m)
    d_dmtrl = float(dual.dual_objective(data, res.alpha, sigma, cfg.lam, loss))
    d_ssdca = hist["dual"][-1]
    assert d_dmtrl == pytest.approx(d_ssdca, rel=0.05), (d_dmtrl, d_ssdca)


def test_centralized_mtrl_parity_squared_loss():
    """Paper Table 2: DMTRL reaches the centralized MTRL solution."""
    sp = synthetic(1, m=5, d=16, n_train_avg=80, n_test_avg=40, seed=4)
    tr = sp.train
    cfg = DMTRLConfig(
        loss="squared", lam=1e-2, outer_iters=3, rounds=10, local_iters=160, seed=0
    )
    res = fit(cfg, tr)
    W_c, sigma_c, _ = fit_centralized_mtrl(cfg, tr, inner_steps=500)
    rmse_d = float(dm.rmse(sp.test, jnp.asarray(res.W)))
    rmse_c = float(dm.rmse(sp.test, jnp.asarray(W_c)))
    assert rmse_d == pytest.approx(rmse_c, rel=0.1), (rmse_d, rmse_c)


def test_rho_grows_with_learned_correlation(fitted):
    _, res = fitted
    # after Omega learning on correlated tasks rho exceeds the identity value 1
    assert res.rho_per_outer[0] == pytest.approx(1.0)
    assert res.rho_per_outer[-1] > 1.5
