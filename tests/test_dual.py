"""Duality-gap and primal-dual map properties (paper Thm. 1 machinery)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dual as dm
from repro.core import omega as om
from repro.core.losses import get_loss
from repro.data.synthetic import synthetic


@pytest.fixture(scope="module")
def small_data():
    return synthetic(1, m=5, d=20, n_train_avg=60, n_test_avg=20, seed=3).train


@pytest.mark.parametrize("loss_name", ["hinge", "squared", "logistic", "smoothed_hinge"])
def test_weak_duality_nonneg_gap(small_data, loss_name):
    """G(alpha) = P(W(alpha)) - D(alpha) >= 0 for any feasible alpha."""
    data = small_data
    loss = get_loss(loss_name)
    sigma, _ = om.init_sigma(data.m)
    rng = np.random.RandomState(0)
    for lam in (1e-2, 1e-4):
        for _ in range(10):
            alpha = jnp.asarray(rng.randn(data.m, data.n_max), jnp.float32) * 0.5
            alpha = loss.dual_feasible(alpha, data.y) * data.mask
            g = float(dm.duality_gap(data, alpha, sigma, lam, loss))
            assert g >= -1e-3, (loss_name, lam, g)


def test_w_alpha_matches_B_sigma(small_data):
    data = small_data
    rng = np.random.RandomState(1)
    alpha = jnp.asarray(rng.rand(data.m, data.n_max), jnp.float32) * data.mask
    sigma, _ = om.init_sigma(data.m)
    W = dm.weights_from_alpha(data, alpha, sigma, 0.1)
    B = dm.compute_B(data, alpha)
    W2 = (B @ sigma).T / 0.1
    np.testing.assert_allclose(np.asarray(W), np.asarray(W2), rtol=1e-5, atol=1e-6)


def test_quad_term_equals_explicit_K(small_data):
    """alpha^T K alpha computed via B equals the explicit kernel matrix."""
    data = small_data
    rng = np.random.RandomState(2)
    m, n_max, d = data.m, data.n_max, data.d
    sigma = jnp.asarray(np.cov(rng.randn(m, 3 * m)) + np.eye(m), jnp.float32)
    alpha = jnp.asarray(rng.randn(m, n_max), jnp.float32) * data.mask
    quad = float(dm.quad_term(data, alpha, sigma))

    # explicit n x n K
    x = np.asarray(data.x)
    msk = np.asarray(data.mask)
    n = np.asarray(data.n)
    a = np.asarray(alpha)
    total = 0.0
    for i in range(m):
        for j in range(m):
            bi = (x[i] * (a[i] * msk[i])[:, None]).sum(0) / n[i]
            bj = (x[j] * (a[j] * msk[j])[:, None]).sum(0) / n[j]
            total += float(sigma[i, j]) * float(bi @ bj)
    assert quad == pytest.approx(total, rel=1e-4, abs=1e-4)


def test_primal_from_alpha_equals_primal_with_omega(small_data):
    """tr(W Omega W^T) shortcut == explicit Omega evaluation at W(alpha)."""
    data = small_data
    loss = get_loss("squared")
    rng = np.random.RandomState(3)
    W0 = jnp.asarray(rng.randn(data.m, data.d), jnp.float32)
    sigma, omega = om.omega_step(W0)
    alpha = jnp.asarray(rng.randn(data.m, data.n_max), jnp.float32) * data.mask
    lam = 1e-2
    p1 = float(dm.primal_objective_from_alpha(data, alpha, sigma, lam, loss))
    W = dm.weights_from_alpha(data, alpha, sigma, lam)
    p2 = float(dm.primal_objective(data, W, omega, lam, loss))
    assert p1 == pytest.approx(p2, rel=1e-3)


def test_metrics_masking(small_data):
    data = small_data
    W = jnp.zeros((data.m, data.d))
    # zero weights: error rate counts sign(0) != sign(y) -> all wrong => 1.0
    assert float(dm.error_rate(data, W)) == pytest.approx(1.0)
    r = float(dm.rmse(data, W))
    y = np.asarray(data.y)[np.asarray(data.mask) > 0]
    assert r == pytest.approx(float(np.sqrt((y**2).mean())), rel=1e-5)
