"""DMTRLEstimator facade: engine-registry parity, options, warm start.

Parity anchors:
  * estimator(engine=E) must be BIT-identical to the deprecated direct
    entry point of E (the adapters only normalize signatures/returns);
  * through the facade, distributed and async(tau=0) stay bit-identical
    (the PR-1 anchor), and reference matches the mesh engines to the same
    float-op-ordering tolerance the direct APIs are tested at;
  * the 8-device mesh variant runs in a subprocess (device count must be
    set before jax initializes) and is marked slow.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    AsyncOptions,
    DistributedOptions,
    DMTRLConfig,
    DMTRLEstimator,
    MeshAxes,
    NotFittedError,
    available_engines,
    get_engine,
)
from repro.core.async_dmtrl import fit_async
from repro.core.distributed import fit_distributed
from repro.core.dmtrl import fit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------
def test_engine_registry_contents():
    names = set(available_engines())
    assert {"reference", "distributed", "async"} <= names
    assert get_engine("reference").needs_mesh is False
    assert get_engine("async").options_cls is AsyncOptions
    assert get_engine("distributed").options_cls is DistributedOptions


def test_unknown_engine_lists_choices():
    with pytest.raises(KeyError, match="reference"):
        get_engine("banana")
    with pytest.raises(KeyError, match="banana"):
        DMTRLEstimator(engine="banana")


# ---------------------------------------------------------------------------
# facade <-> deprecated entry point bit parity
# ---------------------------------------------------------------------------
def test_reference_engine_bit_parity(small_problem, small_cfg):
    res = fit(small_cfg, small_problem.train)
    est = DMTRLEstimator(engine="reference", config=small_cfg).fit(
        small_problem.train
    )
    assert np.array_equal(est.W_, np.asarray(res.W))
    assert np.array_equal(est.alpha_, np.asarray(res.alpha))
    assert np.array_equal(est.sigma_, np.asarray(res.sigma))
    assert np.array_equal(est.omega_, np.asarray(res.omega))
    np.testing.assert_array_equal(est.history["gap"], res.history["gap"])
    assert est.rho_per_outer_ == res.rho_per_outer


def test_distributed_engine_bit_parity(small_problem, small_cfg, one_device_mesh):
    W, sigma, st, hist = fit_distributed(
        small_cfg, small_problem.train, one_device_mesh, MeshAxes(data="data")
    )
    est = DMTRLEstimator(
        engine="distributed", config=small_cfg, mesh=one_device_mesh,
        axes=MeshAxes(data="data"),
    ).fit(small_problem.train)
    assert np.array_equal(est.W_, np.asarray(W))
    assert np.array_equal(est.sigma_, np.asarray(sigma))
    assert np.array_equal(est.alpha_, np.asarray(st.alpha))
    np.testing.assert_array_equal(est.history["gap"], hist["gap"])


def test_async_engine_bit_parity(small_problem, small_cfg, one_device_mesh):
    W, sigma, st, hist = fit_async(
        small_cfg, small_problem.train, one_device_mesh, MeshAxes(data="data")
    )
    est = DMTRLEstimator(
        engine="async", config=small_cfg, mesh=one_device_mesh,
        async_options=AsyncOptions(tau=0),
    ).fit(small_problem.train)
    assert np.array_equal(est.W_, np.asarray(W))
    assert np.array_equal(est.sigma_, np.asarray(sigma))
    np.testing.assert_array_equal(est.history["w_staleness"], hist["w_staleness"])


def test_cross_engine_parity_one_device(small_problem, small_cfg, one_device_mesh):
    """Facade-level cross-engine anchor: distributed == async(tau=0) bitwise;
    reference matches both to the float-op-ordering tolerance the direct
    APIs are pinned at (test_distributed.py)."""
    ref = DMTRLEstimator(engine="reference", config=small_cfg).fit(
        small_problem.train
    )
    dist = DMTRLEstimator(
        engine="distributed", config=small_cfg, mesh=one_device_mesh
    ).fit(small_problem.train)
    asyn = DMTRLEstimator(
        engine="async", config=small_cfg, mesh=one_device_mesh,
        async_options=AsyncOptions(tau=0),
    ).fit(small_problem.train)
    assert np.array_equal(dist.W_, asyn.W_)
    assert np.array_equal(dist.alpha_, asyn.alpha_)
    assert np.array_equal(dist.sigma_, asyn.sigma_)
    np.testing.assert_allclose(ref.W_, dist.W_, atol=2e-4)
    np.testing.assert_allclose(ref.sigma_, dist.sigma_, atol=1e-5)


_PARITY_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax, numpy as np
    sys.path.insert(0, {repo!r} + "/src")
    from repro.core import AsyncOptions, DMTRLConfig, DMTRLEstimator, MeshAxes
    from repro.data.synthetic import synthetic

    sp = synthetic(1, m=8, d=32, n_train_avg=70, n_test_avg=20, seed=2)
    cfg = DMTRLConfig(loss="hinge", lam=1e-3, outer_iters=2, rounds=3,
                      local_iters=64, solver="block_gram", block_size=32, seed=0)
    mesh = jax.make_mesh((8,), ("data",))
    ax = MeshAxes(data="data")
    ref = DMTRLEstimator(engine="reference", config=cfg).fit(sp.train)
    dist = DMTRLEstimator(engine="distributed", config=cfg, mesh=mesh,
                          axes=ax).fit(sp.train)
    asyn = DMTRLEstimator(engine="async", config=cfg, mesh=mesh, axes=ax,
                          async_options=AsyncOptions(tau=0)).fit(sp.train)
    out = dict(
        bit_dist_async=bool(np.array_equal(dist.W_, asyn.W_)
                            and np.array_equal(dist.sigma_, asyn.sigma_)),
        ref_dist_werr=float(np.max(np.abs(ref.W_ - dist.W_))),
    )
    print(json.dumps(out))
    """
)


@pytest.mark.slow
def test_cross_engine_parity_eight_devices():
    code = _PARITY_SUBPROC.format(repo=REPO)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=1200,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["bit_dist_async"] is True
    assert res["ref_dist_werr"] < 2e-4


# ---------------------------------------------------------------------------
# config split / option validation
# ---------------------------------------------------------------------------
def test_async_knobs_rejected_as_core_params():
    with pytest.raises(ValueError, match="AsyncOptions"):
        DMTRLEstimator(engine="async", tau=3)
    with pytest.raises(ValueError, match="DistributedOptions"):
        DMTRLEstimator(engine="distributed", dist_block_hoisted=True)


def test_unknown_config_field_rejected():
    with pytest.raises(ValueError, match="unknown config fields"):
        DMTRLEstimator(engine="reference", stepsize=0.1)


def test_reference_engine_rejects_mesh_and_options(one_device_mesh):
    with pytest.raises(ValueError, match="single-process"):
        DMTRLEstimator(engine="reference", mesh=one_device_mesh)
    with pytest.raises(ValueError, match="reference"):
        DMTRLEstimator(engine="reference", distributed=DistributedOptions())
    with pytest.raises(ValueError, match='engine="async"'):
        DMTRLEstimator(engine="distributed", async_options=AsyncOptions())


def test_async_options_eager_validation():
    for bad in ("fast", "adaptive", None, 1.5, -1):
        with pytest.raises(ValueError, match="tau"):
            AsyncOptions(tau=bad)
    with pytest.raises(ValueError, match="omega_delay"):
        AsyncOptions(omega_delay=-1)
    with pytest.raises(ValueError, match="async_delays"):
        AsyncOptions(async_delays=(1, 0))
    AsyncOptions(tau="auto", async_delays=(1, 2))  # valid forms


def test_config_tau_eager_validation():
    with pytest.raises(ValueError, match="tau"):
        DMTRLConfig(tau="fast")
    with pytest.raises(ValueError, match="tau"):
        DMTRLConfig(tau=-1)
    assert DMTRLConfig(tau="auto").tau == "auto"


def test_async_options_reach_the_engine(small_problem, small_cfg, one_device_mesh):
    """AsyncOptions must override the legacy config fields bit-identically."""
    legacy = dataclasses.replace(small_cfg, omega_delay=1, tau=0)
    W1, s1, _, _ = fit_async(
        legacy, small_problem.train, one_device_mesh, MeshAxes(data="data")
    )
    est = DMTRLEstimator(
        engine="async", config=small_cfg, mesh=one_device_mesh,
        async_options=AsyncOptions(tau=0, omega_delay=1),
    ).fit(small_problem.train)
    assert np.array_equal(est.W_, np.asarray(W1))
    assert np.array_equal(est.sigma_, np.asarray(s1))


# ---------------------------------------------------------------------------
# warm start / predict surface
# ---------------------------------------------------------------------------
def test_partial_fit_continues(small_problem, small_cfg):
    est = DMTRLEstimator(engine="reference", config=small_cfg).fit(
        small_problem.train
    )
    gap0 = est.history["gap"][-1]
    n0 = len(est.history["round"])
    alpha0 = est.alpha_.copy()
    est.partial_fit(small_problem.train)
    assert len(est.history["round"]) == 2 * n0
    # rounds continue, not restart
    assert est.history["round"][n0] == est.history["round"][n0 - 1] + 1
    assert est.history["gap"][-1] <= gap0 + 1e-6
    assert not np.array_equal(est.alpha_, alpha0)
    assert est.n_fit_calls_ == 2


def test_partial_fit_first_call_equals_fit(small_problem, small_cfg):
    a = DMTRLEstimator(engine="reference", config=small_cfg).fit(
        small_problem.train
    )
    b = DMTRLEstimator(engine="reference", config=small_cfg).partial_fit(
        small_problem.train
    )
    assert np.array_equal(a.W_, b.W_)
    assert np.array_equal(a.alpha_, b.alpha_)


def test_partial_fit_warm_start_mesh_engine(small_problem, small_cfg, one_device_mesh):
    """Warm start must round-trip through mesh padding: W(alpha) invariant."""
    from repro.core import dual as dual_mod
    import jax.numpy as jnp

    est = DMTRLEstimator(
        engine="distributed", config=small_cfg, mesh=one_device_mesh
    ).fit(small_problem.train)
    est.partial_fit(small_problem.train)
    W2 = dual_mod.weights_from_alpha(
        small_problem.train, jnp.asarray(est.alpha_), jnp.asarray(est.sigma_),
        small_cfg.lam,
    )
    np.testing.assert_allclose(est.W_, np.asarray(W2), atol=1e-4)


def test_predict_and_decision_function(small_problem, small_cfg):
    est = DMTRLEstimator(engine="reference", config=small_cfg).fit(
        small_problem.train
    )
    te = small_problem.test
    x0 = np.asarray(te.x[0, :4])
    z = est.decision_function(x0, tasks=0)
    np.testing.assert_allclose(z, x0 @ est.W_[0], atol=1e-5)
    labels = est.predict(x0, tasks=0)
    assert set(np.unique(labels)) <= {-1.0, 1.0}
    np.testing.assert_array_equal(labels, np.where(z >= 0, 1.0, -1.0))
    # per-row task ids
    t = np.array([0, 1, 2, 3])
    z2 = est.decision_function(np.asarray(te.x[:, 0]), tasks=t)
    for i in range(4):
        assert z2[i] == pytest.approx(float(np.asarray(te.x[i, 0]) @ est.W_[i]), abs=1e-5)
    # MTLData input returns the masked (m, n_max) matrix
    zm = est.decision_function(te)
    assert zm.shape == (te.m, te.n_max)
    # score is an accuracy for hinge
    assert 0.0 <= est.score(te) <= 1.0


def test_predict_validation(small_problem, small_cfg):
    est = DMTRLEstimator(engine="reference", config=small_cfg)
    with pytest.raises(NotFittedError):
        est.predict(np.zeros((2, 16)), tasks=0)
    est.fit(small_problem.train)
    with pytest.raises(ValueError, match="tasks"):
        est.decision_function(np.zeros((2, small_problem.train.d)))
    with pytest.raises(ValueError, match="task ids"):
        est.decision_function(
            np.zeros((1, small_problem.train.d)), tasks=small_problem.train.m
        )
    with pytest.raises(ValueError, match="features"):
        est.decision_function(np.zeros((2, 3)), tasks=0)
    with pytest.raises(ValueError, match="array inputs"):
        est.decision_function(small_problem.test, tasks=3)


def test_history_requires_fit(small_cfg):
    with pytest.raises(NotFittedError):
        DMTRLEstimator(engine="reference", config=small_cfg).history


def test_deprecated_wrappers_still_importable_and_warn(small_problem, small_cfg):
    import repro.core as core

    with pytest.warns(DeprecationWarning, match="DMTRLEstimator"):
        res = core.fit(small_cfg, small_problem.train, track=False)
    assert np.isfinite(np.asarray(res.W)).all()
