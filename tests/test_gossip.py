"""Decentralized gossip transport (core/gossip.py).

Anchors:
  * topology builders: ring/torus/complete/explicit adjacency are
    symmetric, zero-diagonal, connected; disconnected graphs fail at
    setup; the Metropolis mixing matrix is doubly stochastic with the
    complete graph degenerating to exactly uniform 1/G weights.
  * parity: gossip on a complete graph matches the threaded server member
    to float-association tolerance at tau=0 (the replica-mean invariant),
    and its final objective is within 1e-5 — the acceptance anchor.
  * ring topology still converges on the synthetic fixture (bounded gap
    vs the server trajectory, finite objective).
  * codec sweep none/bf16/int8: final-objective gap bounds + wire stats
    shrink monotonically.
  * random connected topologies (seeded sweep + optional hypothesis
    fuzz): mixing stays doubly stochastic, the fit stays finite and near
    the server member.
  * per-edge staleness events land in the history and
    convergence.staleness_summary picks them up.
"""
import numpy as np
import pytest

from repro.core import AsyncOptions, DMTRLConfig, MeshAxes
from repro.core import convergence as cv
from repro.core.async_dmtrl import fit_async
from repro.core.gossip import build_adjacency, mixing_matrix, spectral_gap
from repro.core.transport import available_transports, get_transport

ATOL = 5e-5  # float-association tolerance (matches test_transport.py)


def _fit(cfg, data, transport, n_workers, **opt_kw):
    opts = AsyncOptions(transport=transport, n_workers=n_workers, **opt_kw)
    return fit_async(cfg, data, None, MeshAxes(data="data"), options=opts)


def _final_objective(hist):
    return float(np.asarray(hist["primal"])[-1])


def _random_connected(G, rng):
    """Random spanning tree + random extra edges: connected by build."""
    adj = np.zeros((G, G), np.int64)
    order = rng.permutation(G)
    for i in range(1, G):
        j = order[rng.integers(0, i)]
        adj[order[i], j] = adj[j, order[i]] = 1
    for _ in range(int(rng.integers(0, G))):
        a, b = rng.integers(0, G, size=2)
        if a != b:
            adj[a, b] = adj[b, a] = 1
    return adj


# ---------------------------------------------------------------------------
# topology builders
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("topology", ["ring", "torus", "complete"])
@pytest.mark.parametrize("G", [1, 2, 3, 4, 6, 8])
def test_adjacency_properties(topology, G):
    adj = build_adjacency(topology, G)
    assert adj.shape == (G, G)
    assert np.array_equal(adj, adj.T)
    assert np.all(np.diag(adj) == 0)
    assert np.all((adj == 0) | (adj == 1))


def test_ring_degrees():
    adj = build_adjacency("ring", 6)
    assert np.all(adj.sum(axis=1) == 2)


def test_torus_is_a_grid():
    adj = build_adjacency("torus", 6)  # 2 x 3 wrap-around grid
    # every node touches its 4 wrapped grid neighbors; on a 2-row torus
    # the up/down wraps coincide, leaving degree 3
    assert np.all(adj.sum(axis=1) == 3)


def test_torus_prime_degenerates_to_ring():
    np.testing.assert_array_equal(
        build_adjacency("torus", 5), build_adjacency("ring", 5)
    )


def test_explicit_adjacency_roundtrips():
    want = build_adjacency("ring", 4)
    got = build_adjacency(want, 4)
    np.testing.assert_array_equal(got, want)


def test_explicit_adjacency_validation():
    bad = np.zeros((3, 3), np.int64)
    bad[0, 1] = 1  # not symmetric
    with pytest.raises(ValueError, match="symmetric"):
        build_adjacency(bad, 3)
    with pytest.raises(ValueError, match="0/1"):
        build_adjacency(np.full((2, 2), 2.0) - 2 * np.eye(2), 2)
    eye = np.eye(3, dtype=np.int64)
    with pytest.raises(ValueError, match="zero diagonal"):
        build_adjacency(eye, 3)
    with pytest.raises(ValueError, match=r"\(4, 4\)"):
        build_adjacency(np.zeros((3, 3), np.int64), 4)
    with pytest.raises(ValueError, match="disconnected"):
        build_adjacency(np.zeros((3, 3), np.int64), 3)
    two_islands = np.zeros((4, 4), np.int64)
    two_islands[0, 1] = two_islands[1, 0] = 1
    two_islands[2, 3] = two_islands[3, 2] = 1
    with pytest.raises(ValueError, match="disconnected"):
        build_adjacency(two_islands, 4)
    with pytest.raises(ValueError, match="unknown gossip topology"):
        build_adjacency("hypercube", 4)


@pytest.mark.parametrize("topology", ["ring", "torus", "complete"])
@pytest.mark.parametrize("G", [2, 3, 4, 6, 8])
def test_mixing_matrix_doubly_stochastic(topology, G):
    M = mixing_matrix(build_adjacency(topology, G))
    np.testing.assert_allclose(M.sum(axis=0), 1.0, atol=1e-12)
    np.testing.assert_allclose(M.sum(axis=1), 1.0, atol=1e-12)
    np.testing.assert_allclose(M, M.T, atol=1e-12)
    assert np.all(M >= -1e-12)


def test_complete_graph_mixing_is_uniform():
    G = 5
    M = mixing_matrix(build_adjacency("complete", G))
    # off-diagonal weights are exactly 1/G; the diagonal takes the slack
    # 1 - (G-1)/G, one float rounding away from 1/G
    off = ~np.eye(G, dtype=bool)
    np.testing.assert_array_equal(M[off], 1.0 / G)
    np.testing.assert_allclose(M, np.full((G, G), 1.0 / G), atol=1e-15)


def test_spectral_gap_ordering():
    # denser graphs contract consensus faster
    gaps = {
        t: spectral_gap(mixing_matrix(build_adjacency(t, 8)))
        for t in ("ring", "torus", "complete")
    }
    assert gaps["complete"] == pytest.approx(1.0)
    assert gaps["ring"] < gaps["torus"] < gaps["complete"]
    # longer rings mix slower
    ring16 = spectral_gap(mixing_matrix(build_adjacency("ring", 16)))
    assert ring16 < gaps["ring"]


def test_random_connected_topologies_mix(seed_range=range(6)):
    for seed in seed_range:
        rng = np.random.default_rng(seed)
        G = int(rng.integers(2, 9))
        adj = _random_connected(G, rng)
        adj2 = build_adjacency(adj, G)  # validates
        M = mixing_matrix(adj2)
        np.testing.assert_allclose(M.sum(axis=0), 1.0, atol=1e-12)
        np.testing.assert_allclose(M.sum(axis=1), 1.0, atol=1e-12)
        assert 0.0 < spectral_gap(M) <= 1.0 + 1e-12


def test_hypothesis_random_topologies():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=2, max_value=10), st.integers(0, 2 ** 31))
    def run(G, seed):
        rng = np.random.default_rng(seed)
        adj = build_adjacency(_random_connected(G, rng), G)
        M = mixing_matrix(adj)
        np.testing.assert_allclose(M.sum(axis=0), 1.0, atol=1e-12)
        np.testing.assert_allclose(M.sum(axis=1), 1.0, atol=1e-12)
        gap = spectral_gap(M)
        assert 0.0 < gap <= 1.0 + 1e-12

    run()


# ---------------------------------------------------------------------------
# registry / option plumbing
# ---------------------------------------------------------------------------
def test_gossip_registered():
    assert "gossip" in available_transports()
    spec = get_transport("gossip")
    assert spec.needs_mesh is False


def test_topology_and_codec_options_validated():
    with pytest.raises(ValueError, match="topology"):
        AsyncOptions(topology="hypercube")
    with pytest.raises(ValueError, match="codec"):
        AsyncOptions(codec="zstd")
    with pytest.raises(ValueError, match="topology"):
        AsyncOptions(topology=7)
    # valid spellings construct eagerly
    AsyncOptions(transport="gossip", topology="ring", codec="int8")


def test_topology_rejected_on_star_transports(small_problem, small_cfg):
    with pytest.raises(ValueError, match="gossip"):
        _fit(
            small_cfg, small_problem.train, "threaded", 2, topology="ring"
        )


def test_codec_rejected_on_simulated(small_problem, small_cfg, one_device_mesh):
    opts = AsyncOptions(transport="simulated", codec="bf16")
    with pytest.raises(ValueError, match="codec"):
        fit_async(
            small_cfg,
            small_problem.train,
            one_device_mesh,
            MeshAxes(data="data"),
            options=opts,
        )


def test_disconnected_explicit_topology_fails_at_setup(
    small_problem, small_cfg
):
    adj = tuple(
        tuple(int(v) for v in row) for row in np.zeros((2, 2), np.int64)
    )
    with pytest.raises(ValueError, match="disconnected"):
        _fit(small_cfg, small_problem.train, "gossip", 2, topology=adj)


# ---------------------------------------------------------------------------
# parity — the acceptance anchor
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def threaded_result(small_problem, small_cfg):
    return _fit(small_cfg, small_problem.train, "threaded", 4, tau=0)


def test_complete_graph_matches_threaded(
    small_problem, small_cfg, threaded_result
):
    Wt, sigt, _, ht = threaded_result
    Wg, sigg, _, hg = _fit(
        small_cfg, small_problem.train, "gossip", 4, tau=0,
        topology="complete",
    )
    np.testing.assert_allclose(Wg, Wt, atol=ATOL)
    np.testing.assert_allclose(sigg, sigt, atol=ATOL)
    # acceptance criterion: final objective within 1e-5
    assert abs(_final_objective(hg) - _final_objective(ht)) <= 1e-5


def test_ring_converges_near_server(
    small_problem, small_cfg, threaded_result
):
    _, _, _, ht = threaded_result
    Wg, _, _, hg = _fit(
        small_cfg, small_problem.train, "gossip", 4, tau=0, topology="ring"
    )
    obj_g, obj_t = _final_objective(hg), _final_objective(ht)
    assert np.isfinite(obj_g)
    assert np.all(np.isfinite(np.asarray(Wg)))
    # sparse mixing perturbs the trajectory but must stay in the same
    # basin on the tiny fixture (loose relative bound, not parity)
    assert abs(obj_g - obj_t) <= 0.2 * abs(obj_t)


@pytest.mark.parametrize("codec", ["none", "bf16", "int8"])
def test_codec_sweep_objective_gap(
    small_problem, small_cfg, threaded_result, codec
):
    _, _, _, ht = threaded_result
    _, _, _, hg = _fit(
        small_cfg, small_problem.train, "gossip", 4, tau=0, codec=codec
    )
    gap = abs(_final_objective(hg) - _final_objective(ht))
    # lossy codecs (with error feedback) stay within a small bounded gap
    # of the exact run; exact codec matches to float association
    bound = {"none": 1e-5, "bf16": 5e-3, "int8": 2e-2}[codec]
    assert gap <= bound * max(1.0, abs(_final_objective(ht)))


def test_random_topology_fit_stays_finite(small_problem, small_cfg):
    rng = np.random.default_rng(3)
    adj = build_adjacency(_random_connected(4, rng), 4)
    topo = tuple(tuple(int(v) for v in row) for row in adj)
    W, sigma, _, hist = _fit(
        small_cfg, small_problem.train, "gossip", 4, tau=0, topology=topo
    )
    assert np.all(np.isfinite(np.asarray(W)))
    assert np.isfinite(_final_objective(hist))


# ---------------------------------------------------------------------------
# per-edge staleness accounting
# ---------------------------------------------------------------------------
def test_per_edge_staleness_history_and_summary(small_problem, small_cfg):
    _, _, _, hist = _fit(
        small_cfg, small_problem.train, "gossip", 4, tau=1, topology="ring"
    )
    for k in ("e_src", "e_dst", "e_stal", "e_tick"):
        assert k in hist and len(hist[k])
    # ring on 4 nodes has 4 edges, one record per edge per exchange
    assert len(hist["e_stal"]) % 4 == 0
    summ = cv.staleness_summary(hist)
    assert summ["n_exchanges"] == len(hist["e_stal"])
    assert summ["max_edge_staleness"] >= summ["mean_edge_staleness"] >= 0.0
    assert set(summ["per_edge_mean"]) == {(0, 1), (0, 3), (1, 2), (2, 3)}


def test_server_histories_have_no_edge_keys(threaded_result):
    _, _, _, ht = threaded_result
    summ = cv.staleness_summary(ht)
    assert "n_exchanges" not in summ
    assert "e_stal" not in ht


# ---------------------------------------------------------------------------
# wire stats
# ---------------------------------------------------------------------------
def test_gossip_wire_stats_monotone_under_codecs(small_problem, small_cfg):
    totals = {}
    for codec in ("none", "bf16", "int8"):
        opts = AsyncOptions(
            transport="gossip", n_workers=4, tau=0, codec=codec
        )
        cfg = opts.merge_into(small_cfg)
        from repro.core import omega_regularizers as omega_reg
        from repro.core.dmtrl import _rho_value
        import jax

        reg = omega_reg.resolve_regularizer(
            cfg, None, m=small_problem.train.m
        )
        t = get_transport("gossip").factory()
        t.setup(
            cfg, small_problem.train, mesh=None, axes=MeshAxes(),
            reg=reg, init=None, track=False,
        )
        try:
            key = jax.random.PRNGKey(0)
            rho_sigma = t.rho_sigma()
            for p in range(cfg.outer_iters):
                rho = _rho_value(
                    cfg, rho_sigma, n_blocks_scale=1.0, reg=reg
                )
                key, ok = jax.random.split(key)
                t.run_w_step(p, rho, ok)
                if reg.learns:
                    sig_t, om_t = reg.step(t.w_true(), cfg.omega_jitter)
                    sig, om = t.pad_sigma(sig_t, om_t)
                    t.install_sigma(sig, om, defer=False)
                    rho_sigma = sig
            s = t.wire_stats
            assert s["n_exchanges"] > 0
            assert s["spectral_gap"] == pytest.approx(1.0)  # complete
            totals[codec] = (
                s["snapshot_bytes"] + s["commit_bytes"] + s["mix_bytes"]
            )
            raw = (
                s["raw_snapshot_bytes"]
                + s["raw_commit_bytes"]
                + s["raw_mix_bytes"]
            )
            assert raw == totals["none"] if codec == "none" else raw > 0
        finally:
            t.close()
    assert totals["none"] > totals["bf16"] > totals["int8"]
