"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash import flash_attention
from repro.kernels.flash.ops import flash_attention_bshd
from repro.kernels.flash.ref import attention_ref
from repro.kernels.sdca import sdca_block_kernel, sdca_round_kernel
from repro.kernels.sdca.ref import sdca_block_ref, sdca_round_ref
from repro.kernels.ssd.ops import ssd_forward
from repro.kernels.ssd.ref import chunk_ref, naive_recurrence
from repro.kernels.ssd import ssd_chunk_kernel


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "B,H,S,HD,causal,window,dtype",
    [
        (2, 3, 256, 64, True, 0, jnp.float32),
        (1, 2, 128, 32, True, 48, jnp.float32),
        pytest.param(2, 2, 256, 64, False, 0, jnp.float32,
                     marks=pytest.mark.slow),
        pytest.param(1, 4, 512, 128, True, 0, jnp.float32,
                     marks=pytest.mark.slow),
        pytest.param(2, 2, 256, 64, True, 0, jnp.bfloat16,
                     marks=pytest.mark.slow),
        (1, 1, 64, 16, True, 16, jnp.float32),
    ],
)
def test_flash_vs_ref(B, H, S, HD, causal, window, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, HD), dtype)
    k = jax.random.normal(ks[1], (B, H, S, HD), dtype)
    v = jax.random.normal(ks[2], (B, H, S, HD), dtype)
    out = flash_attention(q, k, v, causal, window, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal, window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_flash_bshd_wrapper_with_padding():
    key = jax.random.PRNGKey(1)
    B, S, H, HD = 2, 200, 2, 64  # S not a multiple of the block
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, HD))
    k = jax.random.normal(ks[1], (B, S, H, HD))
    v = jax.random.normal(ks[2], (B, S, H, HD))
    out = flash_attention_bshd(q, k, v, causal=True, block_q=64, block_k=64)
    ref = attention_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1), True, 0
    )
    np.testing.assert_allclose(
        np.asarray(jnp.moveaxis(out, 2, 1)), np.asarray(ref), atol=2e-5
    )


# ---------------------------------------------------------------------------
# sdca block kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("loss", ["hinge", "squared", "smoothed_hinge"])
@pytest.mark.parametrize(
    "B,d",
    [
        (16, 50),
        (32, 130),
        pytest.param(64, 1024, marks=pytest.mark.slow),
        pytest.param(128, 700, marks=pytest.mark.slow),
    ],
)
def test_sdca_kernel_vs_ref(loss, B, d):
    key = jax.random.PRNGKey(B * d)
    ks = jax.random.split(key, 6)
    xb = jax.random.normal(ks[1], (B, d))
    w = 0.1 * jax.random.normal(ks[2], (d,))
    r = 0.05 * jax.random.normal(ks[3], (d,))
    y = (
        jnp.sign(jax.random.normal(ks[4], (B,)))
        if loss != "squared"
        else jax.random.normal(ks[4], (B,))
    )
    at0 = (
        y * jnp.abs(0.4 * jax.random.normal(ks[5], (B,))).clip(0, 1)
        if loss != "squared"
        else 0.4 * jax.random.normal(ks[5], (B,))
    )
    cb = jax.random.randint(ks[0], (B,), 0, max(B // 2, 1))  # force duplicates
    kappa = jnp.float32(0.9)
    dk = sdca_block_kernel(xb, w, r, at0, y, cb, kappa, loss, d_tile=256)
    dr = sdca_block_ref(xb, w, r, at0, y, cb, kappa, loss)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), atol=5e-6)


@pytest.mark.parametrize("loss", ["hinge", "squared", "smoothed_hinge"])
@pytest.mark.parametrize(
    "n,d,H,block",
    [
        (60, 40, 64, 16),
        (100, 30, 96, 32),
        pytest.param(256, 130, 256, 64, marks=pytest.mark.slow),
    ],
)
def test_sdca_round_kernel_vs_ref(loss, n, d, H, block):
    """Fused round kernel == sequential coordinate-at-a-time oracle,
    including the on-device coordinate sampling and duplicate handling."""
    key = jax.random.PRNGKey(n * d + H)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (n, d))
    y = (
        jnp.sign(jax.random.normal(ks[1], (n,)))
        if loss != "squared"
        else jax.random.normal(ks[1], (n,))
    )
    alpha = (
        y * jnp.abs(0.4 * jax.random.normal(ks[2], (n,))).clip(0, 1)
        if loss != "squared"
        else 0.4 * jax.random.normal(ks[2], (n,))
    )
    w = 0.1 * jax.random.normal(ks[3], (d,))
    u = jax.random.uniform(ks[4], (H,))
    n_i = jnp.int32(max(n - 7, 1))  # padded tail + duplicate draws
    kappa = jnp.float32(0.9)
    dak, rk = sdca_round_kernel(x, y, alpha, w, u, n_i, kappa, loss, block=block)
    dar, rr = sdca_round_ref(x, y, alpha, w, u, n_i, kappa, loss)
    np.testing.assert_allclose(np.asarray(dak), np.asarray(dar), atol=1e-5)
    np.testing.assert_allclose(np.asarray(rk), np.asarray(rr), atol=1e-5)
    # padded coordinates must never be touched
    assert np.all(np.asarray(dak)[int(n_i):] == 0.0)


# ---------------------------------------------------------------------------
# ssd chunk kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "B,L,H,P,N,chunk",
    [
        pytest.param(2, 96, 4, 16, 8, 32, marks=pytest.mark.slow),
        (1, 64, 2, 32, 16, 16),
        pytest.param(2, 130, 3, 8, 4, 32, marks=pytest.mark.slow),
    ],
)
def test_ssd_forward_vs_naive(B, L, H, P, N, chunk):
    key = jax.random.PRNGKey(L)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[1], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[2], (B, L, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[3], (H,)))
    Bm = jax.random.normal(ks[4], (B, L, H, N)) * 0.3
    Cm = jax.random.normal(ks[5], (B, L, H, N)) * 0.3
    Y0, S0 = naive_recurrence(x, dt, A, Bm, Cm)
    Y, S = ssd_forward(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(Y), np.asarray(Y0), atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S0), atol=2e-4)


def test_ssd_chunk_kernel_matches_chunk_ref():
    key = jax.random.PRNGKey(9)
    B, H, nc, Q, P, N = 2, 3, 4, 16, 8, 8
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[1], (B, H, nc, Q, P))
    dt = jax.nn.softplus(jax.random.normal(ks[2], (B, H, nc, Q))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[3], (H,)))
    Bm = jax.random.normal(ks[4], (B, H, nc, Q, N)) * 0.3
    Cm = jax.random.normal(ks[5], (B, H, nc, Q, N)) * 0.3
    Yk, Sk, ak = ssd_chunk_kernel(x, dt, A, Bm, Cm)
    Yr, Sr, ar = chunk_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(Yk), np.asarray(Yr), atol=1e-5)
    # kernel S is (N, P); ref is (N, P) too via einsum 'bhcqn,bhcqp->bhcnp'
    np.testing.assert_allclose(np.asarray(Sk), np.asarray(Sr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(ak), np.asarray(ar), atol=1e-6)


@pytest.mark.slow
def test_model_ssd_matches_kernel_pipeline():
    """models/ssm.ssd_chunked and kernels/ssd.ops.ssd_forward agree."""
    from repro.models.ssm import ssd_chunked

    key = jax.random.PRNGKey(4)
    B, L, H, P, N = 2, 80, 2, 16, 8
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[1], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[2], (B, L, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[3], (H,)))
    Bm = jax.random.normal(ks[4], (B, L, H, N)) * 0.3
    Cm = jax.random.normal(ks[5], (B, L, H, N)) * 0.3
    Y1, S1 = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    Y2, S2 = ssd_forward(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(Y1), np.asarray(Y2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S2), atol=2e-5)
