"""Loss/conjugate properties: Fenchel–Young, feasibility, SDCA optimality.

hypothesis is an optional test dependency (see pyproject's [test] extra);
property tests import it via ``pytest.importorskip`` at call time so a
missing install skips just those tests instead of erroring collection.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.losses import get_loss, registered_losses

LOSSES = sorted(registered_losses())


def _label_for(loss, rng):
    return float(np.sign(rng.randn()) or 1.0) if loss.is_classification else float(
        rng.randn()
    )


@pytest.mark.parametrize("name", LOSSES)
def test_fenchel_young_inequality(name):
    """l(z) + l*(u) >= u*z for all z, u in dom(l*)."""
    loss = get_loss(name)
    rng = np.random.RandomState(0)
    for _ in range(100):
        y = _label_for(loss, rng)
        z = float(rng.randn() * 3)
        alpha = float(rng.randn())
        alpha = float(loss.dual_feasible(jnp.float32(alpha), jnp.float32(y)))
        u = -alpha
        lhs = float(loss.value(jnp.float32(z), jnp.float32(y))) + float(
            loss.conjugate(jnp.float32(u), jnp.float32(y))
        )
        assert lhs >= u * z - 1e-4, (name, y, z, u, lhs, u * z)


@pytest.mark.parametrize("name", LOSSES)
def test_sdca_delta_maximizes_scalar_objective(name):
    """delta from the closed form must beat random perturbations of the
    1-d concave objective f(d) = -l*(-(at+d)) - c d - a/2 d^2."""
    loss = get_loss(name)
    rng = np.random.RandomState(1)

    def f(d, at, c, a, y):
        val = -loss.conjugate(-(at + d), y) - c * d - 0.5 * a * d * d
        return float(val)

    for _ in range(25):
        y = jnp.float32(_label_for(loss, rng))
        at = loss.dual_feasible(jnp.float32(rng.randn() * 0.5), y)
        c = jnp.float32(rng.randn())
        a = jnp.float32(abs(rng.randn()) + 0.05)
        d_star = loss.sdca_delta(at, c, a, y)
        assert bool(jnp.isfinite(d_star))
        f_star = f(d_star, at, c, a, y)
        for eps in (0.3, 0.05, 0.01):
            for sgn in (+1, -1):
                d_alt = d_star + sgn * eps
                # perturbed point may be infeasible -> clip through feasibility
                a_alt = loss.dual_feasible(at + d_alt, y)
                f_alt = f(a_alt - at, at, c, a, y)
                assert f_star >= f_alt - 1e-3, (
                    name,
                    float(y),
                    float(at),
                    float(c),
                    float(a),
                    float(d_star),
                    f_star,
                    f_alt,
                )


def test_hinge_value_matches_definition():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    loss = get_loss("hinge")

    @settings(max_examples=200, deadline=None)
    @given(z=st.floats(-10, 10), y=st.sampled_from([-1.0, 1.0]))
    def check(z, y):
        assert float(loss.value(jnp.float32(z), jnp.float32(y))) == pytest.approx(
            max(0.0, 1.0 - y * z), abs=1e-5
        )

    check()


def test_squared_conjugate_closed_form():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    loss = get_loss("squared")

    @settings(max_examples=100, deadline=None)
    @given(st.floats(-5, 5), st.floats(-5, 5))
    def check(u, y):
        assert float(loss.conjugate(jnp.float32(u), jnp.float32(y))) == pytest.approx(
            0.5 * u * u + u * y, rel=1e-4, abs=1e-4
        )

    check()


@pytest.mark.parametrize("name", ["hinge", "smoothed_hinge", "logistic"])
def test_classification_feasible_region(name):
    """dual_feasible projects into y*alpha in [0, 1]."""
    loss = get_loss(name)
    rng = np.random.RandomState(2)
    al = jnp.asarray(rng.randn(1000) * 5, jnp.float32)
    y = jnp.asarray(np.sign(rng.randn(1000)), jnp.float32)
    proj = loss.dual_feasible(al, y)
    assert bool(jnp.all(y * proj >= -1e-6))
    assert bool(jnp.all(y * proj <= 1.0 + 1e-6))


def test_subgradients_are_valid():
    """l(b) >= l(a) + g(a)(b-a) for convexity with g the implemented subgrad."""
    rng = np.random.RandomState(3)
    for name in LOSSES:
        loss = get_loss(name)
        for _ in range(50):
            y = jnp.float32(_label_for(loss, rng))
            a = jnp.float32(rng.randn() * 2)
            b = jnp.float32(rng.randn() * 2)
            g = loss.subgradient(a, y)
            lhs = float(loss.value(b, y))
            rhs = float(loss.value(a, y)) + float(g) * float(b - a)
            assert lhs >= rhs - 1e-4, (name, float(y), float(a), float(b))
