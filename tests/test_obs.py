"""Observability layer: span tracer, metrics registry, exporters, and the
unified wire_stats schema shared by every transport.

Tracing and the global registry are process-wide state, so every test that
touches them goes through the ``clean_obs`` fixture (tracer disabled and
cleared on exit, global registry untouched — tests build their own).
"""
import json
import threading
import urllib.request

import pytest

from repro import obs
from repro.obs.export import JsonlExporter, MetricsHTTPServer, to_prometheus
from repro.obs.metrics import (
    MetricsRegistry,
    publish_serving_metrics,
    publish_wire_stats,
)


@pytest.fixture
def clean_obs():
    obs.disable()
    obs.get_tracer().clear()
    yield
    obs.disable()
    obs.get_tracer().clear()


class FakeClock:
    """Deterministic monotone clock: each tick() advances by ``step``."""

    def __init__(self, step=1.0):
        self.t = 0.0
        self.step = step

    def __call__(self):
        return self.t

    def tick(self, dt=None):
        self.t += self.step if dt is None else dt
        return self.t


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------
def test_span_disabled_is_noop(clean_obs):
    assert not obs.enabled()
    with obs.span("gate", cat="transport", worker=0):
        pass
    assert obs.get_tracer().events() == []
    # the disabled path hands back one shared object — no per-call alloc
    assert obs.span("a") is obs.span("b", cat="x", k=1)


def test_span_records_chrome_complete_events(clean_obs):
    clk = FakeClock()
    tracer = obs.enable(clear=True, clock=clk)
    with obs.span("commit", cat="transport", worker=3, round=7):
        clk.tick(0.25)
    obs.disable()
    (e,) = tracer.events()
    assert e["name"] == "commit" and e["cat"] == "transport"
    assert e["ph"] == "X"
    assert e["dur"] == pytest.approx(0.25e6)  # microseconds
    assert e["args"] == {"worker": 3, "round": 7}


def test_span_nesting_and_breakdown(clean_obs):
    clk = FakeClock()
    obs.enable(clear=True, clock=clk)
    with obs.span("round", cat="transport"):
        with obs.span("solve", cat="transport"):
            clk.tick(1.0)
        with obs.span("solve", cat="transport"):
            clk.tick(2.0)
    obs.disable()
    bd = obs.phase_breakdown()
    assert bd["solve"]["count"] == 2
    assert bd["solve"]["total_s"] == pytest.approx(3.0)
    assert bd["solve"]["max_s"] == pytest.approx(2.0)
    assert bd["round"]["total_s"] == pytest.approx(3.0)
    # the inner spans lie inside the outer one on the same thread
    evs = sorted(obs.get_tracer().events(), key=lambda e: e["dur"])
    outer = evs[-1]
    for inner in evs[:-1]:
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]


def test_ring_buffer_caps_and_counts_drops(clean_obs):
    tracer = obs.enable(capacity=4, clear=True)
    for i in range(10):
        with obs.span(f"s{i}"):
            pass
    obs.disable()
    evs = tracer.events()
    assert len(evs) == 4
    assert tracer.dropped == 6
    # ring keeps the NEWEST spans
    assert [e["name"] for e in evs] == ["s6", "s7", "s8", "s9"]


def test_export_chrome_trace(tmp_path, clean_obs):
    obs.enable(clear=True)
    with obs.span("fit_async", cat="driver"):
        with obs.span("w_step", cat="driver", outer=0):
            pass
    obs.disable()
    path = tmp_path / "trace.json"
    n = obs.export_chrome(str(path))
    assert n == 2
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"fit_async", "w_step"}
    # thread-name metadata rows so chrome://tracing labels the lanes
    assert any(e["ph"] == "M" and e["name"] == "thread_name" for e in evs)


def test_concurrent_spans_stay_well_formed(clean_obs):
    """4 threads emit nested spans concurrently; every thread's events
    must form a proper per-thread nesting with no cross-thread bleed."""
    n_threads, n_outer = 4, 25
    tracer = obs.enable(capacity=4096, clear=True)
    barrier = threading.Barrier(n_threads)

    def worker(w):
        barrier.wait()
        for r in range(n_outer):
            with obs.span("round", cat="t", worker=w, round=r):
                for _ in range(3):
                    with obs.span("inner", cat="t", worker=w):
                        pass

    threads = [
        threading.Thread(target=worker, args=(w,)) for w in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    obs.disable()

    evs = tracer.events()
    assert tracer.dropped == 0
    assert len(evs) == n_threads * n_outer * 4
    by_tid = {}
    for e in evs:
        by_tid.setdefault(e["tid"], []).append(e)
    assert len(by_tid) == n_threads
    for tid, tevs in by_tid.items():
        # one worker id per thread: no event landed on the wrong lane
        assert len({e["args"]["worker"] for e in tevs}) == 1
        assert sum(e["name"] == "round" for e in tevs) == n_outer
        # proper nesting: sorted by start (ties: longest first), each span
        # must close before every still-open ancestor does
        tevs.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack = []
        for e in tevs:
            t0, t1 = e["ts"], e["ts"] + e["dur"]
            while stack and stack[-1] <= t0:
                stack.pop()
            if stack:
                assert t1 <= stack[-1] + 1e-6
            stack.append(t1)


def test_enable_capacity_change_rebuilds_ring(clean_obs):
    t1 = obs.enable(capacity=8, clear=True)
    t2 = obs.enable(capacity=8)  # same capacity: same tracer
    assert t1 is t2
    t3 = obs.enable(capacity=16)
    assert t3 is not t1
    obs.disable()


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("repro_test_commits", "commits", labels=("worker",))
    c.inc(worker=0)
    c.inc(2.0, worker=0)
    c.inc(worker=1)
    series = {d["worker"]: v for d, v in c.series()}
    assert series == {"0": 3.0, "1": 1.0}  # label values stringify
    with pytest.raises(ValueError):
        c.inc(-1.0, worker=0)  # counters only go up

    g = reg.gauge("repro_test_depth", "queue depth")
    g.set(5.0)
    g.add(-2.0)
    assert g.value() == 3.0

    h = reg.histogram(
        "repro_test_latency", "s", buckets=(0.1, 1.0, 10.0)
    )
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    ((_, state),) = h.series()
    assert state.count == 4
    assert state.sum == pytest.approx(55.55)
    assert state.counts == [1, 1, 1, 1]  # per-bucket + overflow


def test_metric_label_and_name_validation():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.counter("bad name!", "x")
    c = reg.counter("repro_test_c", "x", labels=("worker",))
    with pytest.raises(ValueError):
        c.inc(replica=0)  # undeclared label
    c.inc()  # omitted declared label defaults to "" (one catch-all series)
    ((labels, v),) = c.series()
    assert labels == {"worker": ""} and v == 1.0


def test_registry_get_or_create_conflicts():
    reg = MetricsRegistry()
    c1 = reg.counter("repro_test_x", "x", labels=("a",))
    assert reg.counter("repro_test_x", "x", labels=("a",)) is c1
    with pytest.raises(TypeError):
        reg.gauge("repro_test_x", "x")  # kind conflict
    with pytest.raises(ValueError):
        reg.counter("repro_test_x", "x", labels=("b",))  # label conflict


def test_registry_as_dict_is_json_ready():
    reg = MetricsRegistry()
    reg.counter("repro_test_n", "n").inc()
    reg.histogram("repro_test_h", "h", buckets=(1.0,)).observe(0.5)
    json.dumps(reg.as_dict())  # must not raise


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter(
        "repro_transport_n_commits", "commits", labels=("transport",)
    ).inc(3, transport="threaded")
    reg.histogram("repro_serve_lat", "s", buckets=(0.5, 1.0)).observe(0.7)
    text = to_prometheus(reg)
    assert "# TYPE repro_transport_n_commits counter" in text
    assert 'repro_transport_n_commits{transport="threaded"} 3' in text
    # histograms expose CUMULATIVE buckets plus _sum/_count
    assert 'repro_serve_lat_bucket{le="0.5"} 0' in text
    assert 'repro_serve_lat_bucket{le="1"} 1' in text  # integral le: no .0
    assert 'repro_serve_lat_bucket{le="+Inf"} 1' in text
    assert "repro_serve_lat_count 1" in text


def test_jsonl_exporter(tmp_path):
    reg = MetricsRegistry()
    g = reg.gauge("repro_test_g", "g")
    path = tmp_path / "metrics.jsonl"
    clk = FakeClock()
    exp = JsonlExporter(str(path), registry=reg, clock=clk)
    g.set(1.0)
    exp.snapshot()
    clk.tick()
    g.set(2.0)
    exp.snapshot()
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len(lines) == 2
    assert lines[0]["t"] == 0.0 and lines[1]["t"] == 1.0
    assert "metrics" in lines[0]


def test_metrics_http_server_serves_prometheus():
    reg = MetricsRegistry()
    reg.counter("repro_test_hits", "hits").inc(7)
    with MetricsHTTPServer(port=0, registry=reg) as srv:
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{srv.port}/metrics", timeout=5
        ).read().decode()
    assert "repro_test_hits 7" in body


# ---------------------------------------------------------------------------
# wire_stats: one schema across every transport
# ---------------------------------------------------------------------------
def test_new_wire_stats_rejects_unknown_keys():
    from repro.core.transport import WIRE_STATS_SCHEMA, new_wire_stats

    ws = new_wire_stats(codec="int8")
    assert set(ws) == set(WIRE_STATS_SCHEMA)
    assert ws["codec"] == "int8"
    with pytest.raises(ValueError):
        new_wire_stats(snapshot_byts=1)  # typo'd counter name


@pytest.mark.parametrize("name", ["simulated", "threaded", "gossip"])
def test_transports_share_wire_stats_schema(
    name, small_problem, small_cfg, one_device_mesh
):
    """Every transport's ``wire_stats`` carries the documented key union —
    gossip-only keys (spectral_gap, mix traffic) included, zeroed where a
    transport has nothing to report."""
    import dataclasses

    from repro.core import MeshAxes
    from repro.core.omega_regularizers import resolve_regularizer
    from repro.core.transport import WIRE_STATS_SCHEMA, get_transport

    cfg = dataclasses.replace(
        small_cfg, transport=name,
        # simulated derives its worker count from the mesh data axis
        n_workers=None if name == "simulated" else 4,
        **({"topology": "ring"} if name == "gossip" else {}),
    )
    reg = resolve_regularizer(cfg, None, m=small_problem.train.m)
    t = get_transport(name).factory()
    kw = (
        dict(mesh=one_device_mesh, axes=MeshAxes(data="data"))
        if name == "simulated"
        else dict(mesh=None, axes=MeshAxes())
    )
    t.setup(cfg, small_problem.train, reg=reg, init=None, track=False, **kw)
    try:
        assert set(t.wire_stats) == set(WIRE_STATS_SCHEMA), name
        assert isinstance(t.wire_stats["codec"], str)
        assert isinstance(t.wire_stats["topology"], str)
        if name == "gossip":
            assert t.wire_stats["spectral_gap"] > 0
        else:
            assert t.wire_stats["spectral_gap"] == 0.0
    finally:
        t.close()


def test_publish_wire_stats_gauges():
    from repro.core.transport import new_wire_stats

    reg = MetricsRegistry()
    ws = new_wire_stats(codec="bf16", n_commits=12, commit_bytes=3456)
    publish_wire_stats(ws, transport="threaded", registry=reg)
    text = to_prometheus(reg)
    assert (
        'repro_transport_n_commits{transport="threaded",codec="bf16",'
        'topology="star"} 12' in text
    )
    assert "repro_transport_commit_bytes" in text
    # str-valued schema fields are labels, not gauges
    assert "repro_transport_codec " not in text


# ---------------------------------------------------------------------------
# ServingMetrics: merge idempotence + summary schema
# ---------------------------------------------------------------------------
_SUMMARY_KEYS = {
    "submitted", "completed", "rejected", "expired", "slo_s",
    "slo_violations", "swaps", "last_version", "elapsed_s",
    "throughput_rps", "queue_depth_max", "tiles", "tile_fill",
    "decode_steps", "slot_occupancy", "ttft", "latency",
    "latency_buckets", "per_task",
}


def _loaded_metrics(clock):
    from repro.serve.metrics import ServingMetrics

    m = ServingMetrics(slo_s=1.0, clock=clock)
    m.on_submit(task=0)
    m.on_submit(task=1)
    m.on_tile(filled=2, slots=4)
    m.on_complete(0, latency_s=0.2, violated=False)
    m.on_complete(1, latency_s=2.0, violated=True)
    m.on_swap(version=3)
    m.observe_queue_depth(5)
    return m


def test_serving_metrics_merge_empty_windows_is_identity():
    """Merging any number of EMPTY windows into a loaded one changes no
    counter — rollups of idle replicas are a no-op, applied repeatedly."""
    from repro.serve.metrics import ServingMetrics

    clk = FakeClock(step=0.0)
    m = _loaded_metrics(clk)
    empties = [ServingMetrics(slo_s=1.0, clock=clk) for _ in range(3)]
    once = m.merge(*empties)
    twice = once.merge(*empties)
    base, s1, s2 = m.summary(), once.summary(), twice.summary()
    assert s1 == base
    assert s2 == s1
    # and empty + empty stays empty
    e = empties[0].merge(empties[1]).summary()
    assert e["submitted"] == 0 and e["completed"] == 0
    assert e["throughput_rps"] == 0.0


def test_serving_metrics_summary_schema_pinned():
    """``summary()`` is the BENCH_serving row shape AND what the obs
    bridge flattens into gauges — additions/renames must be deliberate."""
    clk = FakeClock(step=0.0)
    s = _loaded_metrics(clk).summary()
    assert set(s) == _SUMMARY_KEYS
    json.dumps(s)  # JSON-ready end to end
    assert s["submitted"] == 2 and s["completed"] == 2
    assert s["slo_violations"] == 1
    assert s["tile_fill"] == pytest.approx(0.5)
    assert set(s["per_task"]) == {"0", "1"}


def test_publish_serving_metrics_gauges():
    clk = FakeClock(step=0.0)
    reg = MetricsRegistry()
    publish_serving_metrics(_loaded_metrics(clk), replica="2", registry=reg)
    text = to_prometheus(reg)
    assert 'repro_serve_submitted{replica="2"} 2' in text
    assert 'repro_serve_slo_violations{replica="2"} 1' in text
    # latency quantile sub-dict flattens to its own gauge family
    assert "repro_serve_latency_p50" in text
