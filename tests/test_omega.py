"""Omega-step (closed-form Sigma update) and rho bounds.

hypothesis is an optional test dependency (see pyproject's [test] extra);
property tests import it via ``pytest.importorskip`` at call time so a
missing install skips just those tests instead of erroring collection.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import omega as om
from repro.core import convergence as cv
from repro.data.synthetic import synthetic


def _rand_W(m, d, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(m, d), jnp.float32)


@pytest.mark.parametrize("m,d,seed", [(4, 10, 0), (8, 5, 1), (16, 40, 2)])
def test_omega_step_constraints(m, d, seed):
    W = _rand_W(m, d, seed)
    sigma, omega = om.omega_step(W)
    s = np.asarray(sigma)
    assert float(np.trace(s)) == pytest.approx(1.0, abs=1e-4)
    evs = np.linalg.eigvalsh(s)
    assert evs.min() > 0, evs
    # omega is the inverse
    np.testing.assert_allclose(
        np.asarray(omega) @ s, np.eye(m), atol=5e-2
    )


def test_omega_step_is_optimal():
    """Sigma* = (W^T W)^{1/2}/tr minimizes tr(W Omega W^T) over the trace-1
    PSD ball — any perturbed feasible Sigma must give a larger objective."""
    m, d = 5, 12
    W = _rand_W(m, d, 3)
    sigma, omega = om.omega_step(W, jitter=1e-9)

    def objective(sig):
        return float(jnp.trace(W.T @ (jnp.linalg.solve(sig, W))))
        # tr(W Omega W^T) with Omega = Sigma^{-1}: tr(W^T Omega W)... careful:
        # tr(W Omega W^T) where W rows are tasks: = tr(W_mat^T Sigma^{-1} W_mat)
        # with W_mat = W (m, d): tr(W^T  Omega W) is d x d trace — equivalent.

    base = objective(sigma)
    rng = np.random.RandomState(4)
    for _ in range(20):
        P = rng.randn(m, m) * 0.05
        S2 = np.asarray(sigma) + (P + P.T) / 2
        evs = np.linalg.eigvalsh(S2)
        if evs.min() <= 1e-6:
            continue
        S2 = S2 / np.trace(S2)
        alt = objective(jnp.asarray(S2, jnp.float32))
        assert alt >= base - 1e-3 * abs(base)


def test_zero_W_falls_back_to_uniform():
    sigma, omega = om.omega_step(jnp.zeros((6, 9)))
    np.testing.assert_allclose(np.asarray(sigma), np.eye(6) / 6, atol=1e-3)


def test_rho_bound_ordering():
    """power-iteration estimate <= spectral bound <= Lemma-10 bound."""
    sp = synthetic(1, m=6, d=24, n_train_avg=50, n_test_avg=10, seed=5)
    data = sp.train
    rng = np.random.RandomState(6)
    W = jnp.asarray(rng.randn(data.m, data.d), jnp.float32)
    sigma, _ = om.omega_step(W)
    r_l10 = float(om.rho_lemma10(sigma))
    r_spec = float(om.rho_spectral(sigma))
    r_pi = cv.rho_min_power_iteration(data, sigma, iters=30)
    assert r_spec <= r_l10 + 1e-4
    assert r_pi <= r_spec + 1e-3
    assert r_pi >= 0.9  # rho_min >= eta for any Sigma (alpha in one block)


def test_rho_identity_sigma_is_one():
    sigma, _ = om.init_sigma(8)
    assert float(om.rho_lemma10(sigma)) == pytest.approx(1.0)
    assert float(om.rho_spectral(sigma)) == pytest.approx(1.0, abs=1e-5)


def test_correlated_tasks_have_larger_rho():
    """Paper Section 6.3: more correlated tasks => larger rho (toward m)."""
    m = 6
    ones = jnp.ones((m, m)) / m  # perfectly correlated, trace 1
    corr = 0.98 * ones + 0.02 * jnp.eye(m) / m
    uncorr = jnp.eye(m) / m
    assert float(om.rho_lemma10(corr)) > 3.0
    assert float(om.rho_lemma10(uncorr)) == pytest.approx(1.0)


def test_omega_step_trace_one_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 10), st.integers(0, 1000))
    def check(m, seed):
        W = _rand_W(m, 7, seed)
        sigma, _ = om.omega_step(W)
        assert float(jnp.trace(sigma)) == pytest.approx(1.0, abs=1e-3)

    check()
