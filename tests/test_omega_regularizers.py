"""Omega-regularizer family (core/omega_regularizers.py).

Every registered member must produce a symmetric PD Sigma with a finite
rho bound through every engine; the named members additionally pin their
defining constraints (trace-1, fixed graph coupling, shrinkage toward
identity, STL equivalence).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    DMTRLEstimator,
    available_regularizers,
    get_regularizer,
)
from repro.core.dmtrl import fit
from repro.core.omega_regularizers import resolve_regularizer


def _fit_with(small_problem, small_cfg, reg_name, **params):
    est = DMTRLEstimator(
        engine="reference", config=small_cfg,
        regularizer=reg_name, regularizer_params=params or None,
    )
    return est.fit(small_problem.train)


def test_registry_has_the_family():
    names = set(available_regularizers())
    assert {"trace_constraint", "graph_laplacian", "identity_stl",
            "frobenius_shrunk"} <= names


def test_unknown_regularizer_lists_choices():
    with pytest.raises(KeyError, match="trace_constraint"):
        get_regularizer("banana")


@pytest.mark.parametrize("name", sorted(
    {"trace_constraint", "identity_stl", "frobenius_shrunk"}
))
def test_member_sigma_pd_and_rho_finite(small_problem, small_cfg, name):
    est = _fit_with(small_problem, small_cfg, name)
    s = np.asarray(est.sigma_)
    assert np.allclose(s, s.T, atol=1e-6)
    assert np.linalg.eigvalsh(s).min() > 0
    assert np.trace(s) == pytest.approx(1.0, abs=1e-3)
    assert all(np.isfinite(r) and r > 0 for r in est.rho_per_outer_)


def test_graph_laplacian_fixed_sigma(small_problem, small_cfg):
    m = small_problem.train.m
    A = np.zeros((m, m))
    for i in range(m - 1):  # chain graph
        A[i, i + 1] = A[i + 1, i] = 1.0
    est = _fit_with(small_problem, small_cfg, "graph_laplacian", adjacency=A)
    s = np.asarray(est.sigma_)
    # Sigma never updates: it equals the trace-normalized (L + eps I)^{-1}
    L = np.diag(A.sum(1)) - A
    sigma0 = np.linalg.inv(L + 1e-3 * np.eye(m))
    sigma0 /= np.trace(sigma0)
    np.testing.assert_allclose(s, sigma0, atol=1e-5)
    assert np.linalg.eigvalsh(s).min() > 0
    assert all(np.isfinite(r) and r > 0 for r in est.rho_per_outer_)
    # coupled tasks: neighbours on the chain have positive covariance
    assert s[0, 1] > 0


def test_graph_laplacian_validation(small_cfg):
    with pytest.raises(ValueError, match="exactly one"):
        get_regularizer("graph_laplacian")
    with pytest.raises(ValueError, match="symmetric"):
        get_regularizer("graph_laplacian",
                        adjacency=np.array([[0.0, 1.0], [0.0, 0.0]]))
    with pytest.raises(ValueError, match="non-negative"):
        get_regularizer("graph_laplacian",
                        adjacency=np.array([[0.0, -1.0], [-1.0, 0.0]]))
    reg = get_regularizer("graph_laplacian", adjacency=np.zeros((3, 3)))
    with pytest.raises(ValueError, match="3 tasks"):
        reg.init(5)


def test_identity_stl_equals_learn_omega_false(small_problem, small_cfg):
    legacy = fit(
        dataclasses.replace(small_cfg, learn_omega=False), small_problem.train
    )
    est = _fit_with(small_problem, small_cfg, "identity_stl")
    assert np.array_equal(est.W_, np.asarray(legacy.W))
    assert np.array_equal(est.alpha_, np.asarray(legacy.alpha))
    assert np.array_equal(est.sigma_, np.asarray(legacy.sigma))
    m = small_problem.train.m
    np.testing.assert_allclose(est.sigma_, np.eye(m) / m, atol=1e-7)


def test_trace_constraint_is_the_default_bitwise(small_problem, small_cfg):
    legacy = fit(small_cfg, small_problem.train)
    est = DMTRLEstimator(engine="reference", config=small_cfg).fit(
        small_problem.train
    )
    assert est.regularizer.name == "trace_constraint"
    assert np.array_equal(est.W_, np.asarray(legacy.W))
    assert np.array_equal(est.sigma_, np.asarray(legacy.sigma))


def test_frobenius_shrunk_interpolates(small_problem, small_cfg):
    zy = _fit_with(small_problem, small_cfg, "trace_constraint")
    sh = _fit_with(small_problem, small_cfg, "frobenius_shrunk", shrinkage=0.5)
    m = small_problem.train.m
    eye = np.eye(m) / m

    def offdiag_mass(s):
        return float(np.abs(s - np.diag(np.diag(s))).sum())

    # shrunk couplings sit strictly between the ZY solution and identity
    assert offdiag_mass(sh.sigma_) < offdiag_mass(zy.sigma_)
    assert offdiag_mass(sh.sigma_) > 0
    # shrinkage=1 collapses the update to I/m exactly
    full = _fit_with(small_problem, small_cfg, "frobenius_shrunk", shrinkage=1.0)
    np.testing.assert_allclose(full.sigma_, eye, atol=1e-6)
    with pytest.raises(ValueError, match="shrinkage"):
        get_regularizer("frobenius_shrunk", shrinkage=1.5)


def test_facade_learn_omega_false_maps_to_identity_stl(
    small_problem, small_cfg
):
    """Legacy configs with learn_omega=False must fit through the facade
    (mapped to identity_stl) exactly like the deprecated entry points."""
    stl_cfg = dataclasses.replace(small_cfg, learn_omega=False)
    est = DMTRLEstimator(engine="reference", config=stl_cfg).fit(
        small_problem.train
    )
    assert est.regularizer.name == "identity_stl"
    legacy = fit(stl_cfg, small_problem.train)
    assert np.array_equal(est.W_, np.asarray(legacy.W))
    assert np.array_equal(est.sigma_, np.asarray(legacy.sigma))


def test_resolve_regularizer_precedence(small_cfg):
    assert resolve_regularizer(small_cfg).name == "trace_constraint"
    stl_cfg = dataclasses.replace(small_cfg, learn_omega=False)
    assert resolve_regularizer(stl_cfg).name == "identity_stl"
    assert resolve_regularizer(small_cfg, "identity_stl").name == "identity_stl"
    with pytest.raises(ValueError, match="learn_omega"):
        resolve_regularizer(stl_cfg, get_regularizer("trace_constraint"))


def test_family_through_mesh_engines(small_problem, small_cfg, one_device_mesh):
    """A fixed-graph member must run identically through distributed and
    async(tau=0) — the family is engine-agnostic."""
    m = small_problem.train.m
    A = np.ones((m, m)) - np.eye(m)
    kw = dict(
        config=small_cfg, regularizer="graph_laplacian",
        regularizer_params={"adjacency": A}, mesh=one_device_mesh,
    )
    dist = DMTRLEstimator(engine="distributed", **kw).fit(small_problem.train)
    asyn = DMTRLEstimator(engine="async", **kw).fit(small_problem.train)
    assert np.array_equal(dist.W_, asyn.W_)
    assert np.array_equal(dist.sigma_, asyn.sigma_)
    ref = DMTRLEstimator(
        engine="reference", config=small_cfg, regularizer="graph_laplacian",
        regularizer_params={"adjacency": A},
    ).fit(small_problem.train)
    np.testing.assert_allclose(ref.W_, dist.W_, atol=2e-4)
