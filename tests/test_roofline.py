"""HLO analyzer: trip-count weighting, dot flops, collective bytes."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_parse import analyze_hlo, split_computations


def _compile_text(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_weighted_by_trip_count():
    """XLA cost_analysis counts a while body once; the parser must multiply
    by the trip count."""
    N = 10

    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None

        h, _ = jax.lax.scan(body, x, None, length=N)
        return h

    txt = _compile_text(f, (64, 128), (128, 128))
    costs = analyze_hlo(txt)
    one_matmul = 2 * 64 * 128 * 128
    assert costs.dot_flops >= 0.9 * N * one_matmul, costs.dot_flops
    assert costs.dot_flops <= 1.5 * N * one_matmul, costs.dot_flops


def test_single_dot_flops():
    def f(a, b):
        return a @ b

    txt = _compile_text(f, (32, 64), (64, 48))
    costs = analyze_hlo(txt)
    assert costs.dot_flops == pytest.approx(2 * 32 * 64 * 48, rel=0.01)


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(h, _):
            def inner(h2, _):
                return jnp.tanh(h2 @ w), None

            h2, _ = jax.lax.scan(inner, h, None, length=3)
            return h2, None

        h, _ = jax.lax.scan(outer, x, None, length=4)
        return h

    txt = _compile_text(f, (16, 64), (64, 64))
    costs = analyze_hlo(txt)
    expected = 12 * 2 * 16 * 64 * 64
    assert costs.dot_flops == pytest.approx(expected, rel=0.3), (
        costs.dot_flops,
        expected,
    )


def test_computation_split_handles_index_comments():
    hlo = """HloModule m, is_scheduled=true

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (p: f32[4,4]) -> (f32[4,4], /*index=1*/f32[4,4]) {
  %p = f32[4,4] parameter(0)
  %ar = f32[4,4]{1,0} all-reduce(%p), replica_groups=[2,2]<=[4], to_apply=%add
  ROOT %t = (f32[4,4], f32[4,4]) tuple(%p, %ar)
}
"""
    comps, entry = split_computations(hlo)
    assert entry == "main"
    assert "add" in comps
    costs = analyze_hlo(hlo)
    assert costs.coll_count["all-reduce"] == 1
    assert costs.coll["all-reduce"] == 4 * 4 * 4  # f32[4,4]


def test_collectives_in_loops_weighted():
    """A collective inside a scan body counts trip-count times (built via a
    synthetic HLO since CPU single-device jit emits no collectives)."""
    hlo = """HloModule m, is_scheduled=true

%cond (s: (s32[], f32[8])) -> pred[] {
  %s = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%s), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (s: (s32[], f32[8])) -> (s32[], f32[8]) {
  %s = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%s), index=0
  %x = f32[8] get-tuple-element(%s), index=1
  %ag = f32[8]{0} all-gather(%x), replica_groups=[4]<=[4], dimensions={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8]) tuple(%ip, %ag)
}

ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8]) tuple(%zero, %p)
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8] get-tuple-element(%w), index=1
}
"""
    costs = analyze_hlo(hlo)
    assert costs.coll["all-gather"] == 7 * 8 * 4, costs.coll


def test_bytes_nonzero_and_bounded():
    def f(a, b):
        return jnp.tanh(a @ b)

    txt = _compile_text(f, (128, 256), (256, 128))
    costs = analyze_hlo(txt)
    io_bytes = (128 * 256 + 256 * 128 + 128 * 128) * 4
    assert costs.bytes >= io_bytes * 0.9
    assert costs.bytes <= io_bytes * 20
