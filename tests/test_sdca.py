"""Local SDCA: naive == block-Gram == Pallas kernel; dual ascent property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dual as dm
from repro.core import omega as om
from repro.core.dmtrl import DMTRLConfig, make_w_step_round
from repro.core.losses import get_loss, registered_losses
from repro.core.sdca import local_sdca_block, local_sdca_naive, sample_coords
from repro.data.synthetic import synthetic


@pytest.fixture(scope="module")
def data():
    return synthetic(1, m=4, d=30, n_train_avg=80, n_test_avg=20, seed=7).train


def _args(data, i, loss, key, H=96):
    coords = sample_coords(key, H, data.n[i], data.n_max)
    w = 0.05 * jax.random.normal(key, (data.d,))
    alpha = jnp.zeros((data.n_max,))
    return (
        data.x[i],
        data.y[i],
        alpha,
        w,
        data.n[i],
        jnp.float32(0.25),
        coords,
        2.0,
        1e-3,
        loss,
    )


@pytest.mark.parametrize("loss_name", sorted(registered_losses()))
@pytest.mark.parametrize("block", [16, 32, 96])
def test_block_equals_naive(data, loss_name, block):
    loss = get_loss(loss_name)
    key = jax.random.PRNGKey(11)
    args = _args(data, 1, loss, key)
    da1, r1 = local_sdca_naive(*args)
    da2, r2 = local_sdca_block(*args, block=block)
    np.testing.assert_allclose(np.asarray(da1), np.asarray(da2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=2e-5)


@pytest.mark.parametrize("loss_name", ["hinge", "squared", "smoothed_hinge"])
def test_kernel_backend_equals_jnp_block(data, loss_name):
    """pallas_block (per-block kernel) matches block_gram for the same key."""
    from repro.core.solver_backends import get_backend

    loss = get_loss(loss_name)
    key = jax.random.PRNGKey(13)
    i, H = 0, 64
    w = 0.05 * jax.random.normal(key, (data.d,))
    alpha = jnp.zeros((data.n_max,))
    solve_args = (data.x[i], data.y[i], alpha, w, data.n[i], jnp.float32(0.25), key)
    s1 = get_backend("block_gram").make(loss, 2.0, 1e-3, H, block=32)
    s2 = get_backend("pallas_block").make(loss, 2.0, 1e-3, H, block=32)
    da1, r1 = s1(*solve_args)
    da2, r2 = s2(*solve_args)
    np.testing.assert_allclose(np.asarray(da1), np.asarray(da2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=2e-5)


def test_coords_within_bounds(data):
    for i in range(data.m):
        coords = sample_coords(jax.random.PRNGKey(i), 1000, data.n[i], data.n_max)
        assert int(coords.min()) >= 0
        assert int(coords.max()) < int(data.n[i])


@pytest.mark.parametrize("loss_name", ["hinge", "squared", "logistic"])
def test_w_step_round_monotone_dual_ascent(data, loss_name):
    """Each communication round must not decrease D(alpha) (Lemma 3 with the
    safe rho guarantees ascent in expectation; with lemma-10 rho and eta=1
    the per-round ascent holds deterministically here)."""
    cfg = DMTRLConfig(
        loss=loss_name, lam=1e-3, local_iters=64, solver="block_gram", block_size=32
    )
    loss = get_loss(loss_name)
    sigma, _ = om.init_sigma(data.m)
    rho = float(om.rho_lemma10(sigma))
    round_fn = make_w_step_round(cfg, data, rho)
    alpha = jnp.zeros((data.m, data.n_max))
    W = jnp.zeros((data.m, data.d))
    prev = float(dm.dual_objective(data, alpha, sigma, cfg.lam, loss))
    key = jax.random.PRNGKey(17)
    for t in range(6):
        key, sub = jax.random.split(key)
        alpha, W = round_fn(alpha, W, sigma, sub)
        cur = float(dm.dual_objective(data, alpha, sigma, cfg.lam, loss))
        assert cur >= prev - 1e-4, (loss_name, t, prev, cur)
        prev = cur


def test_w_invariant_after_rounds(data):
    """Carried W must equal W(alpha) after any number of rounds."""
    cfg = DMTRLConfig(loss="hinge", lam=1e-3, local_iters=64)
    sigma, _ = om.init_sigma(data.m)
    round_fn = make_w_step_round(cfg, data, 1.0)
    alpha = jnp.zeros((data.m, data.n_max))
    W = jnp.zeros((data.m, data.d))
    key = jax.random.PRNGKey(23)
    for _ in range(3):
        key, sub = jax.random.split(key)
        alpha, W = round_fn(alpha, W, sigma, sub)
    W2 = dm.weights_from_alpha(data, alpha, sigma, cfg.lam)
    np.testing.assert_allclose(np.asarray(W), np.asarray(W2), atol=1e-4)
