"""Serving invariants: prefill+decode == full forward; ring buffers; engine."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, forward_train, init_params, prefill
from repro.serve import Request, ServeConfig, ServingEngine

from conftest import fast_arch_params

# one representative per family stays in the fast tier-1 run (plain attn,
# SSM, encoder-decoder); sliding-window decode is covered by the gemma
# engine test below, and the full prefill matrix runs under -m slow
ARCH_PARAMS = fast_arch_params(("qwen1_5-4b", "mamba2-780m", "whisper-tiny"))


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S + 3), 0, cfg.vocab_size)
    side = (
        jax.random.normal(key, (B, cfg.enc_frames, cfg.d_model)) * 0.02
        if cfg.is_encoder_decoder
        else None
    )
    ref, _ = forward_train(cfg, params, toks, side)
    last, cache = prefill(cfg, params, toks[:, :S], side)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(ref[:, S - 1]), atol=2e-4
    )
    # three successive decode steps must track the teacher-forced forward
    for t in range(3):
        out, cache = decode_step(cfg, params, toks[:, S + t], cache)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref[:, S + t]), atol=5e-4
        )


@pytest.mark.slow
def test_sliding_window_ring_buffer_long_decode():
    """gemma3-style local layers: decoding far past the window must agree
    with the full forward (ring overwrite correctness)."""
    cfg = get_config("gemma3-1b").reduced()
    assert cfg.window and cfg.local_ratio
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    B, S_total = 1, cfg.window * 3 + 7  # decode way beyond the window
    toks = jax.random.randint(key, (B, S_total), 0, cfg.vocab_size)
    ref, _ = forward_train(cfg, params, toks)
    S0 = 4
    _, cache = prefill(cfg, params, toks[:, :S0], extra_len=S_total)
    for t in range(S0, S_total):
        out, cache = decode_step(cfg, params, toks[:, t], cache)
        if t % 17 == 0 or t == S_total - 1:
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref[:, t]), atol=1e-3,
                err_msg=f"t={t}",
            )


@pytest.mark.slow
def test_ssm_state_decode_long():
    """mamba2: O(1)-state decode tracks the chunked forward over >2 chunks."""
    cfg = get_config("mamba2-780m").reduced()
    key = jax.random.PRNGKey(4)
    params = init_params(cfg, key)
    B, S_total = 2, cfg.ssm_chunk * 3 + 5
    toks = jax.random.randint(key, (B, S_total), 0, cfg.vocab_size)
    ref, _ = forward_train(cfg, params, toks)
    S0 = 8
    _, cache = prefill(cfg, params, toks[:, :S0])
    for t in range(S0, S_total):
        out, cache = decode_step(cfg, params, toks[:, t], cache)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref[:, -1]), atol=1e-3
    )


def test_serving_engine_batch():
    cfg = get_config("qwen1_5-4b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(5))
    eng = ServingEngine(cfg, params, ServeConfig(batch=4, max_len=64))
    reqs = [
        Request(prompt=np.array([3, 5, 7], np.int32), max_new_tokens=8),
        Request(prompt=np.array([11, 13], np.int32), max_new_tokens=5),
    ]
    done = eng.run(reqs)
    assert len(done[0].output) <= 8 and len(done[0].output) >= 1
    assert len(done[1].output) <= 5 and len(done[1].output) >= 1
    for r in done[:2]:
        assert all(0 <= t < cfg.vocab_padded for t in r.output)


def test_greedy_decode_is_deterministic():
    cfg = get_config("gemma3-1b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(6))
    def run():
        eng = ServingEngine(cfg, params, ServeConfig(batch=2, max_len=32))
        reqs = [Request(prompt=np.array([1, 2, 3], np.int32), max_new_tokens=6)]
        return eng.run(reqs)[0].output
    assert run() == run()
