"""Serving invariants: prefill+decode == full forward; ring buffers; engine."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, forward_train, init_params, prefill
from repro.serve import Request, ServeConfig, ServingEngine

from conftest import fast_arch_params

# one representative per family stays in the fast tier-1 run (plain attn,
# SSM, encoder-decoder); sliding-window decode is covered by the gemma
# engine test below, and the full prefill matrix runs under -m slow
ARCH_PARAMS = fast_arch_params(("qwen1_5-4b", "mamba2-780m", "whisper-tiny"))


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S + 3), 0, cfg.vocab_size)
    side = (
        jax.random.normal(key, (B, cfg.enc_frames, cfg.d_model)) * 0.02
        if cfg.is_encoder_decoder
        else None
    )
    ref, _ = forward_train(cfg, params, toks, side)
    last, cache = prefill(cfg, params, toks[:, :S], side)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(ref[:, S - 1]), atol=2e-4
    )
    # three successive decode steps must track the teacher-forced forward
    for t in range(3):
        out, cache = decode_step(cfg, params, toks[:, S + t], cache)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref[:, S + t]), atol=5e-4
        )


@pytest.mark.slow
def test_sliding_window_ring_buffer_long_decode():
    """gemma3-style local layers: decoding far past the window must agree
    with the full forward (ring overwrite correctness)."""
    cfg = get_config("gemma3-1b").reduced()
    assert cfg.window and cfg.local_ratio
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    B, S_total = 1, cfg.window * 3 + 7  # decode way beyond the window
    toks = jax.random.randint(key, (B, S_total), 0, cfg.vocab_size)
    ref, _ = forward_train(cfg, params, toks)
    S0 = 4
    _, cache = prefill(cfg, params, toks[:, :S0], extra_len=S_total)
    for t in range(S0, S_total):
        out, cache = decode_step(cfg, params, toks[:, t], cache)
        if t % 17 == 0 or t == S_total - 1:
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref[:, t]), atol=1e-3,
                err_msg=f"t={t}",
            )


@pytest.mark.slow
def test_ssm_state_decode_long():
    """mamba2: O(1)-state decode tracks the chunked forward over >2 chunks."""
    cfg = get_config("mamba2-780m").reduced()
    key = jax.random.PRNGKey(4)
    params = init_params(cfg, key)
    B, S_total = 2, cfg.ssm_chunk * 3 + 5
    toks = jax.random.randint(key, (B, S_total), 0, cfg.vocab_size)
    ref, _ = forward_train(cfg, params, toks)
    S0 = 8
    _, cache = prefill(cfg, params, toks[:, :S0])
    for t in range(S0, S_total):
        out, cache = decode_step(cfg, params, toks[:, t], cache)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref[:, -1]), atol=1e-3
    )


def test_serving_engine_batch():
    cfg = get_config("qwen1_5-4b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(5))
    eng = ServingEngine(cfg, params, ServeConfig(batch=4, max_len=64))
    reqs = [
        Request(prompt=np.array([3, 5, 7], np.int32), max_new_tokens=8),
        Request(prompt=np.array([11, 13], np.int32), max_new_tokens=5),
    ]
    done = eng.run(reqs)
    assert len(done[0].output) <= 8 and len(done[0].output) >= 1
    assert len(done[1].output) <= 5 and len(done[1].output) >= 1
    for r in done[:2]:
        assert all(0 <= t < cfg.vocab_padded for t in r.output)


def test_greedy_decode_is_deterministic():
    cfg = get_config("gemma3-1b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(6))
    def run():
        eng = ServingEngine(cfg, params, ServeConfig(batch=2, max_len=32))
        reqs = [Request(prompt=np.array([1, 2, 3], np.int32), max_new_tokens=6)]
        return eng.run(reqs)[0].output
    assert run() == run()


# ---------------------------------------------------------------------------
# decode-loop stopping semantics (scripted step: no real model needed)
# ---------------------------------------------------------------------------
def _scripted_engine(monkeypatch, token_rows, batch=2, eos_id=1, seed=0):
    """ServingEngine whose prefill/step hooks are stubbed so greedy decode
    emits ``token_rows[t]`` (one (B,) row per decode position t; a request
    reads the row of the SLOT it occupies). ``drain_every=1`` keeps step
    counts exact; ``max_len`` is large so scripted budgets always admit."""
    import jax.numpy as jnp

    from repro.serve import engine as engine_mod

    cfg = get_config("qwen1_5-4b").reduced()
    eng = engine_mod.ServingEngine(
        cfg,
        None,
        engine_mod.ServeConfig(
            batch=batch, max_len=256, eos_id=eos_id, drain_every=1
        ),
    )
    script = np.asarray(token_rows, np.int32)  # (T, B)
    vocab = int(script.max()) + 2

    def logits_for(t):
        z = np.full((batch, vocab), -10.0, np.float32)
        z[np.arange(batch), script[min(t, script.shape[0] - 1)]] = 10.0
        return jnp.asarray(z)

    calls = {"steps": 0}

    def fake_prefill_one(r):
        slot = eng._free[-1]  # the slot inject() is about to assign
        calls["steps"] = 0
        return logits_for(0)[slot : slot + 1], jnp.zeros(())

    def fake_step(token, cache):
        calls["steps"] += 1
        return logits_for(calls["steps"]), cache

    eng._prefill_one = fake_prefill_one
    eng._step_call = fake_step
    return eng, calls


def test_decode_stops_on_eos_before_budget(monkeypatch):
    """An EOS token finishes the request (and the loop) well before the
    token budget; the EOS is kept in the output."""
    script = [[2, 3], [1, 3], [9, 3], [9, 3]]  # req0 hits EOS at t=1
    eng, calls = _scripted_engine(monkeypatch, script)
    r0 = Request(prompt=np.array([5], np.int32), max_new_tokens=100)
    r1 = Request(prompt=np.array([5], np.int32), max_new_tokens=3)
    eng.run([r0, r1])
    assert r0.output == [2, 1] and r0.done and r0.finish_reason == "eos"
    assert r1.output == [3, 3, 3] and r1.done and r1.finish_reason == "length"
    # loop ended when the last request finished (t=2), not at budget=100
    assert calls["steps"] == 2


def test_decode_stops_on_budget_without_eos(monkeypatch):
    script = [[4, 4], [5, 5], [6, 6], [7, 7]]  # no EOS anywhere
    eng, calls = _scripted_engine(monkeypatch, script)
    r = Request(prompt=np.array([5], np.int32), max_new_tokens=3)
    reqs = [r]
    done = eng.run(reqs)
    assert done is reqs and len(reqs) == 1  # caller's list not padded
    assert r.output == [4, 5, 6] and r.done and r.finish_reason == "length"
    assert calls["steps"] == 2  # budget 3 => prefill logits + 2 steps


def test_lm_engine_behind_scheduler(monkeypatch):
    """The LM engine runs behind the same ContinuousBatchingScheduler as
    the MTL scorer: shared queue shape, tile-level continuous batching."""
    from repro.serve import ContinuousBatchingScheduler, VirtualClock

    script = [[2, 2], [1, 1]]  # everyone EOSes at t=1
    eng, _ = _scripted_engine(monkeypatch, script)
    sched = ContinuousBatchingScheduler(eng, clock=VirtualClock())
    reqs = [Request(prompt=[5, 6], max_new_tokens=4)] + [  # list prompt:
        # admission must canonicalize it so packing can read .shape
        Request(prompt=np.array([5, 6], np.int32), max_new_tokens=4)
        for _ in range(2)
    ]
    for r in reqs:
        sched.submit(r)
    assert isinstance(reqs[0].prompt, np.ndarray)
    n = sched.run_until_idle()
    assert n == 3 and sched.metrics.tiles == 2  # batch=2 -> 2 + 1 packed
    for r in reqs:
        assert r.status == "done" and r.output == [2, 1]
        assert r.finish_reason == "eos" and r.snapshot_version == 0
    with pytest.raises(ValueError, match="prompt"):
        sched.submit(Request(prompt=np.array([], np.int32)))
    with pytest.raises(ValueError, match="max_new_tokens"):
        sched.submit(
            Request(prompt=np.array([1], np.int32), max_new_tokens=0)
        )
    with pytest.raises(ValueError, match="integer"):
        sched.submit(Request(prompt=np.array([1.5, 2.0]), max_new_tokens=2))
