"""Decode-step continuous batching: slot recycling, bucketed-prefill pad
masking, mid-decode admission/publish, retry reset, warmup.

Scripted tests drive the slot machinery through stubbed prefill/step hooks
(deterministic token streams, no model); the bit-equality tests run a real
reduced config through the compiled bucketed-prefill + per-slot decode
path and compare against solo (batch=1) generations token for token.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serve import (
    ContinuousBatchingScheduler,
    ModelSnapshot,
    MTLScoringEngine,
    Request,
    ServeConfig,
    ServingEngine,
    VirtualClock,
)


# ---------------------------------------------------------------------------
# scripted slot engine (no model): token[t][slot] per decode boundary t
# ---------------------------------------------------------------------------
def _slot_scripted_engine(token_rows, batch=2, eos_id=1):
    """ServingEngine whose hooks emit ``token_rows[t][slot]`` at global
    decode boundary t. Unlike test_serve's helper the clock does NOT
    reset on prefill, so requests injected into recycled slots mid-decode
    read the CURRENT script row (their first token) while older slots
    keep advancing — exactly the continuous-batching timeline."""
    cfg = get_config("qwen1_5-4b").reduced()
    eng = ServingEngine(
        cfg,
        None,
        ServeConfig(batch=batch, max_len=256, eos_id=eos_id, drain_every=1),
    )
    script = np.asarray(token_rows, np.int32)  # (T, B)
    vocab = int(script.max()) + 2
    t = {"now": 0}

    def logits_at(tt):
        z = np.full((batch, vocab), -10.0, np.float32)
        z[np.arange(batch), script[min(tt, script.shape[0] - 1)]] = 10.0
        return jnp.asarray(z)

    def fake_prefill_one(r):
        slot = eng._free[-1]
        return logits_at(t["now"])[slot : slot + 1], jnp.zeros(())

    def fake_step(token, cache):
        t["now"] += 1
        return logits_at(t["now"]), cache

    eng._prefill_one = fake_prefill_one
    eng._step_call = fake_step
    return eng


def test_slot_recycling_no_drops_no_double_finish():
    """Four requests stream through two slots: EOS and budget stops free
    slots mid-run, later requests are injected into the RUNNING batch,
    every request finishes exactly once with the scripted tokens."""
    #               t=0     t=1     t=2     t=3
    script = [[5, 6], [7, 8], [9, 1], [2, 3]]
    eng = _slot_scripted_engine(script)
    sched = ContinuousBatchingScheduler(eng, clock=VirtualClock(), policy="fifo")
    r0 = Request(prompt=np.array([4], np.int32), max_new_tokens=3)
    r1 = Request(prompt=np.array([4], np.int32), max_new_tokens=2)
    r2 = Request(prompt=np.array([4], np.int32), max_new_tokens=2)
    r3 = Request(prompt=np.array([4], np.int32), max_new_tokens=2)
    sched.submit_many([r0, r1, r2, r3])

    done = []
    steps = 0
    while (sched.pending or sched.in_flight) and steps < 50:
        done += sched.step()
        steps += 1
    # no drops, no double-finishes across slot recycling
    assert len(done) == 4 and len({id(r) for r in done}) == 4
    assert all(r.status == "done" and r.done for r in done)
    # slot0: r0 runs to budget while slot1 turns over r1 -> r2 -> r3
    assert r0.output == [5, 7, 9] and r0.finish_reason == "length"
    assert r1.output == [6, 8] and r1.finish_reason == "length"
    assert r2.output == [8, 1] and r2.finish_reason == "eos"  # EOS recycle
    assert r3.output == [1] and r3.finish_reason == "eos"  # EOS at prefill
    assert eng.free_slots == eng.batch and eng.active == 0
    m = sched.metrics
    assert m.ttft.count == 4 and m.completed == 4
    assert m.decode_steps == 3 and 0.0 < m.slot_occupancy() <= 1.0
    # a long generation never head-of-line-blocks a short one: r1 (2 tokens)
    # finished before r0 (3 tokens) despite sharing the batch
    assert r1.finish_s <= r0.finish_s


def test_mid_decode_publish_isolation():
    """A publish landing between decode steps must not relabel in-flight
    requests: they complete on the snapshot they were ADMITTED under."""
    script = [[5, 6], [7, 8], [9, 2], [3, 4]]
    eng = _slot_scripted_engine(script)
    sched = ContinuousBatchingScheduler(eng, clock=VirtualClock(), policy="fifo")
    a = Request(prompt=np.array([4], np.int32), max_new_tokens=3)
    b = Request(prompt=np.array([4], np.int32), max_new_tokens=3)
    sched.submit_many([a, b])
    sched.step()  # inject on v0 + one decode step (nobody finished)
    assert a.status == "running" and sched.in_flight == 2
    sched.publish(ModelSnapshot(version=5))  # mid-generation hot-swap
    late = Request(prompt=np.array([4], np.int32), max_new_tokens=1)
    sched.submit(late)
    n = sched.run_until_idle()
    assert n == 3
    # in-flight at publish time -> admitted version; injected after -> new
    assert a.snapshot_version == 0 and b.snapshot_version == 0
    assert late.snapshot_version == 5
    assert sched.metrics.swaps == 1


def test_retry_resets_per_attempt_decode_state():
    """A request evicted after a failed decode keeps no stale output: the
    re-inject resets output/done/finish_reason, so the retry emits the
    scripted stream exactly once (no double-append)."""
    script = [[5, 6], [7, 8], [9, 2], [3, 4]]
    eng = _slot_scripted_engine(script)
    snap = eng.model_snapshot()
    r = Request(prompt=np.array([4], np.int32), max_new_tokens=3)
    eng.inject([r], snap)
    eng.decode_tick()
    assert r.output == [5, 7] and not r.done  # partial attempt drained
    evicted = eng.evict_active()  # simulated tile failure
    assert evicted == [r] and eng.free_slots == eng.batch
    eng.inject([r], snap)  # retry: per-attempt state reset
    while not r.done:
        eng.decode_tick()
    # the retry re-prefills at the current boundary (t=1) and streams
    # fresh: NOT [5, 7] + new tokens (the old double-append bug)
    assert r.output == [7, 9, 3]
    assert len(r.output) == r.max_new_tokens and r.finish_reason == "length"


def test_scheduler_requeues_streaming_engine_failure():
    """A decode-step crash evicts the whole slot table back to the queue
    head; the rerun completes everything with exact budget lengths."""
    script = [[5, 6], [7, 8], [9, 2], [3, 4], [5, 6]]
    eng = _slot_scripted_engine(script)
    sched = ContinuousBatchingScheduler(eng, clock=VirtualClock(), policy="fifo")
    reqs = [
        o.request
        for o in sched.submit_many(
            [
                Request(prompt=np.array([4], np.int32), max_new_tokens=3)
                for _ in range(2)
            ]
        )
    ]
    good_step = eng._step_call

    def boom(token, cache):
        raise RuntimeError("device fell over")

    eng._step_call = boom
    with pytest.raises(RuntimeError, match="fell over"):
        sched.step()
    assert sched.pending == 2 and sched.in_flight == 0
    assert all(r.status == "queued" for r in reqs)
    eng._step_call = good_step
    assert sched.run_until_idle() == 2
    for r in reqs:
        assert r.status == "done" and len(r.output) == 3  # no stale tokens


def test_inject_overflow_and_blocking_run_guards():
    script = [[5, 6], [7, 8]]
    eng = _slot_scripted_engine(script)
    snap = eng.model_snapshot()
    reqs = [
        Request(prompt=np.array([4], np.int32), max_new_tokens=8)
        for _ in range(3)
    ]
    with pytest.raises(RuntimeError, match="free slots"):
        eng.inject(reqs, snap)
    eng.inject(reqs[:2], snap)
    with pytest.raises(RuntimeError, match="in-flight"):
        eng.run([Request(prompt=np.array([4], np.int32), max_new_tokens=1)])


def test_virtual_clock_rejects_negative_dt():
    clk = VirtualClock()
    clk.advance(0.0)
    clk.advance(1.5)
    with pytest.raises(ValueError, match="dt"):
        clk.advance(-0.1)
    assert clk() == 1.5  # unchanged after the rejected advance


# ---------------------------------------------------------------------------
# real-model bit-equality (compiled bucketed prefill + per-slot decode)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def qwen():
    import jax

    from repro.models import init_params

    cfg = get_config("qwen1_5-4b").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(5))


def _solo(cfg, params, prompt, budget, bucket_min=8):
    eng = ServingEngine(
        cfg, params, ServeConfig(batch=1, max_len=64, bucket_min=bucket_min)
    )
    r = Request(prompt=np.asarray(prompt, np.int32), max_new_tokens=budget)
    eng.run([r])
    return r.output


def test_bucketed_pad_prefill_batched_equals_solo(qwen):
    """Prompts of length 3 and 7 share the padded length-8 bucket; the pad
    mask must make their batched generations BIT-equal to solo runs (the
    old left-pad-without-mask path diverged here)."""
    cfg, params = qwen
    p_short, p_long = [3, 5, 7], [2, 4, 6, 8, 10, 12, 14]
    solo_s = _solo(cfg, params, p_short, 6)
    solo_l = _solo(cfg, params, p_long, 6)
    eng = ServingEngine(
        cfg, params, ServeConfig(batch=2, max_len=64, bucket_min=8)
    )
    rs = Request(prompt=np.asarray(p_short, np.int32), max_new_tokens=6)
    rl = Request(prompt=np.asarray(p_long, np.int32), max_new_tokens=6)
    eng.run([rs, rl])
    assert len(eng._prefill_exe) == 1  # one shared length-8 executable
    assert rs.output == solo_s and rl.output == solo_l


def test_mid_decode_admission_bit_equal_to_solo(qwen):
    """A request injected while other slots are mid-generation decodes the
    same tokens it would decode alone."""
    cfg, params = qwen
    prompts = [[3, 5, 7], [11, 13], [2, 4, 6, 8, 10]]
    budgets = [8, 5, 6]
    solo = [
        _solo(cfg, params, p, b) for p, b in zip(prompts, budgets)
    ]
    eng = ServingEngine(
        cfg,
        params,
        ServeConfig(batch=2, max_len=64, bucket_min=8, drain_every=2),
    )
    sched = ContinuousBatchingScheduler(eng, clock=VirtualClock(), policy="fifo")
    reqs = [
        Request(prompt=np.asarray(p, np.int32), max_new_tokens=b)
        for p, b in zip(prompts, budgets)
    ]
    sched.submit_many(reqs[:2])
    sched.step()
    sched.step()  # two decode steps in, slots busy
    sched.submit(reqs[2])  # arrives mid-decode, waits for an EOS/budget slot
    sched.run_until_idle()
    assert [r.output for r in reqs] == solo
    for r in reqs:
        assert r.first_token_s is not None and r.ttft_s <= r.latency_s


def test_warmup_precompiles_all_tile_shapes(qwen):
    """After warmup, serving a bucket-covered request compiles NOTHING new
    (prefill bucket, decode step and slot insert are all AOT-built)."""
    cfg, params = qwen
    eng = ServingEngine(
        cfg, params, ServeConfig(batch=2, max_len=64, bucket_min=8)
    )
    assert eng.warmup() == [8, 16, 32]
    assert eng._decode_exe is not None and eng._insert_exe is not None
    before = set(eng._prefill_exe)
    r = Request(prompt=np.asarray([3, 5, 7], np.int32), max_new_tokens=4)
    eng.run([r])
    assert set(eng._prefill_exe) == before  # no new executables
    assert len(r.output) == 4
    with pytest.raises(ValueError, match="decode room"):
        eng.warmup([64])


def test_mtl_warmup_matches_jitted_scores():
    W = np.random.RandomState(0).randn(5, 12).astype(np.float32)
    X = np.random.RandomState(1).randn(7, 12).astype(np.float32)
    t = np.arange(7, dtype=np.int32) % 5
    cold = MTLScoringEngine(W, batch=4)
    warm = MTLScoringEngine(W, batch=4)
    warm.warmup()
    assert warm._step_exe is not None
    np.testing.assert_array_equal(warm.score_batch(X, t), cold.score_batch(X, t))
