"""Fleet router: affinity, spill, shed, failover, rolling swap, monotonic
reads, metrics rollup.

Everything runs on a shared VIRTUAL clock (thread-safe since the fleet
PR), so routing and queueing behavior is deterministic; the threaded
monotonicity property test at the bottom exercises real concurrency
(producers interleaving publishes with serving) with a version-recording
fake engine — no JAX in the hot loop.
"""
import threading

import numpy as np
import pytest

from repro.serve import (
    ClientToken,
    ContinuousBatchingScheduler,
    FleetRouter,
    LatencyHistogram,
    ModelSnapshot,
    MTLScoringEngine,
    ScoreRequest,
    ServingMetrics,
    SubmitOutcome,
    VirtualClock as ManualClock,
)


@pytest.fixture()
def W():
    return np.random.RandomState(0).randn(5, 12).astype(np.float32)


def _requests(n, m=5, d=12, seed=1):
    rng = np.random.RandomState(seed)
    return [
        ScoreRequest(task=int(rng.randint(m)), x=rng.randn(d).astype(np.float32))
        for _ in range(n)
    ]


def _fleet(W, n=3, batch=4, clock=None, *, version=1, **router_kw):
    clock = clock or ManualClock()
    reps = [
        ContinuousBatchingScheduler(
            MTLScoringEngine(W, batch=batch, version=version), clock=clock
        )
        for _ in range(n)
    ]
    return FleetRouter(reps, **router_kw), reps, clock


# -- virtual clock (satellite) ----------------------------------------------
def test_virtual_clock_rejects_backwards_advance_to():
    clk = ManualClock(5.0)
    with pytest.raises(ValueError, match="earlier than the current time"):
        clk.advance_to(4.0)
    clk.advance_to(5.0)  # equal target is fine (idempotent)
    with pytest.raises(ValueError, match=">= 0"):
        clk.advance(-1.0)
    assert clk() == 5.0


def test_virtual_clock_thread_safe_advances():
    clk = ManualClock()

    def bump():
        for _ in range(1000):
            clk.advance(0.001)

    ts = [threading.Thread(target=bump) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert clk() == pytest.approx(8.0)


# -- submit_many outcomes (satellite bugfix) --------------------------------
def test_submit_many_reports_midbatch_queue_full_and_continues(W):
    """A full queue mid-batch must NOT silently drop the rest: each
    request gets an outcome and later submittable ones still land."""
    clk = ManualClock()
    sched = ContinuousBatchingScheduler(
        MTLScoringEngine(W, batch=4), clock=clk, max_queue=2
    )
    outs = sched.submit_many(_requests(4))
    assert [o.admitted for o in outs] == [True, True, False, False]
    assert {o.reason for o in outs if not o.admitted} == {"queue_full"}
    assert sched.pending == 2
    sched.step()
    # queue drained: the remainder of a NEW batch is attempted per-request
    outs2 = sched.submit_many(_requests(3, seed=2))
    assert [o.admitted for o in outs2] == [True, True, False]
    assert all(isinstance(o, SubmitOutcome) for o in outs2)


def test_submit_many_reports_expired_outcomes(W):
    clk = ManualClock()
    sched = ContinuousBatchingScheduler(MTLScoringEngine(W, batch=4), clock=clk)
    reqs = _requests(2)
    reqs[1].deadline_s = -1.0  # absolute deadline already in the past
    outs = sched.submit_many(reqs)
    assert outs[0].admitted and not outs[1].admitted
    assert outs[1].reason == "expired"
    assert reqs[1].status == "expired"


# -- metrics merge (satellite) ----------------------------------------------
def test_latency_histogram_merge_exact_counts_and_percentiles():
    a, b = LatencyHistogram(), LatencyHistogram()
    va = np.linspace(0.001, 0.1, 500)
    vb = np.linspace(0.05, 0.5, 300)
    for v in va:
        a.observe(float(v))
    for v in vb:
        b.observe(float(v))
    m = a.merge(b)
    assert m.count == 800
    assert m.counts.sum() == 800
    assert a.count == 500 and b.count == 300  # inputs untouched
    both = np.concatenate([va, vb])
    assert m.summary()["mean_s"] == pytest.approx(both.mean())
    assert m.summary()["max_s"] == pytest.approx(both.max())
    # within max_samples the merge keeps every sample: percentiles exact
    assert m.percentile(99.0) == pytest.approx(np.percentile(both, 99.0))


def test_latency_histogram_merge_decimated_strides():
    a = LatencyHistogram(max_samples=64)
    b = LatencyHistogram(max_samples=64)
    rng = np.random.RandomState(3)
    for v in rng.rand(500):  # a overflows -> stride > 1
        a.observe(float(v))
    for v in rng.rand(10):
        b.observe(float(v))
    m = a.merge(b)
    assert m.count == 510
    assert len(m._samples) <= m.max_samples
    assert m._stride >= a._stride
    assert 0.0 < m.percentile(50.0) < 1.0


def test_serving_metrics_merge_rolls_up_counters_and_tasks():
    clk = ManualClock()
    ms = [ServingMetrics(slo_s=0.1, clock=clk) for _ in range(3)]
    clk.advance(2.0)
    for i, m in enumerate(ms):
        for _ in range(i + 1):
            m.on_submit(task=i)
            m.on_complete(i, 0.01 * (i + 1), False)
        m.on_tile(i + 1, 4)
    ms[1].on_expired(task=1)
    ms[2].on_swap(7)
    ms[0].observe_queue_depth(5)
    out = ms[0].merge(ms[1], ms[2])
    assert out.submitted == 6 and out.completed == 6
    assert out.expired == 1 and out.slo_violations == 1
    assert out.swaps == 1 and out.last_version == 7
    assert out.queue_depth_max == 5
    assert out.latency.count == 6
    assert out.per_task[1]["expired"] == 1
    assert out.per_task[2]["completed"] == 3
    # elapsed freezes at merge: fleet throughput uses the SHARED window
    assert out.elapsed_s() == pytest.approx(2.0)
    assert out.throughput() == pytest.approx(3.0)
    for m in ms:  # inputs untouched
        assert m.swaps in (0, 1)


# -- affinity + spill --------------------------------------------------------
def test_affinity_is_deterministic_and_sticky(W):
    router, reps, _ = _fleet(W)
    homes = {t: router.home_of(t) for t in range(5)}
    # same ring, same placement — across router instances too
    router2, _, _ = _fleet(W)
    assert homes == {t: router2.home_of(t) for t in range(5)}
    for t, rid in homes.items():
        r = ScoreRequest(task=t, x=np.zeros(12, np.float32))
        out = router.submit(r)
        assert out.admitted and out.replica == rid


def test_backlogged_home_spills_to_least_loaded(W):
    router, reps, _ = _fleet(W, spill_depth=3)
    t = 0
    home = router.home_of(t)
    for _ in range(3):
        assert router.submit(
            ScoreRequest(task=t, x=np.zeros(12, np.float32))
        ).replica == home
    out = router.submit(ScoreRequest(task=t, x=np.zeros(12, np.float32)))
    assert out.admitted and out.replica != home
    assert router.counters["spills"] == 1


# -- shed --------------------------------------------------------------------
def test_router_sheds_when_every_candidate_exceeds_budget(W):
    router, reps, clock = _fleet(W, slo_s=0.05, tile_cost_s=0.02)
    # 8 pending per replica -> est wait (8//4 + 1) * 20ms = 60ms > 50ms
    for _ in range(24):
        out = router.submit(_requests(1, seed=7)[0])
        assert out.admitted
    shed = router.submit(_requests(1, seed=8)[0])
    assert not shed.admitted and shed.reason == "shed"
    assert shed.request.status == "shed"
    assert router.counters["shed"] == 1
    # shed is router back-pressure, NOT a replica SLO violation
    assert router.metrics().slo_violations == 0
    # an explicit roomy deadline overrides the slo budget -> admitted
    ok = router.submit(_requests(1, seed=9)[0], deadline_s=10.0)
    assert ok.admitted


def test_router_reports_queue_full_instead_of_raising(W):
    clock = ManualClock()
    reps = [
        ContinuousBatchingScheduler(
            MTLScoringEngine(W, batch=4), clock=clock, max_queue=1
        )
        for _ in range(2)
    ]
    router = FleetRouter(reps)
    outs = [router.submit(r) for r in _requests(3, seed=4)]
    assert [o.admitted for o in outs] == [True, True, False]
    assert outs[2].reason == "queue_full"


# -- rolling swap + monotonic reads -----------------------------------------
def test_publish_rolls_one_replica_per_step(W):
    router, reps, clock = _fleet(W)
    W2 = W * 2.0
    v = router.publish_weights(W2)
    assert v == 2
    # one replica converges immediately, one more per step
    assert sorted(r.version for r in reps) == [1, 1, 2]
    assert router.roll_pending == 2
    router.step()
    assert sorted(r.version for r in reps) == [1, 2, 2]
    router.step()
    assert sorted(r.version for r in reps) == [2, 2, 2]
    assert router.roll_pending == 0


def test_client_token_keeps_reads_monotonic_mid_roll(W):
    router, reps, clock = _fleet(W)
    tok = router.session()
    router.publish_weights(W * 2.0)  # v2 on exactly one replica
    fresh = [r for r in reps if r.version == 2]
    assert len(fresh) == 1
    # client observes v2; its next submit may only land on the fresh one
    tok.observe(2)
    for _ in range(6):
        out = router.submit(_requests(1, seed=5)[0], client=tok)
        assert out.admitted and reps[out.replica].version == 2
    done = router.step()
    assert all(r.snapshot_version >= 2 for r in done)


def test_pull_forward_when_no_candidate_satisfies_token(W):
    router, reps, clock = _fleet(W)
    router.publish_weights(W * 2.0)  # v2 on exactly one replica
    (fresh_id,) = [i for i, r in enumerate(reps) if r.version == 2]
    tok = router.session()
    tok.observe(2)
    router.fail_replica(fresh_id)  # the only v2 holder dies mid-roll
    out = router.submit(_requests(1, seed=6)[0], client=tok)
    assert out.admitted and out.replica != fresh_id
    assert reps[out.replica].version == 2  # latest was pulled forward
    assert router.counters["pull_forwards"] == 1


def test_publish_through_router_owns_the_version_space(W):
    router, reps, clock = _fleet(W)
    # an external counter behind the fleet's gets restamped, never ignored
    v = router.publish_weights(W * 3.0, version=1)
    assert v == 2
    v = router.publish_weights(W * 4.0, version=100)
    assert v == 100
    v = router.publish(ModelSnapshot(version=5, W=W * 5.0))
    assert v == 101
    with pytest.raises(ValueError, match="shape"):
        router.publish_weights(np.zeros((2, 2), np.float32))


# -- failover + restore ------------------------------------------------------
def test_failover_requeues_backlog_onto_survivors(W):
    router, reps, clock = _fleet(W)
    reqs = _requests(9, seed=8)
    outs = [router.submit(r) for r in reqs]
    victim = outs[0].replica
    stranded = reps[victim].pending
    assert stranded > 0
    moved = router.fail_replica(victim)
    assert moved == stranded and reps[victim].pending == 0
    assert router.pending == 9  # nothing lost
    done = []
    while router.pending:
        done.extend(router.step())
    assert len(done) == 9 and all(r.status == "done" for r in reqs)
    # completions carry real scores from the surviving replicas
    assert all(r.score is not None for r in reqs)


def test_step_detects_crashing_engine_and_fails_over(W):
    class Boom:
        def __init__(self, inner):
            self.inner, self.crashed = inner, False

        def __getattr__(self, name):
            return getattr(self.inner, name)

        def run_tile(self, reqs, snapshot):
            if self.crashed:
                raise RuntimeError("host down")
            self.inner.run_tile(reqs, snapshot)

    clock = ManualClock()
    engines = [Boom(MTLScoringEngine(W, batch=4, version=1)) for _ in range(3)]
    reps = [
        ContinuousBatchingScheduler(e, clock=clock) for e in engines
    ]
    router = FleetRouter(reps)
    reqs = _requests(12, seed=9)
    for r in reqs:
        assert router.submit(r).admitted
    victim = next(i for i, rep in enumerate(reps) if rep.pending)
    engines[victim].crashed = True
    while router.pending:
        router.step()
    assert not router.replica(victim).up
    assert router.counters["failovers"] == 1
    assert all(r.status == "done" for r in reqs)  # re-pinned and served
    engines[victim].crashed = False
    router.restore_replica(victim)
    assert router.replica(victim).up
    assert router.replica(victim).restarts == 1


def test_restore_catches_replica_up_to_fleet_version(W):
    router, reps, clock = _fleet(W)
    router.fail_replica(1)
    router.publish_weights(W * 2.0)
    while router.roll_pending:
        router.step()
    assert reps[1].version == 1  # down: the roll skipped it
    router.restore_replica(1)
    assert reps[1].version == router.version  # caught up BEFORE rejoining


def test_all_replicas_down_sheds_with_no_replica(W):
    router, reps, clock = _fleet(W, n=2)
    router.fail_replica(0)
    router.fail_replica(1)
    out = router.submit(_requests(1, seed=3)[0])
    assert not out.admitted and out.reason == "no_replica"


# -- fleet metrics + estimator constructor ----------------------------------
def test_fleet_metrics_rollup_and_summary(W):
    router, reps, clock = _fleet(W)
    for r in _requests(10, seed=11):
        router.submit(r)
    while router.pending:
        router.step()
        clock.advance(0.01)
    m = router.metrics()
    assert m.completed == 10
    assert m.completed == sum(rep.metrics.completed for rep in reps)
    s = router.summary()
    assert s["replicas"] == 3 and s["up"] == 3
    assert s["fleet"]["completed"] == 10
    assert len(s["per_replica"]) == 3
    assert s["router"]["admitted"] == 10


def test_estimator_serving_fleet_constructor_and_rolling_push():
    from repro.core import DMTRLEstimator
    from repro.data.synthetic import synthetic

    sp = synthetic(1, m=4, d=16, n_train_avg=30, n_test_avg=10, seed=0)
    est = DMTRLEstimator(
        loss="hinge", lam=1e-4, outer_iters=1, rounds=2, local_iters=16,
        block_size=16, seed=0,
    ).fit(sp.train)
    clock = ManualClock()
    router = est.serving_fleet(n_replicas=2, batch=4, clock=clock)
    assert router.n_replicas == 2
    v0 = router.version
    est.partial_fit(sp.train)  # pushes through the ROUTER (rolling)
    assert router.version > v0
    r = ScoreRequest(task=0, x=np.asarray(sp.test.x[0, 0]))
    out = router.submit(r)
    assert out.admitted
    router.run_until_idle()
    assert r.status == "done" and r.score is not None


def test_fleet_warmup_shares_compiled_step(W):
    router, reps, _ = _fleet(W)
    router.warmup()
    exes = [rep.engine._step_exe for rep in reps]
    assert all(e is not None for e in exes)
    assert exes[0] is exes[1] is exes[2]  # one compile, shared


# -- threaded monotonic-read property test (satellite) -----------------------
class VersionEcho:
    """Minimal adapter engine: 'scores' a request by recording the
    snapshot version it ran against. Keeps the threaded property test
    free of JAX (pure queue/version semantics under contention)."""

    batch = 4

    def __init__(self, version=1):
        self._snap = ModelSnapshot(version=version, W=None)

    def admit(self, r):
        pass

    def task_key(self, r):
        return r.task

    def model_snapshot(self):
        return self._snap

    def run_tile(self, reqs, snapshot):
        for r in reqs:
            r.score = float(snapshot.version)


def test_threaded_publish_storm_never_regresses_client_reads():
    """N producer threads interleave publish/publish_weights across the
    fleet while clients run sequential sessions: no completed request may
    record a snapshot_version below what its client already observed."""
    clock = ManualClock()
    reps = [
        ContinuousBatchingScheduler(VersionEcho(), clock=clock)
        for _ in range(3)
    ]
    router = FleetRouter(reps)
    errors = []

    def producer(k):
        try:
            rng = np.random.RandomState(100 + k)
            for i in range(300):
                if i % 3 == k % 2:
                    router.publish_weights(None, version=int(rng.randint(1, 50)))
                else:
                    router.publish(ModelSnapshot(version=i, W=None))
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    def client(seed):
        try:
            tok = router.session()
            rng_c = np.random.RandomState(seed)
            for _ in range(60):
                r = ScoreRequest(task=int(rng_c.randint(5)),
                                 x=np.zeros(1, np.float32))
                floor = tok.min_version
                out = router.submit(r, client=tok)
                assert out.admitted, out
                while r.status != "done":
                    router.step()
                assert r.snapshot_version >= floor, (
                    f"monotonic read violated: served v{r.snapshot_version} "
                    f"after the client observed v{floor}"
                )
                # the session observes its own completion before the next
                # submit — the sequential regime the guarantee covers
                tok.observe(r.snapshot_version)
        except Exception as e:
            errors.append(e)

    producers = [
        threading.Thread(target=producer, args=(k,)) for k in range(4)
    ]
    clients = [threading.Thread(target=client, args=(s,)) for s in range(6)]
    for t in producers + clients:
        t.start()
    for t in producers + clients:
        t.join()
    assert not errors, errors[0]
    assert router.metrics().completed >= 6 * 60
