"""Batched MTL scoring engine (serve/mtl.py) + estimator wiring."""
import numpy as np
import pytest

from repro.core import DMTRLEstimator
from repro.serve import MTLScoringEngine, ScoreRequest


@pytest.fixture(scope="module")
def W():
    rng = np.random.RandomState(0)
    return rng.randn(5, 12).astype(np.float32)


def test_scores_match_manual(W):
    eng = MTLScoringEngine(W, batch=4)
    rng = np.random.RandomState(1)
    reqs = [
        ScoreRequest(task=t, x=rng.randn(12).astype(np.float32))
        for t in (0, 3, 4, 1, 2, 0, 4)  # 7 requests -> one padded batch
    ]
    done = eng.run(reqs)
    assert done is reqs
    for r in done:
        assert r.score == pytest.approx(float(r.x @ W[r.task]), abs=1e-5)
        assert r.label == (1.0 if r.score >= 0 else -1.0)


def test_regression_mode_has_no_labels(W):
    eng = MTLScoringEngine(W, batch=2, classify=False)
    r = eng.run([ScoreRequest(task=0, x=np.ones(12, np.float32))])[0]
    assert r.score is not None and r.label is None


def test_score_batch_fast_path(W):
    eng = MTLScoringEngine(W, batch=3)
    X = np.random.RandomState(2).randn(5, 12).astype(np.float32)
    t = np.array([0, 1, 2, 3, 4])
    z = eng.score_batch(X, t)
    np.testing.assert_allclose(z, np.einsum("nd,nd->n", X, W[t]), atol=1e-5)
    # scalar task broadcast
    z0 = eng.score_batch(X, 2)
    np.testing.assert_allclose(z0, X @ W[2], atol=1e-5)


def test_request_validation(W):
    eng = MTLScoringEngine(W, batch=2)
    with pytest.raises(ValueError, match="task id"):
        eng.run([ScoreRequest(task=7, x=np.zeros(12, np.float32))])
    with pytest.raises(ValueError, match="feature shape"):
        eng.run([ScoreRequest(task=0, x=np.zeros(3, np.float32))])
    with pytest.raises(ValueError, match="batch"):
        MTLScoringEngine(W, batch=0)
    with pytest.raises(ValueError, match="W must be"):
        MTLScoringEngine(np.zeros(3))


def test_estimator_scoring_engine(small_problem, small_cfg):
    est = DMTRLEstimator(engine="reference", config=small_cfg).fit(
        small_problem.train
    )
    eng = est.scoring_engine(batch=3)
    te = small_problem.test
    x = np.asarray(te.x[1, 0])
    r = eng.run([ScoreRequest(task=1, x=x)])[0]
    # serve path == estimator predict path
    z = est.decision_function(x, tasks=1)
    assert r.score == pytest.approx(float(z[0]), abs=1e-6)
    assert r.label in (-1.0, 1.0)  # hinge => classification labels


# ---------------------------------------------------------------------------
# edge cases: empty lists, tile boundaries, score_batch range errors
# ---------------------------------------------------------------------------
def test_empty_request_list(W):
    eng = MTLScoringEngine(W, batch=4)
    assert eng.run([]) == []
    z = eng.score_batch(np.zeros((0, 12), np.float32), np.zeros(0, np.int32))
    assert z.shape == (0,)


@pytest.mark.parametrize("n", [6, 7, 3])  # n % batch == 0, == 1, == n
def test_tile_boundaries(W, n):
    eng = MTLScoringEngine(W, batch=3)
    rng = np.random.RandomState(n)
    X = rng.randn(n, 12).astype(np.float32)
    t = (np.arange(n) % 5).astype(np.int32)
    np.testing.assert_allclose(
        eng.score_batch(X, t), np.einsum("nd,nd->n", X, W[t]), atol=1e-5
    )


def test_score_batch_out_of_range_tasks(W):
    eng = MTLScoringEngine(W, batch=2)
    X = np.zeros((2, 12), np.float32)
    with pytest.raises(ValueError, match="task id"):
        eng.score_batch(X, np.array([0, 5]))
    with pytest.raises(ValueError, match="task id"):
        eng.score_batch(X, np.array([-1, 0]))
    with pytest.raises(ValueError, match="feature shape"):
        eng.score_batch(np.zeros((2, 5), np.float32), 0)


def test_mixed_shape_requests_fail_loudly(W):
    eng = MTLScoringEngine(W, batch=2)
    reqs = [
        ScoreRequest(task=0, x=np.zeros(12, np.float32)),
        ScoreRequest(task=0, x=np.zeros(3, np.float32)),
    ]
    with pytest.raises(ValueError, match="stack"):
        eng.run(reqs)
    assert all(r.score is None for r in reqs)  # all-or-nothing


# ---------------------------------------------------------------------------
# hot-swap surface + the stale-weights footgun fix
# ---------------------------------------------------------------------------
def test_swap_updates_scores_without_retrace(W):
    eng = MTLScoringEngine(W, batch=4, version=1)
    W2 = np.random.RandomState(9).randn(*W.shape).astype(np.float32)
    x = np.ones(12, np.float32)
    z1 = eng.score_batch(x[None], 0)[0]
    assert eng.swap(W2) == 2 and eng.version == 2
    z2 = eng.score_batch(x[None], 0)[0]
    assert z1 == pytest.approx(float(x @ W[0]), abs=1e-5)
    assert z2 == pytest.approx(float(x @ W2[0]), abs=1e-5)
    assert eng.swap(W2, version=2) == 2  # duplicate delivery: no-op
    with pytest.raises(ValueError, match="not newer"):
        eng.swap(W2, version=1)
    with pytest.raises(RuntimeError, match="source"):
        eng.refresh()  # not built by an estimator


def test_scoring_engine_tracks_partial_fit(small_problem, small_cfg):
    """The stale-weights footgun: an engine built before partial_fit must
    serve the NEW weights afterwards (push on install + pull refresh())."""
    est = DMTRLEstimator(engine="reference", config=small_cfg).fit(
        small_problem.train
    )
    eng = est.scoring_engine(batch=3)
    v1 = eng.version
    W1 = np.asarray(est.W_).copy()
    x = np.asarray(small_problem.test.x[1, 0])
    z_before = eng.run([ScoreRequest(task=1, x=x)])[0].score

    est.partial_fit(small_problem.train)
    assert eng.version == v1 + 1  # snapshot pushed on install
    assert not np.allclose(np.asarray(est.W_), W1)
    z_after = eng.run([ScoreRequest(task=1, x=x)])[0].score
    # the engine serves exactly the estimator's current predict path
    assert z_after == pytest.approx(
        float(est.decision_function(x, tasks=1)[0]), abs=1e-6
    )
    assert z_after != pytest.approx(z_before, abs=1e-12) or not np.allclose(
        W1[1], np.asarray(est.W_)[1]
    )
    assert eng.refresh() == eng.version  # already current: no-op


def test_serving_scheduler_hot_swaps_on_partial_fit(small_problem, small_cfg):
    """estimator.serving_scheduler(): tiles packed after partial_fit score
    against the new version, matching est.decision_function bit-for-bit
    with the engine's own jitted step."""
    est = DMTRLEstimator(engine="reference", config=small_cfg).fit(
        small_problem.train
    )
    sched = est.serving_scheduler(batch=4, slo_s=10.0)
    v1 = sched.version
    x = np.asarray(small_problem.test.x[2, 1])
    r1 = sched.submit(ScoreRequest(task=2, x=x))
    sched.step()
    est.partial_fit(small_problem.train)
    assert sched.version == v1 + 1
    r2 = sched.submit(ScoreRequest(task=2, x=x))
    sched.step()
    assert r1.snapshot_version == v1 and r2.snapshot_version == v1 + 1
    assert r2.score == pytest.approx(
        float(est.decision_function(x, tasks=2)[0]), abs=1e-6
    )
    m = sched.metrics.summary()
    assert m["completed"] == 2 and m["swaps"] == 1


def test_partial_fit_push_survives_manual_swap(small_problem, small_cfg):
    """An engine whose version counter ran ahead (manual swap) must still
    receive the newly trained weights from partial_fit — the push is
    re-stamped, never silently dropped."""
    est = DMTRLEstimator(engine="reference", config=small_cfg).fit(
        small_problem.train
    )
    eng = est.scoring_engine(batch=3)
    W_manual = np.zeros((eng.m, eng.d), np.float32)
    eng.swap(W_manual)  # engine version now ahead of the estimator's
    v_manual = eng.version
    est.partial_fit(small_problem.train)
    assert eng.version > v_manual
    x = np.asarray(small_problem.test.x[0, 0])
    z = eng.run([ScoreRequest(task=0, x=x)])[0].score
    assert z == pytest.approx(
        float(est.decision_function(x, tasks=0)[0]), abs=1e-6
    )
