"""Batched MTL scoring engine (serve/mtl.py) + estimator wiring."""
import numpy as np
import pytest

from repro.core import DMTRLEstimator
from repro.serve import MTLScoringEngine, ScoreRequest


@pytest.fixture(scope="module")
def W():
    rng = np.random.RandomState(0)
    return rng.randn(5, 12).astype(np.float32)


def test_scores_match_manual(W):
    eng = MTLScoringEngine(W, batch=4)
    rng = np.random.RandomState(1)
    reqs = [
        ScoreRequest(task=t, x=rng.randn(12).astype(np.float32))
        for t in (0, 3, 4, 1, 2, 0, 4)  # 7 requests -> one padded batch
    ]
    done = eng.run(reqs)
    assert done is reqs
    for r in done:
        assert r.score == pytest.approx(float(r.x @ W[r.task]), abs=1e-5)
        assert r.label == (1.0 if r.score >= 0 else -1.0)


def test_regression_mode_has_no_labels(W):
    eng = MTLScoringEngine(W, batch=2, classify=False)
    r = eng.run([ScoreRequest(task=0, x=np.ones(12, np.float32))])[0]
    assert r.score is not None and r.label is None


def test_score_batch_fast_path(W):
    eng = MTLScoringEngine(W, batch=3)
    X = np.random.RandomState(2).randn(5, 12).astype(np.float32)
    t = np.array([0, 1, 2, 3, 4])
    z = eng.score_batch(X, t)
    np.testing.assert_allclose(z, np.einsum("nd,nd->n", X, W[t]), atol=1e-5)
    # scalar task broadcast
    z0 = eng.score_batch(X, 2)
    np.testing.assert_allclose(z0, X @ W[2], atol=1e-5)


def test_request_validation(W):
    eng = MTLScoringEngine(W, batch=2)
    with pytest.raises(ValueError, match="task id"):
        eng.run([ScoreRequest(task=7, x=np.zeros(12, np.float32))])
    with pytest.raises(ValueError, match="feature shape"):
        eng.run([ScoreRequest(task=0, x=np.zeros(3, np.float32))])
    with pytest.raises(ValueError, match="batch"):
        MTLScoringEngine(W, batch=0)
    with pytest.raises(ValueError, match="W must be"):
        MTLScoringEngine(np.zeros(3))


def test_estimator_scoring_engine(small_problem, small_cfg):
    est = DMTRLEstimator(engine="reference", config=small_cfg).fit(
        small_problem.train
    )
    eng = est.scoring_engine(batch=3)
    te = small_problem.test
    x = np.asarray(te.x[1, 0])
    r = eng.run([ScoreRequest(task=1, x=x)])[0]
    # serve path == estimator predict path
    z = est.decision_function(x, tasks=1)
    assert r.score == pytest.approx(float(z[0]), abs=1e-6)
    assert r.label in (-1.0, 1.0)  # hinge => classification labels
